//! Jet-substructure tagging codesign (the paper's headline application):
//! trains the paper-exact JSC-2L circuit ((32, 5) L-LUTs, beta=4, F=3,
//! sub-networks N=8/L=4/S=2), converts, and reports the hardware numbers
//! next to the LogicNets / PolyLUT baselines trained on the same dataset —
//! the Table III (low-accuracy segment) story on a single command.
//!
//! Run: `cargo run --release --example jsc_codesign`
//! (env NEURALUT_EPOCHS=N for a quick pass)

use neuralut::coordinator::experiments::{epochs_override, run_config};
use neuralut::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let epochs = epochs_override();
    println!("== jet-substructure codesign (synthetic JSC, DESIGN.md §5) ==\n");
    println!(
        "{:<16} {:>9} {:>8} {:>6} {:>9} {:>9} {:>12}",
        "config", "accuracy", "LUT", "FF", "Fmax MHz", "lat ns", "area*delay"
    );
    for config in ["jsc-2l", "jsc-polylut", "jsc-logicnets"] {
        let s = run_config(&rt, config, 0, epochs)?;
        println!(
            "{:<16} {:>9.4} {:>8} {:>6} {:>9.0} {:>9.1} {:>12.3e}",
            s.config, s.fabric_acc, s.luts, s.ffs, s.fmax_mhz, s.latency_ns,
            s.area_delay
        );
    }
    println!(
        "\npaper shape check: NeuraLUT's 2-layer circuit reaches comparable \
         accuracy with\nfewer pipeline stages (2 vs 3) and the lowest \
         area-delay product of the three."
    );
    Ok(())
}
