//! Reproduce paper **Fig. 5**: ablation on a fixed circuit-level model —
//! sub-network depth L in {1..4}, with and without skip connections,
//! against the LogicNets baseline (N=1, L=1), across seeds.
//!
//! Paper claims to reproduce in shape:
//!  * every NeuraLUT variant beats the baseline at the same L-LUT count;
//!  * with skip connections accuracy grows (or holds) with depth L;
//!  * without skip connections depth stops helping (L=4 regresses).
//!
//! Scale note (DESIGN.md §5): the circuit is (64, 32, 10) on 14x14
//! procedural digits instead of the paper's (256, 100, 100, 100, 100, 10)
//! on MNIST; seeds default to 3 (NEURALUT_SEEDS to change).

use neuralut::coordinator::experiments::{
    epochs_override, mean_std, n_seeds, run_config, save_results,
};
use neuralut::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let seeds: Vec<u64> = (0..n_seeds() as u64).collect();
    println!("== Fig. 5: sub-network ablation on a fixed circuit (digits-mini) ==");
    println!("circuit (64, 32, 10) L-LUTs, beta=2, F=6; {} seeds\n", seeds.len());

    let mut rows = Vec::new();
    let mut table: Vec<(String, f64, f64)> = Vec::new();
    let run_group = |label: &str, config: &str, rows: &mut Vec<_>|
        -> anyhow::Result<(f64, f64)> {
        let mut group = Vec::new();
        for &seed in &seeds {
            let s = run_config(&rt, config, seed, epochs_override())?;
            group.push(s);
        }
        let (mean, std) = mean_std(&group, |r| r.fabric_acc);
        println!("{label:<26} acc {mean:.4} ± {std:.4}");
        rows.extend(group);
        Ok((mean, std))
    };

    let (base, _) = run_group("baseline (LogicNets)", "fig5-baseline", &mut rows)?;
    table.push(("baseline".into(), base, 0.0));
    for l in 1..=4 {
        let (m, s) = run_group(&format!("NeuraLUT L={l} skip"),
                               &format!("fig5-l{l}-skip"), &mut rows)?;
        table.push((format!("L{l}-skip"), m, s));
    }
    for l in 1..=4 {
        let (m, s) = run_group(&format!("NeuraLUT L={l} no-skip"),
                               &format!("fig5-l{l}-noskip"), &mut rows)?;
        table.push((format!("L{l}-noskip"), m, s));
    }

    // Shape checks (warn, don't abort — stochastic across seed budgets).
    let get = |k: &str| table.iter().find(|t| t.0 == k).unwrap().1;
    let mut ok = true;
    for l in 1..=4 {
        if get(&format!("L{l}-skip")) < base {
            println!("WARN: L{l}-skip did not beat the baseline");
            ok = false;
        }
    }
    if get("L4-skip") + 1e-9 < get("L1-skip") - 0.02 {
        println!("WARN: depth did not help with skip connections");
        ok = false;
    }
    if get("L4-noskip") > get("L4-skip") + 0.01 {
        println!("WARN: skip connections did not help at L=4");
        ok = false;
    }
    println!("\nshape {}: NeuraLUT > baseline at fixed L-LUT budget; skips \
              unlock depth", if ok { "REPRODUCED" } else { "PARTIAL" });
    let path = save_results("fig5", &rows)?;
    println!("results written to {}", path.display());
    Ok(())
}
