//! Reproduce paper **Table I**: the parameter-count characteristics of the
//! function hidden inside each L-LUT, for LogicNets, PolyLUT and NeuraLUT,
//! plus the scaling-type claims (linear in F for NeuraLUT at fixed (N, L),
//! polynomial for PolyLUT at fixed D). Cross-checked against the actual
//! manifest shapes of every built artifact bundle.

use neuralut::manifest::Manifest;
use neuralut::nn::formulas::*;

fn main() -> anyhow::Result<()> {
    println!("== Table I: parameters of the function hidden in each L-LUT ==\n");
    println!("{:<22} {:<38} {:>12}", "work", "function inside L-LUT", "params(F=6)");
    println!("{:<22} {:<38} {:>12}", "LogicNets [8]", "linear + activation", t_logicnets(6));
    println!("{:<22} {:<38} {:>12}", "PolyLUT [7] (D=2)", "multivariate polynomial + act.", t_polylut(6, 2));
    println!("{:<22} {:<38} {:>12}", "NeuraLUT (L=4,N=16,S=2)", "arbitrary neural network", t_neuralut(6, 4, 16, 2));

    println!("\nscaling in fan-in F (fixed expressibility):");
    println!("{:>4} {:>12} {:>14} {:>16}", "F", "LogicNets", "PolyLUT D=2", "NeuraLUT 4/16/2");
    for f in [2usize, 4, 6, 8, 12, 16] {
        println!("{:>4} {:>12} {:>14} {:>16}", f, t_logicnets(f), t_polylut(f, 2), t_neuralut(f, 4, 16, 2));
    }
    // Claim: NeuraLUT increments constant (linear), PolyLUT increasing.
    let d_small = t_neuralut(5, 4, 16, 2) - t_neuralut(4, 4, 16, 2);
    let d_large = t_neuralut(16, 4, 16, 2) - t_neuralut(15, 4, 16, 2);
    assert_eq!(d_small, d_large, "NeuraLUT must be linear in F");
    assert!(t_polylut(16, 2) - t_polylut(15, 2) > t_polylut(5, 2) - t_polylut(4, 2));
    println!("-> NeuraLUT increment constant ({d_small}/step): LINEAR in F  [matches Table I]");

    println!("\nscaling in expressibility (F=6): PolyLUT degree vs NeuraLUT width");
    println!("{:>6} {:>12}    {:>6} {:>14}", "D", "PolyLUT", "N", "NeuraLUT L=4,S=2");
    for (d, n) in [(1usize, 4usize), (2, 8), (3, 16), (4, 32), (5, 64)] {
        println!("{:>6} {:>12}    {:>6} {:>14}", d, t_polylut(6, d), n, t_neuralut(6, 4, n, 2));
    }
    println!("-> PolyLUT grows combinatorially in D; NeuraLUT polynomially in N  [matches Table I]");

    // Cross-check against every built bundle's real parameter shapes.
    println!("\ncross-check vs built artifact manifests:");
    let root = neuralut::artifacts_dir();
    let mut checked = 0;
    if root.exists() {
        let mut names: Vec<_> = std::fs::read_dir(&root)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("manifest.json").exists())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        for name in names {
            let m = Manifest::load(&root.join(&name))?;
            for (l, &(lo, hi)) in m.layer_param_slices.iter().enumerate() {
                let neuron: usize = m.params[lo..hi - 5].iter().map(|p| p.elem_count()).sum();
                let f = m.layer_fan_in[l];
                let expect = match m.mode.as_str() {
                    "neuralut" => t_neuralut(f, m.sub_depth, m.sub_width, m.sub_skip),
                    "logicnets" => t_logicnets(f),
                    "polylut" => t_polylut(f, m.degree),
                    other => anyhow::bail!("unknown mode {other}"),
                };
                assert_eq!(neuron, m.layers[l] * expect,
                           "{name} layer {l}: manifest params != Table I formula");
            }
            checked += 1;
        }
    }
    println!("   {checked} bundles verified: per-layer parameter counts == Table I formulas");
    Ok(())
}
