//! Reproduce paper **Figs. 6 & 7**: test-error vs latency (Fig. 6) and
//! test-error vs area (Fig. 7) trade-off studies. A sweep of circuit sizes
//! is trained both in the LogicNets setting (N=1, L=1, S=0) and the
//! NeuraLUT setting (N=16, L=4, S=2); for each point we report the
//! post-"place & route" (cost-model) latency and P-LUT area from the best
//! seed, mirroring the paper's top-performing-run selection.
//!
//! Shape to reproduce: NeuraLUT's Pareto frontier dominates the LogicNets
//! frontier on both planes, and NeuraLUT degrades more gracefully as the
//! circuit shrinks (paper: 2.18 vs 4.81 percentage points).

use neuralut::coordinator::experiments::{
    epochs_override, n_seeds, run_config, save_results, RunSummary,
};
use neuralut::runtime::Runtime;

const SIZES: [(&str, &str); 4] =
    [("xl", "(96,48,10)"), ("lg", "(64,32,10)"), ("md", "(48,24,10)"),
     ("sm", "(32,16,10)")];

fn best(rows: &[RunSummary]) -> &RunSummary {
    rows.iter()
        .max_by(|a, b| a.fabric_acc.partial_cmp(&b.fabric_acc).unwrap())
        .unwrap()
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let seeds: Vec<u64> = (0..n_seeds() as u64).collect();
    println!("== Figs. 6 & 7: error vs latency / area Pareto (digits-mini) ==");
    println!("{} circuit sizes x {{LogicNets, NeuraLUT}} x {} seeds\n",
             SIZES.len(), seeds.len());

    let mut all = Vec::new();
    let mut series: Vec<(String, String, RunSummary)> = Vec::new();
    for mode in ["logicnets", "neuralut"] {
        for (tag, shape) in SIZES {
            let config = format!("pareto-{tag}-{mode}");
            let mut group = Vec::new();
            for &seed in &seeds {
                group.push(run_config(&rt, &config, seed, epochs_override())?);
            }
            let b = best(&group).clone();
            println!("{mode:<10} {shape:<12} best acc {:.4}  latency {:>6.1} ns  \
                      area {:>7} LUT  ADP {:.3e}",
                     b.fabric_acc, b.latency_ns, b.luts, b.area_delay);
            series.push((mode.to_string(), shape.to_string(), b));
            all.extend(group);
        }
    }

    println!("\nFig. 6 series (test error % vs latency ns):");
    for mode in ["logicnets", "neuralut"] {
        let pts: Vec<String> = series.iter().filter(|s| s.0 == mode)
            .map(|s| format!("({:.1}ns, {:.2}%)", s.2.latency_ns,
                             100.0 * (1.0 - s.2.fabric_acc)))
            .collect();
        println!("  {mode:<10} {}", pts.join("  "));
    }
    println!("\nFig. 7 series (test error % vs LUT area):");
    for mode in ["logicnets", "neuralut"] {
        let pts: Vec<String> = series.iter().filter(|s| s.0 == mode)
            .map(|s| format!("({} LUT, {:.2}%)", s.2.luts,
                             100.0 * (1.0 - s.2.fabric_acc)))
            .collect();
        println!("  {mode:<10} {}", pts.join("  "));
    }

    // Shape checks.
    let acc = |mode: &str, tag: &str| {
        series.iter()
            .find(|s| s.0 == mode && s.1 == SIZES.iter().find(|x| x.0 == tag).unwrap().1)
            .unwrap().2.fabric_acc
    };
    let n_drop = acc("neuralut", "xl") - acc("neuralut", "sm");
    let l_drop = acc("logicnets", "xl") - acc("logicnets", "sm");
    println!("\naccuracy drop, largest->smallest circuit: NeuraLUT {:.2} pp \
              vs LogicNets {:.2} pp", 100.0 * n_drop, 100.0 * l_drop);
    println!("shape {}: NeuraLUT degrades more gracefully (paper: 2.18 vs 4.81)",
             if n_drop <= l_drop { "REPRODUCED" } else { "PARTIAL" });

    // Iso-accuracy latency comparison (the paper's 1.3-1.5x claim): for
    // each LogicNets point, the cheapest NeuraLUT point reaching at least
    // its accuracy should not be slower.
    let nl: Vec<&RunSummary> =
        series.iter().filter(|s| s.0 == "neuralut").map(|s| &s.2).collect();
    let mut worst_ratio = f64::INFINITY;
    for s in series.iter().filter(|s| s.0 == "logicnets") {
        if let Some(n) = nl
            .iter()
            .filter(|n| n.fabric_acc + 1e-9 >= s.2.fabric_acc)
            .min_by(|a, b| a.latency_ns.partial_cmp(&b.latency_ns).unwrap())
        {
            let ratio = s.2.latency_ns / n.latency_ns;
            println!("  iso-accuracy (>= {:.4}): LogicNets {:.1} ns vs NeuraLUT {:.1} ns ({ratio:.2}x)",
                     s.2.fabric_acc, s.2.latency_ns, n.latency_ns);
            worst_ratio = worst_ratio.min(ratio);
        }
    }
    println!("Pareto frontier (Fig. 6, iso-accuracy): {}",
             if worst_ratio >= 0.95 { "REPRODUCED" } else { "PARTIAL" });

    let path = save_results("fig67", &all)?;
    println!("results written to {}", path.display());
    Ok(())
}
