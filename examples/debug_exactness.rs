//! Debug helper: train briefly, convert, and dump mismatching samples'
//! model logits vs fabric logit codes (kept as an example because it is a
//! useful diagnostic for anyone extending the quantizer ABI).

use neuralut::coordinator::trainer::{TrainOpts, Trainer};
use neuralut::data::Dataset;
use neuralut::luts::convert;
use neuralut::manifest::Manifest;
use neuralut::netlist::Simulator;
use neuralut::runtime::{from_literal, to_literal, HostTensor, Runtime};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or("moons-neuralut".into());
    let epochs: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(1);
    let dir = neuralut::artifacts_dir().join(&name);
    let m = Manifest::load(&dir)?;
    let ds = Dataset::load_named(&m.dataset)?;
    let rt = Runtime::cpu()?;
    let trainer = Trainer::new(&rt, &m, &ds)?;
    let r = trainer.run(0, &TrainOpts { epochs: Some(epochs), quiet: true, ..Default::default() })?;
    let net = convert::convert(&rt, &m, &r.params)?;
    let sim = Simulator::new(&net);

    // scales for dequant comparison
    for (i, spec) in m.params.iter().enumerate() {
        if spec.name.ends_with(".scale") {
            println!("{} = {:?}", spec.name, r.params.tensors[i].as_f32()?);
        }
    }

    let fwd = rt.load_artifact(&m, "fwd")?;
    let b = m.batch;
    let param_lits: Vec<xla::Literal> =
        r.params.tensors.iter().map(to_literal).collect::<anyhow::Result<_>>()?;
    let n = 256.min(ds.n_test());
    let mut shown = 0;
    let mut total_mism = 0;
    let mut i = 0;
    while i < n {
        let take = b.min(n - i);
        let mut x = ds.test_x[i * m.input_size..(i + take) * m.input_size].to_vec();
        x.resize(b * m.input_size, 0.0);
        let x_lit = to_literal(&HostTensor::f32(vec![b, m.input_size], x.clone()))?;
        let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
        args.push(&x_lit);
        let out = fwd.run_literals_refs(&args)?;
        let logits_t = from_literal(&out[0])?;
        let logits = logits_t.as_f32()?;
        let simres = sim.simulate_batch(&x[..take * m.input_size]);
        for j in 0..take {
            let lm = &logits[j * m.n_class..(j + 1) * m.n_class];
            let lc = &simres.logit_codes[j * m.n_class..(j + 1) * m.n_class];
            let pm = {
                let mut best = 0;
                for (k, &v) in lm.iter().enumerate() { if v > lm[best] { best = k; } }
                best
            };
            let ps = simres.predictions[j] as usize;
            if pm != ps {
                total_mism += 1;
                if shown < 8 {
                    println!("sample {}: model logits {:?} pred {} | sim codes {:?} pred {}",
                             i + j, lm, pm, lc, ps);
                    shown += 1;
                }
            }
        }
        i += take;
    }
    println!("mismatches in first {n}: {total_mism}");
    Ok(())
}
// (accuracy comparison appended at build time via env var is not needed;
//  see main above)
