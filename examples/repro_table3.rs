//! Reproduce paper **Table III** (with Table II architectures): evaluation
//! of NeuraLUT against the trained baselines (PolyLUT, LogicNets) on the
//! digit-classification and jet-substructure tasks, reporting Accuracy /
//! LUT / FF / Fmax / Latency / Area-Delay-Product.
//!
//! FINN, hls4ml, Duarte et al. and Fahim et al. are closed comparators we
//! cannot retrain; their paper-reported rows are printed alongside (marked
//! `paper`) so the table shape matches the original. Absolute hardware
//! numbers come from the synthesis *cost model* (DESIGN.md §4) — the
//! meaningful reproduction targets are the orderings and ratios.

use neuralut::coordinator::experiments::{epochs_override, run_config, save_results, RunSummary};
use neuralut::runtime::Runtime;

struct PaperRow {
    name: &'static str,
    acc: &'static str,
    lut: u64,
    ff: &'static str,
    fmax: u64,
    lat_ns: u64,
    adp: f64,
}

const MNIST_PAPER: &[PaperRow] = &[
    PaperRow { name: "PolyLUT [7] (paper)", acc: "96%", lut: 70673, ff: "4681", fmax: 378, lat_ns: 16, adp: 11.3e5 },
    PaperRow { name: "FINN [13] (paper)", acc: "96%", lut: 91131, ff: "-", fmax: 200, lat_ns: 310, adp: 282.5e5 },
    PaperRow { name: "hls4ml [14] (paper)", acc: "95%", lut: 260092, ff: "165513", fmax: 200, lat_ns: 190, adp: 494.2e5 },
];

const JSC_PAPER: &[PaperRow] = &[
    PaperRow { name: "PolyLUT [7] (paper)", acc: "72%", lut: 12436, ff: "773", fmax: 646, lat_ns: 5, adp: 6.2e4 },
    PaperRow { name: "LogicNets [8] (paper)", acc: "72%", lut: 37931, ff: "810", fmax: 427, lat_ns: 13, adp: 49.3e4 },
    PaperRow { name: "Duarte et al. [1] (paper)", acc: "75%", lut: 887, ff: "97", fmax: 200, lat_ns: 75, adp: 6.7e6 },
    PaperRow { name: "Fahim et al. [10] (paper)", acc: "76%", lut: 63251, ff: "4394", fmax: 200, lat_ns: 45, adp: 2.8e6 },
];

fn print_header() {
    println!("{:<30} {:>9} {:>8} {:>7} {:>9} {:>8} {:>12}",
             "model", "accuracy", "LUT", "FF", "Fmax MHz", "lat ns", "area*delay");
}

fn print_run(label: &str, s: &RunSummary) {
    println!("{:<30} {:>8.2}% {:>8} {:>7} {:>9.0} {:>8.1} {:>12.3e}",
             label, 100.0 * s.fabric_acc, s.luts, s.ffs, s.fmax_mhz,
             s.latency_ns, s.area_delay);
}

fn print_paper(r: &PaperRow) {
    println!("{:<30} {:>9} {:>8} {:>7} {:>9} {:>8} {:>12.3e}",
             r.name, r.acc, r.lut, r.ff, r.fmax, r.lat_ns, r.adp);
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let ep = epochs_override();
    let mut all = Vec::new();
    println!("== Table III: evaluation (ours = trained here on synthetic data, \
              cost-model hardware; 'paper' = reported in the original) ==");

    println!("\n-- digit classification (mini-scale, DESIGN.md §5) --");
    print_header();
    let hdr = run_config(&rt, "hdr-mini", 0, ep)?;
    print_run("NeuraLUT (HDR-mini)", &hdr);
    let hp = run_config(&rt, "hdr-mini-polylut", 0, ep)?;
    print_run("PolyLUT (same circuit)", &hp);
    let hl = run_config(&rt, "hdr-mini-logicnets", 0, ep)?;
    print_run("LogicNets (same circuit)", &hl);
    for r in MNIST_PAPER {
        print_paper(r);
    }
    all.extend([hdr.clone(), hp.clone(), hl.clone()]);

    println!("\n-- jet substructure tagging (low-accuracy segment) --");
    print_header();
    let j2 = run_config(&rt, "jsc-2l", 0, ep)?;
    print_run("NeuraLUT (JSC-2L)", &j2);
    let jp = run_config(&rt, "jsc-polylut", 0, ep)?;
    print_run("PolyLUT (JSC-M-Lite-like)", &jp);
    let jl = run_config(&rt, "jsc-logicnets", 0, ep)?;
    print_run("LogicNets (JSC-M-like)", &jl);
    for r in &JSC_PAPER[..2] {
        print_paper(r);
    }
    all.extend([j2.clone(), jp.clone(), jl.clone()]);

    println!("\n-- jet substructure tagging (high-accuracy segment) --");
    print_header();
    let j5 = run_config(&rt, "jsc-5l", 0, ep)?;
    print_run("NeuraLUT (JSC-5L)", &j5);
    for r in &JSC_PAPER[2..] {
        print_paper(r);
    }
    all.push(j5.clone());

    // --- headline ratio checks (paper: lowest ADP in class; latency
    // reductions vs the trained baselines) -------------------------------
    println!("\nheadline shape checks:");
    let adp_ratio_poly = jp.area_delay / j2.area_delay;
    let adp_ratio_logic = jl.area_delay / j2.area_delay;
    println!("  JSC ADP ratio vs NeuraLUT-2L : PolyLUT {adp_ratio_poly:.1}x, \
              LogicNets {adp_ratio_logic:.1}x (paper: 4.4x, 35.2x)");
    let lat_ratio_poly = jp.latency_ns / j2.latency_ns;
    let lat_ratio_logic = jl.latency_ns / j2.latency_ns;
    println!("  JSC latency ratio            : PolyLUT {lat_ratio_poly:.1}x, \
              LogicNets {lat_ratio_logic:.1}x (paper: 1.6x, 4.3x)");
    let mnist_adp = hp.area_delay / hdr.area_delay;
    println!("  digits ADP ratio vs PolyLUT  : {mnist_adp:.1}x (paper: 1.7x)");
    let who_wins = j2.area_delay <= jp.area_delay.min(jl.area_delay)
        && hdr.area_delay <= hp.area_delay.min(hl.area_delay);
    println!("  NeuraLUT smallest ADP in both tasks: {}",
             if who_wins { "REPRODUCED" } else { "PARTIAL" });

    let path = save_results("table3", &all)?;
    println!("\nresults written to {}", path.display());
    Ok(())
}
