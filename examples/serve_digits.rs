//! End-to-end serving demo: train the HDR-mini digit classifier, convert
//! it to its L-LUT fabric, then serve a Poisson-arrival request stream
//! through the router + dynamic batcher and report latency percentiles
//! and throughput — the edge-deployment scenario the paper motivates.
//!
//! Run: `cargo run --release --example serve_digits`
//! (env NEURALUT_EPOCHS to shorten training, NEURALUT_ENGINE to pick the
//! backend, NEURALUT_WORKERS to size the serving worker pool,
//! NEURALUT_OPT_LEVEL to pick the netlist optimization level, and
//! NEURALUT_FABRIC_CACHE=FILE.nfab to reuse a precompiled fabric across
//! restarts)

use std::time::Duration;

use neuralut::coordinator::experiments::epochs_override;
use neuralut::coordinator::trainer::{TrainOpts, Trainer};
use neuralut::data::{Dataset, Workload};
use neuralut::fabric::{FabricOptions, Model};
use neuralut::luts::convert;
use neuralut::manifest::Manifest;
use neuralut::runtime::Runtime;
use neuralut::util::stats;

fn main() -> anyhow::Result<()> {
    let dir = neuralut::artifacts_dir().join("hdr-mini");
    let m = Manifest::load(&dir)?;
    let ds = Dataset::load_named(&m.dataset)?;
    let rt = Runtime::cpu()?;

    println!("training {} ...", m.name);
    let trainer = Trainer::new(&rt, &m, &ds)?;
    let r = trainer.run(0, &TrainOpts {
        epochs: epochs_override(),
        quiet: true,
        ..Default::default()
    })?;
    println!("float test accuracy: {:.4}", r.test_acc);

    println!("converting to L-LUT fabric ...");
    let model = Model::from_network(convert::convert(&rt, &m, &r.params)?);
    println!("fabric: {}", model.info());

    let n_req = 20_000;
    let rate = 100_000.0; // offered load, req/s
    // NEURALUT_ENGINE=bitsliced serves through the compiled fabric engine;
    // NEURALUT_WORKERS sizes the batcher pool (all workers share one
    // compiled program). Zero/absurd values fail loudly at compile, like
    // the CLI.
    let mut opts = FabricOptions::from_env()?
        .max_batch(512)
        .batch_window(Duration::from_micros(100));
    if opts.get_workers().is_none() {
        opts = opts.workers(2); // this demo defaults to a 2-worker pool
    }
    let fabric = model.compile(&opts)?;
    match fabric.num_word_ops() {
        Some(ops) => println!("backend: {} at {} ({ops} word ops, {} workers)",
                              fabric.backend_name(), fabric.opt_level(),
                              fabric.tuning().workers),
        None => println!("backend: {} ({} workers)",
                         fabric.backend_name(), fabric.tuning().workers),
    }
    let server = fabric.serve();
    let client = server.client();
    let workload = Workload::poisson(&ds, 42, n_req, rate);

    println!("serving {n_req} requests at {rate:.0} req/s offered ...");
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n_req);
    for (t_arrival, feats) in workload.requests {
        let now = t0.elapsed().as_secs_f64();
        if t_arrival > now {
            std::thread::sleep(Duration::from_secs_f64(t_arrival - now));
        }
        pending.push(client.infer_async(feats)?);
    }
    let mut lat_us = Vec::with_capacity(n_req);
    let mut hits = 0usize;
    for (i, rx) in pending.into_iter().enumerate() {
        let reply = rx.recv()?;
        lat_us.push(reply.latency.as_secs_f64() * 1e6);
        if reply.prediction as i32 == ds.test_y[i % ds.n_test()] {
            hits += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = stats::summarize(&lat_us);
    println!("\nthroughput : {:.0} req/s (wall {:.2}s)", n_req as f64 / wall, wall);
    println!("latency    : p50 {:.0} us  p95 {:.0} us  p99 {:.0} us  max {:.0} us",
             s.p50, s.p95, s.p99, s.max);
    println!("served acc : {:.4} (labels follow the jittered test stream)",
             hits as f64 / n_req as f64);
    let st = server.stats();
    println!("server     : {} served / {} rejected over {} workers; \
              mean batch {:.1}, p99 {:.0} us (internal)",
             st.served, st.rejected, st.per_worker_served.len(),
             st.mean_batch, st.latency_p99_us);
    println!("stages     : queue-wait p99 {:.0} us | batch-form p99 {:.0} us \
              | execute p99 {:.0} us",
             st.queue_wait_p99_us, st.batch_form_p99_us, st.execute_p99_us);
    println!("\nfabric latency itself is {} cycles — the serving stack \
              (batching window, queueing) dominates, as it should.",
             model.latency_cycles());
    Ok(())
}
