//! End-to-end serving demo: train the HDR-mini digit classifier, convert
//! it to its L-LUT fabric, then serve a Poisson-arrival request stream
//! through the router + dynamic batcher and report latency percentiles
//! and throughput — the edge-deployment scenario the paper motivates.
//!
//! Run: `cargo run --release --example serve_digits`
//! (env NEURALUT_EPOCHS to shorten training, NEURALUT_ENGINE to pick the
//! backend, NEURALUT_WORKERS to size the serving worker pool,
//! NEURALUT_OPT_LEVEL to pick the netlist optimization level, and
//! NEURALUT_FABRIC_CACHE=FILE.nfab to reuse a precompiled fabric across
//! restarts)
//!
//! With `--listen [HOST:PORT]` the demo serves the trained fabric over
//! TCP instead: it stages the converted model into a manifest directory,
//! starts the network front door (binary wire protocol + HTTP on one
//! port), then runs a tiny built-in client — a binary
//! `WireClient` round trip and a raw HTTP `POST /v1/infer` + `GET
//! /healthz` — against itself:
//!
//! `cargo run --release --example serve_digits -- --listen 127.0.0.1:0`

use std::time::Duration;

use neuralut::coordinator::experiments::epochs_override;
use neuralut::coordinator::trainer::{TrainOpts, Trainer};
use neuralut::data::{Dataset, Workload};
use neuralut::fabric::{FabricOptions, Model};
use neuralut::luts::convert;
use neuralut::manifest::Manifest;
use neuralut::runtime::Runtime;
use neuralut::util::stats;

fn main() -> anyhow::Result<()> {
    let dir = neuralut::artifacts_dir().join("hdr-mini");
    let m = Manifest::load(&dir)?;
    let ds = Dataset::load_named(&m.dataset)?;
    let rt = Runtime::cpu()?;

    println!("training {} ...", m.name);
    let trainer = Trainer::new(&rt, &m, &ds)?;
    let r = trainer.run(0, &TrainOpts {
        epochs: epochs_override(),
        quiet: true,
        ..Default::default()
    })?;
    println!("float test accuracy: {:.4}", r.test_acc);

    println!("converting to L-LUT fabric ...");
    let net = convert::convert(&rt, &m, &r.params)?;

    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Some(i) = args.iter().position(|a| a == "--listen") {
        let addr = args
            .get(i + 1)
            .filter(|a| !a.starts_with("--"))
            .cloned()
            .unwrap_or_else(|| "127.0.0.1:0".into());
        return serve_over_tcp(net, &ds, addr);
    }

    let model = Model::from_network(net);
    println!("fabric: {}", model.info());

    let n_req = 20_000;
    let rate = 100_000.0; // offered load, req/s
    // NEURALUT_ENGINE=bitsliced serves through the compiled fabric engine;
    // NEURALUT_WORKERS sizes the batcher pool (all workers share one
    // compiled program). Zero/absurd values fail loudly at compile, like
    // the CLI.
    let mut opts = FabricOptions::from_env()?
        .max_batch(512)
        .batch_window(Duration::from_micros(100));
    if opts.get_workers().is_none() {
        opts = opts.workers(2); // this demo defaults to a 2-worker pool
    }
    let fabric = model.compile(&opts)?;
    match fabric.num_word_ops() {
        Some(ops) => println!("backend: {} at {} ({ops} word ops, {} workers)",
                              fabric.backend_name(), fabric.opt_level(),
                              fabric.tuning().workers),
        None => println!("backend: {} ({} workers)",
                         fabric.backend_name(), fabric.tuning().workers),
    }
    let server = fabric.serve();
    let client = server.client();
    let workload = Workload::poisson(&ds, 42, n_req, rate);

    println!("serving {n_req} requests at {rate:.0} req/s offered ...");
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n_req);
    for (t_arrival, feats) in workload.requests {
        let now = t0.elapsed().as_secs_f64();
        if t_arrival > now {
            std::thread::sleep(Duration::from_secs_f64(t_arrival - now));
        }
        pending.push(client.infer_async(feats)?);
    }
    let mut lat_us = Vec::with_capacity(n_req);
    let mut hits = 0usize;
    for (i, rx) in pending.into_iter().enumerate() {
        let reply = rx.recv()?;
        lat_us.push(reply.latency.as_secs_f64() * 1e6);
        if reply.prediction as i32 == ds.test_y[i % ds.n_test()] {
            hits += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = stats::summarize(&lat_us);
    println!("\nthroughput : {:.0} req/s (wall {:.2}s)", n_req as f64 / wall, wall);
    println!("latency    : p50 {:.0} us  p95 {:.0} us  p99 {:.0} us  max {:.0} us",
             s.p50, s.p95, s.p99, s.max);
    println!("served acc : {:.4} (labels follow the jittered test stream)",
             hits as f64 / n_req as f64);
    let st = server.stats();
    println!("server     : {} served / {} rejected over {} workers; \
              mean batch {:.1}, p99 {:.0} us (internal)",
             st.served, st.rejected, st.per_worker_served.len(),
             st.mean_batch, st.latency_p99_us);
    println!("stages     : queue-wait p99 {:.0} us | batch-form p99 {:.0} us \
              | execute p99 {:.0} us",
             st.queue_wait_p99_us, st.batch_form_p99_us, st.execute_p99_us);
    println!("\nfabric latency itself is {} cycles — the serving stack \
              (batching window, queueing) dominates, as it should.",
             model.latency_cycles());
    Ok(())
}

/// `--listen` mode: put the network front door in front of the trained
/// fabric and talk to it over loopback with both protocols.
fn serve_over_tcp(
    net: neuralut::luts::LutNetwork,
    ds: &Dataset,
    addr: String,
) -> anyhow::Result<()> {
    use std::io::{Read, Write};
    use neuralut::net::{ModelManager, NetConfig, NetServer, WireClient};

    // The front door serves a manifest *directory*: stage the converted
    // model there as digits.nlut. Overwriting that file while the server
    // runs hot-swaps it with zero downtime.
    let dir = std::env::temp_dir().join(format!("neuralut_serve_digits_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    net.save(&dir.join("digits.nlut"))?;

    let opts = FabricOptions::from_env()?;
    let opts = if opts.get_backend().is_none() { opts.backend("bitsliced") } else { opts };
    let manager = ModelManager::open(&dir, &opts)?;
    manager.start_watcher(Duration::from_millis(200));
    let server = NetServer::start(
        manager.clone(),
        &NetConfig { listen_addr: addr, max_connections: 64 },
    )?;
    let bound = server.local_addr();
    println!("\nlistening on {bound} — binary (NLW1) and HTTP on the same port");
    println!("models dir {} (overwrite digits.nlut to hot-swap)", dir.display());

    // --- tiny binary client: one 4-row batch through the wire protocol.
    let rows = 4;
    let feats = &ds.test_x[..rows * ds.n_feat];
    let mut wire = WireClient::connect(bound)?;
    let preds = wire.infer("digits", feats, rows)?;
    println!("binary  : predictions {preds:?} (labels {:?})", &ds.test_y[..rows]);

    // --- tiny HTTP client: raw POST /v1/infer + GET /healthz.
    let row: Vec<String> = ds.test_x[..ds.n_feat].iter().map(|v| format!("{v}")).collect();
    let body = format!("{{\"model\": \"digits\", \"features\": [{}]}}", row.join(", "));
    let mut http = std::net::TcpStream::connect(bound)?;
    write!(
        http,
        "POST /v1/infer HTTP/1.1\r\nHost: {bound}\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    write!(http, "GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n")?;
    let mut reply = String::new();
    http.read_to_string(&mut reply)?;
    for line in reply.lines().filter(|l| l.starts_with("HTTP/") || l.starts_with('{') || l.starts_with("ok")) {
        println!("http    : {line}");
    }

    drop(server);
    manager.stop_watcher();
    let _ = std::fs::remove_dir_all(&dir);
    println!("clean shutdown: every connection answered, nothing hung.");
    Ok(())
}
