//! Circuit-topology search demo (paper §V future work): successive-halving
//! random search over the built Pareto-sweep bundles, optimizing
//! accuracy − λ·log10(area·delay). Run with a small budget by default:
//!
//!   cargo run --release --example nas_search            # quick (~minutes)
//!   NEURALUT_NAS_ROUNDS=3 cargo run ... --example nas_search

use neuralut::coordinator::nas::{search, NasOpts};
use neuralut::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let space: Vec<String> = [
        "pareto-sm-neuralut", "pareto-md-neuralut", "pareto-lg-neuralut",
        "pareto-sm-logicnets", "pareto-md-logicnets", "pareto-lg-logicnets",
    ].iter().map(|s| s.to_string()).collect();
    let opts = NasOpts {
        base_epochs: 2,
        rounds: std::env::var("NEURALUT_NAS_ROUNDS").ok()
            .and_then(|v| v.parse().ok()).unwrap_or(2),
        lambda: 0.02,
        seeds_per_config: 1,
    };
    println!("== NAS over circuit topologies: {} candidates, {} rounds ==",
             space.len() * opts.seeds_per_config, opts.rounds);
    let ranked = search(&rt, &space, &opts, 42)?;
    println!("\n{:<26} {:>6} {:>9} {:>12} {:>8}", "candidate", "seed",
             "fabric", "area*delay", "score");
    for c in &ranked {
        let s = c.summary.as_ref().unwrap();
        println!("{:<26} {:>6} {:>9.4} {:>12.3e} {:>8.4}",
                 c.config, c.seed, s.fabric_acc, s.area_delay, c.score);
    }
    println!("\nwinner: {} (the paper's NAS direction, §V)", ranked[0].config);
    Ok(())
}
