//! Reproduce paper **Fig. 3**: decision boundaries of a small circuit on
//! the two-moons toy task under the three neuron families (linear /
//! polynomial / sub-network), across seeds. We print per-seed fabric
//! accuracies (the paper's qualitative claim: NeuraLUT converges to
//! consistently strong solutions; the polynomial family is high-variance)
//! and render ASCII decision maps from the *converted L-LUT fabric*.

use neuralut::coordinator::experiments::{epochs_override, n_seeds, run_config, save_results};
use neuralut::coordinator::pipeline::{self, PipelineOpts};
use neuralut::coordinator::trainer::TrainOpts;
use neuralut::data::Dataset;
use neuralut::fabric::{FabricOptions, Model};
use neuralut::manifest::Manifest;
use neuralut::runtime::Runtime;
use neuralut::util::stats;

fn ascii_boundary(rt: &Runtime, config: &str, seed: u64) -> anyhow::Result<Vec<String>> {
    let dir = neuralut::artifacts_dir().join(config);
    let m = Manifest::load(&dir)?;
    let ds = Dataset::load_named(&m.dataset)?;
    let opts = PipelineOpts {
        train: TrainOpts { epochs: epochs_override(), quiet: true, ..Default::default() },
        verify_samples: Some(256),
        out_dir: None,
        emit_rtl: false,
    };
    let r = pipeline::run(rt, &m, &ds, seed, &opts)?;
    // Backend selected by NEURALUT_ENGINE (any registered name).
    let session = Model::from_network(r.net)
        .compile(&FabricOptions::from_env()?)?
        .session();
    let (w, h) = (40usize, 18usize);
    let mut grid = Vec::with_capacity(w * h * 2);
    for row in 0..h {
        for col in 0..w {
            grid.push(col as f32 / (w - 1) as f32);
            grid.push(1.0 - row as f32 / (h - 1) as f32);
        }
    }
    let preds = session.infer_batch(&grid)?.predictions;
    let mut lines = Vec::new();
    for row in 0..h {
        let line: String = (0..w)
            .map(|col| if preds[row * w + col] == 0 { '.' } else { '#' })
            .collect();
        lines.push(line);
    }
    Ok(lines)
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let seeds: Vec<u64> = (0..n_seeds() as u64).collect();
    let configs = ["moons-logicnets", "moons-polylut", "moons-neuralut"];
    println!("== Fig. 3: classifier comparison across seeds (two moons) ==\n");

    let mut all = Vec::new();
    println!("{:<18} {}", "config", seeds.iter().map(|s| format!("seed{s:>2}  ")).collect::<String>());
    for config in configs {
        let mut row = format!("{config:<18} ");
        for &seed in &seeds {
            let s = run_config(&rt, config, seed, epochs_override())?;
            row.push_str(&format!("{:.4}  ", s.fabric_acc));
            all.push(s);
        }
        println!("{row}");
    }

    // Paper's qualitative claims, quantified:
    for config in configs {
        let rows: Vec<_> = all.iter().filter(|r| r.config == config).cloned().collect();
        let accs: Vec<f64> = rows.iter().map(|r| r.fabric_acc).collect();
        let s = stats::summarize(&accs);
        println!("{config:<18} mean {:.4}  std {:.4}  min {:.4}", s.mean, s.std, s.min);
    }

    println!("\nfabric decision maps (seed 0), '#' = class 1:");
    for config in configs {
        println!("\n--- {config} ---");
        for line in ascii_boundary(&rt, config, 0)? {
            println!("  {line}");
        }
    }
    let path = save_results("fig3", &all)?;
    println!("\nresults written to {}", path.display());
    Ok(())
}
