//! Quickstart: the whole NeuraLUT codesign loop in ~50 lines.
//!
//! Trains the two-moons toy model (AOT train steps via PJRT), converts the
//! trained sub-networks into L-LUT truth tables, verifies the fabric
//! simulator against the float model, emits Verilog, prints the synthesis
//! estimate — then reloads the saved model artifact through the unified
//! inference API (`Model` → `CompiledFabric` → `Session`) and classifies
//! the test set with it.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`
//! (NEURALUT_ENGINE picks the inference backend by registered name)

use neuralut::coordinator::pipeline::{self, PipelineOpts};
use neuralut::coordinator::trainer::TrainOpts;
use neuralut::data::Dataset;
use neuralut::fabric::{FabricOptions, Model};
use neuralut::manifest::Manifest;
use neuralut::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = neuralut::artifacts_dir().join("moons-neuralut");
    let manifest = Manifest::load(&dir)?;
    let dataset = Dataset::load_named(&manifest.dataset)?;
    let runtime = Runtime::cpu()?;
    println!("platform: {}", runtime.platform());
    println!("model   : {} ({:?} L-LUTs, mode {})",
             manifest.name, manifest.layers, manifest.mode);

    let opts = PipelineOpts {
        train: TrainOpts { quiet: false, eval_every: 1, ..Default::default() },
        verify_samples: Some(1000),
        out_dir: Some(std::env::temp_dir().join("neuralut_quickstart")),
        emit_rtl: true,
    };
    let r = pipeline::run(&runtime, &manifest, &dataset, /*seed=*/ 0, &opts)?;
    pipeline::verify_consistent(&r, 0.05)?;

    println!("\nfabric accuracy : {:.4} (float monitor {:.4}, {} flips / {})",
             r.sim_acc, r.model_acc, r.mismatches, r.n_verified);
    println!("hardware        : {} P-LUTs, {} FF, Fmax {:.0} MHz",
             r.synth.luts, r.synth.ffs, r.synth.fmax_mhz);
    println!("latency         : {:.1} ns ({} cycles, 1 cycle / L-LUT layer)",
             r.synth.latency_ns, r.synth.latency_cycles);
    println!("area-delay      : {:.3e} LUT*ns", r.synth.area_delay);

    // The pipeline saved the converted model; serve it back through the
    // unified inference API — one artifact, backend picked by name.
    let out_dir = std::env::temp_dir().join("neuralut_quickstart");
    let model = Model::load(&out_dir.join("network.nlut"))?;
    // NEURALUT_ENGINE / NEURALUT_OPT_LEVEL still pick the backend and
    // netlist optimization level; when nothing is set this demo compiles
    // the bitsliced engine through a .nfab fabric cache, so a second run
    // skips the lowering + optimization passes entirely.
    let mut opts = FabricOptions::from_env()?;
    if opts.get_backend().is_none() {
        opts = opts.backend("bitsliced");
        if opts.get_fabric_cache().is_none() {
            opts = opts.fabric_cache(out_dir.join("network.nfab"));
        }
    }
    let fabric = model.compile(&opts)?;
    let session = fabric.session();
    let acc = session.accuracy(&dataset.test_x, &dataset.test_y)?;
    println!("\nreloaded        : {}", model.info());
    // Compile telemetry: per-pass wall time and op deltas (empty pass
    // list when the .nfab cache was reloaded — nothing ran).
    println!("{}", fabric.report());
    match fabric.num_word_ops() {
        Some(ops) => println!("session         : {} backend at {} ({ops} word ops), \
                               accuracy {:.4}",
                              session.backend_name(), fabric.opt_level(), acc),
        None => println!("session         : {} backend, test accuracy {:.4}",
                         session.backend_name(), acc),
    }
    println!("\nartifacts in {}", out_dir.display());
    Ok(())
}
