//! Quickstart: the whole NeuraLUT codesign loop in ~40 lines.
//!
//! Trains the two-moons toy model (AOT train steps via PJRT), converts the
//! trained sub-networks into L-LUT truth tables, verifies the fabric
//! simulator against the float model, emits Verilog, and prints the
//! synthesis estimate.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use neuralut::coordinator::pipeline::{self, PipelineOpts};
use neuralut::coordinator::trainer::TrainOpts;
use neuralut::data::Dataset;
use neuralut::manifest::Manifest;
use neuralut::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = neuralut::artifacts_dir().join("moons-neuralut");
    let manifest = Manifest::load(&dir)?;
    let dataset = Dataset::load_named(&manifest.dataset)?;
    let runtime = Runtime::cpu()?;
    println!("platform: {}", runtime.platform());
    println!("model   : {} ({:?} L-LUTs, mode {})",
             manifest.name, manifest.layers, manifest.mode);

    let opts = PipelineOpts {
        train: TrainOpts { quiet: false, eval_every: 1, ..Default::default() },
        verify_samples: Some(1000),
        out_dir: Some(std::env::temp_dir().join("neuralut_quickstart")),
        emit_rtl: true,
    };
    let r = pipeline::run(&runtime, &manifest, &dataset, /*seed=*/ 0, &opts)?;
    pipeline::verify_consistent(&r, 0.05)?;

    println!("\nfabric accuracy : {:.4} (float monitor {:.4}, {} flips / {})",
             r.sim_acc, r.model_acc, r.mismatches, r.n_verified);
    println!("hardware        : {} P-LUTs, {} FF, Fmax {:.0} MHz",
             r.synth.luts, r.synth.ffs, r.synth.fmax_mhz);
    println!("latency         : {:.1} ns ({} cycles, 1 cycle / L-LUT layer)",
             r.synth.latency_ns, r.synth.latency_cycles);
    println!("area-delay      : {:.3e} LUT*ns", r.synth.area_delay);
    println!("\nartifacts in {}",
             std::env::temp_dir().join("neuralut_quickstart").display());
    Ok(())
}
