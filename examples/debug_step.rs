//! Diagnostic: run one deterministic train step (first `batch` rows,
//! unshuffled) from init and dump scalar outputs + a few named parameters,
//! to cross-check against the identical step executed in Python/jax.

use neuralut::data::Dataset;
use neuralut::manifest::Manifest;
use neuralut::runtime::{from_literal, to_literal, HostTensor, Runtime};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or("moons-neuralut".into());
    let dir = neuralut::artifacts_dir().join(&name);
    let m = Manifest::load(&dir)?;
    let ds = Dataset::load_named(&m.dataset)?;
    let rt = Runtime::cpu()?;
    let init = rt.load_artifact(&m, "init")?;
    let step_exe = rt.load_artifact(&m, "train_step")?;
    let n = m.params.len();
    let b = m.batch;

    let state = init.run_raw(&[to_literal(&HostTensor::scalar_i32(0))?])?;
    let zeros: Vec<xla::Literal> = m
        .params
        .iter()
        .map(|p| to_literal(&HostTensor::f32(p.shape.clone(), vec![0.0; p.elem_count()])))
        .collect::<anyhow::Result<_>>()?;

    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..b {
        x.extend_from_slice(ds.train_row(i));
        y.push(ds.train_y[i]);
    }
    let step_lit = to_literal(&HostTensor::scalar_f32(1.0))?;
    let lr_lit = to_literal(&HostTensor::scalar_f32(0.001))?;
    let x_lit = to_literal(&HostTensor::f32(vec![b, m.input_size], x))?;
    let y_lit = to_literal(&HostTensor::i32(vec![b], y))?;

    let mut args: Vec<&xla::Literal> = Vec::new();
    args.extend(state.iter());
    args.extend(zeros.iter());
    args.extend(zeros.iter());
    args.push(&step_lit);
    args.push(&lr_lit);
    args.push(&x_lit);
    args.push(&y_lit);
    let out = step_exe.run_literals_refs(&args)?;
    println!("outputs: {}", out.len());
    let loss = from_literal(&out[3 * n])?;
    let acc = from_literal(&out[3 * n + 1])?;
    println!("loss = {:?} acc = {:?}", loss.as_f32()?, acc.as_f32()?);
    for (i, spec) in m.params.iter().enumerate() {
        if spec.name.ends_with(".scale") || spec.name == "l0.bn_mean" {
            let t = from_literal(&out[i])?;
            let v = t.as_f32()?;
            println!("new {} = {:?}", spec.name, &v[..v.len().min(4)]);
        }
    }
    // Also dump init values for comparison.
    for (i, spec) in m.params.iter().enumerate() {
        if spec.name == "l0.w1" {
            let t = from_literal(&state[i])?;
            println!("init {} head = {:?}", spec.name, &t.as_f32()?[..4]);
        }
    }
    Ok(())
}
