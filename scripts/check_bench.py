#!/usr/bin/env python3
"""CI bench gate: read BENCH_engine.json / BENCH_server.json /
BENCH_net.json (written by `cargo bench --bench bench_netlist` /
`--bench bench_server` / `--bench bench_net`) and fail if the perf
trajectory regressed. `--net-only` gates just BENCH_net.json (the CI
net-loopback job runs bench_net without the other benches).

Two gate families:

* Deterministic, same-run gates (always armed):
    - every case: O2 word ops <= O0 word ops (the optimizer never bloats);
    - aggregate over the trained-like repro cases: O2 executes >= 10%
      fewer word ops than O0 (the headline claim of the opt pipeline);
    - per case: bitsliced O2 throughput >= 85% of bitsliced O0 measured in
      the *same run* (optimization must not cost wall-clock at run time).
      Quick-mode rows (NEURALUT_BENCH_QUICK, 0.15s windows on shared CI
      runners) relax this to a catastrophic-only 50% margin so scheduler
      noise on an unrelated PR cannot turn CI red;
    - wide planes: on every large repro case (O2 word ops >= 1500), each
      bitsliced-x2/x4/x8 throughput must stay >= 90% of the u64 run over
      the same netlist (50% in quick mode), and the best wide width must
      beat u64 by >= 2x (1.3x in quick mode) on at least one large case;
    - every BENCH_compile_report.json entry: the pass chain is coherent
      (passes[i].ops_before == passes[i-1].ops_after, last pass's
      ops_after == the report's final op count == the engine row's
      word_ops_o2, wall times finite and >= 0);
    - every BENCH_aot.json row (when the aot bench ran): 0 logit-code
      mismatches vs the simulator, and AOT steady-state throughput >= 90%
      of the same run's interpreted bitsliced-auto (50% in quick mode).
      `--aot-only` gates just this file for the CI aot job; a runner
      without a native toolchain writes a marker row and the gates skip.

* Baseline gates (armed per entry once BENCH_baseline.json carries a
  value > 0; entries at 0 are "not yet recorded" and skipped):
    - bitsliced throughput per case must be >= (1 - tolerance) x baseline
      (default tolerance 0.25, i.e. fail on a >25% regression);
    - O2 word ops per case must be <= (1 + tolerance) x baseline;
    - server closed-loop bitsliced 4-worker throughput likewise;
    - server stage latencies (end-to-end p99 and the queue-wait /
      batch-formation / execute stage p99s of the bitsliced 4-worker
      drain) must stay <= (1 + tolerance) x baseline.

Server rows stamped "faults_armed": true were produced with fault
injection armed (NEURALUT_FAULTS — the CI chaos leg). They measure
survival, not speed, and are never compared against throughput or
latency baselines, nor folded into the baseline snippet this script
prints.

To record/refresh the baseline, run the bench-smoke CI job (or the
benches locally), then paste the snippet this script prints into
BENCH_baseline.json and commit it. Throughput baselines are only
comparable on similar hardware, so refresh them from the same CI runner
class that enforces them.
"""

import json
import sys

ENGINE = "BENCH_engine.json"
SERVER = "BENCH_server.json"
REPORTS = "BENCH_compile_report.json"
BASELINE = "BENCH_baseline.json"
NET = "BENCH_net.json"
AOT = "BENCH_aot.json"
# Stage-latency ceilings gated against the baseline (p99s of the
# bitsliced 4-worker drain); baseline key = f"saturation_bitsliced_4w_{k}".
STAGE_KEYS = ("p99_us", "queue_wait_p99_us", "batch_form_p99_us", "execute_p99_us")
MIN_TRAINED_REDUCTION = 0.10
SAME_RUN_THROUGHPUT_MARGIN = 0.85
# Quick-mode timing windows are too short to trust a tight margin on a
# shared runner; still catch catastrophic (>2x) regressions.
SAME_RUN_THROUGHPUT_MARGIN_QUICK = 0.50
# Wide-plane gates (bitsliced-x2/x4/x8 vs the u64 x1 run, same netlist,
# same run). Only armed on the large repro cases: tiny nets fit a single
# block and their per-width deltas are pure timing noise.
LARGE_CASE_MIN_OPS = 1500
WIDE_MUST_NOT_LOSE_MARGIN = 0.90
WIDE_MUST_NOT_LOSE_MARGIN_QUICK = 0.50
# The widest profitable width must beat plain u64 by at least this factor
# on at least one large case — the point of carrying the width family.
BEST_WIDTH_SPEEDUP = 2.0
BEST_WIDTH_SPEEDUP_QUICK = 1.3
# AOT gates: straight-line native code must not lose to the interpreted
# bitsliced-auto run it replaces by more than this, same run. Parity is
# never relaxed — mismatches vs the simulator are a hard red at any
# margin, quick or not.
AOT_MUST_NOT_LOSE_MARGIN = 0.90
AOT_MUST_NOT_LOSE_MARGIN_QUICK = 0.50

failures = []


def fail(msg):
    failures.append(msg)
    print(f"FAIL: {msg}")


def ok(msg):
    print(f"  ok: {msg}")


def load(path, required=True):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        if required:
            fail(f"{path} not found — did the bench run?")
        return None
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")
        return None


def check_reports(report_rows, cases):
    """Deterministic compile-report gates: chain coherence per case, and
    agreement with the engine rows' O2 op counts."""
    seen = set()
    for entry in report_rows:
        case, rep = entry.get("case", "?"), entry.get("report", {})
        seen.add(case)
        passes = rep.get("passes", [])
        if not passes:
            fail(f"compile report for {case} has no passes")
            continue
        chain_ok = True
        for i, p in enumerate(passes):
            wall = p.get("wall_s", -1.0)
            if not (wall >= 0.0):  # catches NaN and negatives
                fail(f"{case}: pass '{p.get('name')}' wall_s {wall!r} invalid")
                chain_ok = False
            if i > 0 and p["ops_before"] != passes[i - 1]["ops_after"]:
                fail(
                    f"{case}: pass chain broken at '{p.get('name')}' "
                    f"({p['ops_before']} != {passes[i - 1]['ops_after']})"
                )
                chain_ok = False
        final = passes[-1]["ops_after"]
        if final != rep.get("ops"):
            fail(f"{case}: last pass ops_after {final} != report ops {rep.get('ops')}")
            chain_ok = False
        row = cases.get(case)
        if row is not None and final != row["word_ops_o2"]:
            fail(
                f"{case}: report final ops {final} != engine word_ops_o2 "
                f"{row['word_ops_o2']:.0f}"
            )
            chain_ok = False
        if chain_ok:
            names = " -> ".join(p.get("name", "?") for p in passes)
            ok(f"{case}: compile report chain {names} coherent ({final} ops)")
    for case in sorted(set(cases) - seen):
        fail(f"{case}: engine row has no compile report in {REPORTS}")


def check_net(net_rows):
    """Deterministic gates over the wire-protocol bench (BENCH_net.json):
    percentile ordering must hold per payload size (p50 <= p90 <= p99,
    all positive), and the saturation leg must still *serve* under
    flooding — admission control that refuses everything would pass a
    refusals-are-typed test while being useless."""
    if not net_rows:
        fail(f"{NET} is empty — bench produced no rows")
        return
    clean = [r for r in net_rows if not r.get("faults_armed")]
    armed = len(net_rows) - len(clean)
    if armed:
        ok(f"net: ignoring {armed} faults-armed row(s)")
    if not clean:
        ok("net: every row is faults-armed; gates skipped")
        return
    payload = [r for r in clean if r.get("section") == "net_payload"]
    if not payload:
        fail(f"no net_payload row in {NET} — payload sweep missing?")
    for r in payload:
        rows_per_frame = r.get("rows_per_frame", "?")
        p50, p90, p99 = (float(r.get(k, -1)) for k in ("p50_us", "p90_us", "p99_us"))
        if not (0 < p50 <= p90 <= p99):
            fail(
                f"net: payload rows={rows_per_frame} percentiles out of order "
                f"(p50 {p50:.0f} / p90 {p90:.0f} / p99 {p99:.0f} us)"
            )
        else:
            ok(
                f"net: payload rows={rows_per_frame} p50 {p50:.0f} <= "
                f"p90 {p90:.0f} <= p99 {p99:.0f} us"
            )
    sat_rows = [r for r in clean if r.get("section") == "net_saturation"]
    if not sat_rows:
        fail(f"no net_saturation row in {NET} — saturation leg missing?")
    for r in sat_rows:
        served = float(r.get("served_per_s", 0))
        refusal = float(r.get("refusal_rate", -1))
        if served <= 0:
            fail(f"net: saturation served {served:.0f} rows/s — nothing got through")
        else:
            ok(f"net: saturation served {served:.0f} rows/s under flooding")
        if not (0.0 <= refusal <= 1.0):
            fail(f"net: saturation refusal_rate {refusal} outside [0, 1]")
        else:
            ok(f"net: saturation refusal rate {refusal:.1%} (typed Overloaded)")


def check_aot(aot_rows):
    """AOT backend gates (BENCH_aot.json, written by `cargo bench --bench
    bench_aot`): parity vs the reference simulator must be exact on every
    row, and steady-state AOT throughput must not lose to the interpreted
    bitsliced-auto run from the same bench by more than the margin. A
    runner without a native toolchain writes a single marker row and the
    gates skip — the backend degrades there, it does not fail."""
    if not aot_rows:
        fail(f"{AOT} is empty — bench produced no rows")
        return
    if any(r.get("toolchain_available") is False for r in aot_rows):
        ok("aot: no native toolchain on the bench runner; gates skipped")
        return
    for r in aot_rows:
        name = r.get("name", "?")
        mismatches = r.get("parity_mismatches")
        if mismatches != 0:
            fail(
                f"aot: {name} has {mismatches!r} logit-code mismatches vs the "
                f"simulator — native codegen parity is a hard release gate"
            )
        else:
            ok(f"aot: {name} parity exact (0 mismatches)")
        aot_sps = float(r.get("aot_samples_per_s", 0))
        interp_sps = float(r.get("bitsliced_auto_samples_per_s", 0))
        margin = (
            AOT_MUST_NOT_LOSE_MARGIN_QUICK
            if r.get("quick")
            else AOT_MUST_NOT_LOSE_MARGIN
        )
        if aot_sps <= 0 or interp_sps <= 0:
            fail(f"aot: {name} throughput missing (aot {aot_sps}, interp {interp_sps})")
        elif aot_sps < margin * interp_sps:
            fail(
                f"aot: {name} {aot_sps:.0f} samples/s loses to bitsliced-auto "
                f"({interp_sps:.0f}; {aot_sps / interp_sps:.2f}x < {margin:.2f}x floor)"
            )
        else:
            ok(
                f"aot: {name} {aot_sps:.0f} samples/s "
                f"({aot_sps / interp_sps:.2f}x of bitsliced-auto)"
            )
        cold = float(r.get("aot_cold_start_s", -1))
        warm = float(r.get("warm_reload_s", -1))
        if cold < 0 or warm < 0:
            fail(f"aot: {name} is missing cold-start/warm-reload timings")
        else:
            ok(f"aot: {name} cold start {cold:.3f}s, warm reload {warm:.3f}s")


def main():
    # `--net-only`: gate just BENCH_net.json — the CI net-loopback job
    # runs bench_net without the engine/server benches.
    if "--net-only" in sys.argv[1:]:
        check_net(load(NET))
        if failures:
            print(f"\nbench gate: {len(failures)} failure(s)")
            return 1
        print("\nbench gate: all net checks passed")
        return 0

    # `--aot-only`: gate just BENCH_aot.json — the CI aot job runs
    # bench_aot without the engine/server benches.
    if "--aot-only" in sys.argv[1:]:
        check_aot(load(AOT))
        if failures:
            print(f"\nbench gate: {len(failures)} failure(s)")
            return 1
        print("\nbench gate: all aot checks passed")
        return 0

    engine_rows = load(ENGINE)
    server_rows = load(SERVER)
    report_rows = load(REPORTS)
    net_rows = load(NET)
    # bench_aot runs in its own CI job; in the combined path its rows are
    # gated when present and silently skipped when the bench didn't run.
    aot_rows = load(AOT, required=False)
    baseline = load(BASELINE) or {}
    tol = float(baseline.get("tolerance", 0.25))

    if net_rows is not None:
        check_net(net_rows)
    if aot_rows is not None:
        check_aot(aot_rows)

    if engine_rows is not None and not engine_rows:
        fail(f"{ENGINE} is empty — bench produced no cases")
    if server_rows is not None and not server_rows:
        fail(f"{SERVER} is empty — bench produced no rows")

    cases = {}
    sat = []
    if engine_rows:
        cases = {row["name"]: row for row in engine_rows}

        # --- deterministic same-run gates -------------------------------
        tr_o0 = tr_o2 = 0
        for name, row in sorted(cases.items()):
            o0, o2 = row["word_ops_o0"], row["word_ops_o2"]
            if o2 > o0:
                fail(f"{name}: O2 executes more word ops than O0 ({o2} > {o0})")
            else:
                ok(f"{name}: word ops O0 {o0:.0f} -> O2 {o2:.0f}")
            if row.get("trained_like"):
                tr_o0 += o0
                tr_o2 += o2
            t0 = row.get("bitsliced_o0_samples_per_s", 0.0)
            t2 = row.get("bitsliced_samples_per_s", 0.0)
            margin = (
                SAME_RUN_THROUGHPUT_MARGIN_QUICK
                if row.get("quick")
                else SAME_RUN_THROUGHPUT_MARGIN
            )
            if t0 > 0 and t2 > 0:
                if t2 < margin * t0:
                    fail(
                        f"{name}: O2 throughput {t2:.0f} samples/s is below "
                        f"{margin:.0%} of O0 ({t0:.0f})"
                    )
                else:
                    ok(f"{name}: O2 throughput {t2:.0f} vs O0 {t0:.0f} samples/s")
        # --- wide-plane gates (deterministic, same run) -----------------
        rows_with_widths = [r for r in cases.values() if r.get("width_samples_per_s")]
        if not rows_with_widths:
            fail(f"no row in {ENGINE} carries width_samples_per_s — wide bench missing?")
        large_rows = 0
        best = (0.0, None, None)  # (speedup vs u64, case, width name)
        any_quick = any(r.get("quick") for r in rows_with_widths)
        for name, row in sorted(cases.items()):
            widths = row.get("width_samples_per_s")
            if not widths:
                continue
            base = float(widths.get("bitsliced", 0.0))
            if base <= 0:
                fail(f"{name}: width table lacks a positive u64 (x1) baseline")
                continue
            if row["word_ops_o2"] < LARGE_CASE_MIN_OPS:
                continue
            large_rows += 1
            margin = (
                WIDE_MUST_NOT_LOSE_MARGIN_QUICK
                if row.get("quick")
                else WIDE_MUST_NOT_LOSE_MARGIN
            )
            for wname, sps in sorted(widths.items()):
                if wname == "bitsliced":
                    continue
                ratio = float(sps) / base
                if ratio < margin:
                    fail(
                        f"{name}: {wname} throughput {float(sps):.0f} samples/s "
                        f"loses to u64 ({base:.0f}; {ratio:.2f}x < {margin:.2f}x floor)"
                    )
                else:
                    ok(f"{name}: {wname} {float(sps):.0f} samples/s ({ratio:.2f}x of u64)")
                if ratio > best[0]:
                    best = (ratio, name, wname)
        if rows_with_widths:
            if large_rows == 0:
                fail(
                    f"no large repro case (word_ops_o2 >= {LARGE_CASE_MIN_OPS}) "
                    f"carries width data — the wide gate never armed"
                )
            else:
                need = BEST_WIDTH_SPEEDUP_QUICK if any_quick else BEST_WIDTH_SPEEDUP
                if best[0] < need:
                    fail(
                        f"best wide speedup is {best[0]:.2f}x ({best[2]} on {best[1]}) "
                        f"— below the {need:.1f}x bar on every large case"
                    )
                else:
                    ok(f"best wide speedup: {best[0]:.2f}x ({best[2]} on {best[1]})")

        if tr_o0 > 0:
            red = 1.0 - tr_o2 / tr_o0
            if red < MIN_TRAINED_REDUCTION:
                fail(
                    f"aggregate O2 op reduction on trained-like cases is "
                    f"{red:.1%} (< {MIN_TRAINED_REDUCTION:.0%})"
                )
            else:
                ok(f"aggregate trained-like O2 op reduction: {red:.1%}")
        else:
            fail("no trained-like cases in BENCH_engine.json")

        # --- baseline gates ---------------------------------------------
        for name, base in sorted(baseline.get("engine", {}).items()):
            row = cases.get(name)
            if row is None:
                fail(f"baseline case '{name}' missing from {ENGINE} — bench shrank?")
                continue
            floor = float(base.get("bitsliced_samples_per_s", 0))
            if floor > 0:
                got = row["bitsliced_samples_per_s"]
                if got < (1 - tol) * floor:
                    fail(
                        f"{name}: bitsliced throughput {got:.0f} regressed "
                        f">{tol:.0%} vs baseline {floor:.0f}"
                    )
                else:
                    ok(f"{name}: throughput {got:.0f} vs baseline {floor:.0f}")
            ceil = float(base.get("word_ops_o2", 0))
            if ceil > 0:
                got = row["word_ops_o2"]
                if got > (1 + tol) * ceil:
                    fail(
                        f"{name}: O2 word ops {got:.0f} grew >{tol:.0%} vs "
                        f"baseline {ceil:.0f}"
                    )
                else:
                    ok(f"{name}: O2 word ops {got:.0f} vs baseline {ceil:.0f}")

    if report_rows is not None:
        if not report_rows:
            fail(f"{REPORTS} is empty — bench produced no compile reports")
        else:
            check_reports(report_rows, cases)

    if server_rows:
        # Chaos-leg rows measure survival under injected faults, never
        # speed: drop them before any throughput/latency comparison.
        armed_rows = [r for r in server_rows if r.get("faults_armed")]
        clean_rows = [r for r in server_rows if not r.get("faults_armed")]
        if armed_rows:
            ok(
                f"server: ignoring {len(armed_rows)} faults-armed row(s) — "
                f"not comparable against throughput baselines"
            )
        sat = [
            r
            for r in clean_rows
            if r.get("section") == "saturation"
            and r.get("backend") == "bitsliced"
            and r.get("workers") == 4
        ]
        if not sat:
            if clean_rows:
                fail(f"no bitsliced 4-worker saturation row in {SERVER}")
            else:
                ok("server: every row is faults-armed; throughput gates skipped")
        else:
            got = sat[0]["served_per_s"]
            floor = float(baseline.get("server", {}).get(
                "saturation_bitsliced_4w_served_per_s", 0))
            if floor > 0 and got < (1 - tol) * floor:
                fail(
                    f"server: bitsliced 4-worker throughput {got:.0f} req/s "
                    f"regressed >{tol:.0%} vs baseline {floor:.0f}"
                )
            else:
                ok(f"server: bitsliced 4-worker throughput {got:.0f} req/s "
                   f"(baseline {floor:.0f})")
            # Stage-latency ceilings: armed once recorded, regression =
            # latency growing past (1 + tol) x baseline.
            for key in STAGE_KEYS:
                got = sat[0].get(key)
                if got is None:
                    fail(f"server: saturation row is missing '{key}'")
                    continue
                ceil = float(baseline.get("server", {}).get(
                    f"saturation_bitsliced_4w_{key}", 0))
                if ceil > 0 and got > (1 + tol) * ceil:
                    fail(
                        f"server: {key} {got:.0f}us regressed >{tol:.0%} "
                        f"vs baseline {ceil:.0f}us"
                    )
                else:
                    ok(f"server: {key} {got:.0f}us (baseline {ceil:.0f}us)")

    # Print a paste-ready baseline snippet for arming/refreshing the gate.
    if engine_rows and sat:
        snippet = {
            "tolerance": tol,
            "engine": {
                name: {
                    "bitsliced_samples_per_s": round(row["bitsliced_samples_per_s"]),
                    "word_ops_o2": round(row["word_ops_o2"]),
                }
                for name, row in sorted(cases.items())
            },
            "server": {
                "saturation_bitsliced_4w_served_per_s": round(sat[0]["served_per_s"]),
                **{
                    f"saturation_bitsliced_4w_{key}": round(sat[0].get(key, 0))
                    for key in STAGE_KEYS
                },
            },
        }
        print("\nto arm/refresh the gate, commit this as BENCH_baseline.json:")
        print(json.dumps(snippet, indent=2))

    if failures:
        print(f"\nbench gate: {len(failures)} failure(s)")
        return 1
    print("\nbench gate: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
