"""Model / experiment configuration registry.

Every artifact bundle (init / train_step / fwd / tt_layer* HLO + manifest)
is produced from one ``ModelConfig``. The registry mirrors the paper's Table
II plus the configurations needed for Figs. 3, 5, 6 and 7; the Rust side
reads the same values from each bundle's ``manifest.json``.

Scale notes (see DESIGN.md §5): paper-exact circuit topologies are used for
the jet-substructure models (JSC-2L exactly, JSC-5L exact topology with
reduced epochs); MNIST experiments default to a documented ``-mini`` scale
(14x14 procedural digits, smaller circuits) to stay tractable on CPU. The
paper-exact HDR-5L topology is registered behind ``--full``.
"""

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """One circuit-level model + its training recipe."""

    name: str
    dataset: str  # jsc | digits | digits28 | moons
    input_size: int
    n_class: int
    layers: Tuple[int, ...]  # L-LUTs per circuit layer; last == n_class
    beta: int  # hidden inter-L-LUT bit-width
    fan_in: int  # F
    mode: str = "neuralut"  # neuralut | logicnets | polylut
    # neuralut sub-network topology (ignored in other modes)
    sub_depth: int = 4  # L
    sub_width: int = 16  # N
    sub_skip: int = 2  # S
    degree: int = 2  # PolyLUT D
    beta_in: int = 0  # input bit-width (0 -> beta)
    beta_out: int = 0  # logit bit-width (0 -> max(beta, 4))
    # Table II "Exceptions" (JSC-5L: beta_0 = 7, F_0 = 2)
    beta_in0: int = 0  # first-layer input bits override (0 -> beta_in)
    fan_in0: int = 0  # first-layer fan-in override (0 -> fan_in)
    batch: int = 128
    epochs: int = 20
    # NeuraLUT's deep sub-networks need a gentler peak LR than the linear /
    # polynomial baselines (quantizer clip zones go dead if early steps
    # overshoot); defaults below are overridden per config family.
    lr_max: float = 4e-3
    lr_min: float = 1e-4
    weight_decay: float = 1e-4
    sgdr_t0: int = 5  # SGDR: first restart period (epochs)
    sgdr_mult: int = 2  # SGDR: period multiplier
    mask_seed: int = 7  # a-priori random sparsity seed (fixed per config)

    def resolved_beta_in(self) -> int:
        return self.beta_in or self.beta

    def resolved_beta_out(self) -> int:
        return self.beta_out or max(self.beta, 4)

    def layer_fan_in(self, layer: int) -> int:
        """Fan-in of L-LUTs in ``layer`` (first layer may be overridden),
        clamped to the actual number of available inputs."""
        f = self.fan_in0 if (layer == 0 and self.fan_in0) else self.fan_in
        avail = self.input_size if layer == 0 else self.layers[layer - 1]
        return min(f, avail)

    def layer_in_bits(self, layer: int) -> int:
        """Bit-width of each of the layer's inputs."""
        if layer == 0:
            return self.beta_in0 or self.resolved_beta_in()
        return self.beta

    def layer_out_bits(self, layer: int) -> int:
        return self.resolved_beta_out() if layer == len(self.layers) - 1 else self.beta

    def tt_entries(self, layer: int) -> int:
        """Truth-table entries per L-LUT in ``layer`` = 2^(bits * F)."""
        return 1 << (self.layer_in_bits(layer) * self.layer_fan_in(layer))


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    return _REGISTRY[name]


def names(full: bool = False) -> List[str]:
    """Configs built by ``make artifacts`` (``full`` adds the heavy ones)."""
    out = [n for n in _REGISTRY if not _REGISTRY[n].name.endswith("-full")]
    if full:
        out = list(_REGISTRY)
    return out


# --------------------------------------------------------------------------
# Fig. 3 — two-moons toy study: 3-layer circuit, one config per neuron kind.
# --------------------------------------------------------------------------
_moons = dict(
    dataset="moons", input_size=2, n_class=2, layers=(8, 4, 2), beta=4,
    fan_in=2, batch=64, epochs=40, lr_max=8e-3, sgdr_t0=10,
)
register(ModelConfig(name="moons-logicnets", mode="logicnets", **_moons))
register(ModelConfig(name="moons-polylut", mode="polylut", degree=4, **_moons))
register(ModelConfig(
    name="moons-neuralut", mode="neuralut",
    sub_depth=2, sub_width=8, sub_skip=0, **_moons,
))

# --------------------------------------------------------------------------
# Table II / Table III — jet substructure tagging (synthetic JSC, §5).
# JSC-2L and JSC-5L are the paper's exact circuit topologies.
# --------------------------------------------------------------------------
register(ModelConfig(
    name="jsc-2l", dataset="jsc", input_size=16, n_class=5,
    layers=(32, 5), beta=4, fan_in=3,
    sub_depth=4, sub_width=8, sub_skip=2, batch=256, epochs=40,
))
register(ModelConfig(
    name="jsc-5l", dataset="jsc", input_size=16, n_class=5,
    layers=(128, 128, 128, 64, 5), beta=4, fan_in=3,
    sub_depth=4, sub_width=16, sub_skip=2,
    beta_in0=7, fan_in0=2, batch=256, epochs=25,
))
# Baselines at the JSC-2L scale (PolyLUT JSC-M Lite / LogicNets JSC-M are
# (64, 32, 5)-shaped in their papers; same circuit family here).
register(ModelConfig(
    name="jsc-polylut", dataset="jsc", input_size=16, n_class=5,
    layers=(64, 32, 5), beta=3, fan_in=4, mode="polylut", degree=2,
    batch=256, epochs=40, lr_max=1e-2,
))
register(ModelConfig(
    name="jsc-logicnets", dataset="jsc", input_size=16, n_class=5,
    layers=(64, 32, 5), beta=3, fan_in=4, mode="logicnets",
    batch=256, epochs=40, lr_max=1e-2,
))

# --------------------------------------------------------------------------
# MNIST-mini (14x14 procedural digits) — HDR-style models for Table III.
# --------------------------------------------------------------------------
_digits = dict(dataset="digits", input_size=196, n_class=10, beta=2, fan_in=6,
               batch=128, epochs=15)
register(ModelConfig(
    name="hdr-mini", layers=(64, 32, 10),
    sub_depth=4, sub_width=16, sub_skip=2, **_digits,
))
register(ModelConfig(
    name="hdr-mini-polylut", layers=(64, 32, 10), mode="polylut", degree=2,
    **_digits,
))
register(ModelConfig(
    name="hdr-mini-logicnets", layers=(64, 32, 10), mode="logicnets",
    **_digits,
))
# Paper-exact HDR-5L topology (28x28 inputs); heavy on CPU -> behind --full.
register(ModelConfig(
    name="hdr-5l-full", dataset="digits28", input_size=784, n_class=10,
    layers=(256, 100, 100, 100, 10), beta=2, fan_in=6,
    sub_depth=4, sub_width=16, sub_skip=2, batch=128, epochs=10,
))

# --------------------------------------------------------------------------
# Fig. 5 — ablation on a fixed circuit: sub-network depth L in {1..4},
# with (S=2 for even L, S=1 otherwise... paper uses skip period 2) and
# without (S=0) skip connections, vs the LogicNets baseline (N=1, L=1).
# --------------------------------------------------------------------------
_fig5 = dict(dataset="digits", input_size=196, n_class=10,
             layers=(64, 32, 10), beta=2, fan_in=6, batch=128, epochs=12)
register(ModelConfig(name="fig5-baseline", mode="logicnets", **_fig5))
for L in (1, 2, 3, 4):
    s_skip = 2 if L % 2 == 0 else 1
    register(ModelConfig(
        name=f"fig5-l{L}-skip", sub_depth=L, sub_width=16, sub_skip=s_skip,
        **_fig5,
    ))
    register(ModelConfig(
        name=f"fig5-l{L}-noskip", sub_depth=L, sub_width=16, sub_skip=0,
        **_fig5,
    ))

# --------------------------------------------------------------------------
# Figs. 6 & 7 — error-vs-latency / error-vs-area Pareto: a sweep of circuit
# sizes, each trained as LogicNets (N=1, L=1, S=0) and as NeuraLUT
# (N=16, L=4, S=2), mirroring the paper's setting.
# --------------------------------------------------------------------------
_PARETO_CIRCUITS = {
    "xl": (96, 48, 10),
    "lg": (64, 32, 10),
    "md": (48, 24, 10),
    "sm": (32, 16, 10),
}
for tag, circuit in _PARETO_CIRCUITS.items():
    common = dict(dataset="digits", input_size=196, n_class=10,
                  layers=circuit, beta=2, fan_in=6, batch=128, epochs=12)
    register(ModelConfig(
        name=f"pareto-{tag}-neuralut", sub_depth=4, sub_width=16, sub_skip=2,
        **common,
    ))
    register(ModelConfig(name=f"pareto-{tag}-logicnets", mode="logicnets",
                         **common))
