"""AOT artifact emitter (build path only — Python never runs at inference).

For every registered config this lowers four function families to **HLO
text** and writes a ``manifest.json`` describing the flat argument ABI:

    artifacts/<config>/init.hlo.txt        (seed:i32) -> params...
    artifacts/<config>/train_step.hlo.txt  (params..., m..., v..., step, lr,
                                            x[B,in], y[B]) ->
                                           (params'..., m'..., v'..., loss, acc)
    artifacts/<config>/fwd.hlo.txt         (params..., x[B,in]) -> logits[B,C]
    artifacts/<config>/tt_layer{l}.hlo.txt (prev_scale?, layer-l params...) ->
                                           codes[M_l, 2^(bits*F)]
    artifacts/<config>/manifest.json

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example/README).

Usage:  cd python && python -m compile.aot --out ../artifacts [--full]
                                            [--configs a,b,...]
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, datasets, model, train, tt
from .configs import ModelConfig

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust).

    ``print_large_constants=True`` is load-bearing: the default HLO printer
    elides big constants as ``{...}``, which the consuming parser silently
    reads back as zeros — any embedded table (e.g. the one-hot wiring
    matrices) would be destroyed.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _struct(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_config(cfg: ModelConfig, out_dir: str, *, use_pallas=True):
    """Lower all artifacts for ``cfg`` into ``out_dir`` + manifest."""
    os.makedirs(out_dir, exist_ok=True)
    spec = model.param_spec(cfg)
    indices = model.build_sparsity(cfg)
    pstructs = [_struct(s) for _, s in spec]
    n = len(spec)
    b = cfg.batch

    # --- init -------------------------------------------------------------
    def init_fn(seed):
        return tuple(model.init_params(cfg, seed))

    hlo_init = to_hlo_text(jax.jit(init_fn, keep_unused=True).lower(_struct((), jnp.int32)))

    # --- train step ---------------------------------------------------------
    def step_fn(*args):
        params = list(args[:n])
        ms = list(args[n : 2 * n])
        vs = list(args[2 * n : 3 * n])
        step, lr, x, y = args[3 * n :]
        p2, m2, v2, loss, acc = train.train_step(
            cfg, params, ms, vs, step, lr, x, y, indices,
            use_pallas=use_pallas,
        )
        return (*p2, *m2, *v2, loss, acc)

    step_args = (
        pstructs + pstructs + pstructs
        + [_struct(()), _struct(()),
           _struct((b, cfg.input_size)), _struct((b,), jnp.int32)]
    )
    hlo_step = to_hlo_text(jax.jit(step_fn, keep_unused=True).lower(*step_args))

    # --- forward (eval) -----------------------------------------------------
    def fwd_fn(*args):
        params = list(args[:n])
        x = args[n]
        logits, _ = model.forward(cfg, params, x, indices,
                                  train=False, use_pallas=use_pallas)
        return logits

    # keep_unused=True everywhere: jax.jit silently drops unused arguments
    # at lowering time, which would desynchronize the flat ABI.
    hlo_fwd = to_hlo_text(
        jax.jit(fwd_fn, keep_unused=True).lower(
            *pstructs, _struct((b, cfg.input_size)))
    )

    # --- truth tables (one per circuit layer) --------------------------------
    slices = model.layer_param_slices(cfg)
    scale_idx = model.scale_param_indices(cfg)
    tt_manifest = []
    tt_hlos = {}
    for l in range(len(cfg.layers)):
        lo, hi = slices[l]
        arg_names = [spec[i][0] for i in range(lo, hi)]
        arg_structs = [pstructs[i] for i in range(lo, hi)]
        if l > 0:
            prev_scale_name = spec[scale_idx[l - 1]][0]
            arg_names = [prev_scale_name] + arg_names
            arg_structs = [_struct(())] + arg_structs

        def tt_fn(l, *args):
            if l > 0:
                prev_scale, layer_params = args[0], list(args[1:])
            else:
                prev_scale, layer_params = None, list(args)
            return tt.tt_layer(cfg, l, layer_params, prev_scale,
                               use_pallas=use_pallas)

        tt_hlos[l] = to_hlo_text(
            jax.jit(functools.partial(tt_fn, l),
                    keep_unused=True).lower(*arg_structs)
        )
        tt_manifest.append({
            "layer": l,
            "path": f"tt_layer{l}.hlo.txt",
            "args": arg_names,
            "num_luts": cfg.layers[l],
            "entries": cfg.tt_entries(l),
            "fan_in": cfg.layer_fan_in(l),
            "in_bits": cfg.layer_in_bits(l),
            "out_bits": cfg.layer_out_bits(l),
            "signed_out": l == len(cfg.layers) - 1,
        })

    # --- write --------------------------------------------------------------
    for name, text in [("init", hlo_init), ("train_step", hlo_step),
                       ("fwd", hlo_fwd)]:
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
    for l, text in tt_hlos.items():
        with open(os.path.join(out_dir, f"tt_layer{l}.hlo.txt"), "w") as f:
            f.write(text)

    manifest = {
        "name": cfg.name,
        "mode": cfg.mode,
        "dataset": cfg.dataset,
        "input_size": cfg.input_size,
        "n_class": cfg.n_class,
        "layers": list(cfg.layers),
        "beta": cfg.beta,
        "beta_in": cfg.resolved_beta_in(),
        "beta_out": cfg.resolved_beta_out(),
        "fan_in": cfg.fan_in,
        "beta_in0": cfg.beta_in0 or cfg.resolved_beta_in(),
        "fan_in0": cfg.layer_fan_in(0),
        "sub_depth": cfg.sub_depth,
        "sub_width": cfg.sub_width,
        "sub_skip": cfg.sub_skip,
        "degree": cfg.degree,
        "batch": b,
        "epochs": cfg.epochs,
        "lr_max": cfg.lr_max,
        "lr_min": cfg.lr_min,
        "weight_decay": cfg.weight_decay,
        "sgdr_t0": cfg.sgdr_t0,
        "sgdr_mult": cfg.sgdr_mult,
        "params": [
            {"name": nm, "shape": list(sh)} for nm, sh in spec
        ],
        "scale_param_idx": scale_idx,
        "layer_param_slices": [list(s) for s in slices],
        "indices": [idx.tolist() for idx in indices],
        "layer_in_bits": [cfg.layer_in_bits(l) for l in range(len(cfg.layers))],
        "layer_fan_in": [cfg.layer_fan_in(l) for l in range(len(cfg.layers))],
        "tt": tt_manifest,
        "artifacts": {
            "init": "init.hlo.txt",
            "train_step": "train_step.hlo.txt",
            "fwd": "fwd.hlo.txt",
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also build the heavy paper-exact configs (*-full)")
    ap.add_argument("--configs", default="",
                    help="comma-separated subset of config names")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower with the jnp reference instead of Pallas")
    ap.add_argument("--single-block", action="store_true",
                    help="lower with the grid-free Pallas schedule")
    args = ap.parse_args()

    names = (args.configs.split(",") if args.configs
             else configs.names(full=args.full))

    t0 = time.time()
    datasets.build_all(os.path.join(args.out, "data"))
    print(f"[aot] datasets written ({time.time()-t0:.1f}s)", flush=True)

    for name in names:
        t1 = time.time()
        cfg = configs.get(name)
        mode = (False if args.no_pallas
                else ("single" if args.single_block else True))
        lower_config(cfg, os.path.join(args.out, name), use_pallas=mode)
        print(f"[aot] {name}: lowered in {time.time()-t1:.1f}s", flush=True)
    print(f"[aot] done: {len(names)} configs in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
