"""Sub-network -> L-LUT truth-table conversion (toolflow stage 2).

For circuit layer ``l`` with M L-LUTs, fan-in F and per-input bit-width b,
enumerate all 2^(b*F) input codes, evaluate the layer's neuron function on
the *dequantized* codes, re-quantize, and emit the integer output codes —
one truth table per L-LUT, [M, 2^(b*F)].

Address convention (shared with ``rust/src/netlist`` and the generated RTL):
input j of a LUT occupies bits [b*j, b*(j+1)) of the table address, i.e.
``addr = sum_j code_j << (b*j)``.

Arguments of the lowered ``tt_layer{l}.hlo.txt``: the previous layer's raw
scale (absent for l = 0, where inputs are fixed-scale) followed by layer
l's own parameters (affines/residuals/poly weights + its raw scale), in the
flat ABI order — listed per-artifact in manifest.json.
"""

from typing import List, Sequence

import jax.numpy as jnp

from . import quant
from .configs import ModelConfig
from .model import layer_apply


def enumerate_inputs(cfg: ModelConfig, layer: int):
    """Decode all 2^(b*F) addresses into per-input integer digits [T, F]."""
    f = cfg.layer_fan_in(layer)
    b = cfg.layer_in_bits(layer)
    t = 1 << (b * f)
    codes = jnp.arange(t, dtype=jnp.int32)
    mask = (1 << b) - 1
    digits = jnp.stack(
        [(codes >> (b * j)) & mask for j in range(f)], axis=-1
    )
    return digits  # [T, F] int32


def tt_layer(cfg: ModelConfig, layer: int, layer_params: Sequence,
             prev_raw_scale=None, *, use_pallas: bool = True):
    """Truth tables for circuit layer ``layer``: -> codes [M, 2^(b*F)] i32.

    ``layer_params`` excludes the scale; the layer's own raw scale must be
    the last element of ``layer_params`` — mirroring the manifest order —
    so callers pass exactly manifest ``tt[l].args``.
    """
    m = cfg.layers[layer]
    digits = enumerate_inputs(cfg, layer)  # [T, F]
    b_in = cfg.layer_in_bits(layer)
    if layer == 0:
        x = quant.dequant_input_code(digits, b_in)
    else:
        assert prev_raw_scale is not None
        x = quant.dequant_unsigned_code(digits, prev_raw_scale, cfg.beta)

    xb = jnp.broadcast_to(x[None], (m, x.shape[0], x.shape[1]))
    # Same code path as eval-mode forward() -> bit-exact conversion; we
    # re-quantize the dequantized float output back to integer codes.
    out, _ = layer_apply(cfg, layer, layer_params, xb, train=False,
                         use_pallas=use_pallas)  # [T, M] dequantized floats
    raw_scale = layer_params[-1]
    if layer == len(cfg.layers) - 1:
        codes = quant.quant_signed_code(out, raw_scale,
                                        cfg.layer_out_bits(layer))
    else:
        codes = quant.quant_unsigned_code(out, raw_scale, cfg.beta)
    return jnp.transpose(codes)  # [M, T]
