"""Learned-scale fake quantization (Brevitas-equivalent) with STE.

Every L-LUT boundary in the circuit-level model carries a ``beta``-bit code.
The quantizers here define the *exact* code <-> float mapping that the Rust
netlist simulator and RTL replicate bit-for-bit, so the rounding convention
matters: we use ``floor(x + 0.5)`` (round-half-up) everywhere because
``jnp.round`` rounds half-to-even while Rust's ``f32::round`` rounds
half-away-from-zero — ``floor(x + 0.5)`` is cheap and identical in both.

Conventions shared with ``rust/src/netlist``:
  * hidden activations: unsigned codes in [0, 2^beta - 1], dequant
    ``code / (2^beta - 1) * scale`` with a learned positive ``scale``;
  * circuit inputs: same but with fixed ``scale = 1`` (features are
    pre-normalized to [0, 1]);
  * logits (last layer): signed codes in [-Q, Q], Q = 2^(beta-1) - 1,
    dequant ``code * scale / Q`` with a learned shared ``scale`` (argmax on
    codes therefore equals argmax on dequantized logits).
"""

import jax
import jax.numpy as jnp


def round_half_up(x):
    """Deterministic round: floor(x + 0.5). Mirrored by the Rust side."""
    return jnp.floor(x + 0.5)


def ste(fn, x):
    """Straight-through estimator: forward ``fn(x)``, identity gradient."""
    return x + jax.lax.stop_gradient(fn(x) - x)


# Gradient slope outside the clip range. BatchNorm (model.py) keeps
# pre-activations mostly inside the quantizer range; the small leak restores
# recovery gradients for the tail that still lands outside, while leaving
# the forward (and hence the truth tables) bit-identical to a hard clip.
LEAK = 0.05


def leaky_clip(x, lo, hi):
    """Forward: hard clip. Backward: 1 inside [lo, hi], ``LEAK`` outside."""
    soft = LEAK * x + (1.0 - LEAK) * jnp.clip(x, lo, hi)
    return soft + jax.lax.stop_gradient(jnp.clip(x, lo, hi) - soft)


def scale_of(raw):
    """Map an unconstrained learned parameter to a positive scale.

    ``exp`` keeps the scale positive; ``raw = 0`` gives scale 1 which is the
    natural init for activations normalized to [0, 1].
    """
    return jnp.exp(raw)


def quant_unsigned(x, raw_scale, beta: int):
    """Fake-quantize to unsigned beta-bit codes on [0, scale].

    Acts as the layer's activation (the clip is the non-linearity, as in
    LogicNets/Brevitas quantized ReLU). Returns dequantized float values.
    """
    levels = float(2**beta - 1)
    s = scale_of(raw_scale)
    u = leaky_clip(x / s, 0.0, 1.0)
    q = ste(lambda t: round_half_up(t * levels) / levels, u)
    return q * s


def quant_unsigned_code(x, raw_scale, beta: int):
    """Integer codes for ``quant_unsigned`` (conversion path, no STE)."""
    levels = float(2**beta - 1)
    s = scale_of(raw_scale)
    u = jnp.clip(x / s, 0.0, 1.0)
    return round_half_up(u * levels).astype(jnp.int32)


def dequant_unsigned_code(code, raw_scale, beta: int):
    """Inverse of ``quant_unsigned_code`` (exact on the code lattice)."""
    levels = float(2**beta - 1)
    return code.astype(jnp.float32) / levels * scale_of(raw_scale)


def quant_input(x, beta: int):
    """Quantize circuit inputs in [0, 1] with a fixed unit scale."""
    levels = float(2**beta - 1)
    u = jnp.clip(x, 0.0, 1.0)
    return ste(lambda t: round_half_up(t * levels) / levels, u)


def quant_input_code(x, beta: int):
    """Integer codes for the circuit inputs (what the fabric receives)."""
    levels = float(2**beta - 1)
    return round_half_up(jnp.clip(x, 0.0, 1.0) * levels).astype(jnp.int32)


def dequant_input_code(code, beta: int):
    levels = float(2**beta - 1)
    return code.astype(jnp.float32) / levels


def quant_signed(x, raw_scale, beta: int):
    """Fake-quantize logits to signed beta-bit codes on [-scale, scale]."""
    q_max = float(2 ** (beta - 1) - 1)
    s = scale_of(raw_scale)
    u = leaky_clip(x / s, -1.0, 1.0)
    q = ste(lambda t: round_half_up(t * q_max) / q_max, u)
    return q * s


def quant_signed_code(x, raw_scale, beta: int):
    """Signed integer codes (two's complement on the wire) for logits."""
    q_max = float(2 ** (beta - 1) - 1)
    s = scale_of(raw_scale)
    u = jnp.clip(x / s, -1.0, 1.0)
    return round_half_up(u * q_max).astype(jnp.int32)
