"""Pure-jnp oracles for the L-LUT neuron functions.

These are the correctness baselines for the Pallas kernel
(``kernels/subnet.py``): pytest asserts ``subnet_pallas == subnet_ref`` over
hypothesis-generated shape/topology sweeps, and the training path's backward
pass is derived from these functions via ``jax.vjp``.

Parameter layout for a circuit layer of M L-LUTs with topology
``SubnetTopo(F, L, N, S)`` — a flat list, stacked over the LUT axis:

    [w_1 (M,d0,d1), b_1 (M,d1), ..., w_L, b_L,
     rw_1 (M,c0,c1), rb_1 (M,c1), ..., rw_C, rb_C]        (C = L/S chunks)

PolyLUT layout: ``[w (M,P,1), b (M,1)]`` with P monomial features.
"""

from typing import List, Sequence

import jax.numpy as jnp

from .topo import PolyTopo, SubnetTopo


def split_params(params: Sequence, topo: SubnetTopo):
    """Split the flat stacked-param list into (affines, residuals)."""
    n_aff = topo.depth
    affines = [(params[2 * i], params[2 * i + 1]) for i in range(n_aff)]
    rest = params[2 * n_aff :]
    residuals = [
        (rest[2 * i], rest[2 * i + 1]) for i in range(topo.num_chunks())
    ]
    return affines, residuals


def subnet_ref(params: Sequence, x, topo: SubnetTopo):
    """Evaluate M stacked sub-networks: x [M, B, F] -> y [M, B].

    Implements paper eqs. (1)-(4): chunks of S affine layers with ReLU
    in-between, a parallel affine residual per chunk, ReLU *between* chunks
    but not after the last one. With S = 0 it is a plain MLP (ReLU between
    affines, none after the last).
    """
    affines, residuals = split_params(params, topo)

    def affine(h, w, b):
        # h [M, B, d_in] @ w [M, d_in, d_out] + b [M, d_out]
        return jnp.einsum("mbi,mio->mbo", h, w) + b[:, None, :]

    h = x
    if topo.skip == 0:
        for i, (w, b) in enumerate(affines):
            h = affine(h, w, b)
            if i + 1 < topo.depth:
                h = jnp.maximum(h, 0.0)
    else:
        s = topo.skip
        for c, (rw, rb) in enumerate(residuals):
            chunk_in = h
            for j in range(s):
                w, b = affines[c * s + j]
                h = affine(h, w, b)
                if j + 1 < s:
                    h = jnp.maximum(h, 0.0)
            h = h + affine(chunk_in, rw, rb)
            if c + 1 < topo.num_chunks():
                h = jnp.maximum(h, 0.0)
    return h[..., 0]


def poly_features(x, topo: PolyTopo):
    """Monomial expansion: x [M, B, F] -> phi [M, B, P]."""
    feats = []
    for e in topo.exponents():
        f = jnp.ones(x.shape[:-1], dtype=x.dtype)
        for i, p in enumerate(e):
            if p:
                f = f * x[..., i] ** p
        feats.append(f)
    return jnp.stack(feats, axis=-1)


def poly_ref(params: Sequence, x, topo: PolyTopo):
    """PolyLUT neuron: x [M, B, F] -> y [M, B]."""
    w, b = params
    phi = poly_features(x, topo)
    return (jnp.einsum("mbp,mpo->mbo", phi, w) + b[:, None, :])[..., 0]


def init_subnet_params(key, m: int, topo: SubnetTopo) -> List:
    """He-normal init of the stacked parameter list for M L-LUTs."""
    import jax

    params = []
    dims = topo.affine_dims() + topo.residual_dims()
    keys = jax.random.split(key, len(dims))
    for k, (di, do) in zip(keys, dims):
        std = (2.0 / di) ** 0.5
        params.append(jax.random.normal(k, (m, di, do), jnp.float32) * std)
        params.append(jnp.zeros((m, do), jnp.float32))
    return params


def init_poly_params(key, m: int, topo: PolyTopo) -> List:
    import jax

    p = topo.num_features()
    std = (2.0 / p) ** 0.5
    w = jax.random.normal(key, (m, p, 1), jnp.float32) * std
    return [w, jnp.zeros((m, 1), jnp.float32)]
