"""Layer-1 Pallas kernel: batched residual-MLP sub-network evaluation.

This is the compute hot-spot of NeuraLUT — every circuit layer evaluates M
independent sub-networks (one per L-LUT) on a batch B, both during training
and during truth-table conversion (where B = 2^(beta*F)).

Kernel structure (see DESIGN.md §8 for the TPU mapping):
  * grid = (M / M_TILE, B / B_TILE): one grid step owns a tile of LUTs and a
    tile of the batch;
  * per-LUT weights are fetched as whole blocks (VMEM-resident across the
    full depth-L chain — they are tiny), activations are streamed in batch
    tiles: the BlockSpec index maps express exactly the HBM<->VMEM schedule
    a GPU implementation would express with threadblocks;
  * the whole depth-L chain, including the residual accumulators, runs
    inside a single kernel invocation — no intermediate round-trips.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO that
the Rust runtime can run (see /opt/xla-example/README.md).

The public entry point ``subnet_apply`` wraps the Pallas forward in a
``jax.custom_vjp`` whose backward is derived from the pure-jnp oracle
(``ref.subnet_ref``) — the Pallas kernel stays on the training hot path
while gradients remain exact.
"""

import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import split_params, subnet_ref
from .topo import SubnetTopo

# Batch tile: kept modest so (B_TILE x max(F, N)) activations plus all
# weights of one LUT fit comfortably in VMEM-scale scratch (~16 KB here).
_B_TILE_MAX = 256


def _pick_b_tile(batch: int) -> int:
    """Largest divisor of ``batch`` not exceeding _B_TILE_MAX."""
    bt = min(batch, _B_TILE_MAX)
    while batch % bt != 0:
        bt -= 1
    return bt


def _subnet_kernel(topo: SubnetTopo, x_ref, *refs):
    """Pallas kernel body: one LUT x one batch tile per grid step."""
    o_ref = refs[-1]
    param_refs = refs[:-1]
    # x block: [1, B_TILE, F] -> [B_TILE, F]
    h = x_ref[0]
    n_aff = topo.depth

    def affine(v, i):
        w = param_refs[2 * i][0]  # [d_in, d_out]
        b = param_refs[2 * i + 1][0]  # [d_out]
        return v @ w + b[None, :]

    def residual(v, c):
        rw = param_refs[2 * n_aff + 2 * c][0]
        rb = param_refs[2 * n_aff + 2 * c + 1][0]
        return v @ rw + rb[None, :]

    if topo.skip == 0:
        for i in range(topo.depth):
            h = affine(h, i)
            if i + 1 < topo.depth:
                h = jnp.maximum(h, 0.0)
    else:
        s = topo.skip
        for c in range(topo.num_chunks()):
            chunk_in = h
            for j in range(s):
                h = affine(h, c * s + j)
                if j + 1 < s:
                    h = jnp.maximum(h, 0.0)
            h = h + residual(chunk_in, c)
            if c + 1 < topo.num_chunks():
                h = jnp.maximum(h, 0.0)
    o_ref[0] = h  # [B_TILE, 1]


def subnet_pallas(params: Sequence, x, topo: SubnetTopo):
    """Pallas evaluation of M stacked sub-networks: x [M, B, F] -> [M, B].

    Tiled schedule: grid over (LUT, batch-tile); this is the kernel as it
    would run on a real TPU (weights VMEM-resident per LUT, activations
    streamed in batch tiles)."""
    m, batch, f = x.shape
    assert f == topo.fan_in, (f, topo.fan_in)
    bt = _pick_b_tile(batch)
    grid = (m, batch // bt)

    in_specs = [
        pl.BlockSpec((1, bt, f), lambda i, j: (i, j, 0)),
    ]
    for p in params:
        if p.ndim == 3:
            in_specs.append(
                pl.BlockSpec((1, p.shape[1], p.shape[2]), lambda i, j: (i, 0, 0))
            )
        else:
            in_specs.append(pl.BlockSpec((1, p.shape[1]), lambda i, j: (i, 0)))

    out = pl.pallas_call(
        functools.partial(_subnet_kernel, topo),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bt, 1), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, batch, 1), x.dtype),
        interpret=True,
    )(x, *params)
    return out[..., 0]


def _subnet_kernel_whole(topo: SubnetTopo, x_ref, *refs):
    """Grid-free kernel body: all LUTs and the whole batch in one block."""
    o_ref = refs[-1]
    param_refs = refs[:-1]
    h = x_ref[...]  # [M, B, F]
    n_aff = topo.depth

    def affine(v, i):
        w = param_refs[2 * i][...]  # [M, d_in, d_out]
        b = param_refs[2 * i + 1][...]  # [M, d_out]
        return jnp.einsum("mbi,mio->mbo", v, w) + b[:, None, :]

    def residual(v, c):
        rw = param_refs[2 * n_aff + 2 * c][...]
        rb = param_refs[2 * n_aff + 2 * c + 1][...]
        return jnp.einsum("mbi,mio->mbo", v, rw) + rb[:, None, :]

    if topo.skip == 0:
        for i in range(topo.depth):
            h = affine(h, i)
            if i + 1 < topo.depth:
                h = jnp.maximum(h, 0.0)
    else:
        s = topo.skip
        for c in range(topo.num_chunks()):
            chunk_in = h
            for j in range(s):
                h = affine(h, c * s + j)
                if j + 1 < s:
                    h = jnp.maximum(h, 0.0)
            h = h + residual(chunk_in, c)
            if c + 1 < topo.num_chunks():
                h = jnp.maximum(h, 0.0)
    o_ref[...] = h


def subnet_pallas_single(params: Sequence, x, topo: SubnetTopo):
    """Grid-free Pallas evaluation (one block holds everything).

    An alternative AOT schedule kept for ablation and as a fallback: the
    whole (M, B, F) problem is a single kernel invocation, trading the
    tiled schedule's VMEM locality for the simplest possible lowering.
    (Historical note: this also served as the workaround while bisecting
    the HLO-text constant-elision bug — see ``aot.to_hlo_text``.)
    """
    out = pl.pallas_call(
        functools.partial(_subnet_kernel_whole, topo),
        out_shape=jax.ShapeDtypeStruct((*x.shape[:2], 1), x.dtype),
        interpret=True,
    )(x, *params)
    return out[..., 0]


def subnet_apply(params: List, x, topo: SubnetTopo, *,
                 single_block: bool = False):
    """Training/inference entry point: Pallas forward, oracle-derived vjp.

    ``params`` is the flat stacked list (see ``ref.py``); returns [M, B].
    ``single_block=True`` selects the grid-free schedule (AOT lowering).
    """
    n = len(params)
    fwd_impl = subnet_pallas_single if single_block else subnet_pallas

    @jax.custom_vjp
    def _apply(*args):
        ps, xx = list(args[:n]), args[n]
        return fwd_impl(ps, xx, topo)

    def _fwd(*args):
        return _apply(*args), args

    def _bwd(res, g):
        ps, xx = list(res[:n]), res[n]
        _, vjp = jax.vjp(lambda p, v: subnet_ref(p, v, topo), ps, xx)
        dp, dx = vjp(g)
        return (*dp, dx)

    _apply.defvjp(_fwd, _bwd)
    return _apply(*params, x)
