"""Sub-network topology descriptors shared by the Pallas kernel, the jnp
reference oracle, the model builder, and (via the manifest) the Rust side.

A NeuraLUT L-LUT hides a residual MLP ``N`` (paper §III-C) characterised by
  * ``fan_in``  (F): number of (quantized) inputs, n_0 = F,
  * ``depth``   (L): number of affine layers A_1..A_L,
  * ``width``   (N): width of every hidden layer (n_1..n_{L-1} = N, n_L = 1),
  * ``skip``    (S): residual period; S = 0 means no skip connections,
                     otherwise L must be a multiple of S and chunk i carries
                     a parallel affine residual R_i (paper eq. (2)).

PolyLUT baselines use ``PolyTopo``: a single affine over the monomial
expansion of the F inputs up to degree D (constant term folded into bias).
"""

import itertools
from dataclasses import dataclass
from typing import List, Tuple


@dataclass(frozen=True)
class SubnetTopo:
    """Residual-MLP topology hidden inside one L-LUT."""

    fan_in: int
    depth: int  # L
    width: int  # N
    skip: int  # S; 0 = no residual connections

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError("depth (L) must be >= 1")
        if self.skip < 0:
            raise ValueError("skip (S) must be >= 0")
        if self.skip > 0 and self.depth % self.skip != 0:
            raise ValueError(f"L={self.depth} must be a multiple of S={self.skip}")

    def layer_widths(self) -> List[int]:
        """[n_0, n_1, ..., n_L] with n_0 = F, hidden = N, n_L = 1."""
        return [self.fan_in] + [self.width] * (self.depth - 1) + [1]

    def affine_dims(self) -> List[Tuple[int, int]]:
        """(d_in, d_out) of A_1..A_L."""
        w = self.layer_widths()
        return list(zip(w[:-1], w[1:]))

    def residual_dims(self) -> List[Tuple[int, int]]:
        """(d_in, d_out) of R_1..R_{L/S}; empty when S = 0."""
        if self.skip == 0:
            return []
        w = self.layer_widths()
        c = self.depth // self.skip
        return [(w[self.skip * (i - 1)], w[self.skip * i]) for i in range(1, c + 1)]

    def num_chunks(self) -> int:
        return 0 if self.skip == 0 else self.depth // self.skip

    def param_count(self) -> int:
        """Exact trainable-parameter count T_N = T_A + T_R (paper eq. (7))."""
        t = sum(di * do + do for di, do in self.affine_dims())
        t += sum(di * do + do for di, do in self.residual_dims())
        return t

    def param_count_formula(self) -> int:
        """Closed-form T_A + T_R from paper eqs. (5)+(6); must equal
        ``param_count()`` — checked by tests on both sides of the stack."""
        F, L, N = self.fan_in, self.depth, self.width

        def t_a(depth: int) -> int:
            if depth == 1:
                return F * 1 + 1
            if depth == 2:
                return (F + 2) * N + 1
            return (depth - 2) * N * N + (F + depth) * N + 1

        total = t_a(L)
        if self.skip > 0:
            c = L // self.skip
            if c == 1:
                total += F + 1
            elif c == 2:
                total += (F + 2) * N + 1
            else:
                total += (c - 2) * N * N + (F + c) * N + 1
        return total


@dataclass(frozen=True)
class PolyTopo:
    """PolyLUT-style multivariate-polynomial neuron (baseline, [7])."""

    fan_in: int
    degree: int  # D

    def exponents(self) -> List[Tuple[int, ...]]:
        """All monomial exponent tuples with 1 <= total degree <= D,
        in deterministic lexicographic order (constant term excluded —
        it folds into the bias)."""
        exps = []
        for total in range(1, self.degree + 1):
            for c in itertools.combinations_with_replacement(
                range(self.fan_in), total
            ):
                e = [0] * self.fan_in
                for i in c:
                    e[i] += 1
                exps.append(tuple(e))
        return exps

    def num_features(self) -> int:
        return len(self.exponents())

    def param_count(self) -> int:
        return self.num_features() + 1  # weights + bias
