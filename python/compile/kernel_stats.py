"""L1 kernel performance model: VMEM footprint + MXU-utilization estimates
for the tiled Pallas schedule (DESIGN.md §8).

``interpret=True`` wallclock is CPU-numpy time and NOT a TPU proxy, so the
optimization target for Layer 1 is structural: per-grid-step VMEM working
set (must sit far below the ~16 MiB/core budget) and the MXU occupancy of
the sub-network matmuls once padded to the 128x128 systolic array
(8x128 lanes per pass, bf16).

Usage:  cd python && python -m compile.kernel_stats [config ...]
"""

import sys
from dataclasses import dataclass

from . import configs, model
from .kernels.subnet import _B_TILE_MAX, _pick_b_tile
from .kernels.topo import PolyTopo, SubnetTopo

MXU_DIM = 128  # systolic array edge (TPU v4-style)
VPU_LANES = 8 * 128  # vector unit shape
VMEM_BYTES = 16 * 1024 * 1024
BF16 = 2  # bytes


@dataclass
class KernelStats:
    """Per-grid-step structural stats of the tiled subnet kernel."""

    config: str
    layer: int
    b_tile: int
    weight_bytes: int  # all affine+residual blocks of one LUT (VMEM-resident)
    act_bytes: int  # activation tile in/out + widest intermediate
    vmem_bytes: int
    flops_per_step: int  # 2 * MACs for one (LUT, batch-tile) grid step
    mxu_utilization: float  # useful MACs / padded-systolic MACs
    # Per-LUT matmuls are tiny (F, N << 128): the MXU is the wrong engine.
    # Packing LUTs along the 128-lane axis runs them on the VPU instead;
    # this is the lane occupancy of an (M_pack x N)-wide FMA sweep.
    vpu_utilization: float

    def report(self) -> str:
        return (
            f"{self.config:<22} layer {self.layer}: B_tile {self.b_tile:>4} "
            f"VMEM {self.vmem_bytes / 1024:7.1f} KiB "
            f"({100 * self.vmem_bytes / VMEM_BYTES:5.2f}% of budget)  "
            f"MXU util {100 * self.mxu_utilization:5.1f}% | VPU (lane-packed) "
            f"{100 * self.vpu_utilization:5.1f}%"
        )


def _matmul_stats(b, k, n):
    """(useful MACs, padded MACs) of a [b,k]x[k,n] product on the MXU."""
    useful = b * k * n
    pad = lambda x: -(-x // MXU_DIM) * MXU_DIM
    padded = pad(b) * pad(k) * pad(n)
    return useful, padded


def _vpu_utilization(cfg, layer, widths) -> float:
    """Lane occupancy when packing LUTs along the 128-lane axis: per FMA
    sweep, min(M, lanes//N) LUTs of width N are live."""
    m = cfg.layers[layer]
    n = max(w for w in widths[1:-1]) if len(widths) > 2 else widths[-1]
    n = max(n, 1)
    packed = min(m, max(VPU_LANES // n, 1))
    return min(1.0, packed * n / VPU_LANES)


def stats_for(cfg, layer: int, batch: int) -> KernelStats:
    topo = model.layer_topo(cfg, layer)
    bt = _pick_b_tile(batch)
    if isinstance(topo, PolyTopo):
        dims = [(topo.num_features(), 1)]
        widths = [topo.num_features(), 1]
    else:
        dims = topo.affine_dims() + topo.residual_dims()
        widths = topo.layer_widths()
    weight_bytes = sum((di * do + do) * BF16 for di, do in dims)
    act_bytes = bt * (max(widths) + widths[0] + widths[-1]) * BF16
    useful = padded = 0
    for di, do in dims:
        u, p = _matmul_stats(bt, di, do)
        useful += u
        padded += p
    return KernelStats(
        config=cfg.name,
        layer=layer,
        b_tile=bt,
        weight_bytes=weight_bytes,
        act_bytes=act_bytes,
        vmem_bytes=weight_bytes + act_bytes,
        flops_per_step=2 * useful,
        mxu_utilization=useful / padded if padded else 0.0,
        vpu_utilization=_vpu_utilization(cfg, layer, widths),
    )


def all_stats(cfg):
    """Stats for every circuit layer at both training batch and the
    truth-table enumeration batch (the two kernel workloads)."""
    out = []
    for l in range(len(cfg.layers)):
        out.append(stats_for(cfg, l, cfg.batch))
        out.append(stats_for(cfg, l, cfg.tt_entries(l)))
    return out


def main():
    names = sys.argv[1:] or ["hdr-mini", "jsc-2l", "jsc-5l"]
    for name in names:
        cfg = configs.get(name)
        print(f"== {name} (batch {cfg.batch}, tt up to "
              f"{max(cfg.tt_entries(l) for l in range(len(cfg.layers)))} "
              f"entries) ==")
        for s in all_stats(cfg):
            print("  " + s.report())
        worst = max(all_stats(cfg), key=lambda s: s.vmem_bytes)
        assert worst.vmem_bytes < VMEM_BYTES, "schedule exceeds VMEM budget"
        print(f"  worst-case VMEM {worst.vmem_bytes / 1024:.1f} KiB — "
              f"{VMEM_BYTES // worst.vmem_bytes}x headroom; the schedule is "
              f"activation-streaming-bound, matching DESIGN.md §8.\n")


if __name__ == "__main__":
    main()
