"""Layer-2 training step: cross-entropy + AdamW (decoupled weight decay).

The paper trains with Decoupled Weight Decay Regularization [23] and SGDR
warm restarts [24]. The *schedule* lives in Rust (the coordinator owns the
per-step learning rate and passes it in as a scalar); the *step math* lives
here and is lowered once to ``train_step.hlo.txt``.

BatchNorm running statistics ride in the flat parameter list: the optimizer
skips them and the step updates them by EMA from the batch statistics
instead (``model.bn_stat_indices``).

Flat ABI (order mirrored in manifest.json):
    inputs : params..., m..., v..., step(f32), lr(f32), x[B,in], y[B](i32)
    outputs: params'..., m'..., v'..., loss(f32), acc(f32)
"""

from typing import List, Sequence

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .model import bn_stat_indices, forward, no_decay_indices

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
BN_MOMENTUM = 0.1  # EMA weight of the current batch's statistics


def loss_fn(cfg: ModelConfig, params: Sequence, x, y, indices, *,
            train: bool, use_pallas: bool = True):
    """Mean softmax cross-entropy on the (dequantized) logits.

    Returns (loss, (acc, bn_stats))."""
    logits, stats = forward(cfg, params, x, indices, train=train,
                            use_pallas=use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    # One-hot cross-entropy instead of take_along_axis: label gathers have
    # the same HLO-text round-trip hazard as the wiring gather (see
    # model.sparse_gather) — iota/compare/dot are version-stable.
    onehot = (jnp.arange(logits.shape[-1], dtype=jnp.int32)[None, :]
              == y[:, None]).astype(logp.dtype)
    nll = -jnp.sum(logp * onehot, axis=-1)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), (acc, stats)


def train_step(cfg: ModelConfig, params: List, m: List, v: List, step, lr,
               x, y, indices, *, use_pallas: bool = True):
    """One AdamW step; returns (params', m', v', loss, acc)."""
    (loss, (acc, stats)), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, x, y, indices, train=True,
                          use_pallas=use_pallas),
        has_aux=True,
    )(params)

    no_decay = set(no_decay_indices(cfg))
    bn_stats = bn_stat_indices(cfg)
    # bn_stats come in (mean, var) pairs, one pair per circuit layer, and
    # stats[l] = (mu_l, var_l) from the batch.
    ema_target = {}
    for l, pair in enumerate(stats):
        mu, var = pair
        ema_target[bn_stats[2 * l]] = mu
        ema_target[bn_stats[2 * l + 1]] = var

    b1, b2 = ADAM_B1, ADAM_B2
    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step
    new_p, new_m, new_v = [], [], []
    for i, (p, g, mi, vi) in enumerate(zip(params, grads, m, v)):
        if i in ema_target:
            # BN running stats: EMA update, optimizer state untouched.
            tgt = jax.lax.stop_gradient(ema_target[i])
            new_p.append((1.0 - BN_MOMENTUM) * p + BN_MOMENTUM * tgt)
            new_m.append(mi)
            new_v.append(vi)
            continue
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * g * g
        update = (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        if i not in no_decay:
            update = update + cfg.weight_decay * p
        new_p.append(p - lr * update)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, loss, acc


def sgdr_lr(cfg: ModelConfig, step: int, steps_per_epoch: int) -> float:
    """Reference SGDR (cosine with warm restarts) schedule.

    The Rust coordinator implements the identical function
    (``coordinator::schedule``) — this copy exists for tests and for
    documentation of the contract."""
    import math

    t0 = cfg.sgdr_t0 * steps_per_epoch
    mult = cfg.sgdr_mult
    t, period = step, t0
    while t >= period:
        t -= period
        period *= mult
    frac = t / max(period, 1)
    return cfg.lr_min + 0.5 * (cfg.lr_max - cfg.lr_min) * (
        1.0 + math.cos(math.pi * frac)
    )
