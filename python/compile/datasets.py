"""Synthetic dataset generators + the binary blob format shared with Rust.

The paper evaluates on (a) the CERN jet-substructure tagging dataset
(16 features, 5 classes) and (b) MNIST. Neither is available offline, so we
generate *synthetic equivalents* that exercise identical code paths and the
same learnability regime (DESIGN.md §5):

  * ``jsc``      — 16-feature, 5-class Gaussian-mixture with class-conditional
                   covariance and a tanh feature warp; class overlap tuned so
                   strong models land around the paper's 72–76 % band.
  * ``digits``   — procedural 14x14 handwritten-digit lookalikes: 7x5 stroke
                   glyphs with random offset, thickness dilation, pixel noise
                   and dropout.
  * ``digits28`` — the same renderer at 28x28 (paper-exact input size).
  * ``moons``    — the two-semicircles toy task of Fig. 3.

Blob format (little-endian), read by ``rust/src/data``:
    magic   u32 = 0x4E4C4453  ("NLDS")
    version u32 = 1
    n_train u32, n_test u32, n_feat u32, n_class u32
    train_x f32[n_train * n_feat]   (row-major, values in [0, 1])
    train_y i32[n_train]
    test_x  f32[n_test * n_feat]
    test_y  i32[n_test]
"""

import os
import struct

import numpy as np

MAGIC = 0x4E4C4453
VERSION = 1

# 7x5 stroke glyphs for digits 0-9 (classic bitmap font).
_GLYPHS = [
    ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],  # 0
    ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],  # 1
    ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],  # 2
    ["01110", "10001", "00001", "00110", "00001", "10001", "01110"],  # 3
    ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],  # 4
    ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],  # 5
    ["01110", "10000", "10000", "11110", "10001", "10001", "01110"],  # 6
    ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],  # 7
    ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],  # 8
    ["01110", "10001", "10001", "01111", "00001", "00001", "01110"],  # 9
]


def _glyph_array(d: int) -> np.ndarray:
    return np.array([[int(c) for c in row] for row in _GLYPHS[d]], np.float32)


def make_moons(seed: int, n_train: int = 2000, n_test: int = 1000):
    """Two interleaved semicircles with Gaussian noise, normalized to [0,1]."""
    rng = np.random.default_rng(seed)

    def sample(n):
        y = rng.integers(0, 2, n)
        theta = rng.uniform(0, np.pi, n)
        x = np.where(y == 0, np.cos(theta), 1.0 - np.cos(theta))
        z = np.where(y == 0, np.sin(theta), 0.5 - np.sin(theta))
        pts = np.stack([x, z], axis=1) + rng.normal(0, 0.12, (n, 2))
        return pts.astype(np.float32), y.astype(np.int32)

    xtr, ytr = sample(n_train)
    xte, yte = sample(n_test)
    lo = np.array([-1.4, -1.7], np.float32)
    hi = np.array([2.4, 1.7], np.float32)
    xtr = np.clip((xtr - lo) / (hi - lo), 0, 1)
    xte = np.clip((xte - lo) / (hi - lo), 0, 1)
    return xtr, ytr, xte, yte


def make_jsc(seed: int, n_train: int = 30000, n_test: int = 10000):
    """Synthetic jet-substructure stand-in: 16 features, 5 classes.

    Per class: latent z ~ N(0, I_6) pushed through a class-specific affine
    map + tanh warp, with a shared nuisance subspace and heteroscedastic
    noise creating controlled class overlap (gluon/quark-style confusion)."""
    rng = np.random.default_rng(seed)
    n_feat, n_class, n_lat = 16, 5, 6
    # Class separation / noise tuned so the *achievable* accuracy ceiling
    # sits in the paper's 72-76 % band (quark/gluon-style confusion).
    means = rng.normal(0, 0.72, (n_class, n_feat))
    maps = rng.normal(0, 0.5, (n_class, n_lat, n_feat))
    shared = rng.normal(0, 0.95, (n_lat, n_feat))  # nuisance directions
    noise_scale = rng.uniform(0.45, 0.8, n_class)

    def sample(n):
        y = rng.integers(0, n_class, n).astype(np.int32)
        z = rng.normal(0, 1, (n, n_lat)).astype(np.float32)
        zn = rng.normal(0, 1, (n, n_lat)).astype(np.float32)
        x = means[y] + np.einsum("nl,nlf->nf", z, maps[y])
        x = np.tanh(0.8 * x) * 2.0 + zn @ shared * 0.45
        x = x + rng.normal(0, 1, x.shape) * noise_scale[y][:, None]
        return x.astype(np.float32), y

    xtr, ytr = sample(n_train)
    xte, yte = sample(n_test)
    lo, hi = np.quantile(xtr, 0.001, axis=0), np.quantile(xtr, 0.999, axis=0)
    xtr = np.clip((xtr - lo) / (hi - lo), 0, 1).astype(np.float32)
    xte = np.clip((xte - lo) / (hi - lo), 0, 1).astype(np.float32)
    return xtr, ytr, xte, yte


def make_digits(seed: int, side: int = 14, n_train: int = 12000,
                n_test: int = 2000):
    """Procedural digit classification at ``side`` x ``side`` resolution."""
    rng = np.random.default_rng(seed)
    scale = side // 7  # glyph upscale factor (14 -> 2, 28 -> 4)
    gh, gw = 7 * scale, 5 * scale

    def sample(n):
        y = rng.integers(0, 10, n).astype(np.int32)
        imgs = np.zeros((n, side, side), np.float32)
        for i in range(n):
            g = np.kron(_glyph_array(y[i]), np.ones((scale, scale), np.float32))
            if rng.random() < 0.35:  # thickness dilation
                d = np.zeros_like(g)
                d[:, 1:] = np.maximum(d[:, 1:], g[:, :-1])
                d[1:, :] = np.maximum(d[1:, :], g[:-1, :])
                g = np.maximum(g, d * 0.9)
            oy = rng.integers(0, side - gh + 1)
            ox = rng.integers(0, side - gw + 1)
            img = imgs[i]
            img[oy : oy + gh, ox : ox + gw] = g * rng.uniform(0.75, 1.0)
            img += rng.normal(0, 0.10, img.shape).astype(np.float32)
            drop = rng.random(img.shape) < 0.04  # dead pixels
            img[drop] = 0.0
        return np.clip(imgs, 0, 1).reshape(n, side * side), y

    xtr, ytr = sample(n_train)
    xte, yte = sample(n_test)
    return xtr, ytr, xte, yte


GENERATORS = {
    "moons": lambda seed: make_moons(seed),
    "jsc": lambda seed: make_jsc(seed),
    "digits": lambda seed: make_digits(seed, side=14),
    "digits28": lambda seed: make_digits(seed, side=28, n_train=8000),
}


def write_blob(path: str, xtr, ytr, xte, yte, n_class: int):
    """Serialize a dataset in the NLDS v1 binary format."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        f.write(struct.pack("<IIIIII", MAGIC, VERSION, xtr.shape[0],
                            xte.shape[0], xtr.shape[1], n_class))
        f.write(np.ascontiguousarray(xtr, np.float32).tobytes())
        f.write(np.ascontiguousarray(ytr, np.int32).tobytes())
        f.write(np.ascontiguousarray(xte, np.float32).tobytes())
        f.write(np.ascontiguousarray(yte, np.int32).tobytes())


N_CLASS = {"moons": 2, "jsc": 5, "digits": 10, "digits28": 10}


def build_all(out_dir: str, seed: int = 2024, names=None):
    """Generate every dataset blob under ``out_dir`` (idempotent by seed)."""
    for name in names or GENERATORS:
        xtr, ytr, xte, yte = GENERATORS[name](seed)
        write_blob(os.path.join(out_dir, f"{name}.bin"), xtr, ytr, xte, yte,
                   N_CLASS[name])
