"""Layer-2: the circuit-level NeuraLUT model in JAX.

A model is a sparse directed graph of L-LUTs. Layer ``l`` has ``M_l`` L-LUTs;
each L-LUT reads ``F`` distinct outputs of layer ``l-1`` (a-priori random
sparsity, LogicNets-style) as ``beta``-bit quantized values, evaluates its
hidden neuron function (residual MLP / linear / polynomial — see
``kernels/``), and emits one ``beta``-bit quantized output. Quantization uses
learned per-layer scales (``quant.py``); everything between the quantized
boundaries is full-precision, exactly as in the paper.

The same forward is used for QAT training, for evaluation, and (per-layer)
for truth-table conversion, which is what makes the L-LUT conversion exact.

As in the paper (§III-E1), the output of every sub-network passes through
BatchNorm and then a learned-scale quantizer. BN uses batch statistics while
training and EMA running statistics at eval/conversion time; the running
stats ride in the flat parameter list (they are state, not weights — the
train step updates them by EMA and the optimizer skips them).

Parameter order (the flat ABI shared with Rust via manifest.json):
    for each circuit layer l:
        l{l}.w1, l{l}.b1, ..., l{l}.wL, l{l}.bL,      (affines)
        l{l}.rw1, l{l}.rb1, ...,                       (residuals, S > 0)
        l{l}.bn_gamma, l{l}.bn_beta,                   (BN affine, [M])
        l{l}.bn_mean, l{l}.bn_var,                     (BN running stats, [M])
        l{l}.scale                                     (raw quant scale, [])
PolyLUT layers contribute ``l{l}.w, l{l}.b, <bn...>, l{l}.scale``.
"""

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quant
from .configs import ModelConfig
from .kernels.ref import (
    init_poly_params,
    init_subnet_params,
    poly_ref,
    subnet_ref,
)
from .kernels.subnet import subnet_apply
from .kernels.topo import PolyTopo, SubnetTopo


def layer_topo(cfg: ModelConfig, layer: int):
    """Neuron topology of circuit layer ``layer`` for the config's mode."""
    f = cfg.layer_fan_in(layer)
    if cfg.mode == "neuralut":
        return SubnetTopo(f, cfg.sub_depth, cfg.sub_width, cfg.sub_skip)
    if cfg.mode == "logicnets":
        return SubnetTopo(f, 1, 1, 0)
    if cfg.mode == "polylut":
        return PolyTopo(f, cfg.degree)
    raise ValueError(f"unknown mode {cfg.mode}")


def build_sparsity(cfg: ModelConfig) -> List[np.ndarray]:
    """A-priori random sparsity: per layer, an [M, F] index matrix selecting
    F *distinct* inputs for each L-LUT from the previous layer's outputs.

    Seeded by ``cfg.mask_seed`` only, so the wiring is a property of the
    config (stable across training seeds and across the manifest)."""
    rng = np.random.default_rng(cfg.mask_seed)
    indices = []
    prev = cfg.input_size
    for l, m in enumerate(cfg.layers):
        f = cfg.layer_fan_in(l)
        idx = np.stack(
            [rng.choice(prev, size=f, replace=False) for _ in range(m)]
        ).astype(np.int32)
        indices.append(idx)
        prev = m
    return indices


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) of every flat parameter — the shared ABI."""
    spec: List[Tuple[str, Tuple[int, ...]]] = []
    for l, m in enumerate(cfg.layers):
        topo = layer_topo(cfg, l)
        if isinstance(topo, PolyTopo):
            spec.append((f"l{l}.w", (m, topo.num_features(), 1)))
            spec.append((f"l{l}.b", (m, 1)))
        else:
            for i, (di, do) in enumerate(topo.affine_dims(), start=1):
                spec.append((f"l{l}.w{i}", (m, di, do)))
                spec.append((f"l{l}.b{i}", (m, do)))
            for i, (di, do) in enumerate(topo.residual_dims(), start=1):
                spec.append((f"l{l}.rw{i}", (m, di, do)))
                spec.append((f"l{l}.rb{i}", (m, do)))
        spec.append((f"l{l}.bn_gamma", (m,)))
        spec.append((f"l{l}.bn_beta", (m,)))
        spec.append((f"l{l}.bn_mean", (m,)))
        spec.append((f"l{l}.bn_var", (m,)))
        spec.append((f"l{l}.scale", ()))
    return spec


def scale_param_indices(cfg: ModelConfig) -> List[int]:
    """Flat indices of the per-layer raw-scale parameters."""
    return [i for i, (n, _) in enumerate(param_spec(cfg)) if n.endswith(".scale")]


def bn_stat_indices(cfg: ModelConfig) -> List[int]:
    """Flat indices of BN running statistics (state, not weights: the
    optimizer skips them; the train step updates them by EMA)."""
    return [
        i for i, (n, _) in enumerate(param_spec(cfg))
        if n.endswith(".bn_mean") or n.endswith(".bn_var")
    ]


def no_decay_indices(cfg: ModelConfig) -> List[int]:
    """Parameters excluded from decoupled weight decay (scales + BN)."""
    return [
        i for i, (n, _) in enumerate(param_spec(cfg))
        if ".bn_" in n or n.endswith(".scale")
    ]


# Number of trailing non-neuron params per layer: bn (4) + scale (1).
_LAYER_TAIL = 5


def layer_param_slices(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """(start, end) flat-index range of each circuit layer's parameters
    (BN + scale included at the end of the range)."""
    slices = []
    start = 0
    for l, _ in enumerate(cfg.layers):
        topo = layer_topo(cfg, l)
        if isinstance(topo, PolyTopo):
            n = 2
        else:
            n = 2 * (len(topo.affine_dims()) + len(topo.residual_dims()))
        slices.append((start, start + n + _LAYER_TAIL))
        start += n + _LAYER_TAIL
    return slices


def init_params(cfg: ModelConfig, seed) -> List:
    """Initialise the flat parameter list from an (optionally traced) i32
    seed — lowered to ``init.hlo.txt`` so Rust owns per-run seeding."""
    key = jax.random.PRNGKey(seed)
    params: List = []
    for l, m in enumerate(cfg.layers):
        key, sub = jax.random.split(key)
        topo = layer_topo(cfg, l)
        if isinstance(topo, PolyTopo):
            params.extend(init_poly_params(sub, m, topo))
        else:
            params.extend(init_subnet_params(sub, m, topo))
        params.append(jnp.ones((m,), jnp.float32))  # bn_gamma
        params.append(0.3 * jnp.ones((m,), jnp.float32))  # bn_beta (shifts
        # post-BN mass into the unsigned quantizer's [0, s] pass band)
        params.append(jnp.zeros((m,), jnp.float32))  # bn_mean
        params.append(jnp.ones((m,), jnp.float32))  # bn_var
        params.append(jnp.zeros((), jnp.float32))  # raw scale -> scale = 1
    return params


BN_EPS = 1e-5


def batch_norm(y, gamma, beta, mean, var, *, train: bool):
    """Per-neuron BatchNorm over the batch axis of y [B, M].

    ``train=True`` normalizes with batch statistics and returns the batch
    stats for the EMA update; ``train=False`` uses the running stats (the
    exact arithmetic the truth-table conversion replays)."""
    if train:
        mu = jnp.mean(y, axis=0)
        sig2 = jnp.var(y, axis=0)
    else:
        mu, sig2 = mean, var
    yn = (y - mu[None, :]) / jnp.sqrt(sig2[None, :] + BN_EPS)
    out = gamma[None, :] * yn + beta[None, :]
    if train:
        return out, (mu, sig2)
    return out, None


def _neuron_apply(cfg: ModelConfig, topo, layer_params: Sequence, x, *,
                  use_pallas):
    """Evaluate one circuit layer's stacked neurons: x [M, B, F] -> [M, B].

    ``use_pallas``: False (jnp oracle), True (tiled Pallas kernel), or
    ``"single"`` (grid-free Pallas — the AOT-safe schedule, see
    ``kernels/subnet.py``)."""
    if isinstance(topo, PolyTopo):
        return poly_ref(layer_params, x, topo)
    if use_pallas:
        return subnet_apply(list(layer_params), x, topo,
                            single_block=use_pallas == "single")
    return subnet_ref(layer_params, x, topo)


def layer_apply(cfg: ModelConfig, layer: int, layer_params: Sequence, g, *,
                train: bool, use_pallas: bool):
    """One circuit layer on gathered inputs g [M, B, F] -> quantized [B, M].

    ``layer_params`` is the manifest slice for the layer:
    neuron params..., bn_gamma, bn_beta, bn_mean, bn_var, raw_scale.
    Returns (quantized activations [B, M], batch BN stats or None).
    This single code path serves training, evaluation *and* (via ``tt.py``)
    truth-table conversion — the root of the bit-exactness invariant.
    """
    topo = layer_topo(cfg, layer)
    *lp, gamma, beta, mean, var, raw_scale = layer_params
    y = _neuron_apply(cfg, topo, lp, g, use_pallas=use_pallas)
    y = jnp.transpose(y)  # [B, M]
    y, stats = batch_norm(y, gamma, beta, mean, var, train=train)
    if layer == len(cfg.layers) - 1:
        out = quant.quant_signed(y, raw_scale, cfg.layer_out_bits(layer))
    else:
        out = quant.quant_unsigned(y, raw_scale, cfg.beta)
    return out, stats


def sparse_gather(a, idx_np: np.ndarray):
    """Gather a [B, P] -> [M, B, F] through a one-hot matmul.

    The sparsity indices are compile-time constants, so the gather is
    expressed as ``a @ onehot`` built from iota + compare + dot. Two reasons
    over ``a[:, idx]``: (1) XLA `gather` round-trips unreliably through HLO
    text into the pinned xla_extension 0.5.1 runtime (observed: wiring
    silently degraded to natural order), while iota/compare/dot are stable
    across versions; (2) on real TPUs this *is* the idiomatic lowering — a
    sparse gather feeding the MXU becomes a one-hot matmul.
    """
    m, f = idx_np.shape
    p = a.shape[1]
    idx = jnp.asarray(idx_np.reshape(-1), dtype=jnp.int32)  # [M*F]
    onehot = (jnp.arange(p, dtype=jnp.int32)[:, None] == idx[None, :]).astype(
        a.dtype
    )  # [P, M*F]
    g = a @ onehot  # [B, M*F] — exact: one unit entry per column
    return jnp.transpose(g.reshape(a.shape[0], m, f), (1, 0, 2))


def forward(cfg: ModelConfig, params: Sequence, x, indices: List[np.ndarray],
            *, train: bool = False, use_pallas: bool = True):
    """Quantized forward pass: x [B, input_size] in [0,1] -> logits [B, C].

    Returns (logits, bn_batch_stats) where the stats list (one (mu, var)
    per layer) is non-None only when ``train=True``.
    """
    slices = layer_param_slices(cfg)
    a = quant.quant_input(x, cfg.layer_in_bits(0))
    all_stats = []
    for l in range(len(cfg.layers)):
        lo, hi = slices[l]
        g = sparse_gather(a, np.asarray(indices[l]))  # [M, B, F]
        a, stats = layer_apply(cfg, l, params[lo:hi], g,
                               train=train, use_pallas=use_pallas)
        all_stats.append(stats)
    return a, (all_stats if train else None)
