"""L2 model tests: quantizers, BN, sparsity, forward shapes, training step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model, quant, train
from compile.configs import ModelConfig


def tiny_cfg(**over):
    base = dict(
        name="t", dataset="moons", input_size=4, n_class=2,
        layers=(4, 2), beta=2, fan_in=2, mode="neuralut",
        sub_depth=2, sub_width=4, sub_skip=0, batch=8, epochs=1,
    )
    base.update(over)
    return ModelConfig(**base)


# ---------------------------------------------------------------- quantizers

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.floats(-3, 3), st.floats(-1, 1))
def test_quant_unsigned_lands_on_lattice(beta, x, raw):
    y = float(quant.quant_unsigned(jnp.float32(x), jnp.float32(raw), beta))
    s = float(np.exp(np.float32(raw)))
    levels = 2**beta - 1
    code = round(y / s * levels)
    assert abs(y - code / levels * s) < 1e-5
    assert 0 <= code <= levels


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.floats(-3, 3), st.floats(-1, 1))
def test_signed_code_dequant_argmax_consistent(beta, x, raw):
    code = int(quant.quant_signed_code(jnp.float32(x), jnp.float32(raw), beta))
    q = 2 ** (beta - 1) - 1
    assert -q <= code <= q
    # quant_signed value equals code * s / q
    y = float(quant.quant_signed(jnp.float32(x), jnp.float32(raw), beta))
    s = float(np.exp(np.float32(raw)))
    assert abs(y - code * s / q) < 1e-5


def test_round_half_up_is_not_bankers():
    # 0.5 -> 1 (bankers rounding would give 0)
    assert float(quant.round_half_up(jnp.float32(0.5))) == 1.0
    assert float(quant.round_half_up(jnp.float32(1.5))) == 2.0
    assert float(quant.round_half_up(jnp.float32(-0.5))) == 0.0


def test_leaky_clip_forward_is_hard_clip():
    xs = jnp.array([-5.0, -0.1, 0.0, 0.4, 1.0, 7.3])
    np.testing.assert_array_equal(
        quant.leaky_clip(xs, 0.0, 1.0), jnp.clip(xs, 0.0, 1.0)
    )


def test_leaky_clip_gradient_leaks():
    g = jax.grad(lambda x: quant.leaky_clip(x, 0.0, 1.0))(5.0)
    assert abs(g - quant.LEAK) < 1e-6
    g_in = jax.grad(lambda x: quant.leaky_clip(x, 0.0, 1.0))(0.5)
    assert abs(g_in - 1.0) < 1e-6


# ---------------------------------------------------------------- sparsity

def test_sparsity_is_deterministic_and_distinct():
    cfg = tiny_cfg()
    a = model.build_sparsity(cfg)
    b = model.build_sparsity(cfg)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    for l, idx in enumerate(a):
        prev = cfg.input_size if l == 0 else cfg.layers[l - 1]
        assert idx.shape == (cfg.layers[l], cfg.layer_fan_in(l))
        for row in idx:
            assert len(set(row.tolist())) == len(row)
            assert row.max() < prev


def test_fan_in_clamped_to_available_inputs():
    cfg = tiny_cfg(input_size=2, fan_in=6)
    assert cfg.layer_fan_in(0) == 2
    idx = model.build_sparsity(cfg)
    assert idx[0].shape[1] == 2


# ---------------------------------------------------------------- forward

@pytest.mark.parametrize("mode,extra", [
    ("neuralut", {}),
    ("logicnets", {}),
    ("polylut", {"degree": 2}),
])
def test_forward_shapes_and_quantized_range(mode, extra):
    cfg = tiny_cfg(mode=mode, **extra)
    idx = model.build_sparsity(cfg)
    params = model.init_params(cfg, 0)
    x = jax.random.uniform(jax.random.PRNGKey(0), (8, 4))
    logits, stats = model.forward(cfg, params, x, idx, train=False,
                                  use_pallas=False)
    assert logits.shape == (8, 2)
    assert stats is None
    # logits are on the signed quant lattice: |logit| <= scale
    s = float(jnp.exp(params[model.scale_param_indices(cfg)[-1]]))
    assert float(jnp.max(jnp.abs(logits))) <= s + 1e-5


def test_forward_train_returns_batch_stats():
    cfg = tiny_cfg()
    idx = model.build_sparsity(cfg)
    params = model.init_params(cfg, 0)
    x = jax.random.uniform(jax.random.PRNGKey(0), (8, 4))
    _, stats = model.forward(cfg, params, x, idx, train=True,
                             use_pallas=False)
    assert len(stats) == 2
    mu, var = stats[0]
    assert mu.shape == (4,) and var.shape == (4,)


def test_param_spec_matches_init_shapes():
    for name in ["moons-neuralut", "jsc-2l", "hdr-mini-polylut",
                 "fig5-l3-skip"]:
        cfg = configs.get(name)
        spec = model.param_spec(cfg)
        params = model.init_params(cfg, 0)
        assert len(spec) == len(params)
        for (nm, sh), p in zip(spec, params):
            assert tuple(p.shape) == tuple(sh), nm


def test_layer_slices_partition_the_spec():
    cfg = configs.get("jsc-5l")
    slices = model.layer_param_slices(cfg)
    spec = model.param_spec(cfg)
    assert slices[0][0] == 0
    assert slices[-1][1] == len(spec)
    for (a, b), (c, d) in zip(slices, slices[1:]):
        assert b == c


# ---------------------------------------------------------------- training

def test_train_step_reduces_loss_on_separable_data():
    cfg = tiny_cfg(layers=(6, 2), beta=3, lr_max=1e-2)
    idx = model.build_sparsity(cfg)
    params = model.init_params(cfg, 1)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (64, 4))
    y = (x[:, 0] > 0.5).astype(jnp.int32)  # trivially separable
    step = jax.jit(lambda p, m, v, s: train.train_step(
        cfg, p, m, v, s, 5e-3, x[:8 * ((int(s) - 1) % 8):][:8],
        y[:8 * ((int(s) - 1) % 8):][:8], idx, use_pallas=False))
    first_loss = None
    for s in range(1, 40):
        b = (s - 1) % 8
        params, m, v, loss, acc = train.train_step(
            cfg, params, m, v, float(s), 5e-3, x[b * 8:(b + 1) * 8],
            y[b * 8:(b + 1) * 8], idx, use_pallas=False)
        if first_loss is None:
            first_loss = float(loss)
    assert float(loss) < first_loss


def test_bn_stats_updated_by_ema_not_adam():
    cfg = tiny_cfg()
    idx = model.build_sparsity(cfg)
    params = model.init_params(cfg, 0)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    x = jax.random.uniform(jax.random.PRNGKey(0), (8, 4))
    y = jnp.zeros((8,), jnp.int32)
    p2, m2, v2, _, _ = train.train_step(
        cfg, params, m, v, 1.0, 1e-3, x, y, idx, use_pallas=False)
    for i in model.bn_stat_indices(cfg):
        # optimizer state for stats must remain zero
        assert float(jnp.max(jnp.abs(m2[i]))) == 0.0
        assert float(jnp.max(jnp.abs(v2[i]))) == 0.0


def test_sgdr_schedule_matches_rust_contract():
    cfg = tiny_cfg(lr_max=1e-2, lr_min=1e-4, sgdr_t0=5, sgdr_mult=2)
    # restart at t0 * spe steps
    spe = 10
    assert abs(train.sgdr_lr(cfg, 0, spe) - 1e-2) < 1e-12
    assert abs(train.sgdr_lr(cfg, 50, spe) - 1e-2) < 1e-12  # warm restart
    mid = train.sgdr_lr(cfg, 25, spe)
    assert abs(mid - (1e-4 + 0.5 * (1e-2 - 1e-4))) < 1e-9
