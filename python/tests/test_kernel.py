"""L1 correctness: Pallas sub-network kernel vs the pure-jnp oracle.

This is the core kernel-correctness signal: hypothesis sweeps topology
(F, L, N, S), LUT count, batch size and dtype; `assert_allclose` against
`ref.subnet_ref`, plus gradient equality through the custom_vjp.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    init_poly_params,
    init_subnet_params,
    poly_ref,
    subnet_ref,
)
from compile.kernels.subnet import (
    subnet_apply,
    subnet_pallas,
    subnet_pallas_single,
)
from compile.kernels.topo import PolyTopo, SubnetTopo


@st.composite
def topologies(draw):
    l = draw(st.integers(1, 5))
    divisors = [0] + [d for d in range(1, l + 1) if l % d == 0]
    s = draw(st.sampled_from(divisors))
    return SubnetTopo(
        fan_in=draw(st.integers(1, 8)),
        depth=l,
        width=draw(st.integers(1, 12)),
        skip=s,
    )


@settings(max_examples=40, deadline=None)
@given(
    topo=topologies(),
    m=st.integers(1, 6),
    batch=st.sampled_from([1, 3, 16, 64, 130]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref(topo, m, batch, seed):
    key = jax.random.PRNGKey(seed)
    params = init_subnet_params(key, m, topo)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, batch, topo.fan_in))
    got = subnet_pallas(params, x, topo)
    want = subnet_ref(params, x, topo)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(topo=topologies(), m=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_single_block_matches_ref(topo, m, seed):
    key = jax.random.PRNGKey(seed)
    params = init_subnet_params(key, m, topo)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, 32, topo.fan_in))
    got = subnet_pallas_single(params, x, topo)
    want = subnet_ref(params, x, topo)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(topo=topologies(), seed=st.integers(0, 2**31 - 1))
def test_custom_vjp_gradients_match_ref(topo, seed):
    key = jax.random.PRNGKey(seed)
    m, batch = 3, 24
    params = init_subnet_params(key, m, topo)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, batch, topo.fan_in))

    def f_pallas(ps):
        return jnp.sum(subnet_apply(ps, x, topo) ** 2)

    def f_ref(ps):
        return jnp.sum(subnet_ref(ps, x, topo) ** 2)

    g1 = jax.grad(f_pallas)(params)
    g2 = jax.grad(f_ref)(params)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_bfloat16_input_supported():
    topo = SubnetTopo(4, 2, 8, 0)
    key = jax.random.PRNGKey(0)
    params = [p.astype(jnp.bfloat16) for p in init_subnet_params(key, 2, topo)]
    x = jax.random.normal(key, (2, 16, 4), jnp.bfloat16)
    got = subnet_pallas(params, x, topo)
    want = subnet_ref(params, x, topo)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=2e-2, atol=2e-2
    )


def test_logicnets_degenerate_topology():
    """L=1, N=1, S=0 is exactly a linear neuron (paper §III-C)."""
    topo = SubnetTopo(5, 1, 1, 0)
    key = jax.random.PRNGKey(7)
    params = init_subnet_params(key, 4, topo)
    x = jax.random.normal(key, (4, 10, 5))
    got = subnet_pallas(params, x, topo)
    w, b = params
    want = jnp.einsum("mbf,mfo->mbo", x, w)[..., 0] + b[:, None, 0]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_poly_ref_matches_manual_expansion():
    topo = PolyTopo(2, 2)
    key = jax.random.PRNGKey(3)
    params = init_poly_params(key, 1, topo)
    x = jnp.array([[[0.5, -1.0]]])
    w, b = params
    # exponents order: (1,0), (0,1), (2,0), (1,1), (0,2)
    feats = jnp.array([0.5, -1.0, 0.25, -0.5, 1.0])
    want = jnp.dot(feats, w[0, :, 0]) + b[0, 0]
    got = poly_ref(params, x, topo)[0, 0]
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_residual_actually_contributes():
    """With S>0 the residual path must change the output."""
    topo_skip = SubnetTopo(3, 2, 4, 2)
    topo_noskip = SubnetTopo(3, 2, 4, 0)
    key = jax.random.PRNGKey(1)
    p_skip = init_subnet_params(key, 1, topo_skip)
    x = jax.random.normal(key, (1, 8, 3))
    y_skip = subnet_ref(p_skip, x, topo_skip)
    # Drop the residual tensors -> same affine chain without skip.
    y_no = subnet_ref(p_skip[:4], x, topo_noskip)
    assert not np.allclose(y_skip, y_no)


def test_rejects_bad_skip():
    with pytest.raises(ValueError):
        SubnetTopo(3, 5, 4, 2)  # L=5 not a multiple of S=2
