"""Tests of the L1 kernel performance model (kernel_stats)."""

import pytest

from compile import configs, kernel_stats


@pytest.mark.parametrize("name", ["hdr-mini", "jsc-2l", "jsc-5l",
                                  "moons-polylut"])
def test_vmem_within_budget(name):
    cfg = configs.get(name)
    for s in kernel_stats.all_stats(cfg):
        assert s.vmem_bytes < kernel_stats.VMEM_BYTES
        assert 0.0 < s.mxu_utilization <= 1.0
        assert s.b_tile >= 1


def test_weight_bytes_match_param_count():
    cfg = configs.get("hdr-mini")
    from compile.model import layer_topo
    s = kernel_stats.stats_for(cfg, 0, cfg.batch)
    topo = layer_topo(cfg, 0)
    assert s.weight_bytes == topo.param_count() * kernel_stats.BF16


def test_mxu_utilization_improves_with_batch_tile():
    cfg = configs.get("hdr-mini")
    small = kernel_stats.stats_for(cfg, 0, 8)
    large = kernel_stats.stats_for(cfg, 0, 256)
    assert large.mxu_utilization >= small.mxu_utilization


def test_padded_macs_at_least_useful():
    u, p = kernel_stats._matmul_stats(64, 6, 16)
    assert p >= u
    # perfectly-aligned shapes reach 100%
    u2, p2 = kernel_stats._matmul_stats(128, 128, 128)
    assert u2 == p2
