"""Topology formulas (paper Table I / eqs. 5-7) and dataset generators."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import datasets
from compile.kernels.topo import PolyTopo, SubnetTopo


@st.composite
def topologies(draw):
    l = draw(st.integers(1, 8))
    divisors = [0] + [d for d in range(1, l + 1) if l % d == 0]
    return SubnetTopo(
        fan_in=draw(st.integers(1, 16)),
        depth=l,
        width=draw(st.integers(1, 32)),
        skip=draw(st.sampled_from(divisors)),
    )


@settings(max_examples=200, deadline=None)
@given(topologies())
def test_param_count_formula_matches_enumeration(topo):
    """Paper eq. (7) closed form == structural enumeration."""
    assert topo.param_count() == topo.param_count_formula()


def test_logicnets_is_special_case():
    """N = L = 1, S = 0 reduces to LogicNets (paper §III-C)."""
    for f in range(1, 10):
        t = SubnetTopo(f, 1, 1, 0)
        assert t.param_count() == f + 1


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(1, 4))
def test_poly_feature_count_is_binomial(f, d):
    import math
    topo = PolyTopo(f, d)
    assert topo.num_features() == math.comb(f + d, d) - 1
    # exponents are unique and within degree bound
    exps = topo.exponents()
    assert len(set(exps)) == len(exps)
    assert all(1 <= sum(e) <= d for e in exps)


def test_scaling_linear_in_f():
    """Table I: NeuraLUT is linear in F for fixed (N, L)."""
    t = lambda f: SubnetTopo(f, 4, 16, 2).param_count()
    diffs = [t(f + 1) - t(f) for f in range(2, 10)]
    assert len(set(diffs)) == 1


# ------------------------------------------------------------------ datasets

@pytest.mark.parametrize("name", list(datasets.GENERATORS))
def test_generators_produce_valid_blobs(name, tmp_path):
    xtr, ytr, xte, yte = datasets.GENERATORS[name](seed=123)
    n_class = datasets.N_CLASS[name]
    assert xtr.min() >= 0.0 and xtr.max() <= 1.0
    assert ytr.min() >= 0 and ytr.max() < n_class
    assert xtr.shape[1] == xte.shape[1]
    # round-trip the binary format
    p = tmp_path / f"{name}.bin"
    datasets.write_blob(str(p), xtr[:100], ytr[:100], xte[:50], yte[:50],
                        n_class)
    raw = p.read_bytes()
    import struct
    magic, ver, ntr, nte, nf, nc = struct.unpack_from("<6I", raw, 0)
    assert magic == datasets.MAGIC and ver == datasets.VERSION
    assert (ntr, nte, nf, nc) == (100, 50, xtr.shape[1], n_class)
    back = np.frombuffer(raw, np.float32, ntr * nf, 24).reshape(ntr, nf)
    np.testing.assert_array_equal(back, xtr[:100])


def test_generators_are_deterministic():
    a = datasets.make_jsc(7, n_train=100, n_test=50)
    b = datasets.make_jsc(7, n_train=100, n_test=50)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_digits_classes_look_different():
    xtr, ytr, _, _ = datasets.make_digits(1, side=14, n_train=600, n_test=10)
    means = np.stack([xtr[ytr == c].mean(axis=0) for c in range(10)])
    # class-mean images must be pairwise distinguishable
    d = np.linalg.norm(means[:, None] - means[None, :], axis=-1)
    assert d[np.triu_indices(10, 1)].min() > 0.3


def test_jsc_is_learnable_but_not_trivial():
    """A linear probe should land well above chance but below ~70 %
    (the paper's task sits in the 72-76 % band for stronger models)."""
    xtr, ytr, xte, yte = datasets.make_jsc(2024, n_train=4000, n_test=1000)
    # one-shot least-squares probe
    xb = np.hstack([xtr, np.ones((len(xtr), 1), np.float32)])
    targets = np.eye(5, dtype=np.float32)[ytr]
    w, *_ = np.linalg.lstsq(xb, targets, rcond=None)
    xtb = np.hstack([xte, np.ones((len(xte), 1), np.float32)])
    acc = (np.argmax(xtb @ w, axis=1) == yte).mean()
    assert 0.35 < acc < 0.85, acc
