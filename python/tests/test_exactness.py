"""The bit-exactness invariant (DESIGN.md §3), Python side:

the quantized forward pass and the layer-by-layer truth-table replay must
produce identical predictions — with *trained-like* (randomly perturbed)
parameters, across modes, with and without the Pallas kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, quant, tt


def perturb(cfg, params, seed):
    """Make scales/BN non-trivial, as after real training."""
    rng = np.random.default_rng(seed)
    out = list(params)
    for i, (nm, sh) in enumerate(model.param_spec(cfg)):
        if nm.endswith(".scale"):
            out[i] = jnp.asarray(np.float32(rng.normal(0, 0.4)))
        elif nm.endswith(".bn_mean"):
            out[i] = jnp.asarray(rng.normal(0, 0.5, sh).astype(np.float32))
        elif nm.endswith(".bn_var"):
            out[i] = jnp.asarray(rng.uniform(0.3, 2.0, sh).astype(np.float32))
        elif nm.endswith(".bn_beta"):
            out[i] = jnp.asarray(rng.normal(0.3, 0.3, sh).astype(np.float32))
    return out


def table_replay(cfg, params, idx, x, *, use_pallas):
    """Evaluate via per-layer truth tables, like the Rust netlist sim."""
    slices = model.layer_param_slices(cfg)
    codes = np.array(quant.quant_input_code(x, cfg.layer_in_bits(0)))
    for l in range(len(cfg.layers)):
        lo, hi = slices[l]
        prev_scale = params[slices[l - 1][1] - 1] if l > 0 else None
        table = np.array(tt.tt_layer(cfg, l, params[lo:hi], prev_scale,
                                     use_pallas=use_pallas))
        b = cfg.layer_in_bits(l)
        out = np.zeros((codes.shape[0], cfg.layers[l]), np.int32)
        for m in range(cfg.layers[l]):
            addr = np.zeros(codes.shape[0], np.int64)
            for j, src in enumerate(idx[l][m]):
                addr |= codes[:, src].astype(np.int64) << (b * j)
            out[:, m] = table[m][addr]
        codes = out
    return codes


@pytest.mark.parametrize("name", ["moons-neuralut", "moons-logicnets",
                                  "moons-polylut"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_forward_equals_table_replay(name, use_pallas):
    cfg = configs.get(name)
    idx = model.build_sparsity(cfg)
    params = perturb(cfg, model.init_params(cfg, 0), seed=1)
    x = np.asarray(
        jax.random.uniform(jax.random.PRNGKey(2), (256, cfg.input_size))
    )
    logits, _ = model.forward(cfg, params, x, idx, train=False,
                              use_pallas=use_pallas)
    pred_model = np.argmax(np.array(logits), axis=1)
    codes = table_replay(cfg, params, idx, x, use_pallas=use_pallas)
    pred_replay = np.argmax(codes, axis=1)
    assert (pred_model != pred_replay).sum() == 0


def test_logit_codes_dequantize_to_logits():
    cfg = configs.get("moons-neuralut")
    idx = model.build_sparsity(cfg)
    params = perturb(cfg, model.init_params(cfg, 3), seed=4)
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(5), (128, 2)))
    logits, _ = model.forward(cfg, params, x, idx, train=False,
                              use_pallas=False)
    codes = table_replay(cfg, params, idx, x, use_pallas=False)
    s = float(jnp.exp(params[model.scale_param_indices(cfg)[-1]]))
    q = 2 ** (cfg.layer_out_bits(len(cfg.layers) - 1) - 1) - 1
    np.testing.assert_allclose(np.array(logits), codes * s / q,
                               rtol=1e-4, atol=1e-5)


def test_tt_enumeration_covers_all_addresses():
    cfg = configs.get("moons-neuralut")
    digits = np.array(tt.enumerate_inputs(cfg, 0))
    b = cfg.layer_in_bits(0)
    f = cfg.layer_fan_in(0)
    assert digits.shape == (1 << (b * f), f)
    # address j reconstructs from digits
    recon = sum(digits[:, j].astype(np.int64) << (b * j) for j in range(f))
    np.testing.assert_array_equal(recon, np.arange(1 << (b * f)))


def test_tt_codes_in_range():
    cfg = configs.get("moons-neuralut")
    idx = model.build_sparsity(cfg)
    params = perturb(cfg, model.init_params(cfg, 0), seed=9)
    slices = model.layer_param_slices(cfg)
    for l in range(len(cfg.layers)):
        lo, hi = slices[l]
        prev = params[slices[l - 1][1] - 1] if l > 0 else None
        codes = np.array(tt.tt_layer(cfg, l, params[lo:hi], prev,
                                     use_pallas=False))
        ob = cfg.layer_out_bits(l)
        if l == len(cfg.layers) - 1:
            q = 2 ** (ob - 1) - 1
            assert codes.min() >= -q and codes.max() <= q
        else:
            assert codes.min() >= 0 and codes.max() <= 2**ob - 1
