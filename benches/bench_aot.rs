//! AOT native-code backend vs the interpreted engine: cold-start cost
//! (codegen + system compiler + dlopen) against the interpreter's
//! compile, warm-reload cost (dlopen of a cached object), steady-state
//! samples/s, and — the hard release gate — bit-exact parity rows
//! against the reference `Simulator` on every measured case.
//!
//! Writes `BENCH_aot.json` rows the CI gate (`scripts/check_bench.py`)
//! checks: `parity_mismatches` must be 0 everywhere, and AOT
//! steady-state throughput must not lose to the interpreted
//! `bitsliced-auto` run by more than the configured margin. Without a
//! native toolchain on PATH the bench writes a single marker row
//! (`"toolchain_available": false`) and exits cleanly — the gate skips,
//! mirroring how the backend itself degrades instead of failing.
//! `NEURALUT_BENCH_QUICK=1` trims to the small cases for CI.

use neuralut::engine::aot::toolchain_available;
use neuralut::fabric::{FabricOptions, Model, OptLevel};
use neuralut::luts::{random_network, structured_network};
use neuralut::netlist::Simulator;
use neuralut::util::bench::bench;
use neuralut::util::json::{obj, Json};

fn quick() -> bool {
    std::env::var_os("NEURALUT_BENCH_QUICK").is_some_and(|v| !v.is_empty())
}

fn write_rows(rows: Vec<Json>) {
    let out = Json::Arr(rows).to_string();
    if let Err(e) = std::fs::write("BENCH_aot.json", &out) {
        eprintln!("could not write BENCH_aot.json: {e}");
    } else {
        println!("wrote BENCH_aot.json");
    }
}

fn main() {
    let quick = quick();
    println!(
        "== bench_aot: native codegen vs the interpreted engine{} ==",
        if quick { " (quick mode)" } else { "" }
    );
    if !toolchain_available() {
        println!("no native toolchain (rustc/cc) on PATH; writing a marker row");
        write_rows(vec![obj(vec![("toolchain_available", Json::Bool(false))])]);
        return;
    }
    // (name, trained-like?, input, input_bits, widths, fan_in, beta) —
    // the same repro cases as bench_netlist. Quick mode keeps the small
    // ones: the big cases push multi-megabyte C files through `cc -O2`,
    // which is exactly the cold-start cost this bench measures, but not
    // something a CI smoke leg should pay four times over.
    let all_cases = [
        ("jsc-2l-trained", true, 16usize, 4usize, vec![32usize, 5], 3usize, 4usize),
        ("logicnets-trained", true, 32, 1, vec![64, 32, 8], 4, 1),
        ("jsc-2l-random", false, 16, 4, vec![32, 5], 3, 4),
        ("hdr-mini-trained", true, 196, 2, vec![64, 32, 10], 6, 2),
        ("jsc-5l-trained", true, 16, 4, vec![128, 128, 128, 64, 5], 3, 4),
        ("hdr-5l-paper-trained", true, 784, 2, vec![256, 100, 100, 100, 10], 6, 2),
    ];
    let n_cases = if quick { 3 } else { all_cases.len() };
    let min_time = if quick { 0.15 } else { 1.0 };
    let batch = 4096usize;
    let cache = std::env::temp_dir().join(format!("neuralut-bench-aot-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache);
    let mut rows: Vec<Json> = Vec::new();

    for (name, trained, input, bits, widths, fan_in, beta) in all_cases.into_iter().take(n_cases) {
        let net = if trained {
            structured_network(1, input, bits, &widths, fan_in, beta, 4)
        } else {
            random_network(1, input, bits, &widths, fan_in, beta, 4)
        };
        let model = Model::from_network(net);
        let sim = Simulator::new(model.network());

        // The interpreter's compile (lower + opt, no native build) is
        // the cold-start baseline AOT is paying extra over.
        let t0 = std::time::Instant::now();
        let interp = model
            .compile(&FabricOptions::new().backend("bitsliced-auto").opt_level(OptLevel::O2))
            .expect("bitsliced-auto compile");
        let interp_compile_s = t0.elapsed().as_secs_f64();

        // Cold start: emit + system compiler + dlopen, nothing cached.
        let aot_opts = FabricOptions::new()
            .backend("aot-c")
            .opt_level(OptLevel::O2)
            .aot_cache_dir(&cache);
        let t0 = std::time::Instant::now();
        let aot = model.compile(&aot_opts).expect("aot compile");
        let cold_start_s = t0.elapsed().as_secs_f64();
        if aot.degraded() {
            eprintln!(
                "{name}: aot degraded to '{}' with a toolchain present — cold-start \
                 numbers would be fiction",
                aot.backend_name()
            );
            std::process::exit(1);
        }
        let report = aot.report();
        if let Err(e) = report.check() {
            eprintln!("BROKEN compile report for {name}: {e}");
            std::process::exit(1);
        }
        let pass_s = |n: &str| {
            report.passes.iter().find(|p| p.name == n).map(|p| p.wall_s).unwrap_or(0.0)
        };
        let (codegen_s, cc_s, dlopen_s) = (pass_s("codegen"), pass_s("cc"), pass_s("dlopen"));

        // Warm reload: the object is cached, so a second process pays
        // only lower + opt + dlopen.
        let t0 = std::time::Instant::now();
        let warm = model.compile(&aot_opts).expect("aot warm reload");
        let warm_reload_s = t0.elapsed().as_secs_f64();
        drop(warm);

        // Parity: the hard release gate. Same batch the throughput
        // loops run, checked code-for-code against the reference
        // simulator before any number is reported.
        let x: Vec<f32> = (0..batch * input).map(|i| (i % 97) as f32 / 97.0).collect();
        let aot_sess = aot.session();
        let interp_sess = interp.session();
        let want = sim.simulate_batch(&x);
        let got = aot_sess.infer_batch(&x).expect("aot inference");
        let parity_mismatches = got
            .logit_codes
            .iter()
            .zip(want.logit_codes.iter())
            .filter(|(a, b)| a != b)
            .count()
            + got.logit_codes.len().abs_diff(want.logit_codes.len());

        let m_aot = bench(
            &format!("engine/aot-c-O2/batch4096/{name}"),
            1,
            min_time,
            200,
            Some((batch as f64, "samples")),
            || {
                std::hint::black_box(aot_sess.infer_batch(&x).unwrap());
            },
        );
        let m_interp = bench(
            &format!("engine/bitsliced-auto-O2/batch4096/{name}"),
            1,
            min_time,
            200,
            Some((batch as f64, "samples")),
            || {
                std::hint::black_box(interp_sess.infer_batch(&x).unwrap());
            },
        );
        let aot_sps = m_aot.throughput.map(|(t, _)| t).unwrap_or(0.0);
        let interp_sps = m_interp.throughput.map(|(t, _)| t).unwrap_or(0.0);
        println!(
            "-- {name}: parity {parity_mismatches} mismatches; cold start {cold_start_s:.3}s \
             (codegen {codegen_s:.3}s, cc {cc_s:.3}s, dlopen {dlopen_s:.4}s) vs \
             interpreted compile {interp_compile_s:.3}s; warm reload {warm_reload_s:.3}s"
        );
        println!(
            "   steady state: aot {aot_sps:.0} vs bitsliced-auto {interp_sps:.0} samples/s \
             ({:.2}x)",
            aot_sps / interp_sps.max(1e-9)
        );
        rows.push(obj(vec![
            ("name", Json::Str(name.to_string())),
            ("toolchain_available", Json::Bool(true)),
            ("quick", Json::Bool(quick)),
            ("batch", Json::Num(batch as f64)),
            ("backend", Json::Str("aot-c".to_string())),
            ("word_ops_o2", Json::Num(aot.num_word_ops().unwrap_or(0) as f64)),
            ("parity_mismatches", Json::Num(parity_mismatches as f64)),
            ("interp_compile_s", Json::Num(interp_compile_s)),
            ("aot_cold_start_s", Json::Num(cold_start_s)),
            ("codegen_s", Json::Num(codegen_s)),
            ("cc_s", Json::Num(cc_s)),
            ("dlopen_s", Json::Num(dlopen_s)),
            ("warm_reload_s", Json::Num(warm_reload_s)),
            ("aot_samples_per_s", Json::Num(aot_sps)),
            ("bitsliced_auto_samples_per_s", Json::Num(interp_sps)),
            ("speedup_vs_interpreter", Json::Num(aot_sps / interp_sps.max(1e-9))),
        ]));
    }

    let _ = std::fs::remove_dir_all(&cache);
    write_rows(rows);
    println!("measured {n_cases} case(s)");
}
