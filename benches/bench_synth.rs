//! Synthesis cost-model throughput (toolflow stage 4): support reduction +
//! ROBDD + 6-LUT covering per L-LUT, across (beta, fan_in) sizes, plus an
//! ablation of the two complexity metrics (cofactor covering vs BDD).

use neuralut::luts::random_network;
use neuralut::synth::{self, robdd};
use neuralut::util::bench::bench;
use neuralut::util::rng::Rng;

fn main() {
    println!("== bench_synth: Vivado-substitute cost model ==");
    for (beta, fan_in) in [(2usize, 6usize), (3, 4), (4, 3), (7, 2)] {
        let k = beta * fan_in;
        let net = random_network(3, 32, beta, &[64, 5], fan_in, beta, 4);
        bench(
            &format!("synth/full-network/b{beta}F{fan_in} (k={k})"),
            1,
            1.0,
            50,
            Some((net.num_luts() as f64, "L-LUTs")),
            || {
                std::hint::black_box(synth::synthesize(&net));
            },
        );
    }

    // Metric ablation on a single 12-input output bit.
    let mut rng = Rng::new(7);
    let bits: Vec<u8> =
        (0..1usize << 12).map(|_| (rng.next_u64() & 1) as u8).collect();
    bench("synth/cost_function/k12/random", 2, 0.5, 5000, None, || {
        std::hint::black_box(synth::cost_function(&bits, 12));
    });
    bench("synth/robdd/k12/random", 2, 0.5, 5000, None, || {
        std::hint::black_box(robdd::node_count(&bits, 12));
    });
    let linear: Vec<u8> = (0..1u32 << 12)
        .map(|a| ((a.count_ones() as usize) > 6) as u8)
        .collect();
    bench("synth/cost_function/k12/threshold", 2, 0.5, 5000, None, || {
        std::hint::black_box(synth::cost_function(&linear, 12));
    });
    let (l_rand, _) = synth::cost_function(&bits, 12);
    let (l_thr, _) = synth::cost_function(&linear, 12);
    println!(
        "structure sensitivity: random table {l_rand} P-LUTs vs threshold \
         table {l_thr} P-LUTs (the paper's 'less simplification' effect)"
    );
}
