//! Fabric-simulator throughput: L-LUT lookups/s and samples/s across the
//! paper's circuit scales (the inference-latency substrate behind Fig. 6 /
//! Table III). Also reports single-sample latency — the netlist simulator
//! is the serving hot path.

use neuralut::luts::random_network;
use neuralut::netlist::Simulator;
use neuralut::util::bench::bench;

fn main() {
    println!("== bench_netlist: fabric simulator ==");
    // (name, input, input_bits, widths, fan_in, beta)
    let cases = [
        ("jsc-2l-scale", 16usize, 4usize, vec![32usize, 5], 3usize, 4usize),
        ("hdr-mini-scale", 196, 2, vec![64, 32, 10], 6, 2),
        ("jsc-5l-scale", 16, 4, vec![128, 128, 128, 64, 5], 3, 4),
        ("hdr-5l-paper-scale", 784, 2, vec![256, 100, 100, 100, 10], 6, 2),
    ];
    for (name, input, bits, widths, fan_in, beta) in cases {
        let net = random_network(1, input, bits, &widths, fan_in, beta, 4);
        let sim = Simulator::new(&net);
        let batch = 4096usize;
        let x: Vec<f32> = (0..batch * input)
            .map(|i| (i % 97) as f32 / 97.0)
            .collect();
        let lookups = batch as f64 * net.num_luts() as f64;
        bench(
            &format!("netlist/batch4096/{name}"),
            1,
            1.0,
            200,
            Some((lookups, "lookups")),
            || {
                std::hint::black_box(sim.simulate_batch(&x));
            },
        );
        let one: Vec<f32> = x[..input].to_vec();
        bench(
            &format!("netlist/single/{name}"),
            10,
            0.5,
            50_000,
            Some((1.0, "samples")),
            || {
                std::hint::black_box(sim.simulate_batch(&one));
            },
        );
    }
}
