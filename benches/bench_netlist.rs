//! Fabric inference throughput and compiled-netlist cost: the scalar
//! backend (per-sample table lookups) vs the compiled bitsliced backend
//! (64 samples per plane word; 128/256/512 for the x2/x4/x8 widths) at
//! every optimization level, across the paper's circuit scales.
//!
//! The repro networks use trained-like tables (`luts::structured_network`
//! — quantized clamped threshold functions, the redundancy profile real
//! NeuraLUT models have); one deliberately adversarial uniform-random
//! case (`*-random`) shows the dense-table floor. Per case this reports
//! the `O0`/`O1`/`O2` word-op counts (the `engine::opt` pipeline's yield)
//! and samples/s for scalar, bitsliced `O0` and bitsliced `O2`, then an
//! aggregate executed-op reduction across the trained-like cases.
//!
//! Writes `BENCH_engine.json` rows the CI `bench-smoke` gate
//! (`scripts/check_bench.py`) checks against `BENCH_baseline.json`, plus
//! `BENCH_compile_report.json` — the per-case O2 `CompileReport`s
//! (per-pass wall time and op deltas) the gate chain-checks and the CI
//! job summary tabulates.
//! `NEURALUT_BENCH_QUICK=1` switches to a low-iteration smoke mode for CI.

use neuralut::engine::{lane_backend_name, BitslicedProgram, LANE_WIDTHS};
use neuralut::fabric::{FabricOptions, Model, OptLevel};
use neuralut::luts::{random_network, structured_network};
use neuralut::util::bench::bench;
use neuralut::util::json::{obj, Json};

fn quick() -> bool {
    std::env::var_os("NEURALUT_BENCH_QUICK").is_some_and(|v| !v.is_empty())
}

fn main() {
    let quick = quick();
    println!(
        "== bench_netlist: scalar vs bitsliced x opt level{} ==",
        if quick { " (quick mode)" } else { "" }
    );
    // (name, trained-like?, input, input_bits, widths, fan_in, beta)
    let cases = [
        ("jsc-2l-trained", true, 16usize, 4usize, vec![32usize, 5], 3usize, 4usize),
        ("hdr-mini-trained", true, 196, 2, vec![64, 32, 10], 6, 2),
        ("jsc-5l-trained", true, 16, 4, vec![128, 128, 128, 64, 5], 3, 4),
        ("hdr-5l-paper-trained", true, 784, 2, vec![256, 100, 100, 100, 10], 6, 2),
        // LogicNets-like low-β point: small per-bit functions, where the
        // word-level engine's logic sharing pays off hardest.
        ("logicnets-trained", true, 32, 1, vec![64, 32, 8], 4, 1),
        // Adversarial floor: uniform-random tables have almost no
        // foldable structure within a layer; only cross-level dead logic
        // remains for the optimizer.
        ("jsc-2l-random", false, 16, 4, vec![32, 5], 3, 4),
    ];
    let n_cases = cases.len();
    let min_time = if quick { 0.15 } else { 1.0 };
    let batch = 4096usize;
    let mut rows: Vec<Json> = Vec::new();
    let mut reports: Vec<Json> = Vec::new();
    let (mut trained_ops_o0, mut trained_ops_o2) = (0usize, 0usize);

    for (name, trained, input, bits, widths, fan_in, beta) in cases {
        let net = if trained {
            structured_network(1, input, bits, &widths, fan_in, beta, 4)
        } else {
            random_network(1, input, bits, &widths, fan_in, beta, 4)
        };
        let model = Model::from_network(net);

        let scalar = model
            .compile(&FabricOptions::new().backend("scalar"))
            .expect("scalar compile")
            .session();
        let compile_at = |level: OptLevel| {
            let t0 = std::time::Instant::now();
            let fabric = model
                .compile(&FabricOptions::new().backend("bitsliced").opt_level(level))
                .expect("lowering failed");
            (fabric, t0.elapsed().as_secs_f64())
        };
        let (fab_o0, _) = compile_at(OptLevel::O0);
        let (fab_o1, _) = compile_at(OptLevel::O1);
        let (fab_o2, compile_s) = compile_at(OptLevel::O2);
        let ops_o0 = fab_o0.num_word_ops().expect("bitsliced program");
        let ops_o1 = fab_o1.num_word_ops().expect("bitsliced program");
        let ops_o2 = fab_o2.num_word_ops().expect("bitsliced program");
        let reduction = 1.0 - ops_o2 as f64 / ops_o0.max(1) as f64;
        if trained {
            trained_ops_o0 += ops_o0;
            trained_ops_o2 += ops_o2;
        }
        println!(
            "-- {name}: {} L-LUTs, word ops O0 {ops_o0} / O1 {ops_o1} / O2 {ops_o2} \
             (-{:.1}% at O2, compile {compile_s:.3}s)",
            model.num_luts(),
            reduction * 100.0
        );
        // Compile telemetry for this case's O2 build: chain-checked here
        // so a broken report fails the bench, then persisted for the CI
        // gate and the job-summary per-pass table.
        let report = fab_o2.report();
        if let Err(e) = report.check() {
            eprintln!("BROKEN compile report for {name}: {e}");
            std::process::exit(1);
        }
        for p in &report.passes {
            println!(
                "   pass {:<10} {:>8.3} ms  ops {} -> {} ({:+})",
                p.name,
                p.wall_s * 1e3,
                p.ops_before,
                p.ops_after,
                -p.ops_removed()
            );
        }
        reports.push(obj(vec![
            ("case", Json::Str(name.to_string())),
            ("report", report.to_json()),
        ]));

        let x: Vec<f32> = (0..batch * input).map(|i| (i % 97) as f32 / 97.0).collect();
        let sess_o0 = fab_o0.session();
        let sess_o2 = fab_o2.session();
        let m_scalar = bench(
            &format!("netlist/scalar/batch4096/{name}"),
            1,
            min_time,
            200,
            Some((batch as f64, "samples")),
            || {
                std::hint::black_box(scalar.infer_batch(&x).unwrap());
            },
        );
        let m_o0 = bench(
            &format!("engine/bitsliced-O0/batch4096/{name}"),
            1,
            min_time,
            200,
            Some((batch as f64, "samples")),
            || {
                std::hint::black_box(sess_o0.infer_batch(&x).unwrap());
            },
        );
        let m_o2 = bench(
            &format!("engine/bitsliced-O2/batch4096/{name}"),
            1,
            min_time,
            200,
            Some((batch as f64, "samples")),
            || {
                std::hint::black_box(sess_o2.infer_batch(&x).unwrap());
            },
        );
        let scalar_sps = m_scalar.throughput.map(|(t, _)| t).unwrap_or(0.0);
        let o0_sps = m_o0.throughput.map(|(t, _)| t).unwrap_or(0.0);
        let o2_sps = m_o2.throughput.map(|(t, _)| t).unwrap_or(0.0);

        // Per-width throughput over the *same* O2 netlist: re-widen the
        // compiled program (no re-lowering) so the widths differ only in
        // plane word format. x1 is the m_o2 run above.
        let nl_o2 = fab_o2.bit_netlist().expect("bitsliced program").clone();
        let mut width_sps: Vec<(String, Json)> = vec![(
            "bitsliced".to_string(),
            Json::Num(o2_sps),
        )];
        let mut best_wide = ("bitsliced", o2_sps);
        for lanes in LANE_WIDTHS {
            if lanes == 1 {
                continue;
            }
            let wname = lane_backend_name(lanes).expect("registered width");
            let exec = BitslicedProgram::from_netlist_wide(nl_o2.clone(), lanes)
                .expect("valid width")
                .executor();
            let m_w = bench(
                &format!("engine/{wname}-O2/batch4096/{name}"),
                1,
                min_time,
                200,
                Some((batch as f64, "samples")),
                || {
                    std::hint::black_box(exec.run_batch(&x));
                },
            );
            let sps = m_w.throughput.map(|(t, _)| t).unwrap_or(0.0);
            width_sps.push((wname.to_string(), Json::Num(sps)));
            if sps > best_wide.1 {
                best_wide = (wname, sps);
            }
        }
        println!(
            "   widths: {}  (best {} at {:.2}x of x1)",
            width_sps
                .iter()
                .map(|(n, v)| format!(
                    "{n} {:.0}/s",
                    if let Json::Num(t) = v { *t } else { 0.0 }
                ))
                .collect::<Vec<_>>()
                .join(", "),
            best_wide.0,
            best_wide.1 / o2_sps.max(1e-9)
        );
        println!(
            "   speedup {:.2}x vs scalar (O0->O2: {:.0} -> {:.0} samples/s, {:+.1}%)",
            o2_sps / scalar_sps.max(1e-9),
            o0_sps,
            o2_sps,
            (o2_sps / o0_sps.max(1e-9) - 1.0) * 100.0
        );
        rows.push(obj(vec![
            ("name", Json::Str(name.to_string())),
            ("trained_like", Json::Bool(trained)),
            // Quick-mode rows carry short (noisy) timing windows; the CI
            // gate relaxes its same-run throughput margin accordingly.
            ("quick", Json::Bool(quick)),
            ("batch", Json::Num(batch as f64)),
            ("l_luts", Json::Num(model.num_luts() as f64)),
            ("word_ops_o0", Json::Num(ops_o0 as f64)),
            ("word_ops_o1", Json::Num(ops_o1 as f64)),
            ("word_ops_o2", Json::Num(ops_o2 as f64)),
            ("op_reduction_o2", Json::Num(reduction)),
            ("compile_s", Json::Num(compile_s)),
            ("scalar_samples_per_s", Json::Num(scalar_sps)),
            ("bitsliced_o0_samples_per_s", Json::Num(o0_sps)),
            ("bitsliced_samples_per_s", Json::Num(o2_sps)),
            ("speedup", Json::Num(o2_sps / scalar_sps.max(1e-9))),
            (
                "width_samples_per_s",
                Json::Obj(width_sps.into_iter().collect()),
            ),
        ]));

        if !quick {
            let one: Vec<f32> = x[..input].to_vec();
            bench(
                &format!("netlist/single/{name}"),
                10,
                0.5,
                50_000,
                Some((1.0, "samples")),
                || {
                    std::hint::black_box(scalar.infer_batch(&one).unwrap());
                },
            );
        }
    }

    let agg = 1.0 - trained_ops_o2 as f64 / trained_ops_o0.max(1) as f64;
    println!(
        "\naggregate over trained-like repro networks: O2 executes {} of {} \
         O0 word ops (-{:.1}%)",
        trained_ops_o2,
        trained_ops_o0,
        agg * 100.0
    );

    let out = Json::Arr(rows).to_string();
    if let Err(e) = std::fs::write("BENCH_engine.json", &out) {
        eprintln!("could not write BENCH_engine.json: {e}");
    } else {
        println!("wrote BENCH_engine.json ({n_cases} cases)");
    }
    let out = Json::Arr(reports).to_string();
    if let Err(e) = std::fs::write("BENCH_compile_report.json", &out) {
        eprintln!("could not write BENCH_compile_report.json: {e}");
    } else {
        println!("wrote BENCH_compile_report.json ({n_cases} cases)");
    }
}
