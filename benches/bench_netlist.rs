//! Fabric inference throughput: the scalar backend (per-sample table
//! lookups) vs the compiled bitsliced backend (64 samples per word)
//! across the paper's circuit scales — the inference-latency substrate
//! behind Fig. 6 / Table III and the serving hot path. Both run as
//! sessions of the unified `Model::compile` API, selected by registry
//! name. Also reports single-sample latency (scalar path) and writes
//! `BENCH_engine.json` rows (samples/sec for both backends) so the perf
//! trajectory is tracked PR over PR.

use neuralut::fabric::{FabricOptions, Model};
use neuralut::luts::random_network;
use neuralut::util::bench::bench;
use neuralut::util::json::{obj, Json};

fn main() {
    println!("== bench_netlist: scalar fabric vs compiled bitsliced engine ==");
    // (name, input, input_bits, widths, fan_in, beta)
    let cases = [
        ("jsc-2l-scale", 16usize, 4usize, vec![32usize, 5], 3usize, 4usize),
        ("hdr-mini-scale", 196, 2, vec![64, 32, 10], 6, 2),
        ("jsc-5l-scale", 16, 4, vec![128, 128, 128, 64, 5], 3, 4),
        ("hdr-5l-paper-scale", 784, 2, vec![256, 100, 100, 100, 10], 6, 2),
        // LogicNets-like low-β point: small per-bit functions, where the
        // word-level engine's logic sharing pays off hardest.
        ("logicnets-scale", 32, 1, vec![64, 32, 8], 4, 1),
    ];
    let n_cases = cases.len();
    let mut rows: Vec<Json> = Vec::new();
    for (name, input, bits, widths, fan_in, beta) in cases {
        let model = Model::from_network(
            random_network(1, input, bits, &widths, fan_in, beta, 4),
        );
        let scalar = model
            .compile(&FabricOptions::new().backend("scalar"))
            .expect("scalar compile")
            .session();
        let t0 = std::time::Instant::now();
        let fabric = model
            .compile(&FabricOptions::new().backend("bitsliced"))
            .expect("lowering failed");
        let compile_s = t0.elapsed().as_secs_f64();
        let bitsliced = fabric.session();
        println!(
            "-- {name}: {} L-LUTs, compiled to {} word ops in {:.3}s",
            model.num_luts(),
            fabric.bit_netlist().expect("bitsliced program").num_ops(),
            compile_s
        );
        let batch = 4096usize;
        let x: Vec<f32> = (0..batch * input)
            .map(|i| (i % 97) as f32 / 97.0)
            .collect();
        let m_scalar = bench(
            &format!("netlist/scalar/batch4096/{name}"),
            1,
            1.0,
            200,
            Some((batch as f64, "samples")),
            || {
                std::hint::black_box(scalar.infer_batch(&x).unwrap());
            },
        );
        let m_bits = bench(
            &format!("engine/bitsliced/batch4096/{name}"),
            1,
            1.0,
            200,
            Some((batch as f64, "samples")),
            || {
                std::hint::black_box(bitsliced.infer_batch(&x).unwrap());
            },
        );
        let scalar_sps = m_scalar.throughput.map(|(t, _)| t).unwrap_or(0.0);
        let bits_sps = m_bits.throughput.map(|(t, _)| t).unwrap_or(0.0);
        println!(
            "   speedup {:.2}x (scalar {:.0} -> bitsliced {:.0} samples/s)",
            bits_sps / scalar_sps.max(1e-9),
            scalar_sps,
            bits_sps
        );
        rows.push(obj(vec![
            ("name", Json::Str(name.to_string())),
            ("batch", Json::Num(batch as f64)),
            ("l_luts", Json::Num(model.num_luts() as f64)),
            (
                "word_ops",
                Json::Num(fabric.bit_netlist().expect("bitsliced program").num_ops() as f64),
            ),
            ("compile_s", Json::Num(compile_s)),
            ("scalar_samples_per_s", Json::Num(scalar_sps)),
            ("bitsliced_samples_per_s", Json::Num(bits_sps)),
            ("speedup", Json::Num(bits_sps / scalar_sps.max(1e-9))),
        ]));

        let one: Vec<f32> = x[..input].to_vec();
        bench(
            &format!("netlist/single/{name}"),
            10,
            0.5,
            50_000,
            Some((1.0, "samples")),
            || {
                std::hint::black_box(scalar.infer_batch(&one).unwrap());
            },
        );
    }
    let out = Json::Arr(rows).to_string();
    if let Err(e) = std::fs::write("BENCH_engine.json", &out) {
        eprintln!("could not write BENCH_engine.json: {e}");
    } else {
        println!("wrote BENCH_engine.json ({n_cases} cases)");
    }
}
