//! AOT train-step latency per config: the XLA-side cost of one optimizer
//! step executed from the Rust coordinator (params marshalled as literals,
//! outputs decomposed). Requires `make artifacts`.

use neuralut::coordinator::schedule::sgdr_lr;
use neuralut::data::Dataset;
use neuralut::manifest::Manifest;
use neuralut::runtime::{to_literal, HostTensor, Runtime};
use neuralut::util::bench::bench;

fn main() -> anyhow::Result<()> {
    println!("== bench_train_step: AOT optimizer step via PJRT ==");
    let rt = Runtime::cpu()?;
    for name in ["moons-neuralut", "jsc-2l", "hdr-mini", "jsc-5l"] {
        let dir = neuralut::artifacts_dir().join(name);
        if !dir.join("manifest.json").exists() {
            println!("skipping {name}: run `make artifacts`");
            continue;
        }
        let m = Manifest::load(&dir)?;
        let ds = Dataset::load_named(&m.dataset)?;
        let init = rt.load_artifact(&m, "init")?;
        let step_exe = rt.load_artifact(&m, "train_step")?;
        let n = m.params.len();
        let state = init.run_raw(&[to_literal(&HostTensor::scalar_i32(0))?])?;
        let zeros: Vec<xla::Literal> = m
            .params
            .iter()
            .map(|p| {
                to_literal(&HostTensor::f32(p.shape.clone(), vec![0.0; p.elem_count()]))
            })
            .collect::<anyhow::Result<_>>()?;
        let b = m.batch;
        let mut x = Vec::with_capacity(b * m.input_size);
        let mut y = Vec::with_capacity(b);
        for i in 0..b {
            x.extend_from_slice(ds.train_row(i));
            y.push(ds.train_y[i]);
        }
        let step_lit = to_literal(&HostTensor::scalar_f32(1.0))?;
        let lr = sgdr_lr(m.lr_min, m.lr_max, m.sgdr_t0, m.sgdr_mult, 100, 0);
        let lr_lit = to_literal(&HostTensor::scalar_f32(lr as f32))?;
        let x_lit = to_literal(&HostTensor::f32(vec![b, m.input_size], x))?;
        let y_lit = to_literal(&HostTensor::i32(vec![b], y))?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * n + 4);
        args.extend(state.iter());
        args.extend(zeros.iter());
        args.extend(zeros.iter());
        args.push(&step_lit);
        args.push(&lr_lit);
        args.push(&x_lit);
        args.push(&y_lit);
        bench(
            &format!("train_step/{name} (batch {b}, {n} tensors)"),
            3,
            2.0,
            500,
            Some((b as f64, "samples")),
            || {
                std::hint::black_box(step_exe.run_literals_refs(&args).unwrap());
            },
        );
    }
    Ok(())
}
