//! Serving stack: router + dynamic batcher over the fabric simulator —
//! throughput and latency percentiles vs offered load and batching window
//! (the edge-deployment claim, and the knob study for the batcher).

use std::sync::Arc;
use std::time::{Duration, Instant};

use neuralut::data::{Dataset, Workload};
use neuralut::luts::random_network;
use neuralut::server::{Server, ServerConfig};
use neuralut::util::stats;

fn drive(net: Arc<neuralut::luts::LutNetwork>, cfg: ServerConfig, rate: f64,
         n_req: usize) -> (f64, stats::Summary) {
    let ds = Dataset::synthetic(1, 16, 256, net.input_size, net.n_class);
    let server = Server::start(net, cfg);
    let client = server.client();
    let workload = Workload::poisson(&ds, 2, n_req, rate);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n_req);
    for (t_arrival, feats) in workload.requests {
        let now = t0.elapsed().as_secs_f64();
        if t_arrival > now {
            std::thread::sleep(Duration::from_secs_f64(t_arrival - now));
        }
        pending.push(client.infer_async(feats).unwrap());
    }
    let lat_us: Vec<f64> = pending
        .into_iter()
        .map(|rx| rx.recv().unwrap().latency.as_secs_f64() * 1e6)
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    (n_req as f64 / wall, stats::summarize(&lat_us))
}

fn main() {
    println!("== bench_server: router + dynamic batcher ==");
    let net = Arc::new(random_network(11, 196, 2, &[64, 32, 10], 6, 2, 4));
    let n_req = 30_000;

    println!("\n-- throughput / latency vs offered load (window 100us, max_batch 512) --");
    for rate in [20_000.0, 50_000.0, 100_000.0, 200_000.0] {
        let cfg = ServerConfig {
            max_batch: 512,
            batch_window: Duration::from_micros(100),
            ..Default::default()
        };
        let (tput, s) = drive(net.clone(), cfg, rate, n_req);
        println!(
            "offered {:>7.0}/s -> served {:>7.0}/s  p50 {:>6.0}us p95 {:>6.0}us p99 {:>6.0}us",
            rate, tput, s.p50, s.p95, s.p99
        );
    }

    println!("\n-- batching-window ablation (offered 100k/s) --");
    for window_us in [0u64, 50, 100, 200, 500] {
        let cfg = ServerConfig {
            max_batch: 512,
            batch_window: Duration::from_micros(window_us),
            ..Default::default()
        };
        let (tput, s) = drive(net.clone(), cfg, 100_000.0, n_req);
        println!(
            "window {:>4}us -> served {:>7.0}/s  p50 {:>6.0}us p99 {:>6.0}us",
            window_us, tput, s.p50, s.p99
        );
    }
}
