//! Serving-runtime bench: worker-pool scaling and the backpressure
//! envelope. Two drives over the multi-worker sharded server:
//!
//! * closed-loop drain — flood the bounded queue and time until every
//!   reply lands: the compute-bound throughput ceiling per worker count
//!   and backend (all workers share ONE compiled fabric);
//! * open-loop shed — paced Poisson arrivals submitted with the
//!   non-blocking `try_infer`, measuring served rate vs rejection rate.
//!
//! Backends are selected by registry name through the unified
//! `Model::compile` path — adding a backend to the sweep is one string.
//! Writes `BENCH_server.json` (throughput, p50/p99 latency, rejection
//! rate and queue-wait / batch-formation / execute stage percentiles per
//! row) so the serving perf trajectory is tracked PR over PR — the CI
//! `bench-smoke` gate reads it against `BENCH_baseline.json` — plus
//! `BENCH_metrics.json`, the raw `neuralut_server_*` metrics snapshot of
//! the bitsliced 4-worker drain, JSON-encoded via `obs::expo`.
//! `NEURALUT_BENCH_QUICK=1` shrinks the request counts for CI smoke runs.

use std::time::{Duration, Instant};

use neuralut::data::{Dataset, Workload};
use neuralut::engine::{detect_lane_words, lane_backend_name};
use neuralut::fabric::{FabricOptions, Model};
use neuralut::luts::random_network;
use neuralut::obs::{expo, MetricsSnapshot};
use neuralut::server::ServerStats;
use neuralut::util::json::{obj, Json};
use neuralut::util::stats;

/// Closed-loop drain: submit `n_req` async requests as fast as the
/// bounded queue accepts them (blocking on backpressure) and time until
/// every reply lands.
fn drain(model: &Model, opts: &FabricOptions, n_req: usize)
         -> (f64, stats::Summary, ServerStats, MetricsSnapshot) {
    let ds = Dataset::synthetic(1, 16, 256, model.input_size(), model.n_class());
    let server = model.compile(opts).expect("compile").serve();
    let client = server.client();
    let workload = Workload::poisson(&ds, 2, n_req, 1e9); // effectively instant
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n_req);
    for (_, feats) in workload.requests {
        pending.push(client.infer_async(feats).unwrap());
    }
    let lat_us: Vec<f64> = pending
        .into_iter()
        .map(|rx| rx.recv().unwrap().latency.as_secs_f64() * 1e6)
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    (
        n_req as f64 / wall,
        stats::summarize(&lat_us),
        server.stats(),
        server.metrics(),
    )
}

/// Open-loop shed: paced arrivals through `try_infer`; a full queue sheds
/// (Overloaded) instead of blocking.
fn shed(model: &Model, opts: &FabricOptions, rate: f64, n_req: usize)
        -> (f64, f64, stats::Summary) {
    let ds = Dataset::synthetic(1, 16, 256, model.input_size(), model.n_class());
    let server = model.compile(opts).expect("compile").serve();
    let client = server.client();
    let workload = Workload::poisson(&ds, 3, n_req, rate);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    for (t_arrival, feats) in workload.requests {
        let now = t0.elapsed().as_secs_f64();
        if t_arrival > now {
            std::thread::sleep(Duration::from_secs_f64(t_arrival - now));
        }
        match client.try_infer(feats) {
            Ok(rx) => pending.push(rx),
            Err(_) => rejected += 1,
        }
    }
    let lat_us: Vec<f64> = pending
        .into_iter()
        .map(|rx| rx.recv().unwrap().latency.as_secs_f64() * 1e6)
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    (
        (n_req - rejected) as f64 / wall,
        rejected as f64 / n_req as f64,
        stats::summarize(&lat_us),
    )
}

fn main() {
    let quick = std::env::var_os("NEURALUT_BENCH_QUICK").is_some_and(|v| !v.is_empty());
    // Rows produced with fault injection armed (NEURALUT_FAULTS — e.g. the
    // CI chaos leg) measure survival, not speed: stamp them so
    // check_bench.py never compares them against clean throughput
    // baselines.
    let faults_armed = neuralut::util::faults::armed();
    println!(
        "== bench_server: multi-worker sharded serving runtime{}{} ==",
        if quick { " (quick mode)" } else { "" },
        if faults_armed { " (FAULTS ARMED — rows excluded from baselines)" } else { "" }
    );
    let model = Model::from_network(random_network(11, 196, 2, &[64, 32, 10], 6, 2, 4));
    let n_req = if quick { 4_000 } else { 30_000 };
    let mut rows: Vec<Json> = Vec::new();

    println!("\n-- worker scaling, closed-loop drain ({n_req} requests, max_batch 256) --");
    let mut bits_1w = 0.0f64;
    let mut bits_4w = 0.0f64;
    let mut snap_4w: Option<MetricsSnapshot> = None;
    // Sweep both built-in reference backends plus the widest plane
    // format this CPU supports (a no-op extra leg on machines where the
    // detector lands on plain `bitsliced`).
    let widest = lane_backend_name(detect_lane_words()).expect("detected width is registered");
    let mut backends = vec!["scalar", "bitsliced"];
    if widest != "bitsliced" {
        backends.push(widest);
    }
    for backend in backends {
        for workers in [1usize, 2, 4] {
            let opts = FabricOptions::new()
                .backend(backend)
                .max_batch(256)
                .batch_window(Duration::from_micros(50))
                .workers(workers)
                .queue_depth(4096);
            let (tput, s, st, snap) = drain(&model, &opts, n_req);
            println!(
                "{backend:<9} workers {workers} -> {tput:>8.0} req/s  p50 {:>7.0}us \
                 p99 {:>7.0}us  mean batch {:.1}",
                s.p50, s.p99, st.mean_batch
            );
            println!(
                "          stages us: queue-wait p50 {:.0} p99 {:.0} | \
                 batch-form p50 {:.0} p99 {:.0} | execute p50 {:.0} p99 {:.0}",
                st.queue_wait_p50_us, st.queue_wait_p99_us,
                st.batch_form_p50_us, st.batch_form_p99_us,
                st.execute_p50_us, st.execute_p99_us
            );
            if backend == "bitsliced" && workers == 1 {
                bits_1w = tput;
            }
            if backend == "bitsliced" && workers == 4 {
                bits_4w = tput;
                snap_4w = Some(snap);
            }
            rows.push(obj(vec![
                ("section", Json::Str("saturation".into())),
                ("faults_armed", Json::Bool(faults_armed)),
                ("backend", Json::Str(backend.into())),
                ("workers", Json::Num(workers as f64)),
                ("requests", Json::Num(n_req as f64)),
                ("served_per_s", Json::Num(tput)),
                ("p50_us", Json::Num(s.p50)),
                ("p99_us", Json::Num(s.p99)),
                ("rejection_rate", Json::Num(0.0)),
                ("mean_batch", Json::Num(st.mean_batch)),
                ("queue_wait_p50_us", Json::Num(st.queue_wait_p50_us)),
                ("queue_wait_p99_us", Json::Num(st.queue_wait_p99_us)),
                ("batch_form_p50_us", Json::Num(st.batch_form_p50_us)),
                ("batch_form_p99_us", Json::Num(st.batch_form_p99_us)),
                ("execute_p50_us", Json::Num(st.execute_p50_us)),
                ("execute_p99_us", Json::Num(st.execute_p99_us)),
            ]));
        }
    }
    println!(
        "bitsliced scaling, 4 workers vs 1: {:.2}x ({:.0} -> {:.0} req/s)",
        bits_4w / bits_1w.max(1e-9), bits_1w, bits_4w
    );

    println!("\n-- backpressure envelope: open-loop try_infer (queue_depth 64, 2 workers) --");
    let rates: &[f64] = if quick { &[100_000.0] } else { &[50_000.0, 100_000.0, 200_000.0] };
    let shed_req = if quick { 4_000 } else { 20_000 };
    for &rate in rates {
        let opts = FabricOptions::new()
            .backend("bitsliced")
            .max_batch(256)
            .batch_window(Duration::from_micros(100))
            .workers(2)
            .queue_depth(64);
        let (tput, rej, s) = shed(&model, &opts, rate, shed_req);
        println!(
            "offered {rate:>7.0}/s -> served {tput:>7.0}/s  shed {:>5.1}%  \
             p50 {:>6.0}us p99 {:>6.0}us",
            rej * 100.0, s.p50, s.p99
        );
        rows.push(obj(vec![
            ("section", Json::Str("backpressure".into())),
            ("faults_armed", Json::Bool(faults_armed)),
            ("backend", Json::Str("bitsliced".into())),
            ("workers", Json::Num(2.0)),
            ("queue_depth", Json::Num(64.0)),
            ("offered_per_s", Json::Num(rate)),
            ("served_per_s", Json::Num(tput)),
            ("p50_us", Json::Num(s.p50)),
            ("p99_us", Json::Num(s.p99)),
            ("rejection_rate", Json::Num(rej)),
        ]));
    }

    let n_rows = rows.len();
    let out = Json::Arr(rows).to_string();
    if let Err(e) = std::fs::write("BENCH_server.json", &out) {
        eprintln!("could not write BENCH_server.json: {e}");
    } else {
        println!("\nwrote BENCH_server.json ({n_rows} rows)");
    }
    // Raw metrics snapshot of the headline (bitsliced, 4-worker) drain —
    // the full neuralut_server_* registry, for the CI artifact upload.
    if let Some(snap) = snap_4w {
        let out = expo::to_json(&snap).to_string();
        if let Err(e) = std::fs::write("BENCH_metrics.json", &out) {
            eprintln!("could not write BENCH_metrics.json: {e}");
        } else {
            println!("wrote BENCH_metrics.json");
        }
    }
}
