//! Network front-door bench: wire-protocol latency over loopback and
//! the connection-level saturation envelope.
//!
//! Two drives against a real `NetServer` (TCP, binary wire protocol):
//!
//! * payload sweep — one blocking `WireClient`, batches of 1 / 8 / 64
//!   rows per request frame, p50/p90/p99 round-trip latency per payload
//!   size: what one well-behaved client sees, protocol overhead
//!   included;
//! * saturation — many client threads flooding pipelined frames through
//!   a deliberately shallow worker queue, counting served rows vs typed
//!   `Overloaded` refusals: the admission-control envelope (refusals
//!   are *answers*, so served + refused must equal offered — a hang
//!   shows up as a missing reply, failing the bench).
//!
//! Writes `BENCH_net.json`; `scripts/check_bench.py` gates that the
//! percentile ordering holds (p50 ≤ p90 ≤ p99) and that saturation
//! still serves (> 0 rows/s). `NEURALUT_BENCH_QUICK=1` shrinks request
//! counts for CI smoke runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use neuralut::fabric::FabricOptions;
use neuralut::luts::random_network;
use neuralut::net::{ModelManager, NetConfig, NetServer, WireClient, WireRefusal};
use neuralut::util::json::{obj, Json};
use neuralut::util::rng::Rng;
use neuralut::util::stats::percentile_sorted;

/// Stage a models directory with one `bench.nlut` and start the front
/// door on an ephemeral loopback port.
fn start_server(opts: &FabricOptions) -> (NetServer, std::net::SocketAddr, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!("neuralut_bench_net_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir models");
    random_network(11, 196, 2, &[64, 32, 10], 6, 2, 4)
        .save(&dir.join("bench.nlut"))
        .expect("save model");
    let manager = ModelManager::open(&dir, opts).expect("open manager");
    let server = NetServer::start(
        manager,
        &NetConfig { listen_addr: "127.0.0.1:0".into(), max_connections: 512 },
    )
    .expect("start server");
    let addr = server.local_addr();
    (server, addr, dir)
}

fn random_features(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
    (0..rows * cols).map(|_| rng.f32()).collect()
}

/// One client, `n_req` sequential request frames of `rows` rows each:
/// round-trip microseconds, sorted.
fn payload_sweep(addr: std::net::SocketAddr, rows: usize, cols: usize, n_req: usize) -> Vec<f64> {
    let mut client = WireClient::connect(addr).expect("connect");
    let mut rng = Rng::new(7 + rows as u64);
    let mut lat_us = Vec::with_capacity(n_req);
    for _ in 0..n_req {
        let feats = random_features(&mut rng, rows, cols);
        let t0 = Instant::now();
        let preds = client.infer("bench", &feats, rows).expect("infer");
        assert_eq!(preds.len(), rows, "every row answered");
        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lat_us
}

/// Flood from `threads` connections; returns (served rows/s, refusal
/// rate, wall seconds). Every frame is answered — served or typed
/// refusal — so the totals must add up.
fn saturate(
    addr: std::net::SocketAddr,
    threads: usize,
    per_thread: usize,
    rows: usize,
    cols: usize,
) -> (f64, f64, f64) {
    let served = Arc::new(AtomicUsize::new(0));
    let refused = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let (served, refused) = (served.clone(), refused.clone());
            std::thread::spawn(move || {
                let mut client = WireClient::connect(addr).expect("connect");
                let mut rng = Rng::new(100 + t as u64);
                for _ in 0..per_thread {
                    let feats = random_features(&mut rng, rows, cols);
                    match client.infer("bench", &feats, rows) {
                        Ok(preds) => {
                            assert_eq!(preds.len(), rows);
                            served.fetch_add(rows, Ordering::Relaxed);
                        }
                        Err(e) => {
                            let refusal = e
                                .downcast_ref::<WireRefusal>()
                                .unwrap_or_else(|| panic!("untyped failure: {e:#}"));
                            assert_eq!(refusal.code, 1, "only Overloaded expected: {refusal}");
                            refused.fetch_add(rows, Ordering::Relaxed);
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("saturation client");
    }
    let wall = t0.elapsed().as_secs_f64();
    let served = served.load(Ordering::Relaxed);
    let refused = refused.load(Ordering::Relaxed);
    let offered = threads * per_thread * rows;
    assert_eq!(served + refused, offered, "every offered row accounted for");
    (served as f64 / wall, refused as f64 / offered as f64, wall)
}

fn main() {
    let quick = std::env::var_os("NEURALUT_BENCH_QUICK").is_some_and(|v| !v.is_empty());
    let faults_armed = neuralut::util::faults::armed();
    println!(
        "== bench_net: wire protocol over loopback{}{} ==",
        if quick { " (quick mode)" } else { "" },
        if faults_armed { " (FAULTS ARMED — rows excluded from baselines)" } else { "" }
    );
    let cols = 196;
    let mut rows_out: Vec<Json> = Vec::new();

    println!("\n-- payload sweep: rows per request frame x round-trip percentiles --");
    let opts = FabricOptions::new().backend("bitsliced").workers(2).queue_depth(4096);
    let (server, addr, dir) = start_server(&opts);
    let n_req = if quick { 300 } else { 3_000 };
    for batch_rows in [1usize, 8, 64] {
        let lat = payload_sweep(addr, batch_rows, cols, n_req);
        let (p50, p90, p99) = (
            percentile_sorted(&lat, 50.0),
            percentile_sorted(&lat, 90.0),
            percentile_sorted(&lat, 99.0),
        );
        let bytes = 15 + 4 + 8 + 4 * batch_rows * cols; // payload size on the wire
        println!(
            "rows {batch_rows:>3} ({bytes:>6} B/frame) -> p50 {p50:>7.0}us  p90 {p90:>7.0}us  \
             p99 {p99:>7.0}us  ({:.0} rows/s one client)",
            batch_rows as f64 * n_req as f64 / (lat.iter().sum::<f64>() / 1e6)
        );
        rows_out.push(obj(vec![
            ("section", Json::Str("net_payload".into())),
            ("faults_armed", Json::Bool(faults_armed)),
            ("rows_per_frame", Json::Num(batch_rows as f64)),
            ("frame_bytes", Json::Num(bytes as f64)),
            ("requests", Json::Num(n_req as f64)),
            ("p50_us", Json::Num(p50)),
            ("p90_us", Json::Num(p90)),
            ("p99_us", Json::Num(p99)),
        ]));
    }
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);

    println!("\n-- saturation: flooding clients vs a shallow queue (depth 128, 2 workers) --");
    let opts = FabricOptions::new().backend("bitsliced").workers(2).queue_depth(128);
    let (server, addr, dir) = start_server(&opts);
    let threads = 8;
    let per_thread = if quick { 150 } else { 1_500 };
    let batch_rows = 16;
    let (served_per_s, refusal_rate, wall) = saturate(addr, threads, per_thread, batch_rows, cols);
    println!(
        "{threads} clients x {per_thread} frames x {batch_rows} rows -> \
         served {served_per_s:.0} rows/s, refused {:.1}% (typed Overloaded), wall {wall:.2}s",
        refusal_rate * 100.0
    );
    rows_out.push(obj(vec![
        ("section", Json::Str("net_saturation".into())),
        ("faults_armed", Json::Bool(faults_armed)),
        ("clients", Json::Num(threads as f64)),
        ("rows_per_frame", Json::Num(batch_rows as f64)),
        ("offered_rows", Json::Num((threads * per_thread * batch_rows) as f64)),
        ("served_per_s", Json::Num(served_per_s)),
        ("refusal_rate", Json::Num(refusal_rate)),
    ]));
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);

    let n = rows_out.len();
    let out = Json::Arr(rows_out).to_string();
    if let Err(e) = std::fs::write("BENCH_net.json", &out) {
        eprintln!("could not write BENCH_net.json: {e}");
    } else {
        println!("\nwrote BENCH_net.json ({n} rows)");
    }
}
