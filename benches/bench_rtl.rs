//! RTL generation throughput (toolflow stage 3): Verilog text emission per
//! L-LUT, across circuit scales.

use neuralut::luts::random_network;
use neuralut::rtl::generate_verilog;
use neuralut::util::bench::bench;

fn main() {
    println!("== bench_rtl: Verilog generation ==");
    for (name, input, bits, widths, fan_in, beta) in [
        ("jsc-2l-scale", 16usize, 4usize, vec![32usize, 5], 3usize, 4usize),
        ("hdr-mini-scale", 196, 2, vec![64, 32, 10], 6, 2),
        ("jsc-5l-scale", 16, 4, vec![128, 128, 128, 64, 5], 3, 4),
    ] {
        let net = random_network(5, input, bits, &widths, fan_in, beta, 4);
        let mut last_len = 0usize;
        bench(
            &format!("rtl/verilog/{name}"),
            1,
            1.0,
            100,
            Some((net.num_luts() as f64, "L-LUTs")),
            || {
                last_len = generate_verilog(&net).len();
                std::hint::black_box(last_len);
            },
        );
        println!("  emitted {last_len} bytes of Verilog");
    }
}
