//! Truth-table conversion throughput (toolflow stage 2): one PJRT call
//! converts a whole circuit layer (all L-LUTs batched over 2^(beta*F)
//! enumerated inputs through the Pallas kernel). Requires `make artifacts`.

use neuralut::coordinator::trainer::{TrainOpts, Trainer};
use neuralut::data::Dataset;
use neuralut::luts::convert;
use neuralut::manifest::Manifest;
use neuralut::runtime::Runtime;
use neuralut::util::bench::bench;

fn main() -> anyhow::Result<()> {
    println!("== bench_conversion: sub-network -> L-LUT enumeration ==");
    let rt = Runtime::cpu()?;
    for name in ["moons-neuralut", "jsc-2l", "hdr-mini"] {
        let dir = neuralut::artifacts_dir().join(name);
        if !dir.join("manifest.json").exists() {
            println!("skipping {name}: run `make artifacts`");
            continue;
        }
        let m = Manifest::load(&dir)?;
        let ds = Dataset::load_named(&m.dataset)?;
        let trainer = Trainer::new(&rt, &m, &ds)?;
        let r = trainer.run(0, &TrainOpts {
            epochs: Some(0),
            quiet: true,
            ..Default::default()
        })?;
        // Warm the executable cache so we bench execution, not compilation.
        let _ = convert::convert(&rt, &m, &r.params)?;
        let total_luts: usize = m.layers.iter().sum();
        let entries: usize = m
            .tt
            .iter()
            .map(|t| t.num_luts * t.entries)
            .sum();
        bench(
            &format!("convert/{name} ({total_luts} L-LUTs, {entries} entries)"),
            1,
            2.0,
            100,
            Some((total_luts as f64, "L-LUTs")),
            || {
                std::hint::black_box(convert::convert(&rt, &m, &r.params).unwrap());
            },
        );
    }
    Ok(())
}
