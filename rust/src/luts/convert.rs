//! Sub-network → L-LUT conversion manager (toolflow stage 2).
//!
//! For each circuit layer, marshals the trained parameters named in the
//! manifest's `tt[l].args` and executes the AOT-compiled enumeration
//! program `tt_layer{l}.hlo.txt` (one PJRT call per layer — all of the
//! layer's L-LUTs convert in a single batched kernel invocation, which is
//! the Pallas hot path at B = 2^(beta*F)). The resulting integer codes
//! become the truth tables of a [`LutNetwork`].

use anyhow::{bail, Context, Result};

use super::{LutLayer, LutNetwork};
use crate::manifest::Manifest;
use crate::nn::params::ParamStore;
use crate::runtime::Runtime;

/// Convert a trained model into its L-LUT network.
pub fn convert(rt: &Runtime, m: &Manifest, params: &ParamStore) -> Result<LutNetwork> {
    let index = params.index();
    let mut layers = Vec::with_capacity(m.tt.len());
    for tt in &m.tt {
        let exe = rt
            .load_artifact(m, &format!("tt_layer{}", tt.layer))
            .with_context(|| format!("loading tt program for layer {}", tt.layer))?;
        let args: Vec<_> = tt
            .args
            .iter()
            .map(|name| {
                index
                    .get(name.as_str())
                    .map(|&i| params.tensors[i].clone())
                    .with_context(|| format!("tt arg {name} missing"))
            })
            .collect::<Result<Vec<_>>>()?;
        let out = exe.run(&args)?;
        if out.len() != 1 {
            bail!("tt program returned {} outputs, expected 1", out.len());
        }
        let codes = out[0].as_i32()?;
        if codes.len() != tt.num_luts * tt.entries {
            bail!(
                "layer {}: tt output size {} != {}x{}",
                tt.layer,
                codes.len(),
                tt.num_luts,
                tt.entries
            );
        }
        let tables: Vec<i16> = codes.iter().map(|&c| c as i16).collect();
        layers.push(LutLayer {
            indices: m.indices[tt.layer].clone(),
            tables,
            fan_in: tt.fan_in,
            in_bits: tt.in_bits,
            out_bits: tt.out_bits,
            signed_out: tt.signed_out,
        });
    }
    let net = LutNetwork {
        name: m.name.clone(),
        input_size: m.input_size,
        input_bits: m.layer_in_bits[0],
        n_class: m.n_class,
        layers,
    };
    net.validate().context("converted network failed validation")?;
    Ok(net)
}
