//! Converted L-LUT network model: truth tables + wiring, with a compact
//! binary serialization ("NLUT" v1) so converted models can be shipped
//! without the training artifacts.
//!
//! An L-LUT in circuit layer `l` has `fan_in` inputs of `in_bits` bits each
//! and one `out_bits`-bit output. Table addresses follow the shared
//! convention (python `tt.py`, `rtl/`): input `j` occupies address bits
//! `[in_bits*j, in_bits*(j+1))`. Output codes are stored as `i16`
//! (unsigned codes for hidden layers, two's-complement signed codes for the
//! logit layer).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

pub mod convert;

/// One circuit layer of the converted network.
#[derive(Debug, Clone)]
pub struct LutLayer {
    /// `[num_luts][fan_in]` indices into the previous layer's outputs.
    pub indices: Vec<Vec<u32>>,
    /// Flattened tables: `num_luts * entries` output codes.
    pub tables: Vec<i16>,
    pub fan_in: usize,
    pub in_bits: usize,
    pub out_bits: usize,
    pub signed_out: bool,
}

impl LutLayer {
    pub fn num_luts(&self) -> usize {
        self.indices.len()
    }

    pub fn entries(&self) -> usize {
        1usize << (self.in_bits * self.fan_in)
    }

    /// The table slice of LUT `i`.
    pub fn table(&self, i: usize) -> &[i16] {
        let e = self.entries();
        &self.tables[i * e..(i + 1) * e]
    }

    fn validate(&self, prev_width: usize) -> Result<()> {
        if self.tables.len() != self.num_luts() * self.entries() {
            bail!("table size mismatch");
        }
        // Code ranges in i32: `1i16 << out_bits` overflows (panics in
        // debug, wraps in release) once out_bits >= 15, and i16 codes
        // cannot hold wider outputs anyway.
        if self.out_bits == 0 || self.out_bits > 15 {
            bail!("out_bits {} unsupported (i16 codes hold 1..=15 bits)",
                  self.out_bits);
        }
        let max_code = 1i32 << self.out_bits;
        for &v in &self.tables {
            let v = v as i32;
            let ok = if self.signed_out {
                let q = (1i32 << (self.out_bits - 1)) - 1;
                (-q..=q).contains(&v)
            } else {
                (0..max_code).contains(&v)
            };
            if !ok {
                bail!("output code {v} out of range for {} bits", self.out_bits);
            }
        }
        for row in &self.indices {
            if row.len() != self.fan_in {
                bail!("index row width != fan_in");
            }
            if row.iter().any(|&i| i as usize >= prev_width) {
                bail!("index out of range");
            }
        }
        Ok(())
    }
}

/// A complete converted model: the circuit-level network of L-LUTs.
#[derive(Debug, Clone)]
pub struct LutNetwork {
    pub name: String,
    pub input_size: usize,
    /// Bit-width of the quantized circuit inputs.
    pub input_bits: usize,
    pub n_class: usize,
    pub layers: Vec<LutLayer>,
}

impl LutNetwork {
    /// Structural validation across layers.
    pub fn validate(&self) -> Result<()> {
        let mut prev = self.input_size;
        for (l, layer) in self.layers.iter().enumerate() {
            layer
                .validate(prev)
                .with_context(|| format!("layer {l}"))?;
            prev = layer.num_luts();
        }
        match self.layers.last() {
            Some(last) if last.num_luts() == self.n_class => Ok(()),
            Some(_) => bail!("last layer width != n_class"),
            None => bail!("no layers"),
        }
    }

    /// Total number of L-LUTs.
    pub fn num_luts(&self) -> usize {
        self.layers.iter().map(|l| l.num_luts()).sum()
    }

    /// Total truth-table storage in bits (the "ROM size" of the design).
    pub fn table_bits(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.num_luts() * l.entries() * l.out_bits)
            .sum()
    }

    /// Stable FNV-1a digest of the whole model — name, shape, wiring and
    /// tables. Compiled-fabric artifacts (`.nfab`) record it so a cached
    /// program is never served against a different network than the one
    /// it was compiled from.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv1a::new();
        h.bytes(self.name.as_bytes());
        for v in [self.input_size, self.input_bits, self.n_class, self.layers.len()] {
            h.u64(v as u64);
        }
        for l in &self.layers {
            for v in [l.fan_in, l.in_bits, l.out_bits, l.signed_out as usize, l.num_luts()] {
                h.u64(v as u64);
            }
            for row in &l.indices {
                for &i in row {
                    h.u64(i as u64);
                }
            }
            for &t in &l.tables {
                h.u64(t as u16 as u64);
            }
        }
        h.finish()
    }

    // ---- serialization ----------------------------------------------------

    const MAGIC: u32 = 0x4E4C5554; // "NLUT"
    const VERSION: u32 = 1;

    /// Serialize to the NLUT v1 binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        let w32 = |f: &mut dyn Write, v: u32| f.write_all(&v.to_le_bytes());
        w32(&mut f, Self::MAGIC)?;
        w32(&mut f, Self::VERSION)?;
        let name = self.name.as_bytes();
        w32(&mut f, name.len() as u32)?;
        f.write_all(name)?;
        w32(&mut f, self.input_size as u32)?;
        w32(&mut f, self.input_bits as u32)?;
        w32(&mut f, self.n_class as u32)?;
        w32(&mut f, self.layers.len() as u32)?;
        for l in &self.layers {
            w32(&mut f, l.num_luts() as u32)?;
            w32(&mut f, l.fan_in as u32)?;
            w32(&mut f, l.in_bits as u32)?;
            w32(&mut f, l.out_bits as u32)?;
            w32(&mut f, l.signed_out as u32)?;
            for row in &l.indices {
                for &i in row {
                    w32(&mut f, i)?;
                }
            }
            for &v in &l.tables {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Load an NLUT v1 file.
    ///
    /// Rejections are diagnosable from the message alone: bad magic and
    /// bad version report expected vs. actual values, and every
    /// truncated read reports what was being read, the byte offset, and
    /// the file length.
    pub fn load(path: &Path) -> Result<LutNetwork> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let file_len = file
            .metadata()
            .with_context(|| format!("reading metadata of {}", path.display()))?
            .len();
        let mut r = NlutReader {
            f: std::io::BufReader::new(file),
            path,
            file_len,
            offset: 0,
        };
        let magic = r.u32("magic")?;
        if magic != Self::MAGIC {
            bail!(
                "{}: bad NLUT magic 0x{magic:08X} (expected 0x{:08X} \"NLUT\"); \
                 file is {file_len} bytes and is not an NLUT model",
                path.display(),
                Self::MAGIC
            );
        }
        let version = r.u32("version")?;
        if version != Self::VERSION {
            bail!(
                "{}: unsupported NLUT version {version} (this build reads \
                 version {}; file is {file_len} bytes)",
                path.display(),
                Self::VERSION
            );
        }
        let name_len = r.u32("name length")? as usize;
        // Untrusted size fields are checked against the file length (and
        // sane format bounds) *before* any allocation or shift, so a
        // corrupt header is an error message, not a panic or OOM.
        if name_len as u64 > file_len {
            bail!(
                "{}: absurd name length {name_len} in NLUT header (file is \
                 {file_len} bytes)",
                path.display()
            );
        }
        let mut name = vec![0u8; name_len];
        r.bytes(&mut name, "model name")?;
        let input_size = r.u32("input_size")? as usize;
        let input_bits = r.u32("input_bits")? as usize;
        let n_class = r.u32("n_class")? as usize;
        let n_layers = r.u32("layer count")? as usize;
        // Every layer needs at least a 20-byte header, so the claimed
        // count must fit in the file before reserving space for it.
        if (n_layers as u64).saturating_mul(20) > file_len {
            bail!(
                "{}: absurd layer count {n_layers} in NLUT header (file is \
                 {file_len} bytes)",
                path.display()
            );
        }
        let mut layers = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            let num_luts = r.u32("layer num_luts")? as usize;
            let fan_in = r.u32("layer fan_in")? as usize;
            let in_bits = r.u32("layer in_bits")? as usize;
            let out_bits = r.u32("layer out_bits")? as usize;
            let signed_out = r.u32("layer signed_out")? != 0;
            // `entries = 1 << (in_bits * fan_in)` must not shift-overflow,
            // and the claimed payload must actually fit in the file.
            const MAX_ADDR_BITS: usize = 26;
            if in_bits == 0 || in_bits > 15 {
                bail!(
                    "{}: layer {li} claims in_bits = {in_bits} (supported: 1..=15)",
                    path.display()
                );
            }
            let addr_bits = in_bits.saturating_mul(fan_in);
            if addr_bits > MAX_ADDR_BITS {
                bail!(
                    "{}: layer {li} claims {addr_bits} table address bits \
                     (in_bits {in_bits} × fan_in {fan_in}; supported: \
                     <= {MAX_ADDR_BITS})",
                    path.display()
                );
            }
            let claimed = (num_luts as u64)
                .saturating_mul(fan_in as u64 * 4 + ((1u64 << addr_bits) * 2));
            if r.offset.saturating_add(claimed) > file_len {
                bail!(
                    "{}: truncated NLUT file: layer {li} claims {num_luts} \
                     LUTs × (fan_in {fan_in} + 2^{addr_bits} entries) = \
                     {claimed} payload bytes at offset {}, but file is \
                     {file_len} bytes",
                    path.display(),
                    r.offset
                );
            }
            let mut indices = Vec::with_capacity(num_luts);
            for _ in 0..num_luts {
                let mut row = Vec::with_capacity(fan_in);
                for _ in 0..fan_in {
                    row.push(r.u32("wire index")?);
                }
                indices.push(row);
            }
            let entries = 1usize << (in_bits * fan_in);
            let mut tables = vec![0i16; num_luts * entries];
            let table_what = format!("layer {li} table entry");
            for v in tables.iter_mut() {
                *v = r.i16(&table_what)?;
            }
            layers.push(LutLayer {
                indices,
                tables,
                fan_in,
                in_bits,
                out_bits,
                signed_out,
            });
        }
        let net = LutNetwork {
            name: String::from_utf8(name)?,
            input_size,
            input_bits,
            n_class,
            layers,
        };
        net.validate()
            .with_context(|| format!("validating {}", path.display()))?;
        Ok(net)
    }
}

/// Position-tracking reader for NLUT files: every short read becomes an
/// error naming the field being read, the byte offset, and the total
/// file length — so a truncated or mislabeled file is diagnosable from
/// the message alone.
struct NlutReader<'a> {
    f: std::io::BufReader<std::fs::File>,
    path: &'a Path,
    file_len: u64,
    offset: u64,
}

impl NlutReader<'_> {
    fn bytes(&mut self, buf: &mut [u8], what: &str) -> Result<()> {
        self.f.read_exact(buf).map_err(|e| {
            anyhow::anyhow!(
                "{}: truncated NLUT file: needed {} byte(s) for {what} at \
                 offset {}, but file is {} bytes: {e}",
                self.path.display(),
                buf.len(),
                self.offset,
                self.file_len
            )
        })?;
        self.offset += buf.len() as u64;
        Ok(())
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let mut b = [0u8; 4];
        self.bytes(&mut b, what)?;
        Ok(u32::from_le_bytes(b))
    }

    fn i16(&mut self, what: &str) -> Result<i16> {
        let mut b = [0u8; 2];
        self.bytes(&mut b, what)?;
        Ok(i16::from_le_bytes(b))
    }
}

/// FNV-1a 64-bit hasher (no external crates in the offline build).
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Self {
        Fnv1a(0xcbf29ce484222325)
    }

    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// Build a small random-but-valid network for tests and benches.
pub fn random_network(seed: u64, input_size: usize, input_bits: usize,
                      widths: &[usize], fan_in: usize, beta: usize,
                      out_bits: usize) -> LutNetwork {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = input_size;
    for (li, &m) in widths.iter().enumerate() {
        let last = li == widths.len() - 1;
        let f = fan_in.min(prev);
        let in_bits = if li == 0 { input_bits } else { beta };
        let ob = if last { out_bits } else { beta };
        let entries = 1usize << (in_bits * f);
        let indices: Vec<Vec<u32>> = (0..m)
            .map(|_| {
                rng.choose_distinct(prev, f).into_iter().map(|v| v as u32).collect()
            })
            .collect();
        let tables: Vec<i16> = (0..m * entries)
            .map(|_| {
                if last {
                    let q = (1i64 << (ob - 1)) - 1;
                    (rng.below((2 * q + 1) as usize) as i64 - q) as i16
                } else {
                    rng.below(1 << ob) as i16
                }
            })
            .collect();
        layers.push(LutLayer {
            indices,
            tables,
            fan_in: f,
            in_bits,
            out_bits: ob,
            signed_out: last,
        });
        prev = m;
    }
    LutNetwork {
        name: format!("random-{seed}"),
        input_size,
        input_bits,
        n_class: *widths.last().unwrap(),
        layers,
    }
}

/// Build a *trained-like* network: every table is a quantized, clamped
/// linear-threshold function of its address bits — the shape collapsed
/// sub-networks actually take after training — with an occasional dead
/// (constant) unit. Unlike [`random_network`]'s uniform tables, these
/// carry the redundancy profile real NeuraLUT models have (saturated
/// constant bits, shared comparator structure, duplicate outputs), which
/// is exactly what the `engine::opt` pass pipeline recovers. Used by the
/// repro benches and the optimization differential tests.
pub fn structured_network(seed: u64, input_size: usize, input_bits: usize,
                          widths: &[usize], fan_in: usize, beta: usize,
                          out_bits: usize) -> LutNetwork {
    use crate::util::rng::Rng;
    const WEIGHTS: [i32; 7] = [-2, -1, -1, 0, 1, 1, 2];
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = input_size;
    for (li, &m) in widths.iter().enumerate() {
        let last = li == widths.len() - 1;
        let f = fan_in.min(prev);
        let in_bits = if li == 0 { input_bits } else { beta };
        let ob = if last { out_bits } else { beta };
        let k = in_bits * f;
        let entries = 1usize << k;
        let q = (1i32 << (ob - 1)) - 1;
        let indices: Vec<Vec<u32>> = (0..m)
            .map(|_| {
                rng.choose_distinct(prev, f).into_iter().map(|v| v as u32).collect()
            })
            .collect();
        let mut tables: Vec<i16> = Vec::with_capacity(m * entries);
        for _ in 0..m {
            if rng.below(10) == 0 {
                // Dead unit: saturated (or pruned) during training.
                let c = if last {
                    rng.below((2 * q + 1) as usize) as i32 - q
                } else {
                    rng.below(1 << ob) as i32
                };
                tables.extend(std::iter::repeat(c as i16).take(entries));
                continue;
            }
            let w: Vec<i32> = (0..k).map(|_| WEIGHTS[rng.below(7)]).collect();
            let bias = rng.below(2 * k + 1) as i32 - k as i32;
            let shift = rng.below(2) as u32;
            for addr in 0..entries {
                let mut s = bias;
                for (j, &wj) in w.iter().enumerate() {
                    if (addr >> j) & 1 == 1 {
                        s += wj;
                    }
                }
                let v = s >> shift; // arithmetic: floor toward -inf
                let v = if last {
                    v.clamp(-q, q)
                } else {
                    v.clamp(0, (1 << ob) - 1)
                };
                tables.push(v as i16);
            }
        }
        layers.push(LutLayer {
            indices,
            tables,
            fan_in: f,
            in_bits,
            out_bits: ob,
            signed_out: last,
        });
        prev = m;
    }
    LutNetwork {
        name: format!("structured-{seed}"),
        input_size,
        input_bits,
        n_class: *widths.last().unwrap(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_network_validates() {
        let net = random_network(1, 16, 2, &[8, 4, 3], 3, 2, 4);
        net.validate().unwrap();
        assert_eq!(net.num_luts(), 15);
    }

    #[test]
    fn save_load_roundtrip() {
        let net = random_network(2, 10, 3, &[6, 2], 2, 3, 4);
        let path = std::env::temp_dir().join("neuralut_test_net.nlut");
        net.save(&path).unwrap();
        let back = LutNetwork::load(&path).unwrap();
        assert_eq!(back.name, net.name);
        assert_eq!(back.layers.len(), net.layers.len());
        for (a, b) in back.layers.iter().zip(&net.layers) {
            assert_eq!(a.tables, b.tables);
            assert_eq!(a.indices, b.indices);
        }
    }

    #[test]
    fn validate_handles_wide_out_bits_without_shift_overflow() {
        let mut net = random_network(4, 4, 2, &[2], 2, 2, 4);
        net.layers[0].out_bits = 15; // widest supported: must not panic
        net.validate().unwrap();
        net.layers[0].out_bits = 16; // would overflow i16 — rejected, not UB
        assert!(net.validate().is_err());
        net.layers[0].out_bits = 0;
        assert!(net.validate().is_err());
    }

    #[test]
    fn structured_network_validates_and_carries_structure() {
        let net = structured_network(5, 12, 2, &[8, 6, 3], 3, 2, 4);
        net.validate().unwrap();
        assert_eq!(net.n_class, 3);
        assert_eq!(net.layers.len(), 3);
        assert!(net.layers.last().unwrap().signed_out);
        // Trained-like tables must be far from uniform noise: some table
        // has a constant (saturated) output bit.
        let any_constant_bit = net.layers.iter().any(|l| {
            (0..l.num_luts()).any(|i| {
                let t = l.table(i);
                (0..l.out_bits).any(|b| {
                    t.iter().all(|&v| (v as u16 >> b) & 1 == (t[0] as u16 >> b) & 1)
                })
            })
        });
        assert!(any_constant_bit, "no saturated bits — not trained-like");
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let net = random_network(6, 8, 2, &[4, 2], 2, 2, 4);
        let d = net.digest();
        assert_eq!(d, net.clone().digest(), "digest must be deterministic");
        let mut other = net.clone();
        other.layers[0].tables[0] ^= 1;
        assert_ne!(d, other.digest(), "table change must change the digest");
        let mut renamed = net.clone();
        renamed.name = "else".into();
        assert_ne!(d, renamed.digest(), "name change must change the digest");
        assert_ne!(
            random_network(7, 8, 2, &[4, 2], 2, 2, 4).digest(),
            d,
            "different seed, different digest"
        );
    }

    #[test]
    fn table_bits_counts_rom() {
        let net = random_network(3, 8, 2, &[4, 2], 2, 2, 4);
        // layer0: 4 luts * 2^(2*2) entries * 2 bits; layer1: 2 * 2^4 * 4.
        assert_eq!(net.table_bits(), 4 * 16 * 2 + 2 * 16 * 4);
    }
}
