//! The AOT bundle manifest — the flat ABI contract with `python/compile`.
//!
//! `manifest.json` (written by `python -m compile.aot`) describes, for one
//! model config: the circuit topology, the ordered flat parameter list (the
//! exact argument order of `init`/`train_step`/`fwd`), the a-priori sparsity
//! wiring, the quantization spec, and the per-layer truth-table artifacts.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Shape + name of one flat parameter.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One truth-table artifact (a circuit layer's conversion program).
#[derive(Debug, Clone)]
pub struct TtSpec {
    pub layer: usize,
    pub path: String,
    /// Parameter names, in order, that the tt HLO takes as arguments.
    pub args: Vec<String>,
    pub num_luts: usize,
    pub entries: usize,
    pub fan_in: usize,
    pub in_bits: usize,
    pub out_bits: usize,
    pub signed_out: bool,
}

/// Parsed manifest of one AOT bundle.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub mode: String,
    pub dataset: String,
    pub input_size: usize,
    pub n_class: usize,
    pub layers: Vec<usize>,
    pub beta: usize,
    pub beta_in: usize,
    pub beta_out: usize,
    pub fan_in: usize,
    pub sub_depth: usize,
    pub sub_width: usize,
    pub sub_skip: usize,
    pub degree: usize,
    pub batch: usize,
    pub epochs: usize,
    pub lr_max: f64,
    pub lr_min: f64,
    pub weight_decay: f64,
    pub sgdr_t0: usize,
    pub sgdr_mult: usize,
    pub params: Vec<ParamSpec>,
    pub scale_param_idx: Vec<usize>,
    pub layer_param_slices: Vec<(usize, usize)>,
    /// Per layer: [num_luts][fan_in] indices into the previous layer.
    pub indices: Vec<Vec<Vec<u32>>>,
    pub layer_in_bits: Vec<usize>,
    pub layer_fan_in: Vec<usize>,
    pub tt: Vec<TtSpec>,
    /// Directory this manifest was loaded from (artifact paths are relative).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = json::from_file(&dir.join("manifest.json"))?;
        Self::from_json(&j, dir)
            .with_context(|| format!("manifest in {}", dir.display()))
    }

    fn from_json(j: &Json, dir: &Path) -> Result<Manifest> {
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let indices = j
            .get("indices")?
            .as_arr()?
            .iter()
            .map(|layer| {
                layer
                    .as_arr()?
                    .iter()
                    .map(|row| {
                        Ok(row
                            .as_arr()?
                            .iter()
                            .map(|v| Ok(v.as_usize()? as u32))
                            .collect::<Result<Vec<u32>>>()?)
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;

        let tt = j
            .get("tt")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(TtSpec {
                    layer: t.get_usize("layer")?,
                    path: t.get("path")?.as_str()?.to_string(),
                    args: t
                        .get("args")?
                        .as_arr()?
                        .iter()
                        .map(|a| Ok(a.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                    num_luts: t.get_usize("num_luts")?,
                    entries: t.get_usize("entries")?,
                    fan_in: t.get_usize("fan_in")?,
                    in_bits: t.get_usize("in_bits")?,
                    out_bits: t.get_usize("out_bits")?,
                    signed_out: t.get("signed_out")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let slices = j
            .get("layer_param_slices")?
            .as_arr()?
            .iter()
            .map(|s| {
                let v = s.usize_vec()?;
                if v.len() != 2 {
                    bail!("bad layer_param_slices entry");
                }
                Ok((v[0], v[1]))
            })
            .collect::<Result<Vec<_>>>()?;

        let m = Manifest {
            name: j.get("name")?.as_str()?.to_string(),
            mode: j.get("mode")?.as_str()?.to_string(),
            dataset: j.get("dataset")?.as_str()?.to_string(),
            input_size: j.get_usize("input_size")?,
            n_class: j.get_usize("n_class")?,
            layers: j.get("layers")?.usize_vec()?,
            beta: j.get_usize("beta")?,
            beta_in: j.get_usize("beta_in")?,
            beta_out: j.get_usize("beta_out")?,
            fan_in: j.get_usize("fan_in")?,
            sub_depth: j.get_usize("sub_depth")?,
            sub_width: j.get_usize("sub_width")?,
            sub_skip: j.get_usize("sub_skip")?,
            degree: j.get_usize("degree")?,
            batch: j.get_usize("batch")?,
            epochs: j.get_usize("epochs")?,
            lr_max: j.get("lr_max")?.as_f64()?,
            lr_min: j.get("lr_min")?.as_f64()?,
            weight_decay: j.get("weight_decay")?.as_f64()?,
            sgdr_t0: j.get_usize("sgdr_t0")?,
            sgdr_mult: j.get_usize("sgdr_mult")?,
            params,
            scale_param_idx: j.get("scale_param_idx")?.usize_vec()?,
            layer_param_slices: slices,
            indices,
            layer_in_bits: j.get("layer_in_bits")?.usize_vec()?,
            layer_fan_in: j.get("layer_fan_in")?.usize_vec()?,
            tt,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural sanity checks (every consumer relies on these).
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            bail!("no circuit layers");
        }
        if *self.layers.last().unwrap() != self.n_class {
            bail!("last layer width != n_class");
        }
        if self.indices.len() != self.layers.len() {
            bail!("indices / layers length mismatch");
        }
        for (l, (idx, &m)) in self.indices.iter().zip(&self.layers).enumerate() {
            if idx.len() != m {
                bail!("layer {l}: {} index rows for {m} luts", idx.len());
            }
            let prev = if l == 0 { self.input_size } else { self.layers[l - 1] };
            for row in idx {
                if row.len() != self.layer_fan_in[l] {
                    bail!("layer {l}: fan-in mismatch");
                }
                if row.iter().any(|&i| i as usize >= prev) {
                    bail!("layer {l}: index out of range");
                }
            }
        }
        if self.tt.len() != self.layers.len() {
            bail!("tt / layers length mismatch");
        }
        for t in &self.tt {
            if t.entries != 1usize << (t.in_bits * t.fan_in) {
                bail!("layer {}: entries != 2^(bits*fan_in)", t.layer);
            }
        }
        if self.scale_param_idx.len() != self.layers.len() {
            bail!("one scale param per layer expected");
        }
        Ok(())
    }

    /// Index of a parameter by name.
    pub fn param_index(&self) -> HashMap<&str, usize> {
        self.params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.as_str(), i))
            .collect()
    }

    /// Total trainable parameter count (for Table I cross-checks).
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.elem_count()).sum()
    }

    pub fn hlo_path(&self, stem: &str) -> PathBuf {
        self.dir.join(format!("{stem}.hlo.txt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> String {
        r#"{
          "name":"t","mode":"neuralut","dataset":"moons","input_size":2,
          "n_class":2,"layers":[2,2],"beta":2,"beta_in":2,"beta_out":4,
          "fan_in":2,"sub_depth":1,"sub_width":1,"sub_skip":0,"degree":2,
          "batch":4,"epochs":1,"lr_max":0.01,"lr_min":0.001,
          "weight_decay":0.0,"sgdr_t0":1,"sgdr_mult":2,
          "params":[{"name":"l0.w1","shape":[2,2,1]},{"name":"l0.b1","shape":[2,1]},
                    {"name":"l0.scale","shape":[]},
                    {"name":"l1.w1","shape":[2,2,1]},{"name":"l1.b1","shape":[2,1]},
                    {"name":"l1.scale","shape":[]}],
          "scale_param_idx":[2,5],
          "layer_param_slices":[[0,3],[3,6]],
          "indices":[[[0,1],[1,0]],[[0,1],[1,0]]],
          "layer_in_bits":[2,2],
          "layer_fan_in":[2,2],
          "tt":[{"layer":0,"path":"tt_layer0.hlo.txt","args":["l0.w1","l0.b1","l0.scale"],
                 "num_luts":2,"entries":16,"fan_in":2,"in_bits":2,"out_bits":2,"signed_out":false},
                {"layer":1,"path":"tt_layer1.hlo.txt","args":["l0.scale","l1.w1","l1.b1","l1.scale"],
                 "num_luts":2,"entries":16,"fan_in":2,"in_bits":2,"out_bits":4,"signed_out":true}]
        }"#.to_string()
    }

    #[test]
    fn parses_and_validates() {
        let j = Json::parse(&mini_manifest_json()).unwrap();
        let m = Manifest::from_json(&j, Path::new("/tmp")).unwrap();
        assert_eq!(m.layers, vec![2, 2]);
        assert_eq!(m.total_params(), 4 + 2 + 1 + 4 + 2 + 1);
        assert_eq!(m.param_index()["l1.w1"], 3);
    }

    #[test]
    fn rejects_bad_indices() {
        let bad = mini_manifest_json().replace("[[0,1],[1,0]],[[0,1]", "[[0,9],[1,0]],[[0,1]");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&j, Path::new("/tmp")).is_err());
    }
}
