//! The pluggable backend registry: execution strategies by *name*.
//!
//! Every inference backend is an entry mapping a normalized name to a
//! factory (`Arc<LutNetwork>` → compile-once [`FabricProgram`]) plus its
//! [`Capabilities`]. `scalar` and the `bitsliced` lane-width family
//! (`bitsliced`, `bitsliced-x2/-x4/-x8`) are registered built-ins;
//! tests and downstream crates [`register`](BackendRegistry::register)
//! their own (mock backends, device-specific lowerings, assembled
//! sub-network variants) and select them through
//! [`FabricOptions`](crate::fabric::FabricOptions) exactly like the
//! built-ins — a new backend is a registry entry, not a cross-crate
//! surgery.
//!
//! Besides concrete entries the registry holds *aliases* — indirection
//! names that resolve (one hop) to a concrete entry. The built-in
//! `bitsliced-auto` alias points at the lane width
//! [`detect_lane_words`] picks for the host CPU; because [`resolve`]
//! (BackendRegistry::resolve) returns the *target* entry, an alias name
//! never reaches a compile report or a `.nfab` artifact — persisted
//! state always names a concrete width.
//!
//! Name lookups are case- and whitespace-insensitive
//! (`NEURALUT_ENGINE=" Bitsliced "` selects `bitsliced`), and every
//! unknown-name error lists the currently registered names.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::bail;

use crate::engine::{
    detect_lane_words, lane_backend_name, BitNetlist, BitslicedProgram, FabricProgram, OptLevel,
    ScalarProgram, LANE_WIDTHS,
};
use crate::luts::LutNetwork;

/// Compiles one network into a shared, executor-spawning program at the
/// requested optimization level (backends without a compile step ignore
/// the level).
pub type BackendFactory = Arc<
    dyn Fn(Arc<LutNetwork>, OptLevel) -> crate::Result<Arc<dyn FabricProgram>> + Send + Sync,
>;

/// Reconstructs a program from a persisted `.nfab` payload (a decoded,
/// validated [`BitNetlist`]) instead of recompiling. Only backends whose
/// compiled artifact *is* a lowered bit-netlist can register one — see
/// [`Capabilities::persistable`].
pub type ProgramLoader = Arc<
    dyn Fn(Arc<LutNetwork>, Arc<BitNetlist>) -> crate::Result<Arc<dyn FabricProgram>>
        + Send
        + Sync,
>;

/// One-time cost class of a backend's compile step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompileCost {
    /// No compile step worth measuring (table lookups run as-is).
    Free,
    /// A full lowering pass per network (support reduction, ROBDD,
    /// netlist emission) — amortized over batch/serving workloads.
    Lowering,
}

/// The batch shape a backend is built for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchAffinity {
    /// Per-sample execution: batch size 1 costs the same per sample.
    Single,
    /// Word-parallel execution: wants ≥ 64-sample batches to fill lanes.
    Wide,
}

/// Static facts about a backend, consulted when picking one for a
/// workload and surfaced in logs/reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    /// Whether the backend accepts signed codes on *hidden* layers.
    /// (The NLUT format allows them; the bitsliced lowering pass rejects
    /// them — only the final logit layer may be signed there.)
    pub signed_hidden: bool,
    /// Preferred batch shape.
    pub batch_affinity: BatchAffinity,
    /// One-time compile cost paid per [`Model::compile`](crate::fabric::Model::compile).
    pub compile_cost: CompileCost,
    /// Whether the compiled program can be persisted to (and reloaded
    /// from) a `.nfab` artifact. Must agree with [`ProgramLoader`]
    /// presence (enforced at registration time); the backend's programs
    /// must then also expose a lowered bit-netlist
    /// ([`FabricProgram::bit_netlist`]) — that part is the
    /// implementation's responsibility and is checked when a save is
    /// attempted.
    pub persistable: bool,
    /// Plane width in `u64` words for word-parallel backends (samples
    /// per block = 64 × `word_lanes`); 0 for backends without a plane
    /// word (scalar lookups, mocks). Persisted into `.nfab` headers so
    /// an artifact compiled at one width is never replayed by an
    /// executor with a different word format.
    pub word_lanes: usize,
}

/// A registered backend: canonical name, capabilities, factory, and (for
/// persistable backends) the artifact loader.
#[derive(Clone)]
pub struct BackendEntry {
    name: String,
    caps: Capabilities,
    factory: BackendFactory,
    loader: Option<ProgramLoader>,
}

impl BackendEntry {
    /// Canonical (normalized) backend name.
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn capabilities(&self) -> Capabilities {
        self.caps
    }

    /// Run the factory: compile `net` into the shared program at `opt`.
    ///
    /// An `Err` from a *non-default* backend does not necessarily abort
    /// the caller: [`Model::compile`](crate::fabric::Model::compile)
    /// treats it as a runtime fault and degrades to the `scalar`
    /// reference backend (recorded as `degraded_from` in the
    /// [`CompileReport`](crate::obs::CompileReport)). Factories should
    /// therefore fail with a descriptive error rather than panic.
    pub fn compile(
        &self,
        net: Arc<LutNetwork>,
        opt: OptLevel,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        (self.factory)(net, opt)
    }

    /// Rebuild the shared program from a persisted, already-validated
    /// netlist (the `.nfab` payload) — no lowering pass, no opt pipeline.
    pub fn load_program(
        &self,
        net: Arc<LutNetwork>,
        nl: Arc<BitNetlist>,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        match &self.loader {
            Some(loader) => loader(net, nl),
            None => bail!(
                "backend '{}' is not persistable: it cannot load a compiled \
                 fabric artifact",
                self.name
            ),
        }
    }
}

impl std::fmt::Debug for BackendEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BackendEntry")
            .field("name", &self.name)
            .field("caps", &self.caps)
            .finish_non_exhaustive()
    }
}

/// Canonical form used for registration and lookup: trimmed, ASCII
/// lowercase. `" Bitsliced "` and `bitsliced` are the same backend.
pub fn normalize_name(name: &str) -> String {
    name.trim().to_ascii_lowercase()
}

/// The name → factory table. One process-wide instance
/// ([`BackendRegistry::global`]) serves every resolution path — CLI
/// flags, `NEURALUT_ENGINE`, server config files and tests all look up
/// the same entries.
pub struct BackendRegistry {
    entries: Mutex<BTreeMap<String, BackendEntry>>,
    /// Alias → concrete entry name. Resolution follows exactly one hop
    /// (aliases cannot chain), so an alias can never be the name an
    /// artifact or report ends up carrying.
    aliases: Mutex<BTreeMap<String, String>>,
}

impl BackendRegistry {
    /// An empty registry (no built-ins) — for isolated tests.
    pub fn empty() -> BackendRegistry {
        BackendRegistry {
            entries: Mutex::new(BTreeMap::new()),
            aliases: Mutex::new(BTreeMap::new()),
        }
    }

    /// The process-wide registry with the built-ins pre-registered:
    ///
    /// | name            | compile cost | batch affinity  | signed hidden | persistable | word lanes |
    /// |-----------------|--------------|-----------------|---------------|-------------|------------|
    /// | `scalar`        | free         | single-sample   | yes           | no          | —          |
    /// | `bitsliced`     | lowering     | wide (64-lane)  | no            | yes (.nfab) | 1          |
    /// | `bitsliced-x2`  | lowering     | wide (128-lane) | no            | yes (.nfab) | 2          |
    /// | `bitsliced-x4`  | lowering     | wide (256-lane) | no            | yes (.nfab) | 4          |
    /// | `bitsliced-x8`  | lowering     | wide (512-lane) | no            | yes (.nfab) | 8          |
    ///
    /// plus the `bitsliced-auto` *alias*, which resolves to the width
    /// [`detect_lane_words`] picks for the host CPU.
    pub fn global() -> &'static BackendRegistry {
        static GLOBAL: OnceLock<BackendRegistry> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let reg = BackendRegistry::empty();
            reg.register(
                "scalar",
                Capabilities {
                    signed_hidden: true,
                    batch_affinity: BatchAffinity::Single,
                    compile_cost: CompileCost::Free,
                    persistable: false,
                    word_lanes: 0,
                },
                Arc::new(|net: Arc<LutNetwork>, _opt: OptLevel| {
                    Ok(Arc::new(ScalarProgram::new(net)) as Arc<dyn FabricProgram>)
                }),
            )
            .expect("registering built-in 'scalar'");
            for lanes in LANE_WIDTHS {
                let name = lane_backend_name(lanes).expect("built-in lane width");
                reg.register_with_loader(
                    name,
                    Capabilities {
                        signed_hidden: false,
                        batch_affinity: BatchAffinity::Wide,
                        compile_cost: CompileCost::Lowering,
                        persistable: true,
                        word_lanes: lanes,
                    },
                    Arc::new(move |net: Arc<LutNetwork>, opt: OptLevel| {
                        Ok(Arc::new(BitslicedProgram::compile_opt_wide(&net, opt, lanes)?)
                            as Arc<dyn FabricProgram>)
                    }),
                    Arc::new(move |_net, nl: Arc<BitNetlist>| {
                        Ok(Arc::new(BitslicedProgram::from_netlist_wide(nl, lanes)?)
                            as Arc<dyn FabricProgram>)
                    }),
                )
                .expect("registering built-in bitsliced width");
            }
            let auto = lane_backend_name(detect_lane_words())
                .expect("detected lane width is a built-in");
            reg.register_alias("bitsliced-auto", auto)
                .expect("registering built-in alias 'bitsliced-auto'");
            reg
        })
    }

    /// Register a non-persistable backend under `name` (normalized).
    /// Duplicate names are an error — a backend is registered exactly
    /// once per process. Backends that can persist their compiled
    /// program use [`register_with_loader`](Self::register_with_loader).
    pub fn register(
        &self,
        name: &str,
        caps: Capabilities,
        factory: BackendFactory,
    ) -> crate::Result<()> {
        self.register_inner(name, caps, factory, None)
    }

    /// Register a persistable backend: `loader` rebuilds the shared
    /// program from a `.nfab` payload without recompiling. The
    /// `persistable` capability must agree with the loader's presence on
    /// both registration paths, so capability reports never lie.
    pub fn register_with_loader(
        &self,
        name: &str,
        caps: Capabilities,
        factory: BackendFactory,
        loader: ProgramLoader,
    ) -> crate::Result<()> {
        self.register_inner(name, caps, factory, Some(loader))
    }

    fn register_inner(
        &self,
        name: &str,
        caps: Capabilities,
        factory: BackendFactory,
        loader: Option<ProgramLoader>,
    ) -> crate::Result<()> {
        let canon = normalize_name(name);
        if canon.is_empty() {
            bail!("backend name '{name}' is empty after normalization");
        }
        if caps.persistable != loader.is_some() {
            bail!(
                "backend '{canon}': persistable capability ({}) does not match \
                 loader presence ({})",
                caps.persistable,
                loader.is_some()
            );
        }
        if self.aliases.lock().unwrap().contains_key(&canon) {
            bail!("backend '{canon}' collides with a registered alias");
        }
        let mut entries = self.entries.lock().unwrap();
        if entries.contains_key(&canon) {
            bail!("backend '{canon}' is already registered");
        }
        entries.insert(canon.clone(), BackendEntry { name: canon, caps, factory, loader });
        Ok(())
    }

    /// Register `alias` as an indirection to the concrete entry
    /// `target`. The target must already be registered (aliases cannot
    /// chain or dangle), and the alias name must not collide with an
    /// entry or another alias. Resolving the alias returns the target
    /// entry, so the alias name itself never lands in reports or
    /// artifacts.
    pub fn register_alias(&self, alias: &str, target: &str) -> crate::Result<()> {
        let canon = normalize_name(alias);
        if canon.is_empty() {
            bail!("alias name '{alias}' is empty after normalization");
        }
        let target_canon = normalize_name(target);
        if !self.entries.lock().unwrap().contains_key(&target_canon) {
            bail!("alias '{canon}' targets unregistered backend '{target_canon}'");
        }
        if self.entries.lock().unwrap().contains_key(&canon) {
            bail!("alias '{canon}' collides with a registered backend");
        }
        let mut aliases = self.aliases.lock().unwrap();
        if aliases.contains_key(&canon) {
            bail!("alias '{canon}' is already registered");
        }
        aliases.insert(canon, target_canon);
        Ok(())
    }

    /// Registered concrete entry names, sorted — the list every
    /// unknown-name error cites (aliases are listed separately there).
    pub fn names(&self) -> Vec<String> {
        self.entries.lock().unwrap().keys().cloned().collect()
    }

    /// Registered aliases as sorted `(alias, target)` pairs.
    pub fn aliases(&self) -> Vec<(String, String)> {
        self.aliases
            .lock()
            .unwrap()
            .iter()
            .map(|(a, t)| (a.clone(), t.clone()))
            .collect()
    }

    /// Look up a backend by (case/whitespace-insensitive) name,
    /// following one alias hop if the name is an alias. The error for
    /// an unknown name lists what *is* registered — uniform across the
    /// CLI, env vars, config files and the builder API.
    pub fn resolve(&self, name: &str) -> crate::Result<BackendEntry> {
        let canon = normalize_name(name);
        let target = self.aliases.lock().unwrap().get(&canon).cloned();
        let lookup = target.as_deref().unwrap_or(&canon);
        let entries = self.entries.lock().unwrap();
        match entries.get(lookup) {
            Some(e) => Ok(e.clone()),
            None => {
                let mut names: Vec<String> = entries.keys().cloned().collect();
                drop(entries);
                for (a, t) in self.aliases.lock().unwrap().iter() {
                    names.push(format!("{a} -> {t}"));
                }
                names.sort();
                bail!(
                    "unknown backend '{}' (registered: {})",
                    name.trim(),
                    names.join(", ")
                )
            }
        }
    }

    /// Capabilities of a registered backend.
    pub fn capabilities(&self, name: &str) -> crate::Result<Capabilities> {
        Ok(self.resolve(name)?.capabilities())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_case_and_whitespace_insensitively() {
        let reg = BackendRegistry::global();
        assert_eq!(reg.resolve("scalar").unwrap().name(), "scalar");
        assert_eq!(reg.resolve(" Bitsliced ").unwrap().name(), "bitsliced");
        assert_eq!(reg.resolve("SCALAR").unwrap().name(), "scalar");
        let caps = reg.capabilities("bitsliced").unwrap();
        assert_eq!(caps.compile_cost, CompileCost::Lowering);
        assert_eq!(caps.batch_affinity, BatchAffinity::Wide);
        assert!(!caps.signed_hidden);
        assert!(caps.persistable, "bitsliced programs persist as .nfab");
        assert_eq!(caps.word_lanes, 1);
        let scalar = reg.capabilities("scalar").unwrap();
        assert!(scalar.signed_hidden);
        assert!(!scalar.persistable);
        assert_eq!(scalar.word_lanes, 0);
    }

    #[test]
    fn every_lane_width_is_registered_with_honest_capabilities() {
        let reg = BackendRegistry::global();
        for lanes in LANE_WIDTHS {
            let name = lane_backend_name(lanes).unwrap();
            let entry = reg.resolve(name).unwrap();
            assert_eq!(entry.name(), name);
            let caps = entry.capabilities();
            assert_eq!(caps.word_lanes, lanes, "{name}");
            assert_eq!(caps.batch_affinity, BatchAffinity::Wide);
            assert!(caps.persistable, "{name} must persist as .nfab");
        }
    }

    #[test]
    fn bitsliced_auto_alias_resolves_to_the_detected_concrete_width() {
        let reg = BackendRegistry::global();
        let entry = reg.resolve(" Bitsliced-AUTO ").unwrap();
        // The alias resolves to a concrete entry — never to itself — so
        // nothing downstream (reports, .nfab headers) can carry "auto".
        assert_ne!(entry.name(), "bitsliced-auto");
        assert_eq!(entry.name(), lane_backend_name(detect_lane_words()).unwrap());
        assert_eq!(entry.capabilities().word_lanes, detect_lane_words());
        let aliases = reg.aliases();
        assert!(
            aliases.iter().any(|(a, _)| a == "bitsliced-auto"),
            "{aliases:?}"
        );
        // The alias name is not a concrete entry.
        assert!(!reg.names().iter().any(|n| n == "bitsliced-auto"));
    }

    #[test]
    fn alias_registration_rejects_dangling_chained_and_colliding_names() {
        let reg = BackendRegistry::empty();
        let caps = Capabilities {
            signed_hidden: true,
            batch_affinity: BatchAffinity::Single,
            compile_cost: CompileCost::Free,
            persistable: false,
            word_lanes: 0,
        };
        let factory: BackendFactory = Arc::new(|net, _opt| {
            Ok(Arc::new(ScalarProgram::new(net)) as Arc<dyn FabricProgram>)
        });
        reg.register("real", caps, factory.clone()).unwrap();
        // Dangling target.
        assert!(reg.register_alias("a", "ghost").is_err());
        // Alias to alias (chaining) — the alias is not a concrete entry.
        reg.register_alias("a", "real").unwrap();
        assert!(reg.register_alias("b", "a").is_err());
        // Colliding with an entry or an existing alias.
        assert!(reg.register_alias("real", "real").is_err());
        assert!(reg.register_alias(" A ", "real").is_err());
        // And an entry cannot shadow an alias.
        assert!(reg.register("a", caps, factory).is_err());
        assert_eq!(reg.resolve("A").unwrap().name(), "real");
    }

    #[test]
    fn unknown_name_error_lists_registered_names() {
        let err = BackendRegistry::global().resolve("fpga").unwrap_err().to_string();
        assert!(err.contains("unknown backend 'fpga'"), "{err}");
        assert!(err.contains("bitsliced"), "{err}");
        assert!(err.contains("scalar"), "{err}");
    }

    #[test]
    fn duplicate_and_empty_registrations_are_rejected() {
        let reg = BackendRegistry::empty();
        let caps = Capabilities {
            signed_hidden: true,
            batch_affinity: BatchAffinity::Single,
            compile_cost: CompileCost::Free,
            persistable: false,
            word_lanes: 0,
        };
        let factory: BackendFactory = Arc::new(|net, _opt| {
            Ok(Arc::new(ScalarProgram::new(net)) as Arc<dyn FabricProgram>)
        });
        reg.register("Mock", caps, factory.clone()).unwrap();
        // Same name modulo case/whitespace → duplicate.
        assert!(reg.register(" mock ", caps, factory.clone()).is_err());
        assert!(reg.register("   ", caps, factory).is_err());
        assert_eq!(reg.names(), vec!["mock".to_string()]);
        assert_eq!(reg.resolve("MOCK ").unwrap().name(), "mock");
    }

    #[test]
    fn persistable_capability_must_match_loader_presence() {
        let reg = BackendRegistry::empty();
        let caps_persist = Capabilities {
            signed_hidden: false,
            batch_affinity: BatchAffinity::Wide,
            compile_cost: CompileCost::Lowering,
            persistable: true,
            word_lanes: 1,
        };
        let factory: BackendFactory = Arc::new(|net, _opt| {
            Ok(Arc::new(ScalarProgram::new(net)) as Arc<dyn FabricProgram>)
        });
        // persistable=true without a loader: rejected.
        let err = reg.register("a", caps_persist, factory.clone()).unwrap_err();
        assert!(err.to_string().contains("persistable"), "{err}");
        // persistable=false with a loader: also rejected.
        let loader: ProgramLoader = Arc::new(|_net, nl| {
            Ok(Arc::new(BitslicedProgram::from_netlist(nl)) as Arc<dyn FabricProgram>)
        });
        let caps_not = Capabilities { persistable: false, ..caps_persist };
        let err = reg
            .register_with_loader("b", caps_not, factory.clone(), loader.clone())
            .unwrap_err();
        assert!(err.to_string().contains("persistable"), "{err}");
        // Matching combinations register fine.
        reg.register_with_loader("c", caps_persist, factory.clone(), loader).unwrap();
        reg.register("d", caps_not, factory).unwrap();
        // And a non-persistable entry refuses to load programs.
        let nl = crate::engine::lower::lower(&crate::luts::random_network(
            1, 4, 1, &[2, 2], 2, 1, 4,
        ))
        .unwrap();
        let net = Arc::new(crate::luts::random_network(1, 4, 1, &[2, 2], 2, 1, 4));
        let err = reg
            .resolve("d")
            .unwrap()
            .load_program(net, Arc::new(nl))
            .unwrap_err();
        assert!(err.to_string().contains("not persistable"), "{err}");
    }
}
