//! The pluggable backend registry: execution strategies by *name*.
//!
//! Every inference backend is an entry mapping a normalized name to a
//! [`BackendProvider`] — one object-safe trait carrying the compile
//! step, the (optional) artifact-reload step and the backend's
//! [`Capabilities`]. `scalar`, the `bitsliced` lane-width family
//! (`bitsliced`, `bitsliced-x2/-x4/-x8`) and the native-code `aot` /
//! `aot-c` backends are registered built-ins; tests and downstream
//! crates [`register`](BackendRegistry::register) their own (mock
//! backends, device-specific lowerings, assembled sub-network variants)
//! and select them through
//! [`FabricOptions`](crate::fabric::FabricOptions) exactly like the
//! built-ins — a new backend is a registry entry, not a cross-crate
//! surgery.
//!
//! Besides concrete entries the registry holds *aliases* — indirection
//! names that resolve (one hop) to a concrete entry. The built-in
//! `bitsliced-auto` alias points at the lane width
//! [`detect_lane_words`] picks for the host CPU; because [`resolve`]
//! (BackendRegistry::resolve) returns the *target* entry, an alias name
//! never reaches a compile report or a `.nfab` artifact — persisted
//! state always names a concrete width.
//!
//! Name lookups are case- and whitespace-insensitive
//! (`NEURALUT_ENGINE=" Bitsliced "` selects `bitsliced`), and every
//! unknown-name error lists the currently registered names.
//!
//! # Migrating from the closure API
//!
//! Until the AOT backend landed, registration took a pair of `Arc`
//! closures (`BackendFactory` / `ProgramLoader`) through three entry
//! points. Backends that own side artifacts (the AOT `.so` beside the
//! `.nfab`) need compile, persist *and* artifact-path hooks that share
//! state — a trait object, not two unrelated closures. External
//! registrants migrate mechanically:
//!
//! | closure-era API                                       | trait-era replacement                                                  |
//! |-------------------------------------------------------|------------------------------------------------------------------------|
//! | `type BackendFactory = Arc<dyn Fn(net, opt) -> ..>`   | `impl BackendProvider { fn compile(&self, net, opt, ctx) -> .. }`      |
//! | `type ProgramLoader = Arc<dyn Fn(net, nl) -> ..>`     | `impl BackendProvider { fn load_persisted(&self, net, nl, ctx) -> .. }`|
//! | `register(name, caps, factory)`                       | `register(name, Arc::new(Provider))` with `capabilities()` → caps      |
//! | `register_with_loader(name, caps, factory, loader)`   | same `register`; set `Capabilities::persistable` and override `load_persisted` |
//! | captured state in the closure environment             | fields on the provider struct                                           |
//! | (inexpressible) side artifacts, cache dirs, digests   | [`ProviderCtx`] passed to both hooks                                    |
//!
//! `register_alias` is unchanged. The `persistable` capability is no
//! longer cross-checked against a loader argument at registration time
//! (there is no separate loader argument); a non-persistable entry
//! still refuses [`BackendEntry::load_program`] with the same error.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::bail;

use crate::engine::aot::{AotProvider, Emitter};
use crate::engine::{
    detect_lane_words, lane_backend_name, BitNetlist, BitslicedProgram, FabricProgram, OptLevel,
    ScalarProgram, LANE_WIDTHS,
};
use crate::luts::LutNetwork;

/// One-time cost class of a backend's compile step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompileCost {
    /// No compile step worth measuring (table lookups run as-is).
    Free,
    /// A full lowering pass per network (support reduction, ROBDD,
    /// netlist emission) — amortized over batch/serving workloads.
    Lowering,
    /// Lowering *plus* native code generation and a system-compiler
    /// invocation — the heaviest cold start, amortized by the `.so`
    /// cache.
    NativeCodegen,
}

/// The batch shape a backend is built for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchAffinity {
    /// Per-sample execution: batch size 1 costs the same per sample.
    Single,
    /// Word-parallel execution: wants ≥ 64-sample batches to fill lanes.
    Wide,
}

/// Static facts about a backend, consulted when picking one for a
/// workload and surfaced in logs/reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    /// Whether the backend accepts signed codes on *hidden* layers.
    /// (The NLUT format allows them; the bitsliced lowering pass rejects
    /// them — only the final logit layer may be signed there.)
    pub signed_hidden: bool,
    /// Preferred batch shape.
    pub batch_affinity: BatchAffinity,
    /// One-time compile cost paid per [`Model::compile`](crate::fabric::Model::compile).
    pub compile_cost: CompileCost,
    /// Whether the compiled program can be persisted to (and reloaded
    /// from) a `.nfab` artifact. A `true` here promises
    /// [`BackendProvider::load_persisted`] is implemented and the
    /// backend's programs expose a lowered bit-netlist
    /// ([`FabricProgram::bit_netlist`]) — checked when a save or load is
    /// attempted.
    pub persistable: bool,
    /// Plane width in `u64` words for word-parallel backends (samples
    /// per block = 64 × `word_lanes`); 0 for backends without a plane
    /// word (scalar lookups, mocks). Persisted into `.nfab` headers so
    /// an artifact compiled at one width is never replayed by an
    /// executor with a different word format.
    pub word_lanes: usize,
    /// Backend this one degrades to when its compile step fails at
    /// runtime (missing toolchain, injected fault). `None` means the
    /// process-wide default (`scalar`). The AOT backends name
    /// `bitsliced` here so a broken compiler costs throughput, never
    /// availability.
    pub fallback: Option<&'static str>,
}

/// Compile-time context handed to every [`BackendProvider`] hook: the
/// facts a backend needs to manage *side artifacts* (the AOT `.so`
/// beside the `.nfab`) that the old closure API could not express.
#[derive(Debug, Clone, Default)]
pub struct ProviderCtx {
    /// Content digest of the source model — side artifacts embed it so
    /// staleness is detected the same way `.nfab` headers detect it.
    pub model_digest: u64,
    /// Directory for backend-owned companion artifacts (`--aot-cache-dir`
    /// / `NEURALUT_AOT`). `None` = the backend's own default location.
    pub aot_cache_dir: Option<PathBuf>,
    /// The `.nfab` path when a fabric cache is driving this compile or
    /// load — providers place companion files beside it (via
    /// [`companion_path`](crate::fabric::artifact::companion_path))
    /// unless `aot_cache_dir` overrides the location.
    pub artifact_path: Option<PathBuf>,
    /// `NEURALUT_AOT=off`: native-codegen backends must refuse to
    /// compile (and therefore degrade to their declared fallback)
    /// without touching the toolchain or the cache.
    pub aot_disabled: bool,
}

/// One inference backend behind the registry: the compile hook, the
/// artifact-reload hook and the capability sheet, as a single
/// object-safe trait (replacing the closure-pair `BackendFactory` /
/// `ProgramLoader` API — see the module docs for the migration table).
pub trait BackendProvider: Send + Sync {
    /// Static facts about this backend. Called once at registration (the
    /// registry caches the copy), so it must be cheap and deterministic.
    fn capabilities(&self) -> Capabilities;

    /// Compile `net` into a shared, executor-spawning program at `opt`
    /// (backends without a compile step ignore the level).
    ///
    /// An `Err` from a *non-default* backend does not necessarily abort
    /// the caller: [`Model::compile`](crate::fabric::Model::compile)
    /// treats it as a runtime fault and degrades to the backend named by
    /// [`Capabilities::fallback`] (the `scalar` reference backend when
    /// `None`), recorded as `degraded_from` in the
    /// [`CompileReport`](crate::obs::CompileReport). Providers should
    /// therefore fail with a descriptive error rather than panic.
    fn compile(
        &self,
        net: Arc<LutNetwork>,
        opt: OptLevel,
        ctx: &ProviderCtx,
    ) -> crate::Result<Arc<dyn FabricProgram>>;

    /// Rebuild the shared program from a persisted, already-validated
    /// netlist (the `.nfab` payload) — no lowering pass, no opt
    /// pipeline. Only meaningful when [`Capabilities::persistable`] is
    /// `true`; the default implementation rejects the call, and the
    /// registry never routes here for non-persistable entries.
    fn load_persisted(
        &self,
        net: Arc<LutNetwork>,
        nl: Arc<BitNetlist>,
        ctx: &ProviderCtx,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        let _ = (net, nl, ctx);
        bail!("backend provider does not implement load_persisted")
    }
}

/// The built-in `scalar` reference backend: direct table lookups over
/// the `LutNetwork`, no lowering, no persistence.
struct ScalarProvider;

impl BackendProvider for ScalarProvider {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            signed_hidden: true,
            batch_affinity: BatchAffinity::Single,
            compile_cost: CompileCost::Free,
            persistable: false,
            word_lanes: 0,
            fallback: None,
        }
    }

    fn compile(
        &self,
        net: Arc<LutNetwork>,
        _opt: OptLevel,
        _ctx: &ProviderCtx,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        Ok(Arc::new(ScalarProgram::new(net)) as Arc<dyn FabricProgram>)
    }
}

/// The built-in bitsliced interpreter family, one provider per plane
/// width (`[u64; N]`, N ∈ {1, 2, 4, 8}).
struct BitslicedProvider {
    lanes: usize,
}

impl BackendProvider for BitslicedProvider {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            signed_hidden: false,
            batch_affinity: BatchAffinity::Wide,
            compile_cost: CompileCost::Lowering,
            persistable: true,
            word_lanes: self.lanes,
            fallback: None,
        }
    }

    fn compile(
        &self,
        net: Arc<LutNetwork>,
        opt: OptLevel,
        _ctx: &ProviderCtx,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        Ok(Arc::new(BitslicedProgram::compile_opt_wide(&net, opt, self.lanes)?)
            as Arc<dyn FabricProgram>)
    }

    fn load_persisted(
        &self,
        _net: Arc<LutNetwork>,
        nl: Arc<BitNetlist>,
        _ctx: &ProviderCtx,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        Ok(Arc::new(BitslicedProgram::from_netlist_wide(nl, self.lanes)?)
            as Arc<dyn FabricProgram>)
    }
}

/// A registered backend: canonical name, cached capability sheet, and
/// the provider behind both hooks.
#[derive(Clone)]
pub struct BackendEntry {
    name: String,
    caps: Capabilities,
    provider: Arc<dyn BackendProvider>,
}

impl BackendEntry {
    /// Canonical (normalized) backend name.
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn capabilities(&self) -> Capabilities {
        self.caps
    }

    /// Run the provider's compile hook: compile `net` into the shared
    /// program at `opt`. See [`BackendProvider::compile`] for the
    /// degradation contract on `Err`.
    pub fn compile(
        &self,
        net: Arc<LutNetwork>,
        opt: OptLevel,
        ctx: &ProviderCtx,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        self.provider.compile(net, opt, ctx)
    }

    /// Rebuild the shared program from a persisted, already-validated
    /// netlist (the `.nfab` payload) — no lowering pass, no opt pipeline.
    pub fn load_program(
        &self,
        net: Arc<LutNetwork>,
        nl: Arc<BitNetlist>,
        ctx: &ProviderCtx,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        if !self.caps.persistable {
            bail!(
                "backend '{}' is not persistable: it cannot load a compiled \
                 fabric artifact",
                self.name
            );
        }
        self.provider.load_persisted(net, nl, ctx)
    }
}

impl std::fmt::Debug for BackendEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BackendEntry")
            .field("name", &self.name)
            .field("caps", &self.caps)
            .finish_non_exhaustive()
    }
}

/// Canonical form used for registration and lookup: trimmed, ASCII
/// lowercase. `" Bitsliced "` and `bitsliced` are the same backend.
pub fn normalize_name(name: &str) -> String {
    name.trim().to_ascii_lowercase()
}

/// The name → provider table. One process-wide instance
/// ([`BackendRegistry::global`]) serves every resolution path — CLI
/// flags, `NEURALUT_ENGINE`, server config files and tests all look up
/// the same entries.
pub struct BackendRegistry {
    entries: Mutex<BTreeMap<String, BackendEntry>>,
    /// Alias → concrete entry name. Resolution follows exactly one hop
    /// (aliases cannot chain), so an alias can never be the name an
    /// artifact or report ends up carrying.
    aliases: Mutex<BTreeMap<String, String>>,
}

impl BackendRegistry {
    /// An empty registry (no built-ins) — for isolated tests.
    pub fn empty() -> BackendRegistry {
        BackendRegistry {
            entries: Mutex::new(BTreeMap::new()),
            aliases: Mutex::new(BTreeMap::new()),
        }
    }

    /// The process-wide registry with the built-ins pre-registered:
    ///
    /// | name            | compile cost   | batch affinity  | signed hidden | persistable | word lanes | fallback    |
    /// |-----------------|----------------|-----------------|---------------|-------------|------------|-------------|
    /// | `scalar`        | free           | single-sample   | yes           | no          | —          | —           |
    /// | `bitsliced`     | lowering       | wide (64-lane)  | no            | yes (.nfab) | 1          | —           |
    /// | `bitsliced-x2`  | lowering       | wide (128-lane) | no            | yes (.nfab) | 2          | —           |
    /// | `bitsliced-x4`  | lowering       | wide (256-lane) | no            | yes (.nfab) | 4          | —           |
    /// | `bitsliced-x8`  | lowering       | wide (512-lane) | no            | yes (.nfab) | 8          | —           |
    /// | `aot`           | native codegen | wide            | no            | yes (.nfab + .so) | auto | `bitsliced` |
    /// | `aot-c`         | native codegen | wide            | no            | yes (.nfab + .so) | auto | `bitsliced` |
    ///
    /// plus the `bitsliced-auto` *alias*, which resolves to the width
    /// [`detect_lane_words`] picks for the host CPU.
    pub fn global() -> &'static BackendRegistry {
        static GLOBAL: OnceLock<BackendRegistry> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let reg = BackendRegistry::empty();
            reg.register("scalar", Arc::new(ScalarProvider))
                .expect("registering built-in 'scalar'");
            for lanes in LANE_WIDTHS {
                let name = lane_backend_name(lanes).expect("built-in lane width");
                reg.register(name, Arc::new(BitslicedProvider { lanes }))
                    .expect("registering built-in bitsliced width");
            }
            let auto = lane_backend_name(detect_lane_words())
                .expect("detected lane width is a built-in");
            reg.register_alias("bitsliced-auto", auto)
                .expect("registering built-in alias 'bitsliced-auto'");
            reg.register("aot", Arc::new(AotProvider::new(Emitter::Rust)))
                .expect("registering built-in 'aot'");
            reg.register("aot-c", Arc::new(AotProvider::new(Emitter::C)))
                .expect("registering built-in 'aot-c'");
            reg
        })
    }

    /// Register a backend provider under `name` (normalized). Duplicate
    /// names are an error — a backend is registered exactly once per
    /// process. The provider's [`Capabilities`] are read once here and
    /// cached on the entry.
    pub fn register(&self, name: &str, provider: Arc<dyn BackendProvider>) -> crate::Result<()> {
        let canon = normalize_name(name);
        if canon.is_empty() {
            bail!("backend name '{name}' is empty after normalization");
        }
        if self.aliases.lock().unwrap().contains_key(&canon) {
            bail!("backend '{canon}' collides with a registered alias");
        }
        let caps = provider.capabilities();
        let mut entries = self.entries.lock().unwrap();
        if entries.contains_key(&canon) {
            bail!("backend '{canon}' is already registered");
        }
        entries.insert(canon.clone(), BackendEntry { name: canon, caps, provider });
        Ok(())
    }

    /// Register `alias` as an indirection to the concrete entry
    /// `target`. The target must already be registered (aliases cannot
    /// chain or dangle), and the alias name must not collide with an
    /// entry or another alias. Resolving the alias returns the target
    /// entry, so the alias name itself never lands in reports or
    /// artifacts.
    pub fn register_alias(&self, alias: &str, target: &str) -> crate::Result<()> {
        let canon = normalize_name(alias);
        if canon.is_empty() {
            bail!("alias name '{alias}' is empty after normalization");
        }
        let target_canon = normalize_name(target);
        if !self.entries.lock().unwrap().contains_key(&target_canon) {
            bail!("alias '{canon}' targets unregistered backend '{target_canon}'");
        }
        if self.entries.lock().unwrap().contains_key(&canon) {
            bail!("alias '{canon}' collides with a registered backend");
        }
        let mut aliases = self.aliases.lock().unwrap();
        if aliases.contains_key(&canon) {
            bail!("alias '{canon}' is already registered");
        }
        aliases.insert(canon, target_canon);
        Ok(())
    }

    /// Registered concrete entry names, sorted — the list every
    /// unknown-name error cites (aliases are listed separately there).
    pub fn names(&self) -> Vec<String> {
        self.entries.lock().unwrap().keys().cloned().collect()
    }

    /// Registered aliases as sorted `(alias, target)` pairs.
    pub fn aliases(&self) -> Vec<(String, String)> {
        self.aliases
            .lock()
            .unwrap()
            .iter()
            .map(|(a, t)| (a.clone(), t.clone()))
            .collect()
    }

    /// Look up a backend by (case/whitespace-insensitive) name,
    /// following one alias hop if the name is an alias. The error for
    /// an unknown name lists what *is* registered — uniform across the
    /// CLI, env vars, config files and the builder API.
    pub fn resolve(&self, name: &str) -> crate::Result<BackendEntry> {
        let canon = normalize_name(name);
        let target = self.aliases.lock().unwrap().get(&canon).cloned();
        let lookup = target.as_deref().unwrap_or(&canon);
        let entries = self.entries.lock().unwrap();
        match entries.get(lookup) {
            Some(e) => Ok(e.clone()),
            None => {
                let mut names: Vec<String> = entries.keys().cloned().collect();
                drop(entries);
                for (a, t) in self.aliases.lock().unwrap().iter() {
                    names.push(format!("{a} -> {t}"));
                }
                names.sort();
                bail!(
                    "unknown backend '{}' (registered: {})",
                    name.trim(),
                    names.join(", ")
                )
            }
        }
    }

    /// Capabilities of a registered backend.
    pub fn capabilities(&self, name: &str) -> crate::Result<Capabilities> {
        Ok(self.resolve(name)?.capabilities())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal test provider: scalar programs under any capability sheet.
    struct TestProvider(Capabilities);

    impl BackendProvider for TestProvider {
        fn capabilities(&self) -> Capabilities {
            self.0
        }

        fn compile(
            &self,
            net: Arc<LutNetwork>,
            _opt: OptLevel,
            _ctx: &ProviderCtx,
        ) -> crate::Result<Arc<dyn FabricProgram>> {
            Ok(Arc::new(ScalarProgram::new(net)) as Arc<dyn FabricProgram>)
        }
    }

    fn free_caps() -> Capabilities {
        Capabilities {
            signed_hidden: true,
            batch_affinity: BatchAffinity::Single,
            compile_cost: CompileCost::Free,
            persistable: false,
            word_lanes: 0,
            fallback: None,
        }
    }

    #[test]
    fn builtins_resolve_case_and_whitespace_insensitively() {
        let reg = BackendRegistry::global();
        assert_eq!(reg.resolve("scalar").unwrap().name(), "scalar");
        assert_eq!(reg.resolve(" Bitsliced ").unwrap().name(), "bitsliced");
        assert_eq!(reg.resolve("SCALAR").unwrap().name(), "scalar");
        let caps = reg.capabilities("bitsliced").unwrap();
        assert_eq!(caps.compile_cost, CompileCost::Lowering);
        assert_eq!(caps.batch_affinity, BatchAffinity::Wide);
        assert!(!caps.signed_hidden);
        assert!(caps.persistable, "bitsliced programs persist as .nfab");
        assert_eq!(caps.word_lanes, 1);
        assert_eq!(caps.fallback, None);
        let scalar = reg.capabilities("scalar").unwrap();
        assert!(scalar.signed_hidden);
        assert!(!scalar.persistable);
        assert_eq!(scalar.word_lanes, 0);
    }

    #[test]
    fn every_lane_width_is_registered_with_honest_capabilities() {
        let reg = BackendRegistry::global();
        for lanes in LANE_WIDTHS {
            let name = lane_backend_name(lanes).unwrap();
            let entry = reg.resolve(name).unwrap();
            assert_eq!(entry.name(), name);
            let caps = entry.capabilities();
            assert_eq!(caps.word_lanes, lanes, "{name}");
            assert_eq!(caps.batch_affinity, BatchAffinity::Wide);
            assert!(caps.persistable, "{name} must persist as .nfab");
        }
    }

    #[test]
    fn aot_backends_register_with_bitsliced_fallback() {
        let reg = BackendRegistry::global();
        for name in ["aot", "aot-c"] {
            let entry = reg.resolve(name).unwrap();
            assert_eq!(entry.name(), name);
            let caps = entry.capabilities();
            assert_eq!(caps.compile_cost, CompileCost::NativeCodegen, "{name}");
            assert_eq!(caps.batch_affinity, BatchAffinity::Wide, "{name}");
            assert!(caps.persistable, "{name} persists .nfab + .so");
            assert_eq!(caps.fallback, Some("bitsliced"), "{name}");
            assert!(caps.word_lanes > 0, "{name} executes a plane word");
        }
    }

    #[test]
    fn bitsliced_auto_alias_resolves_to_the_detected_concrete_width() {
        let reg = BackendRegistry::global();
        let entry = reg.resolve(" Bitsliced-AUTO ").unwrap();
        // The alias resolves to a concrete entry — never to itself — so
        // nothing downstream (reports, .nfab headers) can carry "auto".
        assert_ne!(entry.name(), "bitsliced-auto");
        assert_eq!(entry.name(), lane_backend_name(detect_lane_words()).unwrap());
        assert_eq!(entry.capabilities().word_lanes, detect_lane_words());
        let aliases = reg.aliases();
        assert!(
            aliases.iter().any(|(a, _)| a == "bitsliced-auto"),
            "{aliases:?}"
        );
        // The alias name is not a concrete entry.
        assert!(!reg.names().iter().any(|n| n == "bitsliced-auto"));
    }

    #[test]
    fn alias_registration_rejects_dangling_chained_and_colliding_names() {
        let reg = BackendRegistry::empty();
        reg.register("real", Arc::new(TestProvider(free_caps()))).unwrap();
        // Dangling target.
        assert!(reg.register_alias("a", "ghost").is_err());
        // Alias to alias (chaining) — the alias is not a concrete entry.
        reg.register_alias("a", "real").unwrap();
        assert!(reg.register_alias("b", "a").is_err());
        // Colliding with an entry or an existing alias.
        assert!(reg.register_alias("real", "real").is_err());
        assert!(reg.register_alias(" A ", "real").is_err());
        // And an entry cannot shadow an alias.
        assert!(reg.register("a", Arc::new(TestProvider(free_caps()))).is_err());
        assert_eq!(reg.resolve("A").unwrap().name(), "real");
    }

    #[test]
    fn unknown_name_error_lists_registered_names() {
        let err = BackendRegistry::global().resolve("fpga").unwrap_err().to_string();
        assert!(err.contains("unknown backend 'fpga'"), "{err}");
        assert!(err.contains("bitsliced"), "{err}");
        assert!(err.contains("scalar"), "{err}");
        assert!(err.contains("aot"), "{err}");
    }

    #[test]
    fn duplicate_and_empty_registrations_are_rejected() {
        let reg = BackendRegistry::empty();
        reg.register("Mock", Arc::new(TestProvider(free_caps()))).unwrap();
        // Same name modulo case/whitespace → duplicate.
        assert!(reg.register(" mock ", Arc::new(TestProvider(free_caps()))).is_err());
        assert!(reg.register("   ", Arc::new(TestProvider(free_caps()))).is_err());
        assert_eq!(reg.names(), vec!["mock".to_string()]);
        assert_eq!(reg.resolve("MOCK ").unwrap().name(), "mock");
    }

    #[test]
    fn non_persistable_entry_refuses_to_load_programs() {
        let reg = BackendRegistry::empty();
        reg.register("d", Arc::new(TestProvider(free_caps()))).unwrap();
        let nl = crate::engine::lower::lower(&crate::luts::random_network(
            1, 4, 1, &[2, 2], 2, 1, 4,
        ))
        .unwrap();
        let net = Arc::new(crate::luts::random_network(1, 4, 1, &[2, 2], 2, 1, 4));
        let err = reg
            .resolve("d")
            .unwrap()
            .load_program(net, Arc::new(nl), &ProviderCtx::default())
            .unwrap_err();
        assert!(err.to_string().contains("not persistable"), "{err}");
    }

    #[test]
    fn persistable_provider_without_load_persisted_fails_descriptively() {
        // A provider that *claims* persistability but keeps the default
        // load_persisted: the capability sheet routes the call through,
        // and the default implementation rejects it with a clear error.
        let reg = BackendRegistry::empty();
        let caps = Capabilities { persistable: true, ..free_caps() };
        reg.register("liar", Arc::new(TestProvider(caps))).unwrap();
        let nl = crate::engine::lower::lower(&crate::luts::random_network(
            1, 4, 1, &[2, 2], 2, 1, 4,
        ))
        .unwrap();
        let net = Arc::new(crate::luts::random_network(1, 4, 1, &[2, 2], 2, 1, 4));
        let err = reg
            .resolve("liar")
            .unwrap()
            .load_program(net, Arc::new(nl), &ProviderCtx::default())
            .unwrap_err();
        assert!(err.to_string().contains("load_persisted"), "{err}");
    }
}
