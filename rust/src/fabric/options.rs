//! [`FabricOptions`]: the one resolution path from *any* configuration
//! surface — builder calls, `NEURALUT_ENGINE`/`NEURALUT_WORKERS`/
//! `NEURALUT_OPT_LEVEL`/`NEURALUT_FABRIC_CACHE` environment variables,
//! server config files — to a validated set of compile + serving knobs.
//!
//! Precedence, highest first:
//!
//! 1. explicit builder calls ([`backend`](FabricOptions::backend),
//!    [`workers`](FabricOptions::workers), …) — how CLI flags are applied;
//! 2. environment (`NEURALUT_ENGINE`, `NEURALUT_WORKERS`,
//!    `NEURALUT_OPT_LEVEL`, `NEURALUT_FABRIC_CACHE`,
//!    `NEURALUT_REQUEST_TIMEOUT_MS`, `NEURALUT_LISTEN_ADDR`,
//!    `NEURALUT_MAX_CONNECTIONS`, `NEURALUT_MODELS_DIR`,
//!    `NEURALUT_AOT` — `off`/`on`, or a cache-directory path for the
//!    AOT backends' compiled objects);
//! 3. a [`ServerConfig`] file passed to
//!    [`from_env_and_config`](FabricOptions::from_env_and_config);
//! 4. defaults (`scalar`, opt level `O1`, no fabric cache, 1 worker,
//!    queue depth 1024, max batch 256, 200 µs batch window).
//!
//! Backend names are resolved through the
//! [`BackendRegistry`](crate::fabric::BackendRegistry) at
//! [`Model::compile`](crate::fabric::Model::compile) time —
//! case/whitespace-insensitive, with unknown names erroring against the
//! list of registered names and aliases. `NEURALUT_ENGINE` accepts any
//! registry name, including the bitsliced width family
//! (`bitsliced-x2`/`-x4`/`-x8`, e.g. `NEURALUT_ENGINE=bitsliced-x4` —
//! the CI wide leg) and the `bitsliced-auto` alias, which resolves to
//! the CPU-detected width before compilation so nothing ambiguous
//! reaches a `.nfab` artifact. Worker/queue ranges share the server's
//! [`MAX_WORKERS`]/[`MAX_QUEUE_DEPTH`] bounds, so zero or absurd values
//! are errors on every path, never clamped surprises.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context};

use crate::engine::OptLevel;
use crate::server::{ServerConfig, MAX_QUEUE_DEPTH, MAX_WORKERS};

/// Backend compiled when nothing selects one explicitly.
pub const DEFAULT_BACKEND: &str = "scalar";

/// Resolved serving knobs a [`CompiledFabric`](crate::fabric::CompiledFabric)
/// hands the worker pool. Produced only by [`FabricOptions`] resolution,
/// so the ranges are already validated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FabricTuning {
    /// Maximum requests folded into one fabric batch.
    pub max_batch: usize,
    /// How long a batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Batcher threads sharing the request queue (and the program).
    pub workers: usize,
    /// Bounded request-queue depth — the backpressure limit.
    pub queue_depth: usize,
    /// Default per-request deadline: requests older than this are shed at
    /// dequeue with `DeadlineExceeded`. `None` (the default) = requests
    /// never expire unless the client stamps its own deadline.
    pub request_timeout: Option<Duration>,
}

impl Default for FabricTuning {
    fn default() -> Self {
        FabricTuning {
            max_batch: 256,
            batch_window: Duration::from_micros(200),
            workers: 1,
            queue_depth: 1024,
            request_timeout: None,
        }
    }
}

impl FabricTuning {
    /// The one range check for serving knobs — shared by the options
    /// builder ([`FabricOptions::resolve_tuning`]) and the config-file
    /// parser ([`ServerConfig::validate`]), so the two paths cannot
    /// drift.
    pub fn validate(&self) -> crate::Result<()> {
        if self.workers == 0 || self.workers > MAX_WORKERS {
            bail!("workers = {} out of range (1..={MAX_WORKERS})", self.workers);
        }
        if self.queue_depth == 0 || self.queue_depth > MAX_QUEUE_DEPTH {
            bail!(
                "queue_depth = {} out of range (1..={MAX_QUEUE_DEPTH})",
                self.queue_depth
            );
        }
        if self.max_batch == 0 {
            bail!("max_batch = 0 (need at least 1 request per batch)");
        }
        if self.request_timeout == Some(Duration::ZERO) {
            bail!("request_timeout_ms = 0 would shed every request; omit it for no deadline");
        }
        Ok(())
    }
}

/// Builder for [`Model::compile`](crate::fabric::Model::compile): backend
/// by name plus serving knobs. Unset fields keep layered defaults — see
/// the module docs for the precedence order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FabricOptions {
    backend: Option<String>,
    opt_level: Option<OptLevel>,
    fabric_cache: Option<PathBuf>,
    workers: Option<usize>,
    queue_depth: Option<usize>,
    max_batch: Option<usize>,
    batch_window: Option<Duration>,
    request_timeout: Option<Duration>,
    listen_addr: Option<String>,
    max_connections: Option<usize>,
    models_dir: Option<PathBuf>,
    aot_cache_dir: Option<PathBuf>,
    aot_disabled: Option<bool>,
}

impl FabricOptions {
    /// All fields unset: compiles the [`DEFAULT_BACKEND`] with default
    /// tuning.
    pub fn new() -> FabricOptions {
        FabricOptions::default()
    }

    // ---- builder ----------------------------------------------------------

    /// Select the backend by registry name (case/whitespace-insensitive).
    pub fn backend(mut self, name: impl Into<String>) -> Self {
        self.backend = Some(name.into());
        self
    }

    /// Netlist optimization level the backend compiles at (`O0`/`O1`/`O2`;
    /// default `O1`). Backends without a compile step ignore it.
    pub fn opt_level(mut self, level: OptLevel) -> Self {
        self.opt_level = Some(level);
        self
    }

    /// Persist/reuse the compiled program at this `.nfab` path:
    /// [`Model::compile`](crate::fabric::Model::compile) loads it when it
    /// is fresh (same model digest, backend and opt level) and compiles +
    /// saves otherwise. Requires a persistable backend.
    pub fn fabric_cache(mut self, path: impl Into<PathBuf>) -> Self {
        self.fabric_cache = Some(path.into());
        self
    }

    /// Batcher threads for [`serve`](crate::fabric::CompiledFabric::serve)
    /// (1..=[`MAX_WORKERS`]).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n);
        self
    }

    /// Bounded request-queue depth (1..=[`MAX_QUEUE_DEPTH`]).
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = Some(n);
        self
    }

    /// Maximum requests folded into one fabric batch (≥ 1).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = Some(n);
        self
    }

    /// How long a batcher waits to fill a batch.
    pub fn batch_window(mut self, window: Duration) -> Self {
        self.batch_window = Some(window);
        self
    }

    /// Default per-request deadline for
    /// [`serve`](crate::fabric::CompiledFabric::serve): requests not yet
    /// executing this long after submission are shed with
    /// `DeadlineExceeded`. Must be non-zero.
    pub fn request_timeout(mut self, timeout: Duration) -> Self {
        self.request_timeout = Some(timeout);
        self
    }

    /// `host:port` the network front door (`neuralut serve --listen`)
    /// binds; port 0 picks an ephemeral port.
    pub fn listen_addr(mut self, addr: impl Into<String>) -> Self {
        self.listen_addr = Some(addr.into());
        self
    }

    /// Live-connection cap for the network front door; connections over
    /// it are refused with a typed `Overloaded` / HTTP 429.
    pub fn max_connections(mut self, n: usize) -> Self {
        self.max_connections = Some(n);
        self
    }

    /// Manifest directory of `.nlut` models the network front door
    /// serves (and hot-swaps when their files change).
    pub fn models_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.models_dir = Some(dir.into());
        self
    }

    /// Directory where the AOT backends cache their compiled `.so`
    /// objects (`--aot-cache-dir`). Unset = beside the `.nfab` artifact
    /// when a fabric cache is in use, else a per-user temp directory.
    pub fn aot_cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.aot_cache_dir = Some(dir.into());
        self
    }

    /// Force native codegen off (`NEURALUT_AOT=off`): the `aot` backends
    /// refuse to compile and the model degrades to their declared
    /// `bitsliced` fallback without touching the toolchain or cache.
    pub fn aot_disabled(mut self, disabled: bool) -> Self {
        self.aot_disabled = Some(disabled);
        self
    }

    // ---- getters (what is *set*, before defaulting) -----------------------

    pub fn get_backend(&self) -> Option<&str> {
        self.backend.as_deref()
    }

    pub fn get_opt_level(&self) -> Option<OptLevel> {
        self.opt_level
    }

    pub fn get_fabric_cache(&self) -> Option<&std::path::Path> {
        self.fabric_cache.as_deref()
    }

    pub fn get_workers(&self) -> Option<usize> {
        self.workers
    }

    pub fn get_queue_depth(&self) -> Option<usize> {
        self.queue_depth
    }

    pub fn get_max_batch(&self) -> Option<usize> {
        self.max_batch
    }

    pub fn get_batch_window(&self) -> Option<Duration> {
        self.batch_window
    }

    pub fn get_request_timeout(&self) -> Option<Duration> {
        self.request_timeout
    }

    pub fn get_listen_addr(&self) -> Option<&str> {
        self.listen_addr.as_deref()
    }

    pub fn get_max_connections(&self) -> Option<usize> {
        self.max_connections
    }

    pub fn get_models_dir(&self) -> Option<&std::path::Path> {
        self.models_dir.as_deref()
    }

    pub fn get_aot_cache_dir(&self) -> Option<&std::path::Path> {
        self.aot_cache_dir.as_deref()
    }

    /// Whether native codegen is forced off (defaults to enabled).
    pub fn aot_disabled_or_default(&self) -> bool {
        self.aot_disabled.unwrap_or(false)
    }

    /// The backend name that will be resolved at compile time.
    pub fn backend_or_default(&self) -> &str {
        self.backend.as_deref().unwrap_or(DEFAULT_BACKEND)
    }

    /// The optimization level the backend will compile at.
    pub fn opt_level_or_default(&self) -> OptLevel {
        self.opt_level.unwrap_or_default()
    }

    // ---- resolution -------------------------------------------------------

    /// Options from the process environment only (`NEURALUT_ENGINE`,
    /// `NEURALUT_WORKERS`, `NEURALUT_OPT_LEVEL`, `NEURALUT_FABRIC_CACHE`);
    /// everything else stays unset.
    pub fn from_env() -> crate::Result<FabricOptions> {
        Self::from_env_and_config(None)
    }

    /// The single env+config resolution path: start from `cfg` (a parsed
    /// server-config file, when given), then let environment variables
    /// override it. Builder calls applied afterwards override both —
    /// that is how CLI flags win.
    pub fn from_env_and_config(cfg: Option<&ServerConfig>) -> crate::Result<FabricOptions> {
        Self::with_env(&|key| std::env::var(key).ok(), cfg)
    }

    /// [`from_env_and_config`](Self::from_env_and_config) with an
    /// injectable environment, so precedence is testable without
    /// touching (racy, process-global) real env vars.
    pub fn with_env(
        env: &dyn Fn(&str) -> Option<String>,
        cfg: Option<&ServerConfig>,
    ) -> crate::Result<FabricOptions> {
        let mut opts = FabricOptions::new();
        if let Some(c) = cfg {
            opts.backend = Some(c.backend.clone());
            // `None` = key omitted in the file: stays unset, so it neither
            // pins the opt level nor invalidates a cached `.nfab` artifact
            // compiled at a different level.
            opts.opt_level = c.opt_level;
            opts.fabric_cache = c.fabric_cache.clone();
            opts.workers = Some(c.workers);
            opts.queue_depth = Some(c.queue_depth);
            opts.max_batch = Some(c.max_batch);
            opts.batch_window = Some(c.batch_window);
            opts.request_timeout = c.request_timeout;
            opts.listen_addr = c.listen_addr.clone();
            opts.max_connections = c.max_connections;
            opts.models_dir = c.models_dir.clone();
            opts.aot_cache_dir = c.aot_cache_dir.clone();
        }
        if let Some(v) = env("NEURALUT_ENGINE") {
            opts.backend = Some(v);
        }
        if let Some(v) = env("NEURALUT_WORKERS") {
            let n = v
                .trim()
                .parse::<usize>()
                .with_context(|| format!("NEURALUT_WORKERS = '{v}' is not a number"))?;
            opts.workers = Some(n);
        }
        if let Some(v) = env("NEURALUT_OPT_LEVEL") {
            let level = v
                .parse::<OptLevel>()
                .with_context(|| format!("NEURALUT_OPT_LEVEL = '{v}'"))?;
            opts.opt_level = Some(level);
        }
        if let Some(v) = env("NEURALUT_FABRIC_CACHE") {
            opts.fabric_cache = Some(PathBuf::from(v));
        }
        if let Some(v) = env("NEURALUT_REQUEST_TIMEOUT_MS") {
            let ms = v
                .trim()
                .parse::<u64>()
                .with_context(|| format!("NEURALUT_REQUEST_TIMEOUT_MS = '{v}' is not a number"))?;
            opts.request_timeout = Some(Duration::from_millis(ms));
        }
        if let Some(v) = env("NEURALUT_LISTEN_ADDR") {
            opts.listen_addr = Some(v.trim().to_string());
        }
        if let Some(v) = env("NEURALUT_MAX_CONNECTIONS") {
            let n = v
                .trim()
                .parse::<usize>()
                .with_context(|| format!("NEURALUT_MAX_CONNECTIONS = '{v}' is not a number"))?;
            opts.max_connections = Some(n);
        }
        if let Some(v) = env("NEURALUT_MODELS_DIR") {
            opts.models_dir = Some(PathBuf::from(v));
        }
        if let Some(v) = env("NEURALUT_AOT") {
            match v.trim().to_ascii_lowercase().as_str() {
                "off" | "0" | "false" => opts.aot_disabled = Some(true),
                "on" | "1" | "true" => opts.aot_disabled = Some(false),
                "" => {}
                _ => {
                    // Any other value is a cache directory (and implies
                    // AOT stays enabled).
                    opts.aot_disabled = Some(false);
                    opts.aot_cache_dir = Some(PathBuf::from(v.trim()));
                }
            }
        }
        Ok(opts)
    }

    /// Validate ranges and fill defaults. Called by
    /// [`Model::compile`](crate::fabric::Model::compile); public so
    /// option sets can be checked without compiling anything.
    pub fn resolve_tuning(&self) -> crate::Result<FabricTuning> {
        let d = FabricTuning::default();
        let tuning = FabricTuning {
            max_batch: self.max_batch.unwrap_or(d.max_batch),
            batch_window: self.batch_window.unwrap_or(d.batch_window),
            workers: self.workers.unwrap_or(d.workers),
            queue_depth: self.queue_depth.unwrap_or(d.queue_depth),
            request_timeout: self.request_timeout.or(d.request_timeout),
        };
        tuning.validate()?;
        Ok(tuning)
    }

    /// Validate and fill the network front-door knobs the same way
    /// [`resolve_tuning`](Self::resolve_tuning) fills the serving knobs.
    /// Unset fields keep [`NetConfig::default`] — a loopback ephemeral
    /// port — so library users and tests never collide on a fixed port.
    pub fn resolve_net(&self) -> crate::Result<crate::net::NetConfig> {
        let d = crate::net::NetConfig::default();
        let cfg = crate::net::NetConfig {
            listen_addr: self.listen_addr.clone().unwrap_or(d.listen_addr),
            max_connections: self.max_connections.unwrap_or(d.max_connections),
        };
        if cfg.max_connections == 0 || cfg.max_connections > crate::net::MAX_CONNECTIONS_LIMIT {
            bail!(
                "max_connections = {} out of range (1..={})",
                cfg.max_connections,
                crate::net::MAX_CONNECTIONS_LIMIT
            );
        }
        if cfg.listen_addr.is_empty() {
            bail!("listen_addr must not be empty (use host:port, port 0 for ephemeral)");
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_env(_: &str) -> Option<String> {
        None
    }

    #[test]
    fn defaults_match_server_config_defaults() {
        let t = FabricOptions::new().resolve_tuning().unwrap();
        let c = ServerConfig::default();
        assert_eq!(t.max_batch, c.max_batch);
        assert_eq!(t.batch_window, c.batch_window);
        assert_eq!(t.workers, c.workers);
        assert_eq!(t.queue_depth, c.queue_depth);
        assert_eq!(t.request_timeout, c.request_timeout);
        assert!(t.request_timeout.is_none(), "no deadline unless configured");
        assert_eq!(FabricOptions::new().backend_or_default(), c.backend);
        assert_eq!(FabricOptions::new().opt_level_or_default(), OptLevel::O1);
        assert!(c.opt_level.is_none(), "config default must not pin a level");
        assert!(FabricOptions::new().get_fabric_cache().is_none());
        assert!(c.fabric_cache.is_none());
    }

    #[test]
    fn opt_level_and_cache_follow_the_precedence_chain() {
        let cfg = ServerConfig {
            opt_level: Some(OptLevel::O0),
            fabric_cache: Some("cfg.nfab".into()),
            ..Default::default()
        };
        // A config that omits both keys leaves both unset.
        let bare = FabricOptions::with_env(&no_env, Some(&ServerConfig::default())).unwrap();
        assert_eq!(bare.get_opt_level(), None);
        assert_eq!(bare.get_fabric_cache(), None);
        // Config alone.
        let o = FabricOptions::with_env(&no_env, Some(&cfg)).unwrap();
        assert_eq!(o.get_opt_level(), Some(OptLevel::O0));
        assert_eq!(o.get_fabric_cache(), Some(std::path::Path::new("cfg.nfab")));
        // Env beats config.
        let env = |key: &str| match key {
            "NEURALUT_OPT_LEVEL" => Some(" o2 ".to_string()),
            "NEURALUT_FABRIC_CACHE" => Some("env.nfab".to_string()),
            _ => None,
        };
        let o = FabricOptions::with_env(&env, Some(&cfg)).unwrap();
        assert_eq!(o.get_opt_level(), Some(OptLevel::O2));
        assert_eq!(o.get_fabric_cache(), Some(std::path::Path::new("env.nfab")));
        // Builder beats env.
        let o = o.opt_level(OptLevel::O1).fabric_cache("cli.nfab");
        assert_eq!(o.get_opt_level(), Some(OptLevel::O1));
        assert_eq!(o.get_fabric_cache(), Some(std::path::Path::new("cli.nfab")));
        // A bad env level is an error naming the variable.
        let bad = |key: &str| (key == "NEURALUT_OPT_LEVEL").then(|| "O9".to_string());
        let err = FabricOptions::with_env(&bad, None).unwrap_err().to_string();
        assert!(err.contains("NEURALUT_OPT_LEVEL"), "{err}");
    }

    #[test]
    fn builder_overrides_env_overrides_config() {
        let cfg = ServerConfig { workers: 3, backend: "scalar".into(), ..Default::default() };
        // Config alone.
        let o = FabricOptions::with_env(&no_env, Some(&cfg)).unwrap();
        assert_eq!(o.get_workers(), Some(3));
        assert_eq!(o.get_backend(), Some("scalar"));
        // Env beats config.
        let env = |key: &str| match key {
            "NEURALUT_ENGINE" => Some(" Bitsliced ".to_string()),
            "NEURALUT_WORKERS" => Some("5".to_string()),
            _ => None,
        };
        let o = FabricOptions::with_env(&env, Some(&cfg)).unwrap();
        assert_eq!(o.get_workers(), Some(5));
        assert_eq!(o.get_backend(), Some(" Bitsliced "));
        // Builder beats env.
        let o = o.workers(7).backend("scalar");
        assert_eq!(o.get_workers(), Some(7));
        assert_eq!(o.backend_or_default(), "scalar");
    }

    #[test]
    fn bad_env_workers_is_an_error() {
        let env = |key: &str| {
            (key == "NEURALUT_WORKERS").then(|| "many".to_string())
        };
        let err = FabricOptions::with_env(&env, None).unwrap_err().to_string();
        assert!(err.contains("NEURALUT_WORKERS"), "{err}");
    }

    #[test]
    fn request_timeout_follows_the_precedence_chain() {
        let cfg = ServerConfig {
            request_timeout: Some(Duration::from_millis(200)),
            ..Default::default()
        };
        // Config alone.
        let o = FabricOptions::with_env(&no_env, Some(&cfg)).unwrap();
        assert_eq!(o.get_request_timeout(), Some(Duration::from_millis(200)));
        // Env beats config.
        let env = |key: &str| {
            (key == "NEURALUT_REQUEST_TIMEOUT_MS").then(|| " 75 ".to_string())
        };
        let o = FabricOptions::with_env(&env, Some(&cfg)).unwrap();
        assert_eq!(o.get_request_timeout(), Some(Duration::from_millis(75)));
        // Builder beats env, and the value lands in the resolved tuning.
        let o = o.request_timeout(Duration::from_millis(30));
        let t = o.resolve_tuning().unwrap();
        assert_eq!(t.request_timeout, Some(Duration::from_millis(30)));
        // A non-numeric env value errors naming the variable; a zero
        // builder value fails validation.
        let bad = |key: &str| {
            (key == "NEURALUT_REQUEST_TIMEOUT_MS").then(|| "soon".to_string())
        };
        let err = FabricOptions::with_env(&bad, None).unwrap_err().to_string();
        assert!(err.contains("NEURALUT_REQUEST_TIMEOUT_MS"), "{err}");
        assert!(FabricOptions::new()
            .request_timeout(Duration::ZERO)
            .resolve_tuning()
            .is_err());
    }

    #[test]
    fn net_knobs_follow_the_precedence_chain() {
        let cfg = ServerConfig {
            listen_addr: Some("0.0.0.0:7000".into()),
            max_connections: Some(8),
            models_dir: Some("cfg_models".into()),
            ..Default::default()
        };
        // Config alone.
        let o = FabricOptions::with_env(&no_env, Some(&cfg)).unwrap();
        assert_eq!(o.get_listen_addr(), Some("0.0.0.0:7000"));
        assert_eq!(o.resolve_net().unwrap().max_connections, 8);
        // Env beats config.
        let env = |key: &str| match key {
            "NEURALUT_LISTEN_ADDR" => Some(" 127.0.0.1:7001 ".to_string()),
            "NEURALUT_MAX_CONNECTIONS" => Some("16".to_string()),
            "NEURALUT_MODELS_DIR" => Some("env_models".to_string()),
            _ => None,
        };
        let o = FabricOptions::with_env(&env, Some(&cfg)).unwrap();
        assert_eq!(o.get_listen_addr(), Some("127.0.0.1:7001"));
        assert_eq!(o.get_max_connections(), Some(16));
        assert_eq!(o.get_models_dir(), Some(std::path::Path::new("env_models")));
        // Builder beats env.
        let o = o.listen_addr("127.0.0.1:0").max_connections(4).models_dir("cli");
        let net = o.resolve_net().unwrap();
        assert_eq!(net.listen_addr, "127.0.0.1:0");
        assert_eq!(net.max_connections, 4);
        assert_eq!(o.get_models_dir(), Some(std::path::Path::new("cli")));
        // Unset: ephemeral loopback defaults.
        let net = FabricOptions::new().resolve_net().unwrap();
        assert_eq!(net, crate::net::NetConfig::default());
        // Zero / non-numeric values are loud errors.
        assert!(FabricOptions::new().max_connections(0).resolve_net().is_err());
        assert!(FabricOptions::new().listen_addr("").resolve_net().is_err());
        let bad = |key: &str| (key == "NEURALUT_MAX_CONNECTIONS").then(|| "lots".to_string());
        let err = FabricOptions::with_env(&bad, None).unwrap_err().to_string();
        assert!(err.contains("NEURALUT_MAX_CONNECTIONS"), "{err}");
    }

    #[test]
    fn neuralut_aot_toggles_or_points_at_a_cache_dir() {
        // Unset: enabled, no cache dir.
        let o = FabricOptions::with_env(&no_env, None).unwrap();
        assert!(!o.aot_disabled_or_default());
        assert_eq!(o.get_aot_cache_dir(), None);
        // off/0/false disable; on/1/true enable explicitly.
        for (val, disabled) in
            [("off", true), (" 0 ", true), ("FALSE", true), ("on", false), ("1", false)]
        {
            let env = |key: &str| (key == "NEURALUT_AOT").then(|| val.to_string());
            let o = FabricOptions::with_env(&env, None).unwrap();
            assert_eq!(o.aot_disabled_or_default(), disabled, "NEURALUT_AOT={val}");
        }
        // Any other value is a cache directory.
        let env = |key: &str| (key == "NEURALUT_AOT").then(|| "/var/aot".to_string());
        let o = FabricOptions::with_env(&env, None).unwrap();
        assert!(!o.aot_disabled_or_default());
        assert_eq!(o.get_aot_cache_dir(), Some(std::path::Path::new("/var/aot")));
        // Env beats config; builder beats env.
        let cfg = ServerConfig { aot_cache_dir: Some("cfg_aot".into()), ..Default::default() };
        let o = FabricOptions::with_env(&no_env, Some(&cfg)).unwrap();
        assert_eq!(o.get_aot_cache_dir(), Some(std::path::Path::new("cfg_aot")));
        let o = FabricOptions::with_env(&env, Some(&cfg)).unwrap();
        assert_eq!(o.get_aot_cache_dir(), Some(std::path::Path::new("/var/aot")));
        let o = o.aot_cache_dir("cli_aot").aot_disabled(true);
        assert_eq!(o.get_aot_cache_dir(), Some(std::path::Path::new("cli_aot")));
        assert!(o.aot_disabled_or_default());
    }

    #[test]
    fn out_of_range_tuning_is_rejected() {
        assert!(FabricOptions::new().workers(0).resolve_tuning().is_err());
        assert!(FabricOptions::new().workers(MAX_WORKERS + 1).resolve_tuning().is_err());
        assert!(FabricOptions::new().queue_depth(0).resolve_tuning().is_err());
        assert!(FabricOptions::new()
            .queue_depth(MAX_QUEUE_DEPTH + 1)
            .resolve_tuning()
            .is_err());
        assert!(FabricOptions::new().max_batch(0).resolve_tuning().is_err());
        assert!(FabricOptions::new().workers(MAX_WORKERS).resolve_tuning().is_ok());
    }
}
