//! `.nfab` — the versioned, persistent compiled-fabric artifact.
//!
//! `Model::compile` is a real cost for the bitsliced backend (support
//! reduction, ROBDD construction, the `engine::opt` pass pipeline). A
//! `.nfab` file makes that a *ship-once* step: one process compiles and
//! saves ([`CompiledFabric::save`](crate::fabric::CompiledFabric::save)),
//! every worker process and every restart loads
//! ([`Model::load_fabric`](crate::fabric::Model::load_fabric) /
//! [`Model::compile_cached`](crate::fabric::Model::compile_cached)) the
//! pre-optimized program and serves bit-exactly identical outputs.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! u32  magic     0x4E464142 ("NFAB")
//! u32  version   3
//! u8   artifact kind (0 = self-contained netlist; 1 = netlist plus a
//!      backend-owned companion file — version 3 addition, so loaders
//!      know a sibling artifact participates in staleness checks)
//! u32  backend name length, then that many UTF-8 bytes
//! u64  model digest (LutNetwork::digest of the source network)
//! u32  opt level index (0 / 1 / 2)
//! u32  plane lane width (u64 words per bit-plane; 64·lanes samples
//!      per block — version 2 addition, so a program compiled for one
//!      word format is never replayed verbatim by another)
//! u32  level count, then per level:
//!      u32 n_in_planes, u32 num_luts, u32 out_bits,
//!      u32 op count,     ops as 4 x u32 (sel, hi, lo, dst),
//!      u32 output count, outputs as u32
//! u32  input_size, u32 input_bits, u32 n_class,
//! u32  logit_bits, u32 signed_logits
//! ```
//!
//! Derived stats (`n_wires`, `max_wires`, `max_planes`) are deliberately
//! *not* stored: [`BitNetlist::recompute_stats`] re-derives them on load
//! and [`BitNetlist::check`] then validates the whole structure, so a
//! corrupted payload is an error message, never an out-of-bounds index in
//! the evaluator's hot loop.
//!
//! The reader follows the same offset-carrying error discipline as the
//! NLUT loader: every rejection names the file, the field being read, the
//! byte offset, and expected-vs-actual values, and every untrusted count
//! is checked against the remaining file length *before* any allocation
//! or shift.
//!
//! Backends whose compiled form is more than a netlist (the AOT backends
//! compile a native `.so`) persist the extra piece as a *companion* file
//! beside the `.nfab`, named by [`companion_path`] with the model digest
//! embedded — so the digest/opt-level/lane-width staleness discipline,
//! the tmp+rename atomic write ([`atomic_write`]) and the
//! offset-carrying corruption errors apply uniformly to every backend
//! artifact. A header [`ArtifactKind`] byte records whether a companion
//! participates, and a stale or missing companion is a *recompile*, not
//! a load failure.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::engine::{BitNetlist, Level, MuxOp, OptLevel};
use crate::util::faults;

/// "NFAB", in the same hex-spelling convention as the NLUT magic.
pub const NFAB_MAGIC: u32 = 0x4E464142;
/// Current artifact format version. Version 2 added the plane
/// lane-width field; version 3 added the artifact-kind byte. Older
/// versions are rejected (recompiling is the upgrade path — the cache
/// layer does it automatically).
pub const NFAB_VERSION: u32 = 3;

/// What a `.nfab` artifact consists of, recorded as one header byte so
/// loaders know whether a companion file participates in the staleness
/// contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ArtifactKind {
    /// A self-contained levelized bit-netlist: the `.nfab` payload is
    /// everything the backend needs to reconstruct its program.
    Netlist = 0,
    /// A bit-netlist plus a backend-owned companion file beside the
    /// `.nfab` (the AOT `.so`, named by [`companion_path`]). The
    /// companion is an *optimization*, not a dependency: when it is
    /// stale, truncated or missing, the owning backend silently rebuilds
    /// it from the netlist payload.
    NetlistWithCompanion = 1,
}

impl ArtifactKind {
    fn from_u8(v: u8) -> Option<ArtifactKind> {
        match v {
            0 => Some(ArtifactKind::Netlist),
            1 => Some(ArtifactKind::NetlistWithCompanion),
            _ => None,
        }
    }
}

/// Everything the envelope records about the program it carries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NfabHeader {
    /// Whether this artifact is a self-contained netlist or carries a
    /// backend-owned companion file beside it.
    pub kind: ArtifactKind,
    /// Canonical registry name of the backend that compiled the program.
    pub backend: String,
    /// Optimization level the program was compiled at.
    pub opt_level: OptLevel,
    /// [`LutNetwork::digest`](crate::luts::LutNetwork::digest) of the
    /// source model — loading against any other model is rejected.
    pub model_digest: u64,
    /// Plane width in `u64` words the program was compiled to run at;
    /// replaying it through a backend with a different word format is
    /// rejected at load time.
    pub lanes: usize,
}

/// Where a backend-owned companion artifact lives relative to its
/// `.nfab`: `net.nfab` + digest `0xD` + tag `aot.so` →
/// `net.000000000000000d.aot.so`, as a sibling of `path`. The digest in
/// the file name makes staleness visible in a directory listing and
/// guarantees a model change can never alias an old companion.
pub fn companion_path(path: &Path, model_digest: u64, tag: &str) -> PathBuf {
    let stem = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "fabric".to_string());
    path.with_file_name(format!("{stem}.{model_digest:016x}.{tag}"))
}

/// Serialize a compiled program into a `.nfab` file. Writes to a
/// temporary sibling and renames, so concurrent readers never observe a
/// half-written artifact.
pub(crate) fn save(
    path: &Path,
    kind: ArtifactKind,
    backend: &str,
    opt_level: OptLevel,
    model_digest: u64,
    lanes: usize,
    nl: &BitNetlist,
) -> Result<()> {
    // The loader rejects names over 256 bytes as absurd; refusing to
    // write such an artifact here beats persisting one that every
    // subsequent load refuses (a self-invalidating cache).
    if backend.len() > 256 {
        bail!(
            "backend name of {} bytes is too long for a .nfab artifact \
             (limit 256)",
            backend.len()
        );
    }
    // An alias is an indirection, not a word format: persisting under
    // "bitsliced-auto" would make the artifact mean different things on
    // different machines. The registry resolves aliases before compile,
    // so reaching this is a wiring bug upstream.
    if backend.trim().eq_ignore_ascii_case("bitsliced-auto") {
        bail!(
            "refusing to save a .nfab artifact under the unresolved alias \
             'bitsliced-auto'; resolve it to a concrete lane width (e.g. \
             'bitsliced-x4') first"
        );
    }
    if lanes == 0 || lanes > 64 {
        bail!("refusing to save a .nfab artifact with absurd plane lane width {lanes}");
    }
    let mut out: Vec<u8> = Vec::with_capacity(64 + nl.num_ops() * 16);
    let w32 = |out: &mut Vec<u8>, v: u32| out.extend_from_slice(&v.to_le_bytes());
    w32(&mut out, NFAB_MAGIC);
    w32(&mut out, NFAB_VERSION);
    out.push(kind as u8);
    w32(&mut out, backend.len() as u32);
    out.extend_from_slice(backend.as_bytes());
    out.extend_from_slice(&model_digest.to_le_bytes());
    w32(&mut out, opt_level.index());
    w32(&mut out, lanes as u32);
    w32(&mut out, nl.levels.len() as u32);
    for level in &nl.levels {
        w32(&mut out, level.n_in_planes as u32);
        w32(&mut out, level.num_luts as u32);
        w32(&mut out, level.out_bits as u32);
        w32(&mut out, level.ops.len() as u32);
        for op in &level.ops {
            for v in [op.sel, op.hi, op.lo, op.dst] {
                w32(&mut out, v);
            }
        }
        w32(&mut out, level.outputs.len() as u32);
        for &w in &level.outputs {
            w32(&mut out, w);
        }
    }
    for v in [
        nl.input_size as u32,
        nl.input_bits as u32,
        nl.n_class as u32,
        nl.logit_bits as u32,
        nl.signed_logits as u32,
    ] {
        w32(&mut out, v);
    }
    atomic_write(path, &out)
}

/// Write `bytes` to `path` atomically: a temporary sibling suffixed with
/// the process id takes the payload, then one `rename` publishes it.
/// Concurrent readers see either the old file or the new one, never a
/// torn half-write — the discipline both the `.nfab` artifact and its
/// `.report.json` sibling are persisted under. The
/// [`artifact.write`](crate::util::faults::point::ARTIFACT_WRITE) fault
/// point sits between the payload write and the publishing rename, which
/// is exactly where a crash leaves a stranded `.tmp` file but an intact
/// (old or absent) destination.
pub(crate) fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let tmp = PathBuf::from(format!("{}.tmp.{}", path.display(), std::process::id()));
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    faults::inject(faults::point::ARTIFACT_WRITE)?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// Parse and validate a `.nfab` file. The returned netlist has passed
/// [`BitNetlist::check`]; header/model consistency (digest, backend,
/// opt level) is the caller's decision to enforce —
/// [`Model::load_fabric`](crate::fabric::Model::load_fabric) does.
pub(crate) fn load(path: &Path) -> Result<(NfabHeader, BitNetlist)> {
    let bytes = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    faults::inject(faults::point::ARTIFACT_READ)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut r = NfabReader { bytes: &bytes, path, offset: 0 };
    let magic = r.u32("magic")?;
    if magic != NFAB_MAGIC {
        bail!(
            "{}: bad .nfab magic 0x{magic:08X} (expected 0x{NFAB_MAGIC:08X} \
             \"NFAB\"); file is {} bytes and is not a compiled-fabric artifact",
            path.display(),
            bytes.len()
        );
    }
    let version = r.u32("version")?;
    if version != NFAB_VERSION {
        bail!(
            "{}: unsupported .nfab version {version} (this build reads version \
             {NFAB_VERSION}; file is {} bytes)",
            path.display(),
            bytes.len()
        );
    }
    let kind_byte = r.u8("artifact kind")?;
    let Some(kind) = ArtifactKind::from_u8(kind_byte) else {
        bail!(
            "{}: unknown .nfab artifact kind {kind_byte} at offset {} \
             (this build reads kinds 0..=1)",
            path.display(),
            r.offset - 1
        );
    };
    let name_len = r.u32("backend name length")? as usize;
    if name_len > r.remaining() || name_len > 256 {
        bail!(
            "{}: absurd backend name length {name_len} in .nfab header (file \
             is {} bytes)",
            path.display(),
            bytes.len()
        );
    }
    let backend = String::from_utf8(r.take(name_len, "backend name")?.to_vec())
        .with_context(|| format!("{}: backend name is not UTF-8", path.display()))?;
    let model_digest = r.u64("model digest")?;
    let opt_level = OptLevel::from_index(r.u32("opt level")?)
        .with_context(|| format!("reading {}", path.display()))?;
    let lanes = r.u32("plane lane width")? as usize;
    if lanes == 0 || lanes > 64 {
        bail!(
            "{}: absurd plane lane width {lanes} in .nfab header at offset {} \
             (expected 1..=64 u64 words per plane)",
            path.display(),
            r.offset - 4
        );
    }
    let n_levels = r.u32("level count")? as usize;
    // Every level needs at least a 20-byte header.
    if n_levels.saturating_mul(20) > r.remaining() {
        bail!(
            "{}: absurd level count {n_levels} in .nfab header (only {} bytes \
             remain at offset {})",
            path.display(),
            r.remaining(),
            r.offset
        );
    }
    let mut levels = Vec::with_capacity(n_levels);
    for li in 0..n_levels {
        let n_in_planes = r.u32("level n_in_planes")? as usize;
        let num_luts = r.u32("level num_luts")? as usize;
        let out_bits = r.u32("level out_bits")? as usize;
        let n_ops = r.u32("level op count")? as usize;
        if n_ops.saturating_mul(16) > r.remaining() {
            bail!(
                "{}: truncated .nfab artifact: level {li} claims {n_ops} ops \
                 ({} payload bytes) at offset {}, but only {} bytes remain",
                path.display(),
                n_ops.saturating_mul(16),
                r.offset,
                r.remaining()
            );
        }
        let what = format!("level {li} op");
        let mut ops = Vec::with_capacity(n_ops);
        for _ in 0..n_ops {
            let sel = r.u32(&what)?;
            let hi = r.u32(&what)?;
            let lo = r.u32(&what)?;
            let dst = r.u32(&what)?;
            ops.push(MuxOp { sel, hi, lo, dst });
        }
        let n_outputs = r.u32("level output count")? as usize;
        if n_outputs.saturating_mul(4) > r.remaining() {
            bail!(
                "{}: truncated .nfab artifact: level {li} claims {n_outputs} \
                 outputs at offset {}, but only {} bytes remain",
                path.display(),
                r.offset,
                r.remaining()
            );
        }
        let what = format!("level {li} output wire");
        let mut outputs = Vec::with_capacity(n_outputs);
        for _ in 0..n_outputs {
            outputs.push(r.u32(&what)?);
        }
        levels.push(Level { ops, n_wires: 0, n_in_planes, outputs, num_luts, out_bits });
    }
    let input_size = r.u32("input_size")? as usize;
    let input_bits = r.u32("input_bits")? as usize;
    let n_class = r.u32("n_class")? as usize;
    let logit_bits = r.u32("logit_bits")? as usize;
    let signed_logits = r.u32("signed_logits")? != 0;
    if r.remaining() != 0 {
        bail!(
            "{}: {} trailing byte(s) after the .nfab payload at offset {}",
            path.display(),
            r.remaining(),
            r.offset
        );
    }
    let mut nl = BitNetlist {
        levels,
        input_size,
        input_bits,
        n_class,
        logit_bits,
        signed_logits,
        max_wires: 0,
        max_planes: 0,
    };
    nl.recompute_stats();
    nl.check()
        .with_context(|| format!("validating {}", path.display()))?;
    Ok((NfabHeader { kind, backend, opt_level, model_digest, lanes }, nl))
}

/// Position-tracking reader: every short read names the field, the byte
/// offset, and the file length (mirrors `NlutReader`).
struct NfabReader<'a> {
    bytes: &'a [u8],
    path: &'a Path,
    offset: usize,
}

impl<'a> NfabReader<'a> {
    fn remaining(&self) -> usize {
        self.bytes.len() - self.offset
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "{}: truncated .nfab artifact: needed {n} byte(s) for {what} at \
                 offset {}, but file is {} bytes",
                self.path.display(),
                self.offset,
                self.bytes.len()
            );
        }
        let s = &self.bytes[self.offset..self.offset + n];
        self.offset += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::lower;
    use crate::luts::random_network;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("neuralut_artifact_{name}.nfab"))
    }

    #[test]
    fn nfab_payload_round_trips_exactly() {
        let net = random_network(51, 8, 2, &[6, 3], 3, 2, 4);
        let mut nl = lower::lower(&net).unwrap();
        crate::engine::optimize(&mut nl, OptLevel::O2);
        let path = tmp("roundtrip");
        save(&path, ArtifactKind::Netlist, "bitsliced-x2", OptLevel::O2, net.digest(), 2, &nl)
            .unwrap();
        let (header, back) = load(&path).unwrap();
        assert_eq!(header.kind, ArtifactKind::Netlist);
        assert_eq!(header.backend, "bitsliced-x2");
        assert_eq!(header.opt_level, OptLevel::O2);
        assert_eq!(header.model_digest, net.digest());
        assert_eq!(header.lanes, 2);
        assert_eq!(back.num_ops(), nl.num_ops());
        assert_eq!(back.max_wires, nl.max_wires);
        assert_eq!(back.max_planes, nl.max_planes);
        assert_eq!(back.levels.len(), nl.levels.len());
        for (a, b) in back.levels.iter().zip(&nl.levels) {
            assert_eq!(a.ops, b.ops);
            assert_eq!(a.outputs, b.outputs);
            assert_eq!(a.n_in_planes, b.n_in_planes);
            assert_eq!(a.n_wires, b.n_wires);
        }
        assert_eq!(back.logit_bits, nl.logit_bits);
        assert_eq!(back.signed_logits, nl.signed_logits);
    }

    #[test]
    fn corrupt_payload_fails_the_structural_check() {
        let net = random_network(52, 8, 2, &[6, 3], 3, 2, 4);
        let nl = lower::lower(&net).unwrap();
        let path = tmp("corrupt");
        save(&path, ArtifactKind::Netlist, "bitsliced", OptLevel::O0, net.digest(), 1, &nl)
            .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Smash the final level's last output wire (it sits right before
        // the 20-byte trailer): the decoded netlist must fail validation,
        // not index out of bounds later in the evaluator.
        let n = bytes.len();
        bytes[n - 24..n - 20].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("validating"), "{err:#}");
    }

    #[test]
    fn a_write_failing_before_the_rename_leaves_the_old_artifact_intact() {
        let net = random_network(55, 8, 2, &[6, 3], 3, 2, 4);
        let nl = lower::lower(&net).unwrap();
        let path = tmp("torn");
        save(&path, ArtifactKind::Netlist, "bitsliced", OptLevel::O0, net.digest(), 1, &nl)
            .unwrap();
        let before = std::fs::read(&path).unwrap();
        // Crash the second save between its tmp write and the rename: the
        // destination must still hold the first, fully intact artifact.
        let guard = crate::util::faults::arm_scoped("artifact.write:1:error", 41).unwrap();
        let err =
            save(&path, ArtifactKind::Netlist, "bitsliced", OptLevel::O2, net.digest(), 1, &nl)
                .unwrap_err();
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        assert_eq!(guard.fired("artifact.write"), 1);
        drop(guard);
        assert_eq!(std::fs::read(&path).unwrap(), before, "torn write must not publish");
        let (header, _) = load(&path).unwrap();
        assert_eq!(header.opt_level, OptLevel::O0);
    }

    #[test]
    fn injected_read_faults_surface_as_load_errors() {
        let net = random_network(56, 8, 2, &[6, 3], 3, 2, 4);
        let nl = lower::lower(&net).unwrap();
        let path = tmp("read_fault");
        save(&path, ArtifactKind::Netlist, "bitsliced", OptLevel::O1, net.digest(), 1, &nl)
            .unwrap();
        let guard = crate::util::faults::arm_scoped("artifact.read:1:error", 43).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("injected fault"), "{err}");
        assert!(err.contains(&path.display().to_string()), "{err}");
        assert_eq!(guard.fired("artifact.read"), 1);
        drop(guard);
        load(&path).unwrap();
    }

    #[test]
    fn save_refuses_the_unresolved_auto_alias_and_absurd_widths() {
        let net = random_network(53, 8, 2, &[6, 3], 3, 2, 4);
        let nl = lower::lower(&net).unwrap();
        let path = tmp("auto_alias");
        let err = save(
            &path,
            ArtifactKind::Netlist,
            "Bitsliced-Auto",
            OptLevel::O0,
            net.digest(),
            4,
            &nl,
        )
        .unwrap_err();
        assert!(err.to_string().contains("bitsliced-auto"), "{err}");
        let err = save(&path, ArtifactKind::Netlist, "bitsliced", OptLevel::O0, net.digest(), 0, &nl)
            .unwrap_err();
        assert!(err.to_string().contains("lane width"), "{err}");
        assert!(!path.exists(), "a refused save must not leave a file behind");
    }

    #[test]
    fn companion_kind_round_trips_and_unknown_kinds_are_rejected_with_offset() {
        let net = random_network(57, 8, 2, &[6, 3], 3, 2, 4);
        let nl = lower::lower(&net).unwrap();
        let path = tmp("kind");
        save(
            &path,
            ArtifactKind::NetlistWithCompanion,
            "aot",
            OptLevel::O2,
            net.digest(),
            2,
            &nl,
        )
        .unwrap();
        let (header, _) = load(&path).unwrap();
        assert_eq!(header.kind, ArtifactKind::NetlistWithCompanion);
        assert_eq!(header.backend, "aot");
        // The kind byte sits at offset 8, right after magic + version.
        let mut bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes[8], ArtifactKind::NetlistWithCompanion as u8);
        bytes[8] = 7;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("artifact kind 7"), "{err}");
        assert!(err.contains("offset 8"), "{err}");
    }

    #[test]
    fn companion_paths_embed_the_digest_beside_the_artifact() {
        let p = companion_path(Path::new("/cache/net.nfab"), 0xD, "aot.so");
        assert_eq!(p, Path::new("/cache/net.000000000000000d.aot.so"));
        // Different digests can never alias each other's companions.
        let q = companion_path(Path::new("/cache/net.nfab"), 0xE, "aot.so");
        assert_ne!(p, q);
    }
}
