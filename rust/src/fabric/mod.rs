//! The unified inference API: **`Model` → `CompiledFabric` → `Session`**.
//!
//! The paper's core claim is that entire sub-networks hide inside LUTs —
//! one model artifact, many ways to execute it. This module is that
//! claim as an API: callers hold *one* [`Model`] and pick the execution
//! strategy as a pluggable, by-name choice.
//!
//! ```text
//! Model::load("net.nlut")            // or Model::from_network(net)
//!   .compile(&FabricOptions::from_env()?.backend("bitsliced"))?
//!   ├─ .session()                    // in-process batch inference
//!   └─ .serve()                      // multi-worker serving runtime
//! ```
//!
//! * [`Model`] wraps the converted network (`Arc<LutNetwork>`) plus its
//!   metadata — name, shape, table bits, latency cycles ([`ModelInfo`]).
//! * [`Model::compile`] resolves the backend *by name* through the
//!   [`BackendRegistry`] (built-ins: `scalar`, `bitsliced`) and runs its
//!   factory exactly once, yielding a [`CompiledFabric`] — the shared,
//!   compile-once artifact.
//! * [`CompiledFabric::session`] spawns an in-process [`Session`] for
//!   direct batch inference; [`CompiledFabric::serve`] starts the
//!   multi-worker [`Server`] pool, every worker sharing the one compiled
//!   program.
//!
//! Configuration funnels through one path: [`FabricOptions`] layers
//! builder calls over `NEURALUT_ENGINE`/`NEURALUT_WORKERS`/
//! `NEURALUT_OPT_LEVEL`/`NEURALUT_FABRIC_CACHE` over a parsed
//! [`ServerConfig`](crate::server::ServerConfig) file over defaults, and
//! every unknown-backend error lists the registered names.
//!
//! Compilation degrades gracefully: when a requested backend fails to
//! construct, [`Model::compile`] falls back to the backend named by its
//! [`Capabilities::fallback`] (the reference `scalar` backend when
//! unset; the `aot` backends degrade to `bitsliced`) instead of
//! aborting, records the fallback in the [`CompileReport`]
//! (`degraded_from`) and the `neuralut_degraded` gauge, and never
//! persists the degraded program into a fabric cache.
//!
//! Compilation is a ship-once step: [`CompiledFabric::save`] persists
//! the optimized program as a versioned `.nfab` [`artifact`] (backend
//! name + opt level + model digest + netlist), and
//! [`Model::compile_cached`] / [`Model::load_fabric`] reuse it across
//! worker processes and restarts — bit-exactly, with stale or corrupt
//! artifacts rejected by digest and structural validation.

pub mod artifact;
pub mod options;
pub mod registry;

pub use artifact::{companion_path, ArtifactKind, NfabHeader, NFAB_MAGIC, NFAB_VERSION};
pub use crate::engine::OptLevel;
pub use crate::obs::{CompileReport, PassReport};
pub use options::{FabricOptions, FabricTuning, DEFAULT_BACKEND};
pub use registry::{
    BackendEntry, BackendProvider, BackendRegistry, BatchAffinity, Capabilities, CompileCost,
    ProviderCtx,
};

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context};

use crate::engine::{BitNetlist, FabricProgram, InferenceBackend};
use crate::luts::LutNetwork;
use crate::netlist::SimResult;
use crate::obs::trace;
use crate::server::Server;
use crate::util::faults;

/// Metadata of a loaded model — everything reports and logs need
/// without touching the tables.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelInfo {
    pub name: String,
    /// Feature count of one input row.
    pub input_size: usize,
    /// Bit-width of the quantized circuit inputs.
    pub input_bits: usize,
    pub n_class: usize,
    /// L-LUTs per circuit layer.
    pub layer_widths: Vec<usize>,
    pub num_luts: usize,
    /// Total truth-table storage in bits (the design's "ROM size").
    pub table_bits: usize,
    /// Pipeline latency: one cycle per L-LUT layer.
    pub latency_cycles: usize,
}

impl std::fmt::Display for ModelInfo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {} -> {:?} -> {} classes, {} L-LUTs, {} table bits, {} cycles",
            self.name,
            self.input_size,
            self.layer_widths,
            self.n_class,
            self.num_luts,
            self.table_bits,
            self.latency_cycles
        )
    }
}

/// One converted model artifact: the entry point of the inference API.
///
/// Cheap to clone (the network sits behind an `Arc`); compile it as many
/// times as there are execution strategies worth comparing.
#[derive(Clone)]
pub struct Model {
    net: Arc<LutNetwork>,
}

impl Model {
    /// Load an NLUT file from disk.
    pub fn load(path: &Path) -> crate::Result<Model> {
        Ok(Model::from_network(LutNetwork::load(path)?))
    }

    /// Wrap an in-memory converted network.
    pub fn from_network(net: LutNetwork) -> Model {
        Model { net: Arc::new(net) }
    }

    /// Wrap an already-shared network without cloning it.
    pub fn from_arc(net: Arc<LutNetwork>) -> Model {
        Model { net }
    }

    pub fn name(&self) -> &str {
        &self.net.name
    }

    pub fn input_size(&self) -> usize {
        self.net.input_size
    }

    pub fn n_class(&self) -> usize {
        self.net.n_class
    }

    pub fn num_luts(&self) -> usize {
        self.net.num_luts()
    }

    pub fn table_bits(&self) -> usize {
        self.net.table_bits()
    }

    /// Pipeline latency in cycles (one per L-LUT layer).
    pub fn latency_cycles(&self) -> usize {
        self.net.layers.len()
    }

    /// The shared network this model wraps.
    pub fn network(&self) -> &Arc<LutNetwork> {
        &self.net
    }

    /// Snapshot of the model metadata.
    pub fn info(&self) -> ModelInfo {
        ModelInfo {
            name: self.net.name.clone(),
            input_size: self.net.input_size,
            input_bits: self.net.input_bits,
            n_class: self.net.n_class,
            layer_widths: self.net.layers.iter().map(|l| l.num_luts()).collect(),
            num_luts: self.net.num_luts(),
            table_bits: self.net.table_bits(),
            latency_cycles: self.net.layers.len(),
        }
    }

    /// Compile this model for execution: resolve `opts`' backend name
    /// through the global [`BackendRegistry`], validate the tuning, and
    /// run the backend factory **exactly once** at the requested
    /// [`OptLevel`]. Everything downstream — sessions, serving workers —
    /// shares the one compiled program. When `opts` carries a
    /// [`fabric_cache`](FabricOptions::fabric_cache) path this routes
    /// through [`compile_cached`](Self::compile_cached).
    pub fn compile(&self, opts: &FabricOptions) -> crate::Result<CompiledFabric> {
        self.compile_with(BackendRegistry::global(), opts)
    }

    /// [`compile`](Self::compile) against an explicit registry (isolated
    /// tests; embedders with their own backend set).
    pub fn compile_with(
        &self,
        registry: &BackendRegistry,
        opts: &FabricOptions,
    ) -> crate::Result<CompiledFabric> {
        if let Some(path) = opts.get_fabric_cache() {
            return self.compile_cached_with(registry, opts, path);
        }
        self.compile_fresh(registry, opts)
    }

    fn compile_fresh(
        &self,
        registry: &BackendRegistry,
        opts: &FabricOptions,
    ) -> crate::Result<CompiledFabric> {
        let entry = registry.resolve(opts.backend_or_default())?;
        let tuning = opts.resolve_tuning()?;
        let opt_level = opts.opt_level_or_default();
        let ctx = self.provider_ctx(opts);
        let t0 = Instant::now();
        let compiled = {
            let _span = trace::span(&format!("compile/{}", entry.name()));
            faults::inject(faults::point::BACKEND_COMPILE)
                .and_then(|()| entry.compile(self.net.clone(), opt_level, &ctx))
        };
        // Graceful degradation: a backend that fails to *construct* must
        // not take availability with it when a slower strategy can still
        // serve the model. Fall back to the backend the capability sheet
        // names (`scalar` when unset; `aot` names `bitsliced`), record
        // the degradation in the report (and the `neuralut_degraded`
        // gauge), and keep the original error visible on stderr. Unknown
        // names and bad tuning still fail above — those are caller
        // mistakes, not runtime faults.
        let (entry, program, degraded_from) = match compiled {
            Ok(program) => (entry, program, None),
            Err(cause) => {
                let fallback_name = entry.capabilities().fallback.unwrap_or(DEFAULT_BACKEND);
                let fallback = match registry.resolve(fallback_name) {
                    Ok(f) if entry.name() != f.name() => f,
                    // The backend *is* its own fallback (or the fallback
                    // is not registered): there is nothing left to
                    // degrade to.
                    _ => return Err(cause),
                };
                eprintln!(
                    "warning: backend '{}' failed to compile; degrading to '{}': {cause:#}",
                    entry.name(),
                    fallback.name()
                );
                let program = {
                    let _span = trace::span(&format!("compile/{}", fallback.name()));
                    fallback
                        .compile(self.net.clone(), opt_level, &ctx)
                        .with_context(|| format!("degrading after: {cause:#}"))?
                };
                (fallback, program, Some(entry.name().to_string()))
            }
        };
        let report = build_report(
            self,
            entry.name(),
            opt_level,
            t0.elapsed().as_secs_f64(),
            false,
            degraded_from,
            program.as_ref(),
        );
        Ok(CompiledFabric { model: self.clone(), entry, program, tuning, opt_level, report })
    }

    /// Compile-once, serve-many: reuse the `.nfab` artifact at `path`
    /// when it is fresh — same model digest, same backend, same opt
    /// level — otherwise compile and (re)write it. Workers and restarts
    /// thereby share one precompiled, pre-optimized program instead of
    /// paying the lowering + optimization passes per process. Requires a
    /// persistable backend (e.g. `bitsliced`).
    pub fn compile_cached(
        &self,
        opts: &FabricOptions,
        path: &Path,
    ) -> crate::Result<CompiledFabric> {
        self.compile_cached_with(BackendRegistry::global(), opts, path)
    }

    /// [`compile_cached`](Self::compile_cached) against an explicit
    /// registry.
    pub fn compile_cached_with(
        &self,
        registry: &BackendRegistry,
        opts: &FabricOptions,
        path: &Path,
    ) -> crate::Result<CompiledFabric> {
        // Fail fast on a non-persistable backend: a cache path was asked
        // for explicitly, so silently skipping the cache would lie.
        let entry = registry.resolve(opts.backend_or_default())?;
        if !entry.capabilities().persistable {
            bail!(
                "backend '{}' does not produce a persistable compiled-fabric \
                 artifact (.nfab); drop the fabric cache or pick a persistable \
                 backend",
                entry.name()
            );
        }
        if path.exists() {
            match self.load_fabric_with(registry, opts, path) {
                Ok(fabric) => return Ok(fabric),
                // Stale or corrupt cache: say why (a cache that thrashes
                // every startup should be diagnosable), then recompile
                // below and overwrite.
                Err(e) => eprintln!(
                    "warning: fabric cache {} not reusable, recompiling: {e:#}",
                    path.display()
                ),
            }
        }
        // Pin the artifact path into the compile context even when the
        // caller passed `path` explicitly (compile_cached) rather than
        // through the options — providers place companions beside it.
        let fabric = self.compile_fresh(registry, &opts.clone().fabric_cache(path))?;
        // A degraded fabric is a fallback interpreter standing in for the
        // backend the caller asked to cache — persisting it would poison
        // the cache with the wrong program. Serve it, don't save it.
        if let Some(from) = &fabric.report.degraded_from {
            eprintln!(
                "warning: not caching {}: fabric degraded from '{from}' to '{}'",
                path.display(),
                fabric.entry.name()
            );
            return Ok(fabric);
        }
        // The cache is an optimization, not an availability dependency: a
        // failed write (read-only volume, permissions) must not take down
        // a process that just compiled a perfectly good program.
        if let Err(e) = fabric.save(path) {
            eprintln!(
                "warning: could not write fabric cache {}: {e:#}",
                path.display()
            );
        }
        Ok(fabric)
    }

    /// Strictly load a `.nfab` artifact for this model: the recorded
    /// model digest must match this network, and — when `opts` pins them
    /// explicitly — the recorded backend and opt level must match too.
    /// Any mismatch, truncation or corruption is an error naming the
    /// file, the field and expected-vs-actual values; nothing is ever
    /// recompiled here (that is [`compile_cached`](Self::compile_cached)'s
    /// job).
    pub fn load_fabric(&self, opts: &FabricOptions, path: &Path) -> crate::Result<CompiledFabric> {
        self.load_fabric_with(BackendRegistry::global(), opts, path)
    }

    /// [`load_fabric`](Self::load_fabric) against an explicit registry.
    pub fn load_fabric_with(
        &self,
        registry: &BackendRegistry,
        opts: &FabricOptions,
        path: &Path,
    ) -> crate::Result<CompiledFabric> {
        let t0 = Instant::now();
        let (header, nl) = {
            let _span = trace::span("load/nfab");
            artifact::load(path)?
        };
        if let Some(requested) = opts.get_backend() {
            // Resolve through the registry so an alias (bitsliced-auto)
            // compares as its concrete target, not as the alias name.
            let canon = match registry.resolve(requested) {
                Ok(entry) => entry.name().to_string(),
                Err(_) => registry::normalize_name(requested),
            };
            if canon != header.backend {
                bail!(
                    "{}: artifact was compiled by backend '{}' but options \
                     request '{canon}'",
                    path.display(),
                    header.backend
                );
            }
        }
        if let Some(level) = opts.get_opt_level() {
            if level != header.opt_level {
                bail!(
                    "{}: artifact was compiled at {} but options request {level} \
                     (stale artifact?)",
                    path.display(),
                    header.opt_level
                );
            }
        }
        let digest = self.net.digest();
        if header.model_digest != digest {
            bail!(
                "{}: artifact was compiled from a model with digest \
                 {:016x}, but this model ('{}') has digest {digest:016x} — \
                 stale or mismatched artifact",
                path.display(),
                header.model_digest,
                self.net.name
            );
        }
        if nl.input_size != self.net.input_size
            || nl.input_bits != self.net.input_bits
            || nl.n_class != self.net.n_class
        {
            bail!(
                "{}: artifact shape ({} inputs x {} bits -> {} classes) does \
                 not match model '{}' ({} x {} -> {})",
                path.display(),
                nl.input_size,
                nl.input_bits,
                nl.n_class,
                self.net.name,
                self.net.input_size,
                self.net.input_bits,
                self.net.n_class
            );
        }
        let entry = registry.resolve(&header.backend).with_context(|| {
            format!("{}: resolving the artifact's backend", path.display())
        })?;
        let caps = entry.capabilities();
        if caps.word_lanes != 0 && header.lanes != caps.word_lanes {
            bail!(
                "{}: artifact records a {}-word plane format but backend '{}' \
                 executes {}-word planes — refusing to replay it (recompile, \
                 or pick the matching width backend)",
                path.display(),
                header.lanes,
                entry.name(),
                caps.word_lanes
            );
        }
        let tuning = opts.resolve_tuning()?;
        let mut ctx = self.provider_ctx(opts);
        ctx.artifact_path = Some(path.to_path_buf());
        let program = entry.load_program(self.net.clone(), Arc::new(nl), &ctx)?;
        let report = build_report(
            self,
            entry.name(),
            header.opt_level,
            t0.elapsed().as_secs_f64(),
            true,
            None,
            program.as_ref(),
        );
        Ok(CompiledFabric {
            model: self.clone(),
            entry,
            program,
            tuning,
            opt_level: header.opt_level,
            report,
        })
    }

    /// Stable digest of the underlying network (what `.nfab` artifacts
    /// record).
    pub fn digest(&self) -> u64 {
        self.net.digest()
    }

    /// The compile-time context handed to every [`BackendProvider`]
    /// hook: this model's digest plus the side-artifact knobs from
    /// `opts` (currently the AOT `.so` cache directory).
    fn provider_ctx(&self, opts: &FabricOptions) -> ProviderCtx {
        ProviderCtx {
            model_digest: self.net.digest(),
            aot_cache_dir: opts.get_aot_cache_dir().map(PathBuf::from),
            artifact_path: opts.get_fabric_cache().map(PathBuf::from),
            aot_disabled: opts.aot_disabled_or_default(),
        }
    }
}

// `Debug` goes through `ModelInfo` — tables are megabytes of `i16`s
// nobody wants in a log line.
impl std::fmt::Debug for Model {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Model({})", self.info())
    }
}

/// Assemble the [`CompileReport`] for a freshly compiled (or just
/// loaded) program: per-pass telemetry from the program itself, final
/// netlist shape from its bit-netlist (zeros for table-lookup backends).
fn build_report(
    model: &Model,
    backend: &str,
    opt_level: OptLevel,
    total_s: f64,
    from_cache: bool,
    degraded_from: Option<String>,
    program: &dyn FabricProgram,
) -> CompileReport {
    let (ops, levels, max_planes, max_wires) = match program.bit_netlist() {
        Some(nl) => (nl.num_ops(), nl.levels.len(), nl.max_planes, nl.max_wires),
        None => (0, 0, 0, 0),
    };
    CompileReport {
        model: model.name().to_string(),
        backend: backend.to_string(),
        opt_level: opt_level.to_string(),
        total_s,
        from_cache,
        passes: program.pass_reports().to_vec(),
        ops,
        levels,
        max_planes,
        max_wires,
        lanes: program.plane_lanes().unwrap_or(0),
        degraded_from,
    }
}

/// A compiled model: one backend's shared, compile-once program plus the
/// resolved tuning. Spawn any number of [`session`](Self::session)s and
/// [`serve`](Self::serve) pools from it — none of them recompiles — or
/// [`save`](Self::save) it as a `.nfab` artifact other processes load.
pub struct CompiledFabric {
    model: Model,
    entry: BackendEntry,
    program: Arc<dyn FabricProgram>,
    tuning: FabricTuning,
    opt_level: OptLevel,
    report: CompileReport,
}

impl CompiledFabric {
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// Canonical name of the backend that compiled this fabric.
    pub fn backend_name(&self) -> &str {
        self.entry.name()
    }

    pub fn capabilities(&self) -> Capabilities {
        self.entry.capabilities()
    }

    /// The netlist optimization level this fabric was compiled (or
    /// loaded) at.
    pub fn opt_level(&self) -> OptLevel {
        self.opt_level
    }

    /// Word ops per 64-sample block of the compiled program (`None` for
    /// table-lookup backends with nothing lowered) — the compiled cost
    /// metric benches and the CI gate track.
    pub fn num_word_ops(&self) -> Option<usize> {
        self.program.bit_netlist().map(|nl| nl.num_ops())
    }

    /// Structured compile telemetry: per-pass wall time and op deltas
    /// plus the final netlist shape. For fabrics loaded from a `.nfab`
    /// cache this records the load time with `from_cache = true` and no
    /// passes (nothing was lowered or optimized in this process).
    pub fn report(&self) -> &CompileReport {
        &self.report
    }

    /// True when this fabric is serving degraded: the requested backend
    /// failed to compile and the scalar fallback took over.
    /// [`report`](Self::report)`.degraded_from` names the backend that
    /// was asked for.
    pub fn degraded(&self) -> bool {
        self.report.degraded_from.is_some()
    }

    /// Where [`save`](Self::save) persists the compile report next to a
    /// `.nfab` artifact: `net.nfab` → `net.report.json`.
    pub fn report_path(artifact_path: &Path) -> PathBuf {
        artifact_path.with_extension("report.json")
    }

    /// Persist this fabric as a versioned `.nfab` artifact: the backend
    /// name, opt level, the source model's digest, and the compiled
    /// program. Another process with the same model loads it via
    /// [`Model::load_fabric`] / [`Model::compile_cached`] and serves
    /// bit-exactly identical outputs without recompiling. Errors for
    /// backends whose programs are not persistable.
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        if !self.entry.capabilities().persistable {
            bail!(
                "backend '{}' does not produce a persistable compiled-fabric \
                 artifact (.nfab)",
                self.entry.name()
            );
        }
        let Some(nl) = self.program.bit_netlist() else {
            bail!(
                "backend '{}' is marked persistable but exposes no compiled \
                 bit-netlist to save",
                self.entry.name()
            );
        };
        let lanes = self
            .program
            .plane_lanes()
            .unwrap_or(self.entry.capabilities().word_lanes)
            .max(1);
        // Native-codegen backends own a companion `.so` beside the
        // `.nfab`; the kind byte tells loaders it participates in the
        // staleness contract (a missing companion is rebuilt, not fatal).
        let kind = if self.entry.capabilities().compile_cost == CompileCost::NativeCodegen {
            ArtifactKind::NetlistWithCompanion
        } else {
            ArtifactKind::Netlist
        };
        artifact::save(path, kind, self.entry.name(), self.opt_level, self.model.digest(), lanes, nl)?;
        // The report rides along as a JSON sibling, written with the same
        // tmp+rename discipline as the artifact so a crash mid-save never
        // leaves a torn report next to a good .nfab. Like the artifact
        // cache itself it is telemetry, not an availability dependency:
        // a failed write warns and the fabric stays perfectly usable.
        let report_path = Self::report_path(path);
        if let Err(e) =
            artifact::atomic_write(&report_path, self.report.to_json().to_string().as_bytes())
        {
            eprintln!(
                "warning: could not write compile report {}: {e:#}",
                report_path.display()
            );
        }
        Ok(())
    }

    /// The serving knobs [`serve`](Self::serve) will use.
    pub fn tuning(&self) -> &FabricTuning {
        &self.tuning
    }

    /// The shared compiled program.
    pub fn program(&self) -> &Arc<dyn FabricProgram> {
        &self.program
    }

    /// The lowered bit-netlist, for backends that build one (`None` for
    /// table-lookup backends).
    pub fn bit_netlist(&self) -> Option<&Arc<BitNetlist>> {
        self.program.bit_netlist()
    }

    /// Spawn one raw executor (cheap; `Arc` clones only). Prefer
    /// [`session`](Self::session) unless you are building your own pool.
    pub fn executor(&self) -> Box<dyn InferenceBackend> {
        self.program.executor()
    }

    /// An in-process inference session over the shared program.
    pub fn session(&self) -> Session {
        Session {
            exec: self.program.executor(),
            input_size: self.model.input_size(),
            n_class: self.model.n_class(),
        }
    }

    /// Start the multi-worker serving runtime: `tuning().workers`
    /// batcher threads over one bounded request queue, every worker
    /// executing this fabric's shared program. Infallible — compilation
    /// and validation already happened in [`Model::compile`].
    pub fn serve(&self) -> Server {
        Server::start(self.program.clone(), self.model.input_size(), &self.tuning, self.degraded())
    }
}

impl std::fmt::Debug for CompiledFabric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CompiledFabric({} via {})", self.model.info(), self.entry.name())
    }
}

/// In-process batch inference over one compiled fabric — the
/// direct-call sibling of the serving runtime's
/// [`Client`](crate::server::Client).
pub struct Session {
    exec: Box<dyn InferenceBackend>,
    input_size: usize,
    n_class: usize,
}

impl Session {
    /// Stable name of the executing backend.
    pub fn backend_name(&self) -> &'static str {
        self.exec.name()
    }

    /// Pipeline latency in cycles.
    pub fn latency_cycles(&self) -> usize {
        self.exec.latency_cycles()
    }

    fn check_batch(&self, x: &[f32]) -> crate::Result<usize> {
        if self.input_size == 0 || x.len() % self.input_size != 0 {
            bail!(
                "batch of {} values is not a whole number of {}-feature rows",
                x.len(),
                self.input_size
            );
        }
        Ok(x.len() / self.input_size)
    }

    /// Run raw feature rows (`[batch * input_size]` floats in [0, 1]).
    pub fn infer_batch(&self, x: &[f32]) -> crate::Result<SimResult> {
        self.check_batch(x)?;
        Ok(self.exec.run_batch(x))
    }

    /// Classify a single feature row.
    pub fn infer_one(&self, row: &[f32]) -> crate::Result<u32> {
        if self.input_size == 0 || row.len() != self.input_size {
            bail!(
                "feature vector has {} values, model expects {}",
                row.len(),
                self.input_size
            );
        }
        Ok(self.exec.run_batch(row).predictions[0])
    }

    /// Classification accuracy over a labelled set.
    pub fn accuracy(&self, x: &[f32], y: &[i32]) -> crate::Result<f64> {
        let batch = self.check_batch(x)?;
        if batch != y.len() {
            bail!("{batch} feature rows but {} labels", y.len());
        }
        Ok(self.exec.accuracy(x, y))
    }

    /// Classes the model predicts over.
    pub fn n_class(&self) -> usize {
        self.n_class
    }

    /// Feature count of one input row.
    pub fn input_size(&self) -> usize {
        self.input_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;
    use crate::netlist::Simulator;

    fn model() -> Model {
        Model::from_network(random_network(91, 8, 2, &[6, 3], 3, 2, 4))
    }

    #[test]
    fn model_metadata_reflects_the_network() {
        let m = model();
        let info = m.info();
        assert_eq!(info.input_size, 8);
        assert_eq!(info.n_class, 3);
        assert_eq!(info.layer_widths, vec![6, 3]);
        assert_eq!(info.latency_cycles, 2);
        assert_eq!(info.num_luts, m.num_luts());
        assert_eq!(info.table_bits, m.table_bits());
        assert_eq!(m.name(), info.name);
        assert!(info.to_string().contains("L-LUTs"));
    }

    #[test]
    fn sessions_of_both_builtins_are_bit_exact() {
        let m = model();
        let scalar = m.compile(&FabricOptions::new()).unwrap();
        let bits = m.compile(&FabricOptions::new().backend(" BITSLICED ")).unwrap();
        assert_eq!(scalar.backend_name(), "scalar");
        assert_eq!(bits.backend_name(), "bitsliced");
        let x: Vec<f32> = (0..8 * 130).map(|i| (i % 13) as f32 / 13.0).collect();
        let a = scalar.session().infer_batch(&x).unwrap();
        let b = bits.session().infer_batch(&x).unwrap();
        assert_eq!(a.logit_codes, b.logit_codes);
        assert_eq!(a.predictions, b.predictions);
        let sim = Simulator::new(m.network());
        assert_eq!(sim.simulate_batch(&x).logit_codes, a.logit_codes);
    }

    #[test]
    fn compile_happens_once_per_fabric_not_per_session() {
        let m = model();
        let fabric = m.compile(&FabricOptions::new().backend("bitsliced")).unwrap();
        let prog = fabric.bit_netlist().unwrap().clone();
        let s1 = fabric.session();
        let s2 = fabric.session();
        // One lowered program: fabric + our clone + two session executors.
        assert_eq!(Arc::strong_count(&prog), 4);
        let x: Vec<f32> = (0..8 * 5).map(|i| (i % 7) as f32 / 7.0).collect();
        assert_eq!(
            s1.infer_batch(&x).unwrap().logit_codes,
            s2.infer_batch(&x).unwrap().logit_codes
        );
    }

    #[test]
    fn session_rejects_ragged_batches_and_label_mismatches() {
        let s = model().compile(&FabricOptions::new()).unwrap().session();
        assert!(s.infer_batch(&[0.0; 9]).is_err());
        assert!(s.infer_batch(&[0.0; 16]).is_ok());
        assert!(s.infer_one(&[0.0; 7]).is_err());
        assert!(s.infer_one(&[0.0; 8]).is_ok());
        assert!(s.accuracy(&[0.0; 16], &[0, 1, 2]).is_err());
        assert!(s.accuracy(&[0.0; 16], &[0, 1]).is_ok());
    }

    #[test]
    fn opt_levels_compile_and_never_grow_the_program() {
        let m = model();
        let mut prev = usize::MAX;
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
            let fabric = m
                .compile(&FabricOptions::new().backend("bitsliced").opt_level(level))
                .unwrap();
            assert_eq!(fabric.opt_level(), level);
            let ops = fabric.num_word_ops().expect("bitsliced has a netlist");
            assert!(ops <= prev, "{level} grew the program: {ops} > {prev}");
            prev = ops;
        }
        // Scalar has nothing lowered and reports the default level.
        let scalar = m.compile(&FabricOptions::new()).unwrap();
        assert!(scalar.num_word_ops().is_none());
        assert_eq!(scalar.opt_level(), OptLevel::O1);
    }

    #[test]
    fn fabric_cache_round_trips_through_compile() {
        let m = model();
        let path = std::env::temp_dir().join("neuralut_fabric_mod_cache.nfab");
        let _ = std::fs::remove_file(&path);
        let opts = FabricOptions::new()
            .backend("bitsliced")
            .opt_level(OptLevel::O2)
            .fabric_cache(&path);
        let x: Vec<f32> = (0..8 * 70).map(|i| (i % 9) as f32 / 9.0).collect();
        // First compile populates the cache...
        let a = m.compile(&opts).unwrap();
        assert!(path.exists(), "compile with fabric_cache must write the artifact");
        // ...second compile loads it and serves identical outputs.
        let b = m.compile(&opts).unwrap();
        assert_eq!(a.num_word_ops(), b.num_word_ops());
        assert_eq!(
            a.session().infer_batch(&x).unwrap().logit_codes,
            b.session().infer_batch(&x).unwrap().logit_codes
        );
        // The scalar backend cannot cache; asking for it is an error.
        let err = m
            .compile(&FabricOptions::new().fabric_cache(&path))
            .unwrap_err()
            .to_string();
        assert!(err.contains("persistable"), "{err}");
    }

    #[test]
    fn compile_reports_attach_and_persist() {
        let m = model();
        let fabric = m
            .compile(&FabricOptions::new().backend("bitsliced").opt_level(OptLevel::O2))
            .unwrap();
        let r = fabric.report();
        r.check().unwrap();
        assert!(!r.from_cache);
        assert_eq!(r.ops, fabric.num_word_ops().unwrap());
        assert_eq!(r.opt_level, "O2");
        assert_eq!(
            r.passes.iter().map(|p| p.name.as_str()).collect::<Vec<_>>(),
            ["lower", "simplify", "dce"]
        );
        // Scalar compiles have no passes and no netlist shape.
        let scalar = m.compile(&FabricOptions::new()).unwrap();
        assert!(scalar.report().passes.is_empty());
        assert_eq!(scalar.report().ops, 0);
        scalar.report().check().unwrap();
        // A cached compile writes the JSON sidecar; the reload flags
        // from_cache and keeps the final shape.
        let path = std::env::temp_dir().join("neuralut_fabric_report_cache.nfab");
        let _ = std::fs::remove_file(&path);
        let opts = FabricOptions::new()
            .backend("bitsliced")
            .opt_level(OptLevel::O2)
            .fabric_cache(&path);
        let first = m.compile(&opts).unwrap();
        let sidecar = CompiledFabric::report_path(&path);
        assert!(sidecar.exists(), "save must write the report sibling");
        let parsed =
            CompileReport::from_json(&crate::util::json::from_file(&sidecar).unwrap()).unwrap();
        parsed.check().unwrap();
        assert_eq!(parsed.ops, first.num_word_ops().unwrap());
        assert!(!parsed.from_cache);
        let second = m.compile(&opts).unwrap();
        assert!(second.report().from_cache);
        assert!(second.report().passes.is_empty());
        assert_eq!(second.report().ops, first.report().ops);
    }

    #[test]
    fn failed_backend_compile_degrades_to_scalar_and_stays_bit_exact() {
        let m = model();
        let x: Vec<f32> = (0..8 * 40).map(|i| (i % 11) as f32 / 11.0).collect();
        let guard = crate::util::faults::arm_scoped("backend.compile:1:error", 31).unwrap();
        let fabric = m.compile(&FabricOptions::new().backend("bitsliced")).unwrap();
        assert_eq!(guard.fired("backend.compile"), 1);
        assert!(fabric.degraded());
        assert_eq!(fabric.backend_name(), "scalar");
        assert_eq!(fabric.report().degraded_from.as_deref(), Some("bitsliced"));
        assert!(fabric.report().to_string().contains("DEGRADED"));
        // Degraded answers are still bit-exact: scalar IS the reference.
        let sim = Simulator::new(m.network());
        assert_eq!(
            fabric.session().infer_batch(&x).unwrap().logit_codes,
            sim.simulate_batch(&x).logit_codes
        );
        // When the default backend itself fails there is nothing left to
        // degrade to: the original error propagates.
        let err = m.compile(&FabricOptions::new()).unwrap_err();
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        drop(guard);
        // Disarmed, compiles are healthy again.
        let healthy = m.compile(&FabricOptions::new().backend("bitsliced")).unwrap();
        assert!(!healthy.degraded());
        assert!(healthy.report().degraded_from.is_none());
    }

    #[test]
    fn degraded_fabrics_are_never_written_to_the_cache() {
        let m = model();
        let path = std::env::temp_dir().join("neuralut_fabric_degraded_cache.nfab");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(CompiledFabric::report_path(&path));
        let opts = FabricOptions::new().backend("bitsliced").fabric_cache(&path);
        let guard = crate::util::faults::arm_scoped("backend.compile:1:error", 33).unwrap();
        let fabric = m.compile(&opts).unwrap();
        assert!(fabric.degraded());
        assert!(!path.exists(), "a degraded (scalar) fabric must not poison the cache");
        assert!(!CompiledFabric::report_path(&path).exists());
        drop(guard);
        // Healthy again: the cache fills with the real backend.
        let healthy = m.compile(&opts).unwrap();
        assert!(!healthy.degraded());
        assert!(path.exists());
    }

    #[test]
    fn unknown_backend_and_bad_tuning_fail_at_compile() {
        let m = model();
        let err = m
            .compile(&FabricOptions::new().backend("fpga"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown backend 'fpga'"), "{err}");
        assert!(err.contains("scalar"), "{err}");
        assert!(m.compile(&FabricOptions::new().workers(0)).is_err());
    }
}
