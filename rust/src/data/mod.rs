//! Dataset blobs (NLDS v1, written by `python/compile/datasets.py`) and
//! synthetic workload generation for the serving benches.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

pub const MAGIC: u32 = 0x4E4C4453; // "NLDS"
pub const VERSION: u32 = 1;

/// An in-memory dataset: features are f32 in [0, 1], labels are class ids.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n_feat: usize,
    pub n_class: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl Dataset {
    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    /// Row `i` of the training features.
    pub fn train_row(&self, i: usize) -> &[f32] {
        &self.train_x[i * self.n_feat..(i + 1) * self.n_feat]
    }

    /// Row `i` of the test features.
    pub fn test_row(&self, i: usize) -> &[f32] {
        &self.test_x[i * self.n_feat..(i + 1) * self.n_feat]
    }

    /// Load an NLDS v1 blob.
    pub fn load(path: &Path) -> Result<Dataset> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut header = [0u8; 24];
        f.read_exact(&mut header)?;
        let word = |i: usize| {
            u32::from_le_bytes(header[4 * i..4 * i + 4].try_into().unwrap())
        };
        if word(0) != MAGIC {
            bail!("{}: bad magic {:#x}", path.display(), word(0));
        }
        if word(1) != VERSION {
            bail!("{}: unsupported version {}", path.display(), word(1));
        }
        let (n_train, n_test, n_feat, n_class) = (
            word(2) as usize,
            word(3) as usize,
            word(4) as usize,
            word(5) as usize,
        );
        let train_x = read_f32s(&mut f, n_train * n_feat)?;
        let train_y = read_i32s(&mut f, n_train)?;
        let test_x = read_f32s(&mut f, n_test * n_feat)?;
        let test_y = read_i32s(&mut f, n_test)?;
        let ds = Dataset { n_feat, n_class, train_x, train_y, test_x, test_y };
        ds.validate()?;
        Ok(ds)
    }

    /// Load by short name from the artifacts tree.
    pub fn load_named(name: &str) -> Result<Dataset> {
        Self::load(&crate::artifacts_dir().join("data").join(format!("{name}.bin")))
    }

    pub fn validate(&self) -> Result<()> {
        if self.train_x.len() != self.n_train() * self.n_feat {
            bail!("train_x size mismatch");
        }
        if self.test_x.len() != self.n_test() * self.n_feat {
            bail!("test_x size mismatch");
        }
        let ok_label = |y: &[i32]| y.iter().all(|&v| (v as usize) < self.n_class);
        if !ok_label(&self.train_y) || !ok_label(&self.test_y) {
            bail!("label out of range");
        }
        Ok(())
    }

    /// A synthetic dataset for tests (uniform features, random labels).
    pub fn synthetic(seed: u64, n_train: usize, n_test: usize, n_feat: usize,
                     n_class: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut gen = |n: usize| {
            let x: Vec<f32> = (0..n * n_feat).map(|_| rng.f32()).collect();
            let y: Vec<i32> =
                (0..n).map(|_| rng.below(n_class) as i32).collect();
            (x, y)
        };
        let (train_x, train_y) = gen(n_train);
        let (test_x, test_y) = gen(n_test);
        Dataset { n_feat, n_class, train_x, train_y, test_x, test_y }
    }
}

fn read_f32s(f: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn read_i32s(f: &mut impl Read, n: usize) -> Result<Vec<i32>> {
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Poisson-arrival inference workload for the server benches.
#[derive(Debug, Clone)]
pub struct Workload {
    /// (arrival time in seconds, feature vector) per request.
    pub requests: Vec<(f64, Vec<f32>)>,
}

impl Workload {
    /// Draw `n` requests at `rate` req/s, features sampled from `ds` test
    /// rows (cycled) with jitter — a stand-in for the paper's edge traffic.
    pub fn poisson(ds: &Dataset, seed: u64, n: usize, rate: f64) -> Workload {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut requests = Vec::with_capacity(n);
        for i in 0..n {
            t += rng.exp(rate);
            let row = ds.test_row(i % ds.n_test());
            let jittered = row
                .iter()
                .map(|&v| (v + 0.01 * rng.normal() as f32).clamp(0.0, 1.0))
                .collect();
            requests.push((t, jittered));
        }
        Workload { requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_roundtrip_fields() {
        let ds = Dataset::synthetic(1, 100, 20, 8, 3);
        assert_eq!(ds.n_train(), 100);
        assert_eq!(ds.n_test(), 20);
        assert_eq!(ds.train_row(5).len(), 8);
        ds.validate().unwrap();
    }

    #[test]
    fn workload_arrivals_monotone() {
        let ds = Dataset::synthetic(2, 10, 10, 4, 2);
        let w = Workload::poisson(&ds, 3, 100, 1000.0);
        assert_eq!(w.requests.len(), 100);
        for pair in w.requests.windows(2) {
            assert!(pair[1].0 >= pair[0].0);
        }
    }

    #[test]
    fn loads_written_blob() {
        // Write a tiny blob by hand and read it back.
        let dir = std::env::temp_dir().join("neuralut_test_data");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.bin");
        let mut bytes = Vec::new();
        for w in [MAGIC, VERSION, 2, 1, 3, 2] {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        for v in [0.1f32, 0.2, 0.3, 0.4, 0.5, 0.6] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in [0i32, 1] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in [0.7f32, 0.8, 0.9] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&1i32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let ds = Dataset::load(&path).unwrap();
        assert_eq!(ds.n_feat, 3);
        assert_eq!(ds.train_y, vec![0, 1]);
        assert!((ds.test_x[2] - 0.9).abs() < 1e-6);
    }
}
