//! # NeuraLUT — FPL 2024 reproduction
//!
//! *NeuraLUT: Hiding Neural Network Density in Boolean Synthesizable
//! Functions* (Andronic & Constantinides). This crate is Layer 3 of a
//! three-layer Rust + JAX + Pallas stack: it owns the whole codesign
//! toolflow after `make artifacts` — training (executing AOT-compiled XLA
//! train steps via PJRT), sub-network → L-LUT conversion, RTL generation,
//! synthesis estimation, cycle-accurate fabric simulation, and serving.
//! Python never runs at request time.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — from-scratch substrates: JSON, RNG, stats, thread pool,
//!   property-test + bench harnesses (offline build: no external crates
//!   beyond `xla`/`anyhow`).
//! * [`data`] — dataset blobs produced by the build path.
//! * [`manifest`] — the flat parameter ABI shared with `python/compile`.
//! * [`runtime`] — PJRT client wrapper: load HLO text, compile, execute.
//! * [`nn`] — parameter store, Table-I formulas, metrics.
//! * [`config`] — TOML-subset experiment-suite files (`neuralut suite`).
//! * [`coordinator`] — training driver (SGDR schedule), conversion
//!   manager, end-to-end codesign pipeline.
//! * [`luts`] — truth tables and the converted L-LUT network model.
//! * [`netlist`] — cycle-accurate LUT-network simulator (the FPGA fabric
//!   substitute).
//! * [`engine`] — execution backends: the bit-level lowering pass, the
//!   `engine::opt` netlist optimization pipeline (`O0`/`O1`/`O2`:
//!   constant folding, cross-level CSE, dead-wire elimination, plane
//!   compaction), and the bitsliced evaluator family (`[u64; N]` planes,
//!   64/128/256/512 samples per block for `bitsliced`/`-x2`/`-x4`/`-x8`),
//!   behind the `FabricProgram` (compile-once) / `InferenceBackend`
//!   (per-worker) traits, plus `engine::aot` — the `aot`/`aot-c`
//!   native-code backends that emit the optimized netlist as
//!   straight-line source, run the system compiler at `Model::compile`
//!   time, and `dlopen` the cached shared object.
//! * [`fabric`] — **the unified inference API**: `Model` →
//!   `CompiledFabric` → `Session`/serving, with the pluggable
//!   `BackendRegistry` (backends by name), the `FabricOptions`
//!   resolution path (builder < env < config file < defaults), and
//!   persistent `.nfab` compiled-fabric artifacts
//!   (`CompiledFabric::save` / `Model::compile_cached`).
//! * [`obs`] — observability: the metrics registry (counters / gauges /
//!   log2 histograms, lock-free hot path), compile-pass tracing
//!   (`CompileReport`, `NEURALUT_TRACE` span log) and Prometheus-text +
//!   JSON exposition. `std`-only by design.
//! * [`rtl`] — Verilog + testbench generation.
//! * [`synth`] — Vivado-substitute synthesis/P&R cost model (support
//!   reduction, ROBDD, 6-LUT covering, timing).
//! * [`server`] — multi-worker sharded inference serving runtime: bounded
//!   request queue, N *supervised* batcher threads over one shared
//!   compiled fabric (worker panics are caught, in-flight requests
//!   answered with a typed `WorkerCrashed`, crashed slots respawned with
//!   capped backoff), explicit backpressure (`try_infer` → `Overloaded`,
//!   opt-in `RetryPolicy`), per-request deadlines shed at dequeue
//!   (`request_timeout_ms` → `DeadlineExceeded`), graceful
//!   drain-on-shutdown, and per-request latency telemetry (queue-wait /
//!   batch-formation / execute stages) in an `obs` metrics registry.
//!   Started via `CompiledFabric::serve`; chaos-tested against the named
//!   fault points in `util::faults` (`NEURALUT_FAULTS`).
//! * [`net`] — network serving front-end over [`server`]: length-prefixed
//!   binary wire protocol and HTTP/1.1 (`POST /v1/infer` JSON,
//!   `GET /metrics`, `GET /healthz`) sniffed on one TCP port, a
//!   `ModelManager` serving several named models from a manifest
//!   directory with zero-downtime hot-swap, connection cap, and typed
//!   overload refusals (`Overloaded` → wire code 1 / HTTP 429) — the
//!   bounded worker queue stays the single admission point. Started via
//!   `neuralut serve --listen`.
//!
//! ## The inference API
//!
//! One model artifact, execution strategy as a pluggable choice:
//!
//! ```ignore
//! use neuralut::fabric::{FabricOptions, Model};
//!
//! let model = Model::load(path)?;                       // or from_network(net)
//! let fabric = model.compile(
//!     &FabricOptions::from_env()?.backend("bitsliced"), // by registry name
//! )?;
//! let session = fabric.session();                       // in-process batches
//! let result = session.infer_batch(&x)?;
//! let server = fabric.serve();                          // worker-pool serving
//! let reply = server.client().infer(feats)?;
//! ```
//!
//! `Model::compile` resolves the backend name through
//! `fabric::BackendRegistry` — `scalar` (zero compile cost, per-sample
//! lookups) and the bitsliced width family (`bitsliced` at 64 samples
//! per `u64` word, `bitsliced-x2`/`-x4`/`-x8` at 128/256/512 samples
//! per `[u64; N]` plane, all over the same lowered netlist) are
//! built-ins; tests and extensions register more. `bitsliced-auto` is a
//! registry alias that resolves to the width runtime CPU detection
//! picks (AVX2 x86-64 → x4, other 64-bit → x2) before anything is
//! compiled or persisted — `NEURALUT_ENGINE=bitsliced-x4` pins a width
//! explicitly, and wider is only faster while its planes stay cache-
//! resident. The backend factory runs exactly once per compile;
//! sessions and serving workers all share
//! the one compiled program (`Arc` clones only). Configuration funnels
//! through `FabricOptions::from_env_and_config`: defaults, then a server
//! config file, then `NEURALUT_ENGINE`/`NEURALUT_WORKERS`/
//! `NEURALUT_OPT_LEVEL`/`NEURALUT_FABRIC_CACHE`, then explicit
//! builder/CLI settings — with uniform, name-listing errors for unknown
//! backends on every path.
//!
//! ## Optimization levels and `.nfab` artifacts
//!
//! The bitsliced backend compiles through the `engine::opt` pass
//! pipeline. `FabricOptions::opt_level` picks how hard it works: `O0`
//! (lowered netlist verbatim), `O1` (default — constant folding, mux
//! simplification, per-level CSE, dead-wire elimination) or `O2` (`O1`
//! plus cross-level value numbering and plane compaction). All levels
//! are bit-exact; higher levels only remove work from the evaluator's
//! hot loop.
//!
//! Compilation itself becomes a ship-once step with the `.nfab`
//! compiled-fabric artifact: `CompiledFabric::save(path)` persists the
//! backend name, opt level, model digest and optimized program;
//! `Model::compile_cached(&opts, path)` (or
//! `FabricOptions::fabric_cache`) loads it when fresh and recompiles +
//! rewrites it when stale or corrupt. Workers and restarts share one
//! precompiled, pre-optimized program; a digest mismatch is an error,
//! never a silently wrong answer.
//!
//! ## Observability
//!
//! Every compile yields a [`obs::CompileReport`]
//! (`CompiledFabric::report()`): per-pass wall time and op/plane deltas
//! for `lower` → `simplify` → `dce` plus the final netlist shape,
//! persisted as `*.report.json` beside `.nfab` artifacts. The serving
//! runtime splits each request's latency into queue-wait /
//! batch-formation / execute histograms in a `neuralut_server_*` metrics
//! registry (`Server::metrics()`), and [`obs::expo`] renders any
//! snapshot as Prometheus text or JSON — see the `report` and `stats`
//! CLI subcommands, or set `NEURALUT_TRACE=1` for a live span log of the
//! compile passes.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod fabric;
pub mod luts;
pub mod manifest;
pub mod net;
pub mod netlist;
pub mod nn;
pub mod obs;
pub mod rtl;
pub mod runtime;
pub mod server;
pub mod synth;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifact tree produced by `make artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("NEURALUT_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
