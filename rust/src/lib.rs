//! # NeuraLUT — FPL 2024 reproduction
//!
//! *NeuraLUT: Hiding Neural Network Density in Boolean Synthesizable
//! Functions* (Andronic & Constantinides). This crate is Layer 3 of a
//! three-layer Rust + JAX + Pallas stack: it owns the whole codesign
//! toolflow after `make artifacts` — training (executing AOT-compiled XLA
//! train steps via PJRT), sub-network → L-LUT conversion, RTL generation,
//! synthesis estimation, cycle-accurate fabric simulation, and serving.
//! Python never runs at request time.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — from-scratch substrates: JSON, RNG, stats, thread pool,
//!   property-test + bench harnesses (offline build: no external crates
//!   beyond `xla`/`anyhow`).
//! * [`data`] — dataset blobs produced by the build path.
//! * [`manifest`] — the flat parameter ABI shared with `python/compile`.
//! * [`runtime`] — PJRT client wrapper: load HLO text, compile, execute.
//! * [`nn`] — parameter store, Table-I formulas, metrics.
//! * [`config`] — TOML-subset experiment-suite files (`neuralut suite`).
//! * [`coordinator`] — training driver (SGDR schedule), conversion
//!   manager, end-to-end codesign pipeline.
//! * [`luts`] — truth tables and the converted L-LUT network model.
//! * [`netlist`] — cycle-accurate LUT-network simulator (the FPGA fabric
//!   substitute).
//! * [`engine`] — compiled fabric engine: bit-level lowering pass +
//!   bitsliced (64-samples-per-word) evaluator behind the
//!   `InferenceBackend` trait.
//! * [`rtl`] — Verilog + testbench generation.
//! * [`synth`] — Vivado-substitute synthesis/P&R cost model (support
//!   reduction, ROBDD, 6-LUT covering, timing).
//! * [`server`] — multi-worker sharded inference serving runtime: bounded
//!   request queue, N batcher threads over one shared compiled fabric,
//!   explicit backpressure (`try_infer` → `Overloaded`), graceful
//!   drain-on-shutdown, atomic serving stats.
//!
//! ## Compiled fabric engine
//!
//! `engine::lower` compiles a converted network once: every L-LUT truth
//! table is expanded into per-output-bit Boolean functions over the
//! previous layer's wires, support-reduced and ROBDD-factored
//! (`synth::boolfn` / `synth::robdd`), and emitted as a levelized netlist
//! of fused word-wide mux ops. `engine::BitslicedEngine` then evaluates
//! 64 samples per `u64` word — batch inference as pure AND/OR/XOR
//! streaming, bit-exact against `netlist::Simulator`. Pick the `scalar`
//! backend for tiny batches or one-off runs (zero compile cost); pick
//! `bitsliced` for batch/serving workloads, where word-level parallelism
//! and logic sharing amortize the one-time lowering. The server
//! (`ServerConfig::backend`), the CLI (`--engine`) and the examples
//! (`NEURALUT_ENGINE`) all select backends through `engine::BackendKind`.
//!
//! Backends constructed through `engine::backend` / `engine::SharedFabric`
//! are `'static`: they hold the network (and compiled program) behind
//! `Arc`s, so the serving runtime's worker threads own cheap executors of
//! one shared compile — N workers, one lowering pass per server start.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod luts;
pub mod manifest;
pub mod netlist;
pub mod nn;
pub mod rtl;
pub mod runtime;
pub mod server;
pub mod synth;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifact tree produced by `make artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("NEURALUT_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
