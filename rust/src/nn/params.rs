//! Named trained-parameter store: the flat parameter list of one model,
//! with binary persistence so trained models can be converted / re-served
//! without retraining. Format "NPRM" v1.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::manifest::Manifest;
use crate::runtime::{HostTensor, TensorData};

/// The trained parameters of one model, in manifest (flat ABI) order.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub names: Vec<String>,
    pub tensors: Vec<HostTensor>,
}

impl ParamStore {
    pub fn new(manifest: &Manifest, tensors: Vec<HostTensor>) -> Result<Self> {
        if tensors.len() != manifest.params.len() {
            bail!(
                "expected {} tensors, got {}",
                manifest.params.len(),
                tensors.len()
            );
        }
        for (spec, t) in manifest.params.iter().zip(&tensors) {
            if spec.shape != t.shape {
                bail!(
                    "{}: shape {:?} != manifest {:?}",
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
        }
        Ok(ParamStore {
            names: manifest.params.iter().map(|p| p.name.clone()).collect(),
            tensors,
        })
    }

    /// Name -> flat index.
    pub fn index(&self) -> HashMap<&str, usize> {
        self.names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.as_str(), i))
            .collect()
    }

    /// Fetch a tensor by name.
    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        let i = self
            .names
            .iter()
            .position(|n| n == name)
            .with_context(|| format!("no parameter named {name}"))?;
        Ok(&self.tensors[i])
    }

    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.elem_count()).sum()
    }

    const MAGIC: u32 = 0x4E50524D; // "NPRM"

    /// Persist to a binary file.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(&Self::MAGIC.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in self.names.iter().zip(&self.tensors) {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            match &t.data {
                TensorData::F32(v) => {
                    f.write_all(&0u32.to_le_bytes())?;
                    for x in v {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
                TensorData::I32(v) => {
                    f.write_all(&1u32.to_le_bytes())?;
                    for x in v {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Load from a binary file (validated against the manifest).
    pub fn load(path: &Path, manifest: &Manifest) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening {}", path.display()))?,
        );
        let r32 = |f: &mut dyn Read| -> Result<u32> {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            Ok(u32::from_le_bytes(b))
        };
        if r32(&mut f)? != Self::MAGIC {
            bail!("bad magic");
        }
        let n = r32(&mut f)? as usize;
        let mut names = Vec::with_capacity(n);
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = r32(&mut f)? as usize;
            let mut nb = vec![0u8; name_len];
            f.read_exact(&mut nb)?;
            names.push(String::from_utf8(nb)?);
            let rank = r32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r32(&mut f)? as usize);
            }
            let count = shape.iter().product::<usize>().max(1);
            let dtype = r32(&mut f)?;
            let mut buf = vec![0u8; count * 4];
            f.read_exact(&mut buf)?;
            let t = match dtype {
                0 => HostTensor::f32(
                    shape,
                    buf.chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                1 => HostTensor::i32(
                    shape,
                    buf.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                d => bail!("unknown dtype tag {d}"),
            };
            tensors.push(t);
        }
        let store = ParamStore { names, tensors };
        // Validate against manifest order.
        for (spec, (name, t)) in manifest
            .params
            .iter()
            .zip(store.names.iter().zip(&store.tensors))
        {
            if &spec.name != name || spec.shape != t.shape {
                bail!("param file does not match manifest ({name})");
            }
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> Manifest {
        // Build via JSON to reuse the validated constructor.
        let dir = std::env::temp_dir().join("neuralut_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        let json = r#"{
          "name":"t","mode":"logicnets","dataset":"moons","input_size":2,
          "n_class":2,"layers":[2],"beta":2,"beta_in":2,"beta_out":4,
          "fan_in":2,"sub_depth":1,"sub_width":1,"sub_skip":0,"degree":2,
          "batch":4,"epochs":1,"lr_max":0.01,"lr_min":0.001,
          "weight_decay":0.0,"sgdr_t0":1,"sgdr_mult":2,
          "params":[{"name":"l0.w1","shape":[2,2,1]},{"name":"l0.scale","shape":[]}],
          "scale_param_idx":[1],
          "layer_param_slices":[[0,2]],
          "indices":[[[0,1],[1,0]]],
          "layer_in_bits":[2],"layer_fan_in":[2],
          "tt":[{"layer":0,"path":"tt_layer0.hlo.txt","args":["l0.w1","l0.scale"],
                 "num_luts":2,"entries":16,"fan_in":2,"in_bits":2,"out_bits":4,"signed_out":true}]
        }"#;
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn save_load_roundtrip() {
        let m = tiny_manifest();
        let store = ParamStore::new(
            &m,
            vec![
                HostTensor::f32(vec![2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]),
                HostTensor::scalar_f32(0.5),
            ],
        )
        .unwrap();
        let path = std::env::temp_dir().join("neuralut_params_test/p.nprm");
        store.save(&path).unwrap();
        let back = ParamStore::load(&path, &m).unwrap();
        assert_eq!(back.names, store.names);
        assert_eq!(back.get("l0.w1").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rejects_wrong_shapes() {
        let m = tiny_manifest();
        assert!(ParamStore::new(
            &m,
            vec![
                HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
                HostTensor::scalar_f32(0.5),
            ],
        )
        .is_err());
    }
}
