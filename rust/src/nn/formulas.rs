//! Parameter-count formulas of paper Table I (and eqs. (5)-(7)).
//!
//! These are cross-checked two ways: against the Python topology code
//! (pytest `test_topo.py`) and against the actual manifest parameter shapes
//! (proptest-style test below + `examples/repro_table1.rs`).

/// Parameters of one affine map R^d1 -> R^d2 (weights + bias), eq. T(X).
pub fn t_affine(d1: usize, d2: usize) -> usize {
    d1 * d2 + d2
}

/// T_A: parameters of the affine chain A_1..A_L (paper eq. (5)).
pub fn t_a(f: usize, l: usize, n: usize) -> usize {
    match l {
        0 => 0,
        1 => f + 1,
        2 => (f + 2) * n + 1,
        _ => (l - 2) * n * n + (f + l) * n + 1,
    }
}

/// T_R: parameters of the residual maps R_1..R_{L/S} (paper eq. (6));
/// 0 when S = 0 (no skip connections).
pub fn t_r(f: usize, l: usize, n: usize, s: usize) -> usize {
    if s == 0 {
        return 0;
    }
    assert_eq!(l % s, 0, "L must be a multiple of S");
    let c = l / s;
    match c {
        1 => f + 1,
        2 => (f + 2) * n + 1,
        _ => (c - 2) * n * n + (f + c) * n + 1,
    }
}

/// T_N = T_A + T_R: trainable parameters of one NeuraLUT L-LUT (eq. (7)).
pub fn t_neuralut(f: usize, l: usize, n: usize, s: usize) -> usize {
    t_a(f, l, n) + t_r(f, l, n, s)
}

/// LogicNets: linear + activation, O(F) (Table I row 1).
pub fn t_logicnets(f: usize) -> usize {
    f + 1
}

/// Binomial coefficient (exact in u128 for our ranges).
pub fn binomial(n: usize, k: usize) -> usize {
    let k = k.min(n - k.min(n));
    let mut num: u128 = 1;
    let mut den: u128 = 1;
    for i in 0..k {
        num *= (n - i) as u128;
        den *= (i + 1) as u128;
    }
    (num / den) as usize
}

/// PolyLUT: all monomials of F inputs up to degree D, O(C(F+D, D))
/// (Table I row 2); the constant monomial folds into the bias, so the
/// trainable count is C(F+D, D) - 1 weights + 1 bias = C(F+D, D).
pub fn t_polylut(f: usize, d: usize) -> usize {
    binomial(f + d, d)
}

/// Structural parameter count of the hidden sub-network, enumerating the
/// affine/residual dims directly — must equal [`t_neuralut`] (the closed
/// form). Mirrors `SubnetTopo.param_count()` in Python.
pub fn t_neuralut_structural(f: usize, l: usize, n: usize, s: usize) -> usize {
    let widths: Vec<usize> = std::iter::once(f)
        .chain(std::iter::repeat(n).take(l.saturating_sub(1)))
        .chain(std::iter::once(1))
        .collect();
    let mut total = 0;
    for w in widths.windows(2) {
        total += t_affine(w[0], w[1]);
    }
    if s > 0 {
        let c = l / s;
        for i in 1..=c {
            total += t_affine(widths[s * (i - 1)], widths[s * i]);
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    #[test]
    fn closed_form_matches_structural_enumeration() {
        // Property: paper eqs. (5)+(6) == direct shape enumeration, over a
        // random sweep of (F, L, N, S).
        forall(
            0xA11CE,
            500,
            |r: &mut Rng| {
                let l = 1 + r.below(6);
                let divisors: Vec<usize> =
                    (1..=l).filter(|d| l % d == 0).collect();
                let s = if r.below(3) == 0 {
                    0
                } else {
                    divisors[r.below(divisors.len())]
                };
                (1 + r.below(16), l, 1 + r.below(32), s)
            },
            |&(f, l, n, s)| {
                t_neuralut(f, l, n, s) == t_neuralut_structural(f, l, n, s)
            },
        );
    }

    #[test]
    fn table1_reference_points() {
        // LogicNets == NeuraLUT with N = L = 1, S = 0 (paper §III-C).
        for f in 1..10 {
            assert_eq!(t_logicnets(f), t_neuralut(f, 1, 1, 0));
        }
        // Paper's HDR-5L sub-network: F=6, L=4, N=16, S=2.
        assert_eq!(t_neuralut(6, 4, 16, 2), 802);
        // PolyLUT: F=6, D=2 -> C(8,2) = 28.
        assert_eq!(t_polylut(6, 2), 28);
    }

    #[test]
    fn scaling_is_linear_in_f_for_fixed_n_l() {
        // Table I: NeuraLUT scales linearly in F (fixed N, L).
        let (l, n, s) = (4, 16, 2);
        let d1 = t_neuralut(8, l, n, s) - t_neuralut(7, l, n, s);
        let d2 = t_neuralut(20, l, n, s) - t_neuralut(19, l, n, s);
        assert_eq!(d1, d2, "increments must be constant in F");
        // while PolyLUT grows polynomially: increments increase.
        assert!(t_polylut(8, 3) - t_polylut(7, 3) > t_polylut(5, 3) - t_polylut(4, 3));
    }

    #[test]
    fn binomial_basics() {
        assert_eq!(binomial(8, 2), 28);
        assert_eq!(binomial(5, 0), 1);
        assert_eq!(binomial(5, 5), 1);
        assert_eq!(binomial(10, 3), 120);
    }
}
