//! Classification metrics used across training, simulation and serving.

/// Accuracy from predictions vs labels.
pub fn accuracy(pred: &[u32], labels: &[i32]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hit = pred
        .iter()
        .zip(labels)
        .filter(|(&p, &y)| p as i32 == y)
        .count();
    hit as f64 / pred.len() as f64
}

/// Argmax over rows of a flat `[n, c]` logits matrix; ties break low
/// (matching `jnp.argmax` and the netlist simulator).
pub fn argmax_rows(logits: &[f32], c: usize) -> Vec<u32> {
    logits
        .chunks_exact(c)
        .map(|row| {
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            best as u32
        })
        .collect()
}

/// Confusion matrix `[true][pred]` as flat `n_class * n_class` counts.
pub fn confusion(pred: &[u32], labels: &[i32], n_class: usize) -> Vec<usize> {
    let mut m = vec![0usize; n_class * n_class];
    for (&p, &y) in pred.iter().zip(labels) {
        m[y as usize * n_class + p as usize] += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_hits() {
        assert_eq!(accuracy(&[0, 1, 2, 1], &[0, 1, 1, 1]), 0.75);
    }

    #[test]
    fn argmax_breaks_ties_low() {
        let logits = [1.0f32, 1.0, 0.5, 0.2, 0.9, 0.9];
        assert_eq!(argmax_rows(&logits, 3), vec![0, 1]);
    }

    #[test]
    fn confusion_diagonal_when_perfect() {
        let c = confusion(&[0, 1, 2], &[0, 1, 2], 3);
        assert_eq!(c, vec![1, 0, 0, 0, 1, 0, 0, 0, 1]);
    }
}
