//! Neural-network-side helpers: Table-I parameter-count formulas, the
//! trained-parameter store, and classification metrics.

pub mod formulas;
pub mod metrics;
pub mod params;
