//! Descriptive statistics for experiment reporting and benches.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Compute a [`Summary`]; empty input yields NaN fields with n = 0.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary {
            n: 0,
            mean: f64::NAN,
            std: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
            p50: f64::NAN,
            p95: f64::NAN,
            p99: f64::NAN,
        };
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
        / (n.max(2) - 1) as f64;
    let mut sorted = xs.to_vec();
    // total_cmp: a stray NaN sample (e.g. a poisoned latency) sorts last
    // instead of panicking the whole report.
    sorted.sort_by(f64::total_cmp);
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p95: percentile_sorted(&sorted, 95.0),
        p99: percentile_sorted(&sorted, 99.0),
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice. `pct` is
/// clamped to `[0, 100]` (`pct` outside that range used to index out of
/// bounds); a NaN `pct` yields NaN.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if pct.is_nan() {
        return f64::NAN;
    }
    let rank = pct.clamp(0.0, 100.0) / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

/// Format a count of bytes / items with SI-ish suffixes for reports.
pub fn human(x: f64) -> String {
    let (v, suffix) = if x >= 1e9 {
        (x / 1e9, "G")
    } else if x >= 1e6 {
        (x / 1e6, "M")
    } else if x >= 1e3 {
        (x / 1e3, "k")
    } else {
        (x, "")
    };
    format!("{v:.2}{suffix}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - 1.5811388).abs() < 1e-5);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
        assert!(s.std.is_nan());
        assert!(s.min.is_nan());
        assert!(s.p50.is_nan() && s.p95.is_nan() && s.p99.is_nan());
    }

    #[test]
    fn single_sample_is_degenerate_but_finite() {
        let s = summarize(&[7.5]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.std, 0.0, "one sample has no spread, not NaN");
        assert_eq!((s.min, s.max), (7.5, 7.5));
        assert_eq!((s.p50, s.p95, s.p99), (7.5, 7.5, 7.5));
    }

    #[test]
    fn percentile_extremes_and_out_of_range_are_clamped() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
        // Out-of-range percentiles clamp instead of indexing out of
        // bounds (pct > 100 used to panic).
        assert_eq!(percentile_sorted(&v, 150.0), 4.0);
        assert_eq!(percentile_sorted(&v, -5.0), 1.0);
        assert!(percentile_sorted(&v, f64::NAN).is_nan());
        assert!(percentile_sorted(&[], 50.0).is_nan());
        // Single element: every percentile is that element.
        assert_eq!(percentile_sorted(&[9.0], 99.0), 9.0);
    }

    #[test]
    fn nan_samples_do_not_panic_the_sort() {
        let s = summarize(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0, "NaN sorts last under total_cmp");
    }
}
