//! Miniature property-testing harness (proptest is not vendored offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` inputs from `gen` and
//! asserts `prop` on each; on failure it panics with the failing case's
//! debug representation and the sub-seed that regenerates it, so failures
//! are reproducible (`Rng::new(sub_seed)` + the same generator).

use super::rng::Rng;

/// Run a property over `cases` generated inputs. Panics on the first
/// counterexample with enough information to replay it.
pub fn forall<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    for case in 0..cases {
        let sub_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(sub_seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property falsified on case {case} (sub_seed {sub_seed:#x}):\n{input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result`, so failures can carry
/// a message.
pub fn forall_res<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let sub_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(sub_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property falsified on case {case} (sub_seed {sub_seed:#x}): {msg}\n{input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 100, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn failing_property_reports() {
        forall(1, 100, |r| r.below(100), |&x| x < 50);
    }
}
