//! Tiny bench harness for `cargo bench` targets (criterion is not vendored
//! offline). Measures wall time with warmup, reports mean ± std and
//! throughput, and prints rows a human (or EXPERIMENTS.md) can diff.

use std::time::Instant;

use super::stats;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl Measurement {
    pub fn report(&self) {
        let per = fmt_ns(self.mean_ns);
        let sd = fmt_ns(self.std_ns);
        match self.throughput {
            Some((tp, unit)) => println!(
                "bench {:<44} {:>12}/iter ± {:>10}  ({} {}/s, {} iters)",
                self.name,
                per,
                sd,
                stats::human(tp),
                unit,
                self.iters
            ),
            None => println!(
                "bench {:<44} {:>12}/iter ± {:>10}  ({} iters)",
                self.name, per, sd, self.iters
            ),
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Run `f` repeatedly: `warmup` throwaway iterations, then timed iterations
/// until `min_time_s` of measurement or `max_iters`, whichever first.
/// `items_per_iter` (with a unit) turns the result into throughput.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    min_time_s: f64,
    max_iters: usize,
    items_per_iter: Option<(f64, &'static str)>,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < min_time_s && samples.len() < max_iters
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    if samples.is_empty() {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let s = stats::summarize(&samples);
    let m = Measurement {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: s.mean,
        std_ns: if s.std.is_nan() { 0.0 } else { s.std },
        throughput: items_per_iter.map(|(n, u)| (n / (s.mean / 1e9), u)),
    };
    m.report();
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("noop", 1, 0.01, 1000, Some((1.0, "ops")), || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.mean_ns >= 0.0);
        assert!(m.iters >= 1);
    }
}
