//! Minimal, complete JSON parser + writer (RFC 8259 subset we emit/consume).
//!
//! Used to read `manifest.json` from the AOT bundle and to persist
//! experiment results / converted models' metadata. Numbers are kept as
//! `f64` (the manifests only carry integers small enough to be exact).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// Largest integer an `f64` represents exactly (2^53 − 1). Integer reads
/// beyond this would be lossy, so the strict accessors reject them.
const MAX_SAFE_INT: f64 = 9_007_199_254_740_991.0;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    /// Strict unsigned-integer read: the number must be integral (no
    /// `3.7`, `NaN`, or infinities), non-negative, and small enough that
    /// the `f64` carrying it is exact (≤ 2^53 − 1). A raw `as` cast here
    /// would silently map `-1.0` to 0 and truncate fractions.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got non-integral number {n}");
        }
        if !(0.0..=MAX_SAFE_INT).contains(&n) {
            bail!("integer out of range for usize: {n}");
        }
        Ok(n as usize)
    }

    /// Strict signed-integer read; same integrality and exact-`f64`
    /// range rules as [`as_usize`](Self::as_usize).
    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got non-integral number {n}");
        }
        if !(-MAX_SAFE_INT..=MAX_SAFE_INT).contains(&n) {
            bail!("integer out of range for i64: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array, got a different type")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(anyhow!("expected object")),
        }
    }

    /// Field access on an object (error mentions the key).
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// Strict integer field access: [`get`](Self::get) followed by
    /// [`as_usize`](Self::as_usize), with the key carried in the error.
    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?
            .as_usize()
            .with_context(|| format!("key '{key}'"))
    }

    /// Optional field access.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Convenience: array of usize.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Helper for building objects in code.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Load and parse a JSON file.
pub fn from_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| {
            format!("bad number '{text}' at offset {start}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": true, "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("b").unwrap().as_str().unwrap(), "x\ny");
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn strict_integer_casts_reject_lossy_values() {
        // The old raw-`as` casts mapped -1.0 to 0 and truncated 3.7 — the
        // strict reads must refuse every lossy shape instead.
        assert_eq!(Json::Num(7.0).as_usize().unwrap(), 7);
        assert_eq!(Json::Num(0.0).as_usize().unwrap(), 0);
        assert_eq!(Json::Num(-42.0).as_i64().unwrap(), -42);
        assert!(Json::Num(-1.0).as_usize().is_err());
        assert!(Json::Num(3.7).as_usize().is_err());
        assert!(Json::Num(3.7).as_i64().is_err());
        assert!(Json::Num(f64::NAN).as_usize().is_err());
        assert!(Json::Num(f64::NAN).as_i64().is_err());
        assert!(Json::Num(f64::INFINITY).as_usize().is_err());
        assert!(Json::Num(9.1e15).as_usize().is_err());
        assert!(Json::Num(-9.1e15).as_i64().is_err());
        assert!(Json::Str("3".into()).as_usize().is_err());
        // usize_vec inherits the strictness.
        assert!(Json::parse("[1, -2, 3]").unwrap().usize_vec().is_err());
        assert_eq!(Json::parse("[1, 2]").unwrap().usize_vec().unwrap(), vec![1, 2]);
    }

    #[test]
    fn get_usize_names_the_key() {
        let j = Json::parse(r#"{"beta": -1, "ok": 4}"#).unwrap();
        assert_eq!(j.get_usize("ok").unwrap(), 4);
        let err = format!("{:#}", j.get_usize("beta").unwrap_err());
        assert!(err.contains("beta"), "{err}");
        assert!(j.get_usize("missing").is_err());
    }
}
