//! Deterministic PRNGs (SplitMix64 seeding + xoshiro256**) and sampling
//! helpers. Every stochastic choice in the coordinator (batch shuffling,
//! workload generation, property-test case generation) flows through this
//! module so runs are reproducible from a single `u64` seed.

/// xoshiro256** generator, seeded via SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times for workloads).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample `k` distinct values from 0..n (k <= n), in random order.
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        // Partial Fisher–Yates over an index map (sparse for small k).
        let mut map = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vj = *map.get(&j).unwrap_or(&j);
            let vi = *map.get(&i).unwrap_or(&i);
            map.insert(j, vi);
            out.push(vj);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn choose_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..50 {
            let v = r.choose_distinct(20, 8);
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
            assert!(v.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn normal_moments_plausible() {
        let mut r = Rng::new(3);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(100);
        let mut s = p.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
