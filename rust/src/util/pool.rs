//! Scoped data-parallel helpers over std threads (tokio is not vendored in
//! this offline image; the netlist simulator and workload sweeps only need
//! fork-join parallelism, which `std::thread::scope` provides cleanly).

/// Number of worker threads to use (`NEURALUT_THREADS` overrides).
pub fn num_threads() -> usize {
    if let Some(v) = std::env::var_os("NEURALUT_THREADS") {
        if let Ok(n) = v.to_string_lossy().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(chunk_index, item_range)` across `n_items` split into roughly
/// equal contiguous ranges, one per worker, and collect the results in
/// chunk order. `f` must be `Send`; results are gathered after the join.
pub fn parallel_ranges<T, F>(n_items: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
{
    let workers = workers.clamp(1, n_items.max(1));
    let chunk = n_items.div_ceil(workers);
    let ranges: Vec<std::ops::Range<usize>> = (0..workers)
        .map(|w| (w * chunk).min(n_items)..((w + 1) * chunk).min(n_items))
        .collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                let fref = &f;
                scope.spawn(move || fref(i, r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Map `f` over mutable equal-size row chunks of `data` in parallel —
/// the netlist simulator's batch-sharding primitive.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if rows == 0 || data.is_empty() {
        return;
    }
    let row_len = data.len() / rows;
    assert_eq!(data.len(), rows * row_len, "data not divisible into rows");
    let workers = num_threads().min(rows);
    let rows_per = rows.div_ceil(workers);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut row0 = 0;
        for _ in 0..workers {
            let take = (rows_per.min(rows - row0)) * row_len;
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take);
            let start_row = row0;
            let fref = &f;
            scope.spawn(move || fref(start_row, head));
            rest = tail;
            row0 += rows_per.min(rows - row0);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_ranges_covers_everything() {
        let sums = parallel_ranges(1000, 7, |_, r| r.sum::<usize>());
        assert_eq!(sums.iter().sum::<usize>(), (0..1000).sum::<usize>());
    }

    #[test]
    fn parallel_chunks_mut_touches_all_rows() {
        let rows = 13;
        let cols = 5;
        let mut data = vec![0u32; rows * cols];
        parallel_chunks_mut(&mut data, rows, |start_row, chunk| {
            for (i, row) in chunk.chunks_mut(cols).enumerate() {
                for v in row.iter_mut() {
                    *v = (start_row + i) as u32;
                }
            }
        });
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(data[r * cols + c], r as u32);
            }
        }
    }

    #[test]
    fn handles_empty() {
        parallel_chunks_mut::<u32, _>(&mut [], 0, |_, _| {});
        let v: Vec<usize> = parallel_ranges(0, 4, |_, r| r.len());
        assert!(v.iter().sum::<usize>() == 0);
    }
}
