//! Scoped data-parallel helpers over std threads (tokio is not vendored in
//! this offline image; the netlist simulator and workload sweeps only need
//! fork-join parallelism, which `std::thread::scope` provides cleanly),
//! plus the bounded MPMC queue the serving runtime shards work over.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::util::faults;

/// Poison-recovering lock: a consumer that panicked mid-pop (e.g. a
/// backend bug, or an armed [`faults`] point) must not cascade into every
/// other producer/consumer seeing `PoisonError`. `QueueState` is a
/// `VecDeque` + flag whose invariants hold between any two statements, so
/// recovering the guard is always safe.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Number of worker threads to use (`NEURALUT_THREADS` overrides).
pub fn num_threads() -> usize {
    if let Some(v) = std::env::var_os("NEURALUT_THREADS") {
        if let Ok(n) = v.to_string_lossy().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(chunk_index, item_range)` across `n_items` split into roughly
/// equal contiguous ranges, one per worker, and collect the results in
/// chunk order. `f` must be `Send`; results are gathered after the join.
pub fn parallel_ranges<T, F>(n_items: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
{
    let workers = workers.clamp(1, n_items.max(1));
    let chunk = n_items.div_ceil(workers);
    let ranges: Vec<std::ops::Range<usize>> = (0..workers)
        .map(|w| (w * chunk).min(n_items)..((w + 1) * chunk).min(n_items))
        .collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                let fref = &f;
                scope.spawn(move || fref(i, r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Map `f` over mutable equal-size row chunks of `data` in parallel —
/// the netlist simulator's batch-sharding primitive.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if rows == 0 || data.is_empty() {
        return;
    }
    let row_len = data.len() / rows;
    assert_eq!(data.len(), rows * row_len, "data not divisible into rows");
    let workers = num_threads().min(rows);
    let rows_per = rows.div_ceil(workers);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut row0 = 0;
        for _ in 0..workers {
            let take = (rows_per.min(rows - row0)) * row_len;
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take);
            let start_row = row0;
            let fref = &f;
            scope.spawn(move || fref(start_row, head));
            rest = tail;
            row0 += rows_per.min(rows - row0);
        }
    });
}

/// Why a push into a [`BoundedQueue`] was not accepted. The item is handed
/// back so the caller can reply to it or retry.
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue at capacity — shed load or wait for a consumer.
    Full(T),
    /// Queue closed — no new work is accepted.
    Closed(T),
}

/// Outcome of a timed pop.
#[derive(Debug)]
pub enum Pop<T> {
    Item(T),
    /// Nothing arrived before the deadline (the queue may still get items).
    TimedOut,
    /// Closed *and* drained: no item will ever arrive again.
    Closed,
}

/// Bounded multi-producer multi-consumer queue over `Mutex` + `Condvar`
/// (std `mpsc` is single-consumer, and crossbeam is not vendored offline).
///
/// Semantics chosen for serving: [`try_push`](Self::try_push) is the
/// backpressure primitive (never blocks, reports `Full` explicitly);
/// [`push`](Self::push) blocks producers while full; closing wakes every
/// waiter — producers fail fast, consumers drain the backlog and only then
/// observe closure. That drain-then-closed order is what lets a server
/// shut down gracefully: every accepted request is still answered.
pub struct BoundedQueue<T> {
    capacity: usize,
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        BoundedQueue {
            capacity: capacity.max(1),
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.state).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once [`close`](Self::close) has been called. Used by the
    /// server's supervisor to abandon a respawn backoff the moment
    /// shutdown starts.
    pub fn is_closed(&self) -> bool {
        lock_recover(&self.state).closed
    }

    /// Non-blocking push.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = lock_recover(&self.state);
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        st.items.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push: waits for space; `Err(item)` once closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = lock_recover(&self.state);
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.capacity {
                break;
            }
            st = self.not_full.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        st.items.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop: `None` only once closed *and* fully drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = lock_recover(&self.state);
        // Fault point fires *while the lock is held*, so a `panic` mode
        // here poisons the mutex — exactly the cascade `lock_recover`
        // exists to absorb. The item is still queued when it fires, so a
        // respawned consumer pops it later; nothing is lost.
        faults::panic_point(faults::point::QUEUE_POP);
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Pop with a deadline; distinguishes "nothing yet" from "never again".
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let deadline = Instant::now() + timeout;
        let mut st = lock_recover(&self.state);
        faults::panic_point(faults::point::QUEUE_POP);
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Pop::Item(item);
            }
            if st.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            st = self
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(PoisonError::into_inner)
                .0;
        }
    }

    /// Reject future pushes and wake every waiter. Items already queued
    /// remain poppable.
    pub fn close(&self) {
        let mut st = lock_recover(&self.state);
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Close the queue and take everything still queued in one step — no
    /// fault points on this path, so the last supervisor out (or `Drop`)
    /// can always answer the backlog even mid-crash-storm.
    pub fn close_and_drain(&self) -> Vec<T> {
        let mut st = lock_recover(&self.state);
        st.closed = true;
        let items: Vec<T> = st.items.drain(..).collect();
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
        items
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_ranges_covers_everything() {
        let sums = parallel_ranges(1000, 7, |_, r| r.sum::<usize>());
        assert_eq!(sums.iter().sum::<usize>(), (0..1000).sum::<usize>());
    }

    #[test]
    fn parallel_chunks_mut_touches_all_rows() {
        let rows = 13;
        let cols = 5;
        let mut data = vec![0u32; rows * cols];
        parallel_chunks_mut(&mut data, rows, |start_row, chunk| {
            for (i, row) in chunk.chunks_mut(cols).enumerate() {
                for v in row.iter_mut() {
                    *v = (start_row + i) as u32;
                }
            }
        });
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(data[r * cols + c], r as u32);
            }
        }
    }

    #[test]
    fn handles_empty() {
        parallel_chunks_mut::<u32, _>(&mut [], 0, |_, _| {});
        let v: Vec<usize> = parallel_ranges(0, 4, |_, r| r.len());
        assert!(v.iter().sum::<usize>() == 0);
    }

    #[test]
    fn queue_is_fifo_and_bounded() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert!(matches!(
            q.pop_timeout(Duration::from_millis(1)),
            Pop::TimedOut
        ));
    }

    #[test]
    fn closed_queue_drains_then_reports_closed() {
        let q = BoundedQueue::new(4);
        q.try_push("a").unwrap();
        q.close();
        match q.try_push("b") {
            Err(PushError::Closed("b")) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        // backlog still drains before closure is observed
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), None);
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed));
    }

    #[test]
    fn queue_survives_a_deliberately_poisoned_lock() {
        // Arm a certain panic inside `pop` — it fires while the state
        // mutex is held, poisoning it the old-fashioned way.
        let q = BoundedQueue::new(4);
        q.try_push(1u32).unwrap();
        {
            let _guard = faults::arm_scoped("queue.pop:1:panic:0", 11).unwrap();
            let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| q.pop()));
            assert!(poisoned.is_err(), "armed pop must panic under the lock");
        }
        // Disarmed again: every operation must push straight through the
        // poisoned mutex — the panicked consumer took nothing with it.
        assert_eq!(q.len(), 1, "the item the panicked pop left behind");
        q.try_push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.push(3).unwrap();
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Pop::Item(3)));
        assert!(!q.is_closed());
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_and_drain_returns_the_backlog_and_closes() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        let drained = q.close_and_drain();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
        assert!(q.is_closed());
        assert!(matches!(q.try_push(9), Err(PushError::Closed(9))));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_producers_and_consumers() {
        use std::sync::Arc;
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(0u32).unwrap();
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || q.push(7)) // blocks: queue full
        };
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                // drains the backlog (0, and 7 if the producer won the
                // race before close), then sees None
                while q.pop().is_some() {}
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        // producer either got its item in before close or had it returned
        let _ = producer.join().unwrap();
        consumer.join().unwrap();
    }
}
