//! From-scratch utility substrates.
//!
//! The build environment is offline with only `xla` + `anyhow` vendored, so
//! everything a framework normally pulls from crates.io lives here: a JSON
//! parser/writer ([`json`]), deterministic PRNGs ([`rng`]), descriptive
//! statistics ([`stats`]), a scoped thread pool ([`pool`]), a miniature
//! property-testing harness ([`check`]), a bench harness ([`bench`]) and a
//! fault-injection harness for chaos testing ([`faults`]).

pub mod bench;
pub mod check;
pub mod faults;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
