//! Named fault-injection points for chaos-testing the serving runtime.
//!
//! A fault point is a named hook compiled into a risky code path — worker
//! batch execution, queue pop, artifact I/O, backend construction. Unarmed
//! (the default), every hook is two relaxed atomic loads and returns
//! immediately, so production binaries pay nothing. Armed, each hit rolls a
//! deterministic PRNG against the point's probability and either panics,
//! returns an error, or sleeps — letting tests prove that supervision,
//! poison recovery, and graceful degradation actually hold under fire.
//!
//! Arming surfaces:
//!
//! * **Environment** — `NEURALUT_FAULTS=point:prob:mode[:arg][,…]`, parsed
//!   once on first hit. `prob` is a probability in `[0, 1]`; `mode` is
//!   `panic`, `error`, or `delay`; the optional `arg` is milliseconds for
//!   `delay` and a skip count (ignore the first N would-be firings) for
//!   `panic`/`error`. Example: `NEURALUT_FAULTS=worker.execute:0.3:panic`.
//!   A malformed spec is ignored with a warning rather than taking the
//!   process down — fault injection must never be the fault.
//! * **Tests** — [`arm_scoped`] installs a plan for the lifetime of a
//!   guard and restores the previous plan (usually: unarmed) on drop.
//!   The guard also holds a global lock so concurrently running tests
//!   cannot fight over the process-wide plan.
//!
//! The planted points are named by the `point::*` constants; call sites
//! use [`inject`] where an `Err` can propagate and [`panic_point`] where
//! the only legal failure is an unwind (e.g. inside a worker thread whose
//! supervisor catches panics).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, Once, PoisonError};
use std::time::Duration;

use anyhow::{bail, Context};

use crate::util::rng::Rng;

/// Canonical names of the fault points planted in the codebase.
pub mod point {
    /// Worker batch execution (`server::worker_loop`), immediately before
    /// the backend runs a formed batch. `panic` here exercises the
    /// in-flight drop-guard and the supervisor respawn path.
    pub const WORKER_EXECUTE: &str = "worker.execute";
    /// Inside [`BoundedQueue`](crate::util::pool::BoundedQueue) pop, while
    /// the queue mutex is held — a `panic` here poisons the lock and
    /// exercises the poison-recovering lock discipline.
    pub const QUEUE_POP: &str = "queue.pop";
    /// `.nfab` artifact read (`fabric::artifact::load`), after the bytes
    /// are on hand — `error` simulates a corrupt/unreadable artifact.
    pub const ARTIFACT_READ: &str = "artifact.read";
    /// Atomic artifact/report write, between the tmp-file write and the
    /// rename — `panic` simulates a crash mid-write (the torn-write test).
    pub const ARTIFACT_WRITE: &str = "artifact.write";
    /// Backend factory invocation (`Model::compile`) — `error` simulates a
    /// backend that fails to construct and drives the scalar-degradation
    /// fallback.
    pub const BACKEND_COMPILE: &str = "backend.compile";
    /// Network frame read (`net::frame::read_frame`), after the length
    /// prefix is on hand but before the payload is parsed — `error`
    /// simulates a torn/poisoned connection read and exercises the
    /// per-connection teardown path (the connection must close, never
    /// hang).
    pub const NET_READ: &str = "net.read";
    /// AOT source emission (`engine::aot`), before any file is written —
    /// `error` simulates a codegen bug and drives the bitsliced-degradation
    /// fallback.
    pub const AOT_CODEGEN: &str = "aot.codegen";
    /// System-compiler invocation (`rustc` / `cc`) on the emitted AOT
    /// source — `error` simulates a missing or broken toolchain.
    pub const AOT_CC: &str = "aot.cc";
    /// `dlopen`/`dlsym` of the compiled AOT shared object — `error`
    /// simulates a corrupt or unloadable `.so`.
    pub const AOT_DLOPEN: &str = "aot.dlopen";
}

/// What an armed fault point does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// Unwind the current thread (`panic!`).
    Panic,
    /// Return an `Err` from [`inject`].
    Error,
    /// Sleep for the point's `arg` milliseconds, then succeed.
    Delay,
}

#[derive(Debug)]
struct FaultPoint {
    name: String,
    prob: f64,
    mode: FaultMode,
    /// Milliseconds for [`FaultMode::Delay`]; for `panic`/`error`, the
    /// number of initial would-be firings to let pass unharmed.
    arg: u64,
    skipped: u64,
    fired: u64,
}

#[derive(Debug)]
struct FaultPlan {
    points: Vec<FaultPoint>,
    rng: Rng,
}

impl FaultPlan {
    fn parse(spec: &str, seed: u64) -> crate::Result<FaultPlan> {
        let mut points = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 3 || fields.len() > 4 {
                bail!("fault spec '{part}' is not point:prob:mode[:arg]");
            }
            let prob: f64 = fields[1]
                .trim()
                .parse()
                .with_context(|| format!("fault probability '{}' in '{part}'", fields[1]))?;
            if !(0.0..=1.0).contains(&prob) {
                bail!("fault probability {prob} in '{part}' is outside [0, 1]");
            }
            let mode = match fields[2].trim() {
                "panic" => FaultMode::Panic,
                "error" => FaultMode::Error,
                "delay" => FaultMode::Delay,
                other => bail!("unknown fault mode '{other}' in '{part}' (panic|error|delay)"),
            };
            let arg = match fields.get(3) {
                Some(v) => v
                    .trim()
                    .parse::<u64>()
                    .with_context(|| format!("fault arg '{v}' in '{part}'"))?,
                None if mode == FaultMode::Delay => 1,
                None => 0,
            };
            points.push(FaultPoint {
                name: fields[0].trim().to_string(),
                prob,
                mode,
                arg,
                skipped: 0,
                fired: 0,
            });
        }
        if points.is_empty() {
            bail!("fault spec '{spec}' names no fault points");
        }
        Ok(FaultPlan { points, rng: Rng::new(seed) })
    }

    /// Roll a hit against `point`. Returns the action to take, if any.
    fn hit(&mut self, point: &str) -> Option<(FaultMode, u64)> {
        let FaultPlan { points, rng } = self;
        let p = points.iter_mut().find(|p| p.name == point)?;
        if p.prob < 1.0 && rng.f64() >= p.prob {
            return None;
        }
        if p.mode != FaultMode::Delay && p.skipped < p.arg {
            p.skipped += 1;
            return None;
        }
        p.fired += 1;
        Some((p.mode, p.arg))
    }
}

/// Fast-path flag: true iff a plan is installed. Checked before touching
/// the plan mutex so unarmed hooks cost two atomic loads.
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();
/// Serializes [`arm_scoped`] callers so parallel tests cannot fight over
/// the process-wide plan.
static SCOPE: Mutex<()> = Mutex::new(());

fn lock_plan() -> MutexGuard<'static, Option<FaultPlan>> {
    // Poison-recovering by design: a fault point that panicked while a
    // test thread held this lock must not wedge the harness itself.
    PLAN.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Install `plan` (or disarm with `None`), returning the previous plan.
fn install(plan: Option<FaultPlan>) -> Option<FaultPlan> {
    let mut slot = lock_plan();
    let prev = std::mem::replace(&mut *slot, plan);
    ARMED.store(slot.is_some(), Ordering::Release);
    prev
}

fn ensure_env_armed() {
    ENV_INIT.call_once(|| {
        let Ok(spec) = std::env::var("NEURALUT_FAULTS") else { return };
        if spec.trim().is_empty() {
            return;
        }
        match FaultPlan::parse(&spec, 0x5EED_FA17) {
            Ok(plan) => {
                install(Some(plan));
            }
            Err(e) => eprintln!("warning: ignoring NEURALUT_FAULTS = '{spec}': {e:#}"),
        }
    });
}

/// True iff any fault plan is currently armed (environment or scoped).
/// Benches use this to stamp rows produced under fault injection so perf
/// gates never compare them against clean baselines.
pub fn armed() -> bool {
    ensure_env_armed();
    ARMED.load(Ordering::Acquire)
}

/// Hit the named fault point. Unarmed: `Ok(())` at atomic-load cost.
/// Armed: may panic ([`FaultMode::Panic`]), return an error naming the
/// point ([`FaultMode::Error`]), or sleep ([`FaultMode::Delay`]).
pub fn inject(point: &str) -> crate::Result<()> {
    ensure_env_armed();
    if !ARMED.load(Ordering::Acquire) {
        return Ok(());
    }
    // Decide under the lock, act after releasing it: a panic or sleep
    // while holding the plan mutex would couple fault points together.
    let action = {
        let mut slot = lock_plan();
        slot.as_mut().and_then(|plan| plan.hit(point))
    };
    match action {
        None => Ok(()),
        Some((FaultMode::Delay, ms)) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some((FaultMode::Error, _)) => bail!("injected fault at '{point}'"),
        Some((FaultMode::Panic, _)) => panic!("injected fault at '{point}'"),
    }
}

/// [`inject`] for call sites with no error channel: both `panic` and
/// `error` modes unwind (the supervisor treats them identically).
pub fn panic_point(point: &str) {
    if let Err(e) = inject(point) {
        panic!("{e:#}");
    }
}

/// How many times the named point has fired under the current plan.
/// `0` when unarmed or the point is not in the plan.
pub fn fired_count(point: &str) -> u64 {
    lock_plan()
        .as_ref()
        .and_then(|plan| plan.points.iter().find(|p| p.name == point))
        .map(|p| p.fired)
        .unwrap_or(0)
}

/// Guard returned by [`arm_scoped`]: holds the plan installed (and the
/// cross-test serialization lock) until dropped, then restores whatever
/// was armed before — usually nothing.
#[derive(Debug)]
pub struct ScopedFaults {
    _serial: MutexGuard<'static, ()>,
    prev: Option<FaultPlan>,
}

impl ScopedFaults {
    /// [`fired_count`] scoped to this guard's plan, for asserting a chaos
    /// test actually exercised its fault point.
    pub fn fired(&self, point: &str) -> u64 {
        fired_count(point)
    }
}

impl Drop for ScopedFaults {
    fn drop(&mut self) {
        install(self.prev.take());
    }
}

/// Arm `spec` (same grammar as `NEURALUT_FAULTS`) with a deterministic
/// `seed` for the lifetime of the returned guard. Serializes against
/// other scoped armings, so parallel tests queue rather than interleave.
pub fn arm_scoped(spec: &str, seed: u64) -> crate::Result<ScopedFaults> {
    ensure_env_armed();
    let serial = SCOPE.lock().unwrap_or_else(PoisonError::into_inner);
    let plan = FaultPlan::parse(spec, seed)?;
    let prev = install(Some(plan));
    Ok(ScopedFaults { _serial: serial, prev })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_points_are_no_ops() {
        let _guard = arm_scoped("other.point:1:error", 1).unwrap();
        // Armed plan, but this point is not in it.
        assert!(inject("not.planted").is_ok());
        assert_eq!(fired_count("not.planted"), 0);
    }

    #[test]
    fn error_mode_fires_and_counts() {
        let guard = arm_scoped("demo.point:1:error", 42).unwrap();
        let err = inject("demo.point").unwrap_err().to_string();
        assert!(err.contains("demo.point"), "{err}");
        assert_eq!(guard.fired("demo.point"), 1);
        drop(guard);
        assert!(inject("demo.point").is_ok(), "disarmed after guard drop");
    }

    #[test]
    fn skip_count_delays_the_first_firings() {
        let _guard = arm_scoped("demo.skip:1:error:2", 7).unwrap();
        assert!(inject("demo.skip").is_ok());
        assert!(inject("demo.skip").is_ok());
        assert!(inject("demo.skip").is_err(), "third hit fires");
    }

    #[test]
    fn probability_is_deterministic_per_seed() {
        let fire = |seed: u64| {
            let guard = arm_scoped("demo.prob:0.5:error", seed).unwrap();
            let fired: Vec<bool> = (0..16).map(|_| inject("demo.prob").is_err()).collect();
            drop(guard);
            fired
        };
        assert_eq!(fire(3), fire(3), "same seed, same firing pattern");
        let pattern = fire(3);
        assert!(pattern.iter().any(|&f| f) && pattern.iter().any(|&f| !f));
    }

    #[test]
    fn panic_mode_unwinds() {
        let _guard = arm_scoped("demo.panic:1:panic", 9).unwrap();
        let caught = std::panic::catch_unwind(|| panic_point("demo.panic"));
        assert!(caught.is_err());
    }

    #[test]
    fn malformed_specs_are_errors() {
        for bad in ["p", "p:1", "p:2.0:error", "p:x:error", "p:1:nuke", "p:1:error:x", ""] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "{bad:?} should not parse");
        }
        assert!(FaultPlan::parse("a:1:panic, b:0.5:delay:10", 0).is_ok());
    }
}
