//! Bit-level lowering: `LutNetwork` → `BitNetlist`.
//!
//! Each L-LUT output bit is a Boolean function of the previous layer's
//! *wires* (individual activation bits). The pass expands every `i16`
//! truth table into those per-bit functions, support-reduces them
//! ([`synth::boolfn`]), builds their ROBDDs ([`synth::robdd::build`]) and
//! maps every decision node onto one fused word-wide mux op
//! (`dst = lo ^ (sel & (hi ^ lo))`). Structural hashing on
//! `(sel, hi, lo)` shares logic across output bits and across L-LUTs of
//! the same layer; literal nodes (`mux(x, 1, 0) = x`) lower to plain wire
//! aliases and cost nothing at run time.
//!
//! The result is a levelized program — one op list per circuit layer, in
//! bottom-up topological order — that the bitslice evaluator streams over
//! 64-sample `u64` words. This is the software analogue of the paper's
//! "each L-LUT layer is evaluated in one clock cycle": a layer is one
//! compiled block of pure word ops between two register planes.

use anyhow::{bail, Result};

use crate::luts::LutNetwork;
use crate::synth::{boolfn, robdd};

/// Wire id of the constant-0 plane.
pub const W_ZERO: u32 = 0;
/// Wire id of the constant-1 plane.
pub const W_ONE: u32 = 1;
/// First wire id of a level's input planes (previous activations).
pub const W_INPUTS: u32 = 2;

/// One fused word op: `dst = lo ^ (sel & (hi ^ lo))` — a 2:1 mux that
/// selects `hi` where the `sel` word has 1-bits and `lo` elsewhere.
/// AND/OR/XOR/NOT are special cases (`a & b = mux(a, b, 0)`,
/// `a | b = mux(a, 1, b)`, `!a = mux(a, 0, 1)`), so one branch-free
/// interpreter loop covers the whole repertoire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MuxOp {
    pub sel: u32,
    pub hi: u32,
    pub lo: u32,
    pub dst: u32,
}

/// One compiled circuit layer.
#[derive(Debug, Clone)]
pub struct Level {
    /// Word ops in topological order; `dst` ids are dense and sequential
    /// starting right after the input planes.
    pub ops: Vec<MuxOp>,
    /// Scratch wires needed to evaluate this level
    /// (`2 consts + n_in_planes + ops.len()`).
    pub n_wires: usize,
    /// Input planes consumed — always the previous level's
    /// `outputs.len()` (for level 0: `input_size * input_bits`).
    pub n_in_planes: usize,
    /// Wire id of every output bit-plane. As lowered this is
    /// `[num_luts * out_bits]` with bit-plane `b` of L-LUT `i` at index
    /// `i * out_bits + b`; after `engine::opt` plane compaction (`O2`) an
    /// *intermediate* level keeps only the distinct planes the next level
    /// reads. The final level's logit-plane layout is never compacted.
    pub outputs: Vec<u32>,
    /// L-LUTs of the original circuit layer (metadata; unchanged by
    /// optimization).
    pub num_luts: usize,
    /// Bits per L-LUT output in the original layer (metadata).
    pub out_bits: usize,
}

/// A whole network compiled to a levelized word-op netlist — the stable
/// representation the bitslice evaluator (and future device-specific
/// backends) consume.
#[derive(Debug, Clone)]
pub struct BitNetlist {
    pub levels: Vec<Level>,
    pub input_size: usize,
    pub input_bits: usize,
    pub n_class: usize,
    /// Bits per logit code (last layer's `out_bits`).
    pub logit_bits: usize,
    /// Whether logit codes are two's-complement signed.
    pub signed_logits: bool,
    /// Largest `Level::n_wires` (one scratch buffer serves every level).
    pub max_wires: usize,
    /// Largest inter-level plane count (double-buffer sizing).
    pub max_planes: usize,
}

impl BitNetlist {
    /// Total word ops per 64-sample block — the compiled cost metric.
    pub fn num_ops(&self) -> usize {
        self.levels.iter().map(|l| l.ops.len()).sum()
    }

    /// Recompute every derived stat — per-level `n_wires`, the global
    /// `max_wires`/`max_planes` — from the ops and outputs. This is the
    /// *one* place those numbers come from: `lower` calls it after
    /// building, `engine::opt` after every pass pipeline, and the `.nfab`
    /// loader after decoding, so no pass maintains them ad hoc.
    pub fn recompute_stats(&mut self) {
        let mut max_wires = 2;
        let mut max_planes = 0;
        for level in &mut self.levels {
            level.n_wires = W_INPUTS as usize + level.n_in_planes + level.ops.len();
            max_wires = max_wires.max(level.n_wires);
            max_planes = max_planes.max(level.n_in_planes.max(level.outputs.len()));
        }
        self.max_wires = max_wires;
        self.max_planes = max_planes;
    }

    /// Structural invariants every consumer relies on: the plane chain
    /// (each level consumes exactly what the previous produced), dense
    /// sequential op `dst` ids, topological operand order, in-bounds
    /// outputs, the logit-plane layout, and stats consistent with
    /// [`recompute_stats`](Self::recompute_stats).
    pub fn check(&self) -> Result<()> {
        let mut prev_planes = self.input_size * self.input_bits;
        let (mut max_wires, mut max_planes) = (2usize, 0usize);
        for (li, level) in self.levels.iter().enumerate() {
            if level.n_in_planes != prev_planes {
                bail!(
                    "level {li}: consumes {} planes but the previous level \
                     produces {prev_planes}",
                    level.n_in_planes
                );
            }
            let base = W_INPUTS as usize + level.n_in_planes;
            for (i, op) in level.ops.iter().enumerate() {
                if op.dst as usize != base + i {
                    bail!("level {li} op {i}: dst {} is not dense (expected {})",
                          op.dst, base + i);
                }
                for src in [op.sel, op.hi, op.lo] {
                    if src as usize >= base + i {
                        bail!("level {li} op {i}: operand {src} is not earlier \
                               than dst {}", op.dst);
                    }
                }
            }
            if level.n_wires != base + level.ops.len() {
                bail!("level {li}: n_wires {} != {} (2 consts + {} planes + {} ops)",
                      level.n_wires, base + level.ops.len(), level.n_in_planes,
                      level.ops.len());
            }
            for &w in &level.outputs {
                if w as usize >= level.n_wires {
                    bail!("level {li}: output wire {w} >= n_wires {}", level.n_wires);
                }
            }
            max_wires = max_wires.max(level.n_wires);
            max_planes = max_planes.max(level.n_in_planes.max(level.outputs.len()));
            prev_planes = level.outputs.len();
        }
        match self.levels.last() {
            None => bail!("netlist has no levels"),
            Some(last) if last.outputs.len() != self.n_class * self.logit_bits => bail!(
                "final level produces {} planes, logit layout needs {} \
                 ({} classes x {} bits)",
                last.outputs.len(),
                self.n_class * self.logit_bits,
                self.n_class,
                self.logit_bits
            ),
            Some(_) => {}
        }
        if self.max_wires != max_wires || self.max_planes != max_planes {
            bail!(
                "stale stats: max_wires {} (actual {max_wires}), max_planes {} \
                 (actual {max_planes}) — recompute_stats was not run",
                self.max_wires,
                self.max_planes
            );
        }
        Ok(())
    }

    /// Debug-build assertion wrapper around [`check`](Self::check).
    pub fn debug_check(&self) {
        #[cfg(debug_assertions)]
        if let Err(e) = self.check() {
            panic!("inconsistent BitNetlist: {e}");
        }
    }
}

/// Lower a validated network. Fails when a layer's `in_bits` does not
/// match the previous layer's `out_bits` (the scalar simulator silently
/// assumes this; the compiled representation checks it).
pub fn lower(net: &LutNetwork) -> Result<BitNetlist> {
    net.validate()?;
    let mut levels = Vec::with_capacity(net.layers.len());
    let mut prev_width = net.input_size;
    let mut prev_bits = net.input_bits;
    for (li, layer) in net.layers.iter().enumerate() {
        if layer.in_bits != prev_bits {
            bail!(
                "layer {li}: in_bits {} != previous out_bits {prev_bits} \
                 (cannot lower to a bit netlist)",
                layer.in_bits
            );
        }
        if layer.signed_out && li != net.layers.len() - 1 {
            // The scalar simulator widens hidden codes through u16, so a
            // negative hidden code floods the next layer's address bits;
            // there is no consistent bit-level semantics to lower to.
            bail!("layer {li}: signed outputs on a non-final layer");
        }
        let k = layer.in_bits * layer.fan_in;
        if k > 26 {
            bail!("layer {li}: {k} address bits is beyond the lowering cap");
        }
        let n_in_planes = prev_width * prev_bits;
        let mut next_wire = W_INPUTS + n_in_planes as u32;
        let mut ops: Vec<MuxOp> = Vec::new();
        // Structural hashing across bits and L-LUTs of this level.
        let mut memo: std::collections::HashMap<(u32, u32, u32), u32> =
            std::collections::HashMap::new();
        let mut outputs = Vec::with_capacity(layer.num_luts() * layer.out_bits);
        let mut bits_buf = vec![0u8; layer.entries()];
        for lut in 0..layer.num_luts() {
            let table = layer.table(lut);
            // Address bit p reads bit (p % in_bits) of source (p / in_bits).
            let plane_of = |p: usize| -> u32 {
                let src = layer.indices[lut][p / layer.in_bits] as usize;
                W_INPUTS + (src * prev_bits + p % layer.in_bits) as u32
            };
            for b in 0..layer.out_bits {
                for (addr, slot) in bits_buf.iter_mut().enumerate() {
                    *slot = ((table[addr] as u16) >> b) as u8 & 1;
                }
                let root = if let Some(c) = boolfn::const_value(&bits_buf) {
                    // Constant bit (common in trained tables: saturated or
                    // dead units) — skip support analysis entirely.
                    if c == 0 { W_ZERO } else { W_ONE }
                } else {
                    let sup = boolfn::support(&bits_buf, k);
                    let proj = boolfn::project(&bits_buf, k, &sup);
                    let bdd = robdd::build(&proj, sup.len());
                    // Map BDD node ids to wires, bottom-up.
                    let mut wire_of = vec![0u32; bdd.nodes.len() + 2];
                    wire_of[0] = W_ZERO;
                    wire_of[1] = W_ONE;
                    for (i, n) in bdd.nodes.iter().enumerate() {
                        let sel = plane_of(sup[n.var as usize]);
                        let hi = wire_of[n.hi as usize];
                        let lo = wire_of[n.lo as usize];
                        wire_of[i + 2] = if hi == W_ONE && lo == W_ZERO {
                            sel // literal: the plane itself, no op
                        } else {
                            *memo.entry((sel, hi, lo)).or_insert_with(|| {
                                let dst = next_wire;
                                next_wire += 1;
                                ops.push(MuxOp { sel, hi, lo, dst });
                                dst
                            })
                        };
                    }
                    wire_of[bdd.root as usize]
                };
                outputs.push(root);
            }
        }
        levels.push(Level {
            n_wires: next_wire as usize,
            n_in_planes,
            ops,
            outputs,
            num_luts: layer.num_luts(),
            out_bits: layer.out_bits,
        });
        prev_width = layer.num_luts();
        prev_bits = layer.out_bits;
    }
    let last = net.layers.last().expect("validated network has layers");
    let mut nl = BitNetlist {
        levels,
        input_size: net.input_size,
        input_bits: net.input_bits,
        n_class: net.n_class,
        logit_bits: last.out_bits,
        signed_logits: last.signed_out,
        max_wires: 0,
        max_planes: 0,
    };
    // Derived stats come from exactly one place; the debug check keeps the
    // build honest against the invariants every consumer assumes.
    nl.recompute_stats();
    nl.debug_check();
    Ok(nl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::{random_network, LutLayer, LutNetwork};

    #[test]
    fn lowers_random_networks_with_bounded_shapes() {
        let net = random_network(11, 10, 2, &[8, 4, 3], 3, 2, 4);
        let nl = lower(&net).unwrap();
        assert_eq!(nl.levels.len(), 3);
        assert_eq!(nl.levels[0].n_in_planes, 10 * 2);
        assert_eq!(nl.levels[0].outputs.len(), 8 * 2);
        assert_eq!(nl.levels[2].outputs.len(), 3 * 4);
        assert_eq!(nl.n_class, 3);
        assert!(nl.signed_logits);
        assert!(nl.max_wires >= 2 + nl.levels[0].n_in_planes);
        // Every op reads only consts, planes, or earlier op results.
        for level in &nl.levels {
            let base = W_INPUTS as usize + level.n_in_planes;
            for (i, op) in level.ops.iter().enumerate() {
                assert_eq!(op.dst as usize, base + i);
                for src in [op.sel, op.hi, op.lo] {
                    assert!((src as usize) < base + i);
                }
            }
            for &w in &level.outputs {
                assert!((w as usize) < level.n_wires);
            }
        }
    }

    #[test]
    fn literal_passthrough_lowers_to_zero_ops() {
        // table[a] = a over 2 bits: each output bit is a plain input bit.
        let net = LutNetwork {
            name: "id".into(),
            input_size: 1,
            input_bits: 2,
            n_class: 1,
            layers: vec![LutLayer {
                indices: vec![vec![0]],
                tables: (0..4).map(|i| i as i16).collect(),
                fan_in: 1,
                in_bits: 2,
                out_bits: 2,
                signed_out: false,
            }],
        };
        let nl = lower(&net).unwrap();
        assert_eq!(nl.num_ops(), 0);
        assert_eq!(nl.levels[0].outputs, vec![W_INPUTS, W_INPUTS + 1]);
    }

    #[test]
    fn constant_tables_lower_to_constant_wires() {
        let net = LutNetwork {
            name: "const".into(),
            input_size: 1,
            input_bits: 1,
            n_class: 1,
            layers: vec![LutLayer {
                indices: vec![vec![0]],
                tables: vec![3, 3],
                fan_in: 1,
                in_bits: 1,
                out_bits: 2,
                signed_out: false,
            }],
        };
        let nl = lower(&net).unwrap();
        assert_eq!(nl.num_ops(), 0);
        assert_eq!(nl.levels[0].outputs, vec![W_ONE, W_ONE]);
    }

    #[test]
    fn rejects_signed_hidden_layers() {
        let mut net = random_network(17, 6, 2, &[4, 2], 2, 2, 4);
        net.layers[0].signed_out = true;
        assert!(lower(&net).is_err());
    }

    #[test]
    fn rejects_inconsistent_bit_widths() {
        let net = LutNetwork {
            name: "bad".into(),
            input_size: 2,
            input_bits: 2,
            n_class: 1,
            layers: vec![LutLayer {
                indices: vec![vec![0, 1]],
                tables: vec![0; 1 << 2],
                fan_in: 2,
                in_bits: 1, // != input_bits
                out_bits: 2,
                signed_out: false,
            }],
        };
        assert!(lower(&net).is_err());
    }

    #[test]
    fn structural_hashing_shares_identical_luts() {
        // Two L-LUTs with the same wiring and table must share all ops.
        let mut net = random_network(13, 6, 2, &[2, 2], 3, 2, 4);
        let l0 = &mut net.layers[0];
        l0.indices[1] = l0.indices[0].clone();
        let e = l0.entries();
        let (a, b) = l0.tables.split_at_mut(e);
        b.copy_from_slice(a);
        let nl = lower(&net).unwrap();
        let lvl = &nl.levels[0];
        assert_eq!(&lvl.outputs[..2], &lvl.outputs[2..]);
    }
}
