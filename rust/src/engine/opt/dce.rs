//! Backward dead-wire elimination, inter-level plane compaction and wire
//! renumbering.
//!
//! Liveness seeds from each level's outputs and walks the op list
//! backwards: an op whose `dst` nothing reads is dropped (this sweeps the
//! subtrees [`simplify`](super::simplify) strands, and — at `O2`, where
//! compaction shrinks what upstream levels must produce — entire L-LUTs
//! the next layer's sparse wiring never samples).
//!
//! Compaction runs on each adjacent level pair, back to front: output
//! planes the consuming level never reads are removed from the producing
//! level's `outputs`, and duplicate planes (two outputs naming the same
//! wire) collapse to one, with the consumer's plane references rewritten.
//! Because the sweep is backward, the producing level is then DCE'd
//! against its *already shrunk* output set, cascading dead logic toward
//! the inputs. The final level's outputs (the logit planes) and level 0's
//! input planes (the quantized network inputs) keep their layouts — the
//! evaluator's transposes depend on them.

use std::collections::HashMap;

use crate::engine::lower::{BitNetlist, MuxOp, W_INPUTS};

/// Remap one wire: constants and planes through `plane_map`, op results
/// through `dst_map`.
fn remap(w: u32, old_base: u32, plane_map: &[Option<u32>], dst_map: &HashMap<u32, u32>) -> u32 {
    if w < W_INPUTS {
        w
    } else if w < old_base {
        let p = plane_map[(w - W_INPUTS) as usize].expect("remapped plane is live");
        W_INPUTS + p
    } else {
        dst_map[&w]
    }
}

/// Run DCE (and, with `compact`, plane compaction) in place. Returns
/// `(dead_ops, dead_planes)`.
pub(super) fn run(nl: &mut BitNetlist, compact: bool) -> (u64, u64) {
    let (mut dead_ops, mut dead_planes) = (0u64, 0u64);
    for i in (0..nl.levels.len()).rev() {
        let (head, tail) = nl.levels.split_at_mut(i);
        let lvl = &mut tail[0];

        // Liveness, backwards from the outputs.
        let mut live = vec![false; lvl.n_wires];
        for &w in &lvl.outputs {
            live[w as usize] = true;
        }
        let mut kept: Vec<MuxOp> = Vec::with_capacity(lvl.ops.len());
        for op in lvl.ops.iter().rev() {
            if live[op.dst as usize] {
                live[op.sel as usize] = true;
                live[op.hi as usize] = true;
                live[op.lo as usize] = true;
                kept.push(*op);
            } else {
                dead_ops += 1;
            }
        }
        kept.reverse();
        lvl.ops = kept;

        if !compact || i == 0 {
            continue;
        }
        // Compact the plane interface with the producing level: keep one
        // plane per live, distinct produced wire.
        let prev = &mut head[i - 1];
        let old_base = W_INPUTS + lvl.n_in_planes as u32;
        let mut plane_map: Vec<Option<u32>> = vec![None; lvl.n_in_planes];
        let mut new_prev_outputs: Vec<u32> = Vec::new();
        let mut plane_of_wire: HashMap<u32, u32> = HashMap::new();
        for p in 0..lvl.n_in_planes {
            if !live[W_INPUTS as usize + p] {
                continue;
            }
            let w = prev.outputs[p];
            let np = *plane_of_wire.entry(w).or_insert_with(|| {
                new_prev_outputs.push(w);
                (new_prev_outputs.len() - 1) as u32
            });
            plane_map[p] = Some(np);
        }
        dead_planes += (prev.outputs.len() - new_prev_outputs.len()) as u64;
        prev.outputs = new_prev_outputs;

        // Rewrite this level onto the compacted plane base.
        let mut dst_map: HashMap<u32, u32> = HashMap::new();
        let mut next = W_INPUTS + prev.outputs.len() as u32;
        let ops = std::mem::take(&mut lvl.ops);
        lvl.ops = ops
            .into_iter()
            .map(|op| {
                let mapped = MuxOp {
                    sel: remap(op.sel, old_base, &plane_map, &dst_map),
                    hi: remap(op.hi, old_base, &plane_map, &dst_map),
                    lo: remap(op.lo, old_base, &plane_map, &dst_map),
                    dst: next,
                };
                dst_map.insert(op.dst, next);
                next += 1;
                mapped
            })
            .collect();
        let outputs = std::mem::take(&mut lvl.outputs);
        lvl.outputs = outputs
            .into_iter()
            .map(|w| remap(w, old_base, &plane_map, &dst_map))
            .collect();
        lvl.n_in_planes = prev.outputs.len();
        lvl.n_wires = next as usize;
    }
    (dead_ops, dead_planes)
}

/// Re-pack every level's op `dst` ids densely after op removal (levels
/// already rewritten by compaction come out unchanged).
pub(super) fn renumber(nl: &mut BitNetlist) {
    for lvl in &mut nl.levels {
        let base = W_INPUTS + lvl.n_in_planes as u32;
        let mut dst_map: HashMap<u32, u32> = HashMap::new();
        let mut next = base;
        let get = |w: u32, m: &HashMap<u32, u32>| if w < base { w } else { m[&w] };
        for slot in lvl.ops.iter_mut() {
            let op = *slot;
            *slot = MuxOp {
                sel: get(op.sel, &dst_map),
                hi: get(op.hi, &dst_map),
                lo: get(op.lo, &dst_map),
                dst: next,
            };
            dst_map.insert(op.dst, next);
            next += 1;
        }
        for w in &mut lvl.outputs {
            *w = get(*w, &dst_map);
        }
        lvl.n_wires = next as usize;
    }
}
