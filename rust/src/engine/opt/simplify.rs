//! Forward simplification over the levelized netlist: constant folding,
//! constant-input mux collapsing, literal canonicalization and common-
//! subexpression elimination, all driven by **value numbering**.
//!
//! Every wire is mapped to a value id. Constants are values `0`/`1`;
//! input planes inherit the value of the producing output in the previous
//! level (`O2`) or get fresh ids (`O1`, where only constants propagate
//! across the register plane). Each op is then folded on its operand
//! *values* — which sees through duplicate planes, aliased wires and
//! constants in a way the build-time wire-keyed hashing cannot:
//!
//! * `mux(0, h, l) = l`, `mux(1, h, l) = h` — constant select;
//! * `mux(s, a, a) = a` — equal branches;
//! * `mux(s, s, l) = mux(s, 1, l)`, `mux(s, h, s) = mux(s, h, 0)` —
//!   select-in-branch canonicalization (exposes more sharing);
//! * `mux(s, 1, 0) = s` — literal;
//! * identical `(sel, hi, lo)` value triples share one op (CSE). With
//!   `global` set the CSE table persists across levels, so a function
//!   already computed by an earlier level is re-used whenever a plane
//!   still carries its value.
//!
//! Folded ops leave the level's op list immediately; outputs are rewired
//! to the surviving representative. The pass never reorders surviving
//! ops, so topological order is preserved by construction. Dead ops it
//! strands (results nothing reads anymore) are swept by the companion
//! [`dce`](super::dce) pass.

use std::collections::HashMap;

use crate::engine::lower::{BitNetlist, MuxOp, W_INPUTS, W_ONE, W_ZERO};

/// Value ids of the constant-0 / constant-1 planes (mirroring the wire
/// ids, so `wire <= W_ONE` ⇔ `value <= V_ONE`).
const V_ZERO: u32 = 0;
const V_ONE: u32 = 1;

/// Run the pass in place. Returns `(folded, merged)` op counts.
pub(super) fn run(nl: &mut BitNetlist, global: bool) -> (u64, u64) {
    let mut next_val: u32 = 2;
    let n_input_planes = nl.input_size * nl.input_bits;
    let mut plane_vals: Vec<u32> = (0..n_input_planes as u32).map(|i| 2 + i).collect();
    next_val += n_input_planes as u32;
    // (sel, hi, lo) value triple -> value id. Persists across levels when
    // `global`, giving cross-level CSE; cleared per level otherwise.
    let mut cse: HashMap<(u32, u32, u32), u32> = HashMap::new();
    let (mut folded, mut merged) = (0u64, 0u64);

    for level in &mut nl.levels {
        if !global {
            cse.clear();
        }
        debug_assert_eq!(level.n_in_planes, plane_vals.len());
        let base = W_INPUTS as usize + level.n_in_planes;
        // Old wire id -> value id (wires are dense after lower/renumber).
        let mut val_of = vec![u32::MAX; level.n_wires];
        val_of[W_ZERO as usize] = V_ZERO;
        val_of[W_ONE as usize] = V_ONE;
        // Value id -> wire (in the *new* numbering) that carries it here.
        let mut wire_of_val: HashMap<u32, u32> = HashMap::new();
        wire_of_val.insert(V_ZERO, W_ZERO);
        wire_of_val.insert(V_ONE, W_ONE);
        for (p, &v) in plane_vals.iter().enumerate() {
            let w = W_INPUTS + p as u32;
            val_of[w as usize] = v;
            wire_of_val.entry(v).or_insert(w);
        }

        let mut new_ops: Vec<MuxOp> = Vec::with_capacity(level.ops.len());
        let mut next_wire = base as u32;
        for op in &level.ops {
            let sv = val_of[op.sel as usize];
            let mut hv = val_of[op.hi as usize];
            let mut lv = val_of[op.lo as usize];
            let fold = if sv == V_ZERO {
                Some(lv)
            } else if sv == V_ONE {
                Some(hv)
            } else if hv == lv {
                Some(hv)
            } else {
                if sv == hv {
                    hv = V_ONE; // mux(s, s, l) = s | l = mux(s, 1, l)
                }
                if sv == lv {
                    lv = V_ZERO; // mux(s, h, s) = s & h = mux(s, h, 0)
                }
                (hv == V_ONE && lv == V_ZERO).then_some(sv) // literal
            };
            if let Some(v) = fold {
                val_of[op.dst as usize] = v;
                folded += 1;
                continue;
            }
            let key = (sv, hv, lv);
            let v = match cse.get(&key) {
                Some(&v) => {
                    if wire_of_val.contains_key(&v) {
                        // Same function already materialized in this level.
                        val_of[op.dst as usize] = v;
                        merged += 1;
                        continue;
                    }
                    v // known value, but not carried by any wire here
                }
                None => {
                    let v = next_val;
                    next_val += 1;
                    cse.insert(key, v);
                    v
                }
            };
            let dst = next_wire;
            next_wire += 1;
            new_ops.push(MuxOp {
                sel: wire_of_val[&sv],
                hi: wire_of_val[&hv],
                lo: wire_of_val[&lv],
                dst,
            });
            wire_of_val.insert(v, dst);
            val_of[op.dst as usize] = v;
        }

        let out_vals: Vec<u32> = level.outputs.iter().map(|&w| val_of[w as usize]).collect();
        level.ops = new_ops;
        level.outputs = out_vals.iter().map(|&v| wire_of_val[&v]).collect();
        level.n_wires = next_wire as usize;
        // Next level's planes carry these values. At O1 only constants
        // propagate; every other plane gets a fresh, unrelated id.
        plane_vals = if global {
            out_vals
        } else {
            out_vals
                .iter()
                .map(|&v| {
                    if v <= V_ONE {
                        v
                    } else {
                        let nv = next_val;
                        next_val += 1;
                        nv
                    }
                })
                .collect()
        };
    }
    (folded, merged)
}
