//! Netlist optimization pass pipeline over the lowered [`BitNetlist`].
//!
//! The lowering pass emits the ROBDD-derived mux graph essentially
//! verbatim: per-level structural hashing shares identical `(sel, hi, lo)`
//! triples, but nothing looks *across* register planes. A real synthesis
//! flow sweeps much more — and every node it sweeps is wall-clock time the
//! bitsliced evaluator stops paying per 64-sample block. This module is
//! that sweep, run once at compile time between `lower` and execution:
//!
//! * **Constant folding + mux simplification** (`simplify` pass): a
//!   level's output that is constant (`W_ZERO`/`W_ONE`) makes the next
//!   level's plane constant, so muxes selecting on it collapse to one
//!   branch (`mux(0, h, l) = l`, `mux(1, h, l) = h`); equal branches
//!   (`mux(s, a, a) = a`) and literal forms (`mux(s, 1, 0) = s`)
//!   disappear; `mux(s, s, l)`/`mux(s, h, s)` canonicalize to
//!   `mux(s, 1, l)`/`mux(s, h, 0)`, exposing further sharing.
//! * **Global common-subexpression elimination** (also `simplify`, `O2`):
//!   value numbering that persists across levels. Two planes carrying the
//!   same value — duplicate L-LUT outputs, constants, shared literals —
//!   get one value id, so ops that differed only in which duplicate plane
//!   they read now merge, which the per-build wire-keyed hashing cannot see.
//! * **Dead-wire elimination + renumbering** (`dce` pass): backward
//!   liveness from each level's outputs removes ops whose results are
//!   never read (including entire L-LUTs the next layer's sparse wiring
//!   skips), then re-packs `dst` ids densely.
//! * **Level compaction / plane repacking** (also `dce`, `O2`): output
//!   planes the next level never reads are dropped and duplicate planes
//!   deduplicated, shrinking the evaluator's double-buffer
//!   (`max_planes`) and per-level scratch (`max_wires`), which
//!   [`BitNetlist::recompute_stats`] re-derives afterwards.
//!
//! Every pass is semantics-preserving on the quantized fabric: `O0`, `O1`
//! and `O2` netlists are bit-exact against each other and against the
//! scalar simulator (differentially property-tested in
//! `tests/properties.rs`).

mod dce;
mod simplify;

use std::time::Instant;

use anyhow::bail;

use crate::obs::{trace, PassReport};

use super::lower::BitNetlist;

/// How hard [`optimize`] works on the lowered netlist.
///
/// | level | passes                                                        |
/// |-------|---------------------------------------------------------------|
/// | `O0`  | none — the lowering pass output, verbatim                     |
/// | `O1`  | constant folding + mux simplification, per-level CSE, DCE     |
/// | `O2`  | `O1` + cross-level value numbering (global CSE) + plane compaction |
///
/// `O1` is the default: it is cheap (one linear pass over the ops) and
/// strictly removes work. `O2` additionally shrinks the inter-level
/// planes, which pays off on networks whose layers are wider than what
/// the next layer's sparse wiring actually reads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum OptLevel {
    /// Lowered netlist verbatim (no optimization passes).
    O0,
    /// Constant folding, mux simplification, per-level CSE, dead-wire
    /// elimination.
    #[default]
    O1,
    /// `O1` plus global (cross-level) CSE and plane compaction.
    O2,
}

impl OptLevel {
    /// Stable index used by CLI flags and the `.nfab` header.
    pub fn index(self) -> u32 {
        match self {
            OptLevel::O0 => 0,
            OptLevel::O1 => 1,
            OptLevel::O2 => 2,
        }
    }

    /// Inverse of [`index`](Self::index); rejects unknown levels.
    pub fn from_index(i: u32) -> crate::Result<OptLevel> {
        match i {
            0 => Ok(OptLevel::O0),
            1 => Ok(OptLevel::O1),
            2 => Ok(OptLevel::O2),
            other => bail!("unknown opt level {other} (supported: 0, 1, 2)"),
        }
    }
}

impl std::fmt::Display for OptLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "O{}", self.index())
    }
}

impl std::str::FromStr for OptLevel {
    type Err = anyhow::Error;

    /// Accepts `0`/`1`/`2` and `O0`/`o1`/`O2` (trimmed).
    fn from_str(s: &str) -> crate::Result<OptLevel> {
        let t = s.trim();
        let digits = t
            .strip_prefix('O')
            .or_else(|| t.strip_prefix('o'))
            .unwrap_or(t);
        match digits {
            "0" => Ok(OptLevel::O0),
            "1" => Ok(OptLevel::O1),
            "2" => Ok(OptLevel::O2),
            _ => bail!("unknown opt level '{s}' (supported: O0, O1, O2)"),
        }
    }
}

/// What [`optimize`] removed, for logs and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OptReport {
    /// Ops folded away by constant/mux simplification.
    pub folded: u64,
    /// Ops merged into an equivalent earlier op (CSE).
    pub merged: u64,
    /// Ops removed as dead (result never read).
    pub dead_ops: u64,
    /// Inter-level planes dropped by compaction (`O2` only).
    pub dead_planes: u64,
}

impl OptReport {
    /// Total ops removed by all passes.
    pub fn removed_ops(&self) -> u64 {
        self.folded + self.merged + self.dead_ops
    }
}

/// Run the pass pipeline for `level` in place. Returns what was removed.
/// The netlist's derived stats (`n_wires`, `max_wires`, `max_planes`) are
/// recomputed afterwards and the structural invariants re-checked (debug
/// builds), so an optimized netlist is as trustworthy as a lowered one.
pub fn optimize(nl: &mut BitNetlist, level: OptLevel) -> OptReport {
    optimize_traced(nl, level).0
}

/// [`optimize`], additionally returning one timed [`PassReport`] per
/// pass run (`simplify`, then `dce` — which includes renumbering and,
/// at `O2`, plane compaction). The reports chain: each pass's
/// `ops_before` is the previous pass's `ops_after`, and the last
/// `ops_after` is the netlist's final op count. `O0` returns no passes.
pub fn optimize_traced(nl: &mut BitNetlist, level: OptLevel) -> (OptReport, Vec<PassReport>) {
    let mut report = OptReport::default();
    let mut passes = Vec::new();
    if level == OptLevel::O0 {
        return (report, passes);
    }
    let global = level == OptLevel::O2;

    let ops_before = nl.num_ops();
    let t0 = Instant::now();
    let (folded, merged) = {
        let _span = trace::span("opt/simplify");
        simplify::run(nl, global)
    };
    report.folded = folded;
    report.merged = merged;
    let after_simplify = nl.num_ops();
    passes.push(PassReport {
        name: "simplify".into(),
        wall_s: t0.elapsed().as_secs_f64(),
        ops_before,
        ops_after: after_simplify,
        planes_removed: 0,
    });

    let t0 = Instant::now();
    let (dead_ops, dead_planes) = {
        let _span = trace::span("opt/dce");
        let r = dce::run(nl, global);
        dce::renumber(nl);
        nl.recompute_stats();
        nl.debug_check();
        r
    };
    report.dead_ops = dead_ops;
    report.dead_planes = dead_planes;
    passes.push(PassReport {
        name: "dce".into(),
        wall_s: t0.elapsed().as_secs_f64(),
        ops_before: after_simplify,
        ops_after: nl.num_ops(),
        planes_removed: dead_planes as usize,
    });
    (report, passes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::lower::{self, W_INPUTS, W_ONE};
    use crate::luts::{random_network, structured_network, LutLayer, LutNetwork};

    fn lowered(net: &LutNetwork) -> BitNetlist {
        lower::lower(net).unwrap()
    }

    #[test]
    fn opt_level_parses_and_round_trips() {
        for (s, want) in [
            ("0", OptLevel::O0),
            ("O1", OptLevel::O1),
            (" o2 ", OptLevel::O2),
            ("2", OptLevel::O2),
        ] {
            let got: OptLevel = s.parse().unwrap();
            assert_eq!(got, want);
            assert_eq!(OptLevel::from_index(got.index()).unwrap(), got);
        }
        assert!("O3".parse::<OptLevel>().is_err());
        assert!("fast".parse::<OptLevel>().is_err());
        assert!(OptLevel::from_index(7).is_err());
        assert_eq!(OptLevel::default(), OptLevel::O1);
        assert_eq!(OptLevel::O2.to_string(), "O2");
    }

    #[test]
    fn o0_is_the_identity() {
        let net = random_network(19, 10, 2, &[8, 4], 3, 2, 4);
        let mut nl = lowered(&net);
        let before = nl.num_ops();
        let rep = optimize(&mut nl, OptLevel::O0);
        assert_eq!(rep, OptReport::default());
        assert_eq!(nl.num_ops(), before);
    }

    #[test]
    fn higher_levels_never_add_ops_and_keep_invariants() {
        for seed in [3u64, 11, 29] {
            let net = random_network(seed, 12, 2, &[8, 6, 3], 3, 2, 4);
            let mut prev = usize::MAX;
            for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
                let mut nl = lowered(&net);
                optimize(&mut nl, level);
                nl.check().unwrap();
                assert!(
                    nl.num_ops() <= prev,
                    "{level} grew the netlist: {} > {prev}",
                    nl.num_ops()
                );
                prev = nl.num_ops();
            }
        }
    }

    #[test]
    fn constant_layer_outputs_fold_through_the_next_level() {
        // Layer 0 emits constants only; every layer-1 op must fold away.
        let net = LutNetwork {
            name: "const-feed".into(),
            input_size: 2,
            input_bits: 1,
            n_class: 2,
            layers: vec![
                LutLayer {
                    indices: vec![vec![0, 1], vec![1, 0]],
                    tables: vec![1, 1, 1, 1, 0, 0, 0, 0],
                    fan_in: 2,
                    in_bits: 1,
                    out_bits: 1,
                    signed_out: false,
                },
                LutLayer {
                    indices: vec![vec![0, 1], vec![1, 0]],
                    tables: (0..8).map(|i| (i % 4) as i16 - 1).collect(),
                    fan_in: 2,
                    in_bits: 1,
                    out_bits: 3,
                    signed_out: true,
                },
            ],
        };
        let mut nl = lowered(&net);
        let rep = optimize(&mut nl, OptLevel::O1);
        assert_eq!(nl.num_ops(), 0, "constant planes must fold everything");
        assert!(rep.folded > 0 || rep.dead_ops > 0 || nl.levels[1].ops.is_empty());
        // All logit planes are constant wires now.
        assert!(nl.levels[1].outputs.iter().all(|&w| w <= W_ONE));
    }

    #[test]
    fn duplicate_lut_outputs_merge_downstream_only_at_o2() {
        // Two identical L-LUTs in layer 0 produce duplicate planes; layer 1
        // reads both. O2's value numbering merges the duplicate work.
        let mut net = random_network(23, 6, 2, &[2, 2], 3, 2, 4);
        let l0 = &mut net.layers[0];
        l0.indices[1] = l0.indices[0].clone();
        let e = l0.entries();
        let (a, b) = l0.tables.split_at_mut(e);
        b.copy_from_slice(a);
        let mut o1 = lowered(&net);
        optimize(&mut o1, OptLevel::O1);
        let mut o2 = lowered(&net);
        let rep = optimize(&mut o2, OptLevel::O2);
        assert!(
            o2.num_ops() <= o1.num_ops(),
            "O2 ({}) must not exceed O1 ({})",
            o2.num_ops(),
            o1.num_ops()
        );
        // The duplicate planes themselves are compacted away.
        assert!(rep.dead_planes > 0, "duplicate planes should be dropped");
        assert!(o2.levels[1].n_in_planes < o1.levels[1].n_in_planes);
        assert_eq!(o2.levels[1].n_in_planes, o2.levels[0].outputs.len());
    }

    #[test]
    fn dead_units_are_swept_at_o2() {
        // A wide hidden layer feeding a narrow output layer: most hidden
        // units are never read and their ops must disappear at O2.
        let net = random_network(31, 12, 2, &[32, 2], 2, 2, 4);
        let mut o0 = lowered(&net);
        let mut o2 = lowered(&net);
        optimize(&mut o2, OptLevel::O2);
        o0.recompute_stats();
        assert!(
            (o2.num_ops() as f64) < 0.9 * o0.num_ops() as f64,
            "expected >10% dead work: O0 {} -> O2 {}",
            o0.num_ops(),
            o2.num_ops()
        );
        assert!(o2.max_planes <= o0.max_planes);
        assert!(o2.max_wires <= o0.max_wires);
    }

    #[test]
    fn structured_networks_shrink_hard_at_every_level() {
        let net = structured_network(7, 16, 2, &[16, 8, 4], 3, 2, 4);
        let o0 = lowered(&net).num_ops();
        let mut n1 = lowered(&net);
        optimize(&mut n1, OptLevel::O1);
        let mut n2 = lowered(&net);
        optimize(&mut n2, OptLevel::O2);
        assert!(n1.num_ops() <= o0);
        assert!(n2.num_ops() <= n1.num_ops());
        assert!(
            (n2.num_ops() as f64) <= 0.9 * o0.max(1) as f64,
            "trained-like tables must shed >=10%: O0 {o0} -> O2 {}",
            n2.num_ops()
        );
    }

    #[test]
    fn traced_passes_chain_and_match_the_plain_report() {
        let net = structured_network(7, 16, 2, &[16, 8, 4], 3, 2, 4);
        let mut nl = lowered(&net);
        let lowered_ops = nl.num_ops();
        let (rep, passes) = optimize_traced(&mut nl, OptLevel::O2);
        assert_eq!(passes.len(), 2);
        assert_eq!(passes[0].name, "simplify");
        assert_eq!(passes[1].name, "dce");
        assert_eq!(passes[0].ops_before, lowered_ops);
        assert_eq!(passes[1].ops_before, passes[0].ops_after);
        assert_eq!(passes[1].ops_after, nl.num_ops());
        assert_eq!(passes[0].ops_removed(), (rep.folded + rep.merged) as i64);
        assert_eq!(passes[1].ops_removed(), rep.dead_ops as i64);
        assert_eq!(passes[1].planes_removed, rep.dead_planes as usize);
        assert!(passes.iter().all(|p| p.wall_s >= 0.0));
        // O0 runs no passes at all.
        let mut nl0 = lowered(&net);
        let (rep0, p0) = optimize_traced(&mut nl0, OptLevel::O0);
        assert_eq!(rep0, OptReport::default());
        assert!(p0.is_empty());
    }

    #[test]
    fn optimized_ops_stay_densely_numbered_and_topological() {
        let net = structured_network(13, 10, 2, &[8, 6, 3], 3, 2, 4);
        for level in [OptLevel::O1, OptLevel::O2] {
            let mut nl = lowered(&net);
            optimize(&mut nl, level);
            for lvl in &nl.levels {
                let base = W_INPUTS as usize + lvl.n_in_planes;
                for (i, op) in lvl.ops.iter().enumerate() {
                    assert_eq!(op.dst as usize, base + i);
                    for src in [op.sel, op.hi, op.lo] {
                        assert!((src as usize) < base + i);
                    }
                }
            }
        }
    }
}
