//! AOT native-code backend: the compiled [`BitNetlist`] emitted as
//! straight-line source, built with the system compiler at
//! [`Model::compile`](crate::fabric::Model::compile) time, and executed
//! through a `dlopen`ed shared object.
//!
//! The bitsliced interpreter ([`super::bitslice`]) already removed the
//! per-sample lookup cost; what it still pays is the per-op decode — a
//! load of the `MuxOp`, four indexed accesses, a bounds check — for
//! every op of every block. This backend removes that too: the netlist
//! *is* the program. [`codegen`] prints one function per level with
//! every wire index a literal and the fused mux
//! (`dst = lo ^ (sel & (hi ^ lo))`) written out per op, [`toolchain`]
//! hands the source to `rustc --crate-type=cdylib` (the `aot` backend)
//! or `cc -shared` (the `aot-c` backend, also `aot`'s silent fallback
//! when `rustc` is missing), and [`loader`] maps the resulting `.so`
//! and resolves `neuralut_eval`. Executors keep the interpreter's exact
//! transpose/plane layout, so the native code is bit-exact against
//! `bitsliced` — and therefore against the scalar simulator — by
//! construction.
//!
//! **Caching.** A compiled `.so` is a *companion artifact*: when a
//! fabric cache drives the compile it lives beside the `.nfab` (named
//! by [`companion_path`], digest embedded), otherwise under
//! `--aot-cache-dir` / `NEURALUT_AOT` or a per-user temp directory. The
//! object embeds a [`SoMeta`] fingerprint (ABI version, model digest, a
//! content hash of the exact op stream, lane width, shape counts) that
//! is validated after every `dlopen`: stale, truncated, or foreign
//! objects are silently recompiled, never executed. Publication is
//! atomic (tmp + rename), same as `.nfab` writes.
//!
//! **Failure policy.** Native codegen must never cost availability: a
//! missing toolchain, a failed compile, or an unloadable object makes
//! [`BackendProvider::compile`] return an error, and the fabric layer
//! degrades the model to this backend's declared fallback (`bitsliced`)
//! with [`degraded_from`](crate::obs::CompileReport) recorded — serving
//! continues on the interpreter. `NEURALUT_AOT=off` forces that path
//! without touching the toolchain. Chaos coverage drives the same
//! paths through the [`aot.codegen`](crate::util::faults::point::AOT_CODEGEN),
//! [`aot.cc`](crate::util::faults::point::AOT_CC) and
//! [`aot.dlopen`](crate::util::faults::point::AOT_DLOPEN) fault points.

mod codegen;
mod loader;
mod toolchain;

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context};

use crate::fabric::{
    companion_path, BackendProvider, BatchAffinity, Capabilities, CompileCost, ProviderCtx,
};
use crate::luts::LutNetwork;
use crate::netlist::{quantize_input, SimResult};
use crate::obs::{trace, PassReport};
use crate::util::{faults, pool};

use super::{
    detect_lane_words, BitNetlist, BitslicedProgram, FabricProgram, InferenceBackend, OptLevel,
};
use loader::Library;

/// Words in the `neuralut_meta` export of a generated object.
pub(crate) const META_WORDS: usize = 8;

/// Generated-object ABI version — word 0 of `neuralut_meta`. Bumped
/// whenever the export set, the meta layout, or the eval contract
/// changes; a mismatch just means "recompile".
const ABI_VERSION: u64 = 1;

/// Blocks at which a batch shards across the worker pool — same
/// threshold as the bitsliced interpreter, so backend choice never
/// changes sharding behavior.
const PARALLEL_BLOCK_THRESHOLD: usize = 8;

/// Which source language the backend emits — `aot` (Rust) and `aot-c`
/// (C) are the same backend modulo this choice. `Rust` silently falls
/// back to the C emitter when `rustc` is absent but `cc` is present.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Emitter {
    Rust,
    C,
}

impl Emitter {
    fn backend_name(self) -> &'static str {
        match self {
            Emitter::Rust => "aot",
            Emitter::C => "aot-c",
        }
    }

    fn src_ext(self) -> &'static str {
        match self {
            Emitter::Rust => "rs",
            Emitter::C => "c",
        }
    }
}

/// The staleness fingerprint embedded in (and validated against) every
/// generated object's `neuralut_meta` export. All [`META_WORDS`] words
/// must match for a cached `.so` to be reused; the content hash covers
/// the exact op stream, so two opt levels of the same model — or the
/// same model lowered at different lane widths — never alias.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SoMeta {
    abi: u64,
    model_digest: u64,
    program_fnv: u64,
    lanes: u64,
    levels: u64,
    ops: u64,
    max_wires: u64,
    max_planes: u64,
}

impl SoMeta {
    fn for_netlist(nl: &BitNetlist, model_digest: u64, lanes: usize) -> SoMeta {
        SoMeta {
            abi: ABI_VERSION,
            model_digest,
            program_fnv: fingerprint(nl),
            lanes: lanes as u64,
            levels: nl.levels.len() as u64,
            ops: nl.num_ops() as u64,
            max_wires: nl.max_wires as u64,
            max_planes: nl.max_planes as u64,
        }
    }

    fn to_words(self) -> [u64; META_WORDS] {
        [
            self.abi,
            self.model_digest,
            self.program_fnv,
            self.lanes,
            self.levels,
            self.ops,
            self.max_wires,
            self.max_planes,
        ]
    }

    fn check_loaded(self, got: &[u64; META_WORDS], path: &Path) -> crate::Result<()> {
        const NAMES: [&str; META_WORDS] = [
            "ABI version",
            "model digest",
            "program fingerprint",
            "lane width",
            "level count",
            "op count",
            "max wires",
            "max planes",
        ];
        let want = self.to_words();
        for (i, name) in NAMES.iter().enumerate() {
            if got[i] != want[i] {
                bail!(
                    "{}: stale or foreign AOT object: {name} is {:#x}, this program needs {:#x}",
                    path.display(),
                    got[i],
                    want[i]
                );
            }
        }
        Ok(())
    }
}

fn mix(h: &mut u64, v: u64) {
    *h ^= v;
    *h = h.wrapping_mul(0x0000_0100_0000_01b3);
}

/// FNV-1a over every field the generated code depends on — the exact op
/// stream, output wiring, and interface shape. This is what makes `.so`
/// reuse safe across opt levels: identical fingerprints mean identical
/// generated source.
fn fingerprint(nl: &BitNetlist) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    mix(&mut h, nl.input_size as u64);
    mix(&mut h, nl.input_bits as u64);
    mix(&mut h, nl.n_class as u64);
    mix(&mut h, nl.logit_bits as u64);
    mix(&mut h, nl.signed_logits as u64);
    mix(&mut h, nl.levels.len() as u64);
    for level in &nl.levels {
        mix(&mut h, level.n_in_planes as u64);
        mix(&mut h, level.ops.len() as u64);
        mix(&mut h, level.outputs.len() as u64);
        for op in &level.ops {
            mix(&mut h, op.sel as u64);
            mix(&mut h, op.hi as u64);
            mix(&mut h, op.lo as u64);
            mix(&mut h, op.dst as u64);
        }
        for &w in &level.outputs {
            mix(&mut h, w as u64);
        }
    }
    h
}

/// An open, meta-validated generated object: the library handle plus
/// the resolved `neuralut_eval` entry point. Shared by every executor
/// of one [`AotProgram`] behind an `Arc`; the function pointer stays
/// valid exactly as long as the `Library` lives, which the struct
/// enforces by owning both.
struct NativeFabric {
    _lib: Library,
    eval: unsafe extern "C" fn(*mut u64, *mut u64),
}

impl NativeFabric {
    fn load(path: &Path, want: SoMeta) -> crate::Result<NativeFabric> {
        let lib = Library::open(path)?;
        let meta = lib.sym("neuralut_meta")? as *const u64;
        if meta.is_null() {
            bail!("{}: neuralut_meta resolved to null", path.display());
        }
        // Safety: word 0 (the ABI version) is readable in every ABI this
        // loader has ever emitted; the remaining words are only read
        // once the ABI matches this build's layout.
        let abi = unsafe { meta.read_unaligned() };
        if abi != ABI_VERSION {
            bail!(
                "{}: AOT object ABI version {abi}, this build needs {ABI_VERSION}",
                path.display()
            );
        }
        let mut got = [0u64; META_WORDS];
        for (i, g) in got.iter_mut().enumerate() {
            // Safety: ABI matched, so the export is [u64; META_WORDS].
            *g = unsafe { meta.add(i).read_unaligned() };
        }
        want.check_loaded(&got, path)?;
        let eval = lib.sym("neuralut_eval")?;
        if eval.is_null() {
            bail!("{}: neuralut_eval resolved to null", path.display());
        }
        // Safety: the symbol was emitted by our codegen as
        // `extern "C" fn(*mut u64, *mut u64)` (meta validation above
        // ties the object to this exact program and ABI).
        let eval = unsafe {
            std::mem::transmute::<*mut std::ffi::c_void, unsafe extern "C" fn(*mut u64, *mut u64)>(
                eval,
            )
        };
        Ok(NativeFabric { _lib: lib, eval })
    }
}

/// The `aot` / `aot-c` registry provider. Lowers through the same
/// [`BitslicedProgram`] pipeline as the interpreter (so opt levels and
/// pass telemetry behave identically), then builds-or-reuses the native
/// object for the resulting netlist.
pub struct AotProvider {
    emitter: Emitter,
    lanes: usize,
}

impl AotProvider {
    /// Provider at the host-detected lane width — what the built-in
    /// `aot` / `aot-c` registrations use.
    pub fn new(emitter: Emitter) -> Self {
        AotProvider { emitter, lanes: detect_lane_words() }
    }

    /// Provider at an explicit lane width (tests crossing the width
    /// matrix; the width is validated when the lowering pipeline runs).
    pub fn with_lanes(emitter: Emitter, lanes: usize) -> Self {
        AotProvider { emitter, lanes }
    }

    /// Where this provider's `.so` for the given context lives: the
    /// explicit cache dir wins, else beside the `.nfab` as a companion
    /// file, else a per-user temp cache.
    fn so_path(&self, ctx: &ProviderCtx) -> PathBuf {
        let tag = format!("{}.so", self.emitter.backend_name());
        if let Some(dir) = &ctx.aot_cache_dir {
            dir.join(format!("{:016x}.x{}.{tag}", ctx.model_digest, self.lanes))
        } else if let Some(art) = &ctx.artifact_path {
            companion_path(art, ctx.model_digest, &tag)
        } else {
            std::env::temp_dir()
                .join("neuralut-aot")
                .join(format!("{:016x}.x{}.{tag}", ctx.model_digest, self.lanes))
        }
    }

    /// Reuse a cached object if its fingerprint matches, else emit
    /// source, run the system compiler, publish atomically, and load.
    /// Appends the `codegen`/`cc`/`dlopen` timing passes it ran.
    fn build_or_load(
        &self,
        nl: &Arc<BitNetlist>,
        ctx: &ProviderCtx,
        passes: &mut Vec<PassReport>,
    ) -> crate::Result<Arc<NativeFabric>> {
        let meta = SoMeta::for_netlist(nl, ctx.model_digest, self.lanes);
        let so_path = self.so_path(ctx);
        let ops = nl.num_ops();
        let synth = |name: &str, t0: Instant| PassReport {
            name: name.into(),
            wall_s: t0.elapsed().as_secs_f64(),
            ops_before: ops,
            ops_after: ops,
            planes_removed: 0,
        };
        if so_path.exists() {
            let t0 = Instant::now();
            let reuse = {
                let _span = trace::span("aot/dlopen");
                NativeFabric::load(&so_path, meta)
            };
            match reuse {
                Ok(native) => {
                    passes.push(synth("dlopen", t0));
                    return Ok(Arc::new(native));
                }
                Err(e) => eprintln!(
                    "warning: cached AOT object {} not reusable; recompiling: {e:#}",
                    so_path.display()
                ),
            }
        }
        let mut emitter = self.emitter;
        if emitter == Emitter::Rust && !toolchain::have_rustc() {
            if toolchain::have_cc() {
                eprintln!("warning: rustc not found; 'aot' emitting C and compiling with cc");
                emitter = Emitter::C;
            } else {
                bail!("no native toolchain: neither `rustc` nor `cc` is on PATH");
            }
        }
        if emitter == Emitter::C && !toolchain::have_cc() {
            bail!("no native toolchain: `cc` is not on PATH");
        }

        let t0 = Instant::now();
        faults::inject(faults::point::AOT_CODEGEN).context("aot source emission")?;
        let source = {
            let _span = trace::span("aot/codegen");
            match emitter {
                Emitter::Rust => codegen::emit_rust(nl, self.lanes, &meta.to_words()),
                Emitter::C => codegen::emit_c(nl, self.lanes, &meta.to_words()),
            }
        };
        passes.push(synth("codegen", t0));

        let t0 = Instant::now();
        {
            let _span = trace::span("aot/cc");
            if let Some(dir) = so_path.parent() {
                fs::create_dir_all(dir)
                    .with_context(|| format!("creating AOT cache dir {}", dir.display()))?;
            }
            let pid = std::process::id();
            let src_tmp = sibling(&so_path, &format!("tmp.{pid}.{}", emitter.src_ext()));
            let so_tmp = sibling(&so_path, &format!("tmp.{pid}"));
            let built = (|| -> crate::Result<()> {
                fs::write(&src_tmp, &source)
                    .with_context(|| format!("writing {}", src_tmp.display()))?;
                toolchain::compile(emitter, &src_tmp, &so_tmp)?;
                fs::rename(&so_tmp, &so_path)
                    .with_context(|| format!("publishing {}", so_path.display()))?;
                Ok(())
            })();
            let _ = fs::remove_file(&src_tmp);
            if built.is_err() {
                let _ = fs::remove_file(&so_tmp);
            }
            built?;
        }
        passes.push(synth("cc", t0));

        let t0 = Instant::now();
        let native = {
            let _span = trace::span("aot/dlopen");
            NativeFabric::load(&so_path, meta)
                .with_context(|| format!("loading just-compiled {}", so_path.display()))?
        };
        passes.push(synth("dlopen", t0));
        Ok(Arc::new(native))
    }

    fn program(
        &self,
        nl: Arc<BitNetlist>,
        native: Arc<NativeFabric>,
        passes: Vec<PassReport>,
    ) -> Arc<dyn FabricProgram> {
        Arc::new(AotProgram {
            nl,
            native,
            lanes: self.lanes,
            passes,
            backend: self.emitter.backend_name(),
        })
    }
}

/// `path` with `.suffix` appended (keeping the full original name, so
/// tmp files sort beside their target and never collide with it).
fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".");
    s.push(suffix);
    PathBuf::from(s)
}

impl BackendProvider for AotProvider {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            signed_hidden: false,
            batch_affinity: BatchAffinity::Wide,
            compile_cost: CompileCost::NativeCodegen,
            persistable: true,
            word_lanes: self.lanes,
            fallback: Some("bitsliced"),
        }
    }

    fn compile(
        &self,
        net: Arc<LutNetwork>,
        opt: OptLevel,
        ctx: &ProviderCtx,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        if ctx.aot_disabled {
            bail!("aot compilation disabled (NEURALUT_AOT=off)");
        }
        let base = BitslicedProgram::compile_opt_wide(&net, opt, self.lanes)?;
        let nl = base
            .bit_netlist()
            .expect("bitsliced programs always carry a netlist")
            .clone();
        let mut passes = base.pass_reports().to_vec();
        let native = self.build_or_load(&nl, ctx, &mut passes)?;
        Ok(self.program(nl, native, passes))
    }

    fn load_persisted(
        &self,
        _net: Arc<LutNetwork>,
        nl: Arc<BitNetlist>,
        ctx: &ProviderCtx,
    ) -> crate::Result<Arc<dyn FabricProgram>> {
        if ctx.aot_disabled {
            bail!("aot compilation disabled (NEURALUT_AOT=off)");
        }
        // The netlist came out of a validated `.nfab`; the `.so` beside
        // it is reused when fresh and silently rebuilt when stale,
        // truncated, or missing.
        let mut passes = Vec::new();
        let native = self.build_or_load(&nl, ctx, &mut passes)?;
        Ok(self.program(nl, native, passes))
    }
}

/// Compile-once artifact of the AOT backends: the lowered netlist (for
/// persistence and inspection) plus the loaded native object every
/// executor calls into.
pub struct AotProgram {
    nl: Arc<BitNetlist>,
    native: Arc<NativeFabric>,
    lanes: usize,
    passes: Vec<PassReport>,
    backend: &'static str,
}

impl FabricProgram for AotProgram {
    fn executor(&self) -> Box<dyn InferenceBackend> {
        Box::new(AotEngine {
            nl: self.nl.clone(),
            native: self.native.clone(),
            lanes: self.lanes,
            backend: self.backend,
        })
    }

    fn bit_netlist(&self) -> Option<&Arc<BitNetlist>> {
        Some(&self.nl)
    }

    fn pass_reports(&self) -> &[PassReport] {
        &self.passes
    }

    fn plane_lanes(&self) -> Option<usize> {
        Some(self.lanes)
    }
}

/// Per-worker executor over a loaded native object. Mirrors the
/// bitsliced interpreter's batch protocol exactly — same quantization,
/// same plane layout, same shard boundaries — with the level loop
/// replaced by one call into generated code per block.
pub struct AotEngine {
    nl: Arc<BitNetlist>,
    native: Arc<NativeFabric>,
    lanes: usize,
    backend: &'static str,
}

impl AotEngine {
    /// Samples evaluated per native call: 64 per plane word.
    fn block_lanes(&self) -> usize {
        64 * self.lanes
    }

    fn scratch(&self) -> (Vec<u64>, Vec<u64>) {
        (
            vec![0u64; self.nl.max_planes.max(1) * self.lanes],
            vec![0u64; self.nl.max_wires * self.lanes],
        )
    }

    /// Evaluate a contiguous range of blocks into `out`, which covers
    /// samples `blocks.start * block_lanes .. min(batch, blocks.end * block_lanes)`.
    fn run_blocks(
        &self,
        x: &[f32],
        blocks: std::ops::Range<usize>,
        batch: usize,
        planes: &mut [u64],
        buf: &mut [u64],
        out: &mut [i16],
    ) {
        let n_class = self.nl.n_class;
        let per_block = self.block_lanes();
        let base_sample = blocks.start * per_block;
        for block in blocks {
            let lanes_here = per_block.min(batch - block * per_block);
            self.transpose_in(x, block, lanes_here, planes);
            // Safety: `planes` holds max_planes and `buf` max_wires
            // N-word slots (see `scratch`), which is the generated
            // code's documented requirement; meta validation pinned the
            // object to exactly this netlist and lane width.
            unsafe { (self.native.eval)(planes.as_mut_ptr(), buf.as_mut_ptr()) };
            let lo = (block * per_block - base_sample) * n_class;
            self.transpose_out(planes, lanes_here, &mut out[lo..lo + lanes_here * n_class]);
        }
    }

    /// Transpose quantized input codes of one block into flat
    /// bit-planes — sample `s` lands in bit `s & 63` of word `s >> 6`
    /// of each plane, plane `i` at `planes[i * N..]`.
    fn transpose_in(&self, x: &[f32], block: usize, lanes: usize, planes: &mut [u64]) {
        let n = self.lanes;
        let in_sz = self.nl.input_size;
        let in_bits = self.nl.input_bits;
        planes[..in_sz * in_bits * n].fill(0);
        for s in 0..lanes {
            let sample = block * self.block_lanes() + s;
            let row = &x[sample * in_sz..(sample + 1) * in_sz];
            let word = s >> 6;
            let lane_bit = 1u64 << (s & 63);
            for (i, &v) in row.iter().enumerate() {
                let mut code = quantize_input(v, in_bits);
                let mut b = 0usize;
                while code != 0 {
                    if code & 1 == 1 {
                        planes[(i * in_bits + b) * n + word] |= lane_bit;
                    }
                    code >>= 1;
                    b += 1;
                }
            }
        }
    }

    /// Transpose logit bit-planes back into per-sample signed codes.
    fn transpose_out(&self, planes: &[u64], lanes: usize, out: &mut [i16]) {
        let n = self.lanes;
        let lb = self.nl.logit_bits;
        let n_class = self.nl.n_class;
        let shift = 16 - lb as u32;
        for c in 0..n_class {
            for w in 0..n {
                let lo_s = w * 64;
                if lo_s >= lanes {
                    break;
                }
                let n_here = 64.min(lanes - lo_s);
                let mut raw = [0u16; 64];
                for b in 0..lb {
                    let word = planes[(c * lb + b) * n + w];
                    for (s, r) in raw.iter_mut().enumerate().take(n_here) {
                        *r |= (((word >> s) & 1) as u16) << b;
                    }
                }
                for (s, &r) in raw.iter().enumerate().take(n_here) {
                    out[(lo_s + s) * n_class + c] = if self.nl.signed_logits {
                        ((r << shift) as i16) >> shift
                    } else {
                        r as i16
                    };
                }
            }
        }
    }
}

impl InferenceBackend for AotEngine {
    fn name(&self) -> &'static str {
        self.backend
    }

    fn latency_cycles(&self) -> usize {
        self.nl.levels.len()
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        let in_sz = self.nl.input_size;
        assert_eq!(x.len() % in_sz, 0, "ragged batch");
        let batch = x.len() / in_sz;
        let n_class = self.nl.n_class;
        let per_block = self.block_lanes();
        let n_blocks = batch.div_ceil(per_block);
        let mut logit_codes = vec![0i16; batch * n_class];
        if n_blocks >= PARALLEL_BLOCK_THRESHOLD {
            let shards = pool::parallel_ranges(n_blocks, pool::num_threads(), |_, range| {
                if range.is_empty() {
                    return (0, Vec::new());
                }
                let (mut planes, mut buf) = self.scratch();
                let first = range.start * per_block;
                let count = batch.min(range.end * per_block) - first;
                let mut out = vec![0i16; count * n_class];
                self.run_blocks(x, range, batch, &mut planes, &mut buf, &mut out);
                (first, out)
            });
            for (first, shard) in shards {
                logit_codes[first * n_class..first * n_class + shard.len()]
                    .copy_from_slice(&shard);
            }
        } else {
            let (mut planes, mut buf) = self.scratch();
            self.run_blocks(x, 0..n_blocks, batch, &mut planes, &mut buf, &mut logit_codes);
        }
        SimResult::from_logit_codes(logit_codes, n_class, self.latency_cycles())
    }
}

/// Is any system compiler available for AOT builds? (CI and benches key
/// their clean-skip on this.)
pub fn toolchain_available() -> bool {
    toolchain::available()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;
    use crate::netlist::Simulator;

    fn small_net() -> LutNetwork {
        random_network(71, 8, 2, &[6, 3], 3, 2, 4)
    }

    fn tmp_cache(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("neuralut_aot_unit_{name}"));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn fingerprint_tracks_the_op_stream() {
        let net = small_net();
        let mut nl = super::super::lower::lower(&net).unwrap();
        let a = fingerprint(&nl);
        nl.levels[0].ops[0].sel ^= 1;
        let b = fingerprint(&nl);
        assert_ne!(a, b, "a changed op must change the fingerprint");
    }

    #[test]
    fn so_paths_prefer_cache_dir_then_companion_then_temp() {
        let p = AotProvider::with_lanes(Emitter::Rust, 2);
        let mut ctx = ProviderCtx { model_digest: 0xD, ..Default::default() };
        ctx.aot_cache_dir = Some(PathBuf::from("/cache"));
        ctx.artifact_path = Some(PathBuf::from("/models/net.nfab"));
        assert_eq!(p.so_path(&ctx), PathBuf::from("/cache/000000000000000d.x2.aot.so"));
        ctx.aot_cache_dir = None;
        assert_eq!(
            p.so_path(&ctx),
            PathBuf::from("/models/net.000000000000000d.aot.so")
        );
        ctx.artifact_path = None;
        assert!(p.so_path(&ctx).ends_with("neuralut-aot/000000000000000d.x2.aot.so"));
    }

    #[test]
    fn emitters_declare_the_abi_surface() {
        let net = small_net();
        let nl = super::super::lower::lower(&net).unwrap();
        let meta = SoMeta::for_netlist(&nl, 7, 2).to_words();
        for src in [codegen::emit_c(&nl, 2, &meta), codegen::emit_rust(&nl, 2, &meta)] {
            assert!(src.contains("neuralut_meta"), "meta export missing");
            assert!(src.contains("neuralut_eval"), "eval export missing");
            assert!(src.contains(&format!("{}", meta[2])), "fingerprint not embedded");
        }
    }

    #[test]
    fn c_emitter_compiles_runs_and_caches_bit_exactly() {
        if !toolchain::have_cc() {
            eprintln!("skipping: no `cc` on this host");
            return;
        }
        let net = Arc::new(small_net());
        let dir = tmp_cache("roundtrip");
        let ctx = ProviderCtx {
            model_digest: net.digest(),
            aot_cache_dir: Some(dir.clone()),
            ..Default::default()
        };
        let provider = AotProvider::with_lanes(Emitter::C, 1);
        let program = provider.compile(net.clone(), OptLevel::O2, &ctx).unwrap();
        let engine = program.executor();
        let x: Vec<f32> = (0..70 * net.input_size)
            .map(|i| (i % 97) as f32 / 96.0)
            .collect();
        let want = Simulator::new(&net).simulate_batch(&x);
        let got = engine.run_batch(&x);
        assert_eq!(got.logit_codes, want.logit_codes, "aot-c vs scalar logits");
        assert_eq!(got.predictions, want.predictions);
        // Second compile must reuse the published object: its pass list
        // is dlopen-only.
        let again = provider.compile(net, OptLevel::O2, &ctx).unwrap();
        let aot_passes: Vec<&str> = again
            .pass_reports()
            .iter()
            .map(|p| p.name.as_str())
            .filter(|n| matches!(*n, "codegen" | "cc" | "dlopen"))
            .collect();
        assert_eq!(aot_passes, ["dlopen"], "cache hit must skip codegen and cc");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_ctx_refuses_before_touching_the_toolchain() {
        let net = Arc::new(small_net());
        let ctx = ProviderCtx { aot_disabled: true, ..Default::default() };
        let err = AotProvider::new(Emitter::Rust)
            .compile(net, OptLevel::O1, &ctx)
            .unwrap_err();
        assert!(err.to_string().contains("NEURALUT_AOT=off"), "got: {err:#}");
    }
}
