//! System-compiler invocation for the AOT backend.
//!
//! Two toolchains are probed, once per process, by running
//! `<tool> --version`: `rustc` (the `aot` backend's first choice) and
//! the platform C compiler `cc` (the `aot-c` backend, and the silent
//! fallback `aot` takes when `rustc` is absent — common in deployment
//! containers that ship only a libc toolchain). Probe results are
//! cached in `OnceLock`s so a missing tool costs one failed spawn per
//! process, not one per compile.
//!
//! Invocations write to a caller-chosen temp path; the caller renames
//! into place on success (same atomic-publish discipline as
//! [`crate::fabric::artifact`]'s writer), so a crashed or failed
//! compile can never leave a half-written `.so` where a later process
//! would `dlopen` it.
//!
//! Faults: [`compile`] routes through the
//! [`aot.cc`](crate::util::faults::point::AOT_CC) injection point
//! before spawning anything, which is how chaos tests simulate a broken
//! toolchain and exercise the degrade-to-`bitsliced` path.

use std::path::Path;
use std::process::Command;
use std::sync::OnceLock;

use anyhow::{bail, Context};

use crate::util::faults;

use super::Emitter;

static HAVE_RUSTC: OnceLock<bool> = OnceLock::new();
static HAVE_CC: OnceLock<bool> = OnceLock::new();

fn probe(tool: &str) -> bool {
    Command::new(tool)
        .arg("--version")
        .output()
        .map(|o| o.status.success())
        .unwrap_or(false)
}

/// Is `rustc` on `PATH`? Probed once per process.
pub(crate) fn have_rustc() -> bool {
    *HAVE_RUSTC.get_or_init(|| probe("rustc"))
}

/// Is the system C compiler (`cc`) on `PATH`? Probed once per process.
pub(crate) fn have_cc() -> bool {
    *HAVE_CC.get_or_init(|| probe("cc"))
}

/// Is *any* usable toolchain present? (What CI's `aot` job keys its
/// clean-skip on.)
pub(crate) fn available() -> bool {
    have_rustc() || have_cc()
}

/// Compile `src` into the shared object `out` with the emitter's
/// toolchain. `out` should be a temp path the caller renames into place
/// afterwards. On failure the tail of the compiler's stderr is folded
/// into the error so a codegen bug surfaces as more than "exit 1".
pub(crate) fn compile(emitter: Emitter, src: &Path, out: &Path) -> crate::Result<()> {
    faults::inject(faults::point::AOT_CC)
        .with_context(|| format!("compiling {}", src.display()))?;
    let (tool, output) = match emitter {
        Emitter::Rust => (
            "rustc",
            Command::new("rustc")
                .args(["--edition", "2021", "--crate-type", "cdylib"])
                .args(["-C", "opt-level=3", "-C", "debuginfo=0"])
                .arg("-o")
                .arg(out)
                .arg(src)
                .output(),
        ),
        Emitter::C => (
            "cc",
            Command::new("cc")
                .args(["-O2", "-shared", "-fPIC", "-o"])
                .arg(out)
                .arg(src)
                .output(),
        ),
    };
    let output = output.with_context(|| format!("spawning {tool} for {}", src.display()))?;
    if !output.status.success() {
        let stderr = String::from_utf8_lossy(&output.stderr);
        let tail: String = stderr
            .lines()
            .rev()
            .take(12)
            .collect::<Vec<_>>()
            .into_iter()
            .rev()
            .collect::<Vec<_>>()
            .join("\n");
        bail!(
            "{tool} failed ({}) compiling {}:\n{tail}",
            output.status,
            src.display()
        );
    }
    Ok(())
}
