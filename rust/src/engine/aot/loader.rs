//! Minimal `dlopen`/`dlsym` FFI shim — no `libloading`, just the four
//! libdl entry points the AOT backend needs, wrapped in a RAII handle.
//!
//! Everything here is deliberately small: [`Library`] opens a shared
//! object with `RTLD_NOW` (so a truncated or mis-linked `.so` fails at
//! open time, not mid-inference), resolves symbols with the
//! `dlerror`-clearing dance the manpage prescribes, and `dlclose`s on
//! drop. The handle is `Send + Sync` — the loaded code segment is
//! immutable and the exported data (`neuralut_meta`) is read-only — so
//! one [`Library`] can back every worker's executor behind an `Arc`.
//!
//! Faults: [`Library::open`] routes through the
//! [`aot.dlopen`](crate::util::faults::point::AOT_DLOPEN) injection
//! point, which is how chaos tests simulate a corrupt artifact without
//! manufacturing one.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::faults;

#[cfg(unix)]
mod ffi {
    use std::ffi::{c_char, c_int, c_void};

    #[link(name = "dl")]
    extern "C" {
        pub fn dlopen(filename: *const c_char, flags: c_int) -> *mut c_void;
        pub fn dlsym(handle: *mut c_void, symbol: *const c_char) -> *mut c_void;
        pub fn dlclose(handle: *mut c_void) -> c_int;
        pub fn dlerror() -> *mut c_char;
    }

    /// Resolve all symbols at open time — corruption fails fast.
    pub const RTLD_NOW: c_int = 2;
}

#[cfg(unix)]
fn last_dl_error() -> String {
    // Safety: dlerror returns a thread-local, NUL-terminated C string
    // (or null when no error is pending); we copy it out immediately.
    unsafe {
        let p = ffi::dlerror();
        if p.is_null() {
            "unknown dlerror".to_string()
        } else {
            std::ffi::CStr::from_ptr(p).to_string_lossy().into_owned()
        }
    }
}

/// An open shared object. Closed (`dlclose`) when dropped; symbols
/// resolved from it must not outlive it, which the AOT backend
/// guarantees by keeping the `Library` inside the same struct as every
/// function pointer taken from it.
pub(crate) struct Library {
    #[cfg(unix)]
    handle: *mut std::ffi::c_void,
    path: PathBuf,
}

// Safety: the mapped segments are immutable after RTLD_NOW resolution
// and libdl handles are usable from any thread; dlclose in Drop runs
// exactly once because Library is not Clone.
unsafe impl Send for Library {}
unsafe impl Sync for Library {}

impl Library {
    /// `dlopen` a shared object with `RTLD_NOW`.
    pub(crate) fn open(path: &Path) -> crate::Result<Library> {
        faults::inject(faults::point::AOT_DLOPEN)
            .with_context(|| format!("loading {}", path.display()))?;
        #[cfg(unix)]
        {
            use std::os::unix::ffi::OsStrExt;
            let cpath = std::ffi::CString::new(path.as_os_str().as_bytes())
                .with_context(|| format!("NUL byte in path {}", path.display()))?;
            // Safety: cpath is a valid NUL-terminated string for the call.
            let handle = unsafe { ffi::dlopen(cpath.as_ptr(), ffi::RTLD_NOW) };
            if handle.is_null() {
                anyhow::bail!("dlopen {}: {}", path.display(), last_dl_error());
            }
            Ok(Library { handle, path: path.to_path_buf() })
        }
        #[cfg(not(unix))]
        {
            anyhow::bail!(
                "the aot backend needs dlopen; {} cannot be loaded on this platform",
                path.display()
            )
        }
    }

    /// Resolve an exported symbol, distinguishing "symbol missing" from
    /// "symbol legitimately at address zero" via the pending `dlerror`.
    pub(crate) fn sym(&self, name: &str) -> crate::Result<*mut std::ffi::c_void> {
        #[cfg(unix)]
        {
            let cname = std::ffi::CString::new(name)
                .with_context(|| format!("NUL byte in symbol name '{name}'"))?;
            // Safety: handle is live (we own it), cname is NUL-terminated.
            // dlerror() first to clear any stale error, then check after.
            unsafe {
                ffi::dlerror();
                let p = ffi::dlsym(self.handle, cname.as_ptr());
                let err = ffi::dlerror();
                if !err.is_null() {
                    anyhow::bail!(
                        "dlsym '{name}' in {}: {}",
                        self.path.display(),
                        std::ffi::CStr::from_ptr(err).to_string_lossy()
                    );
                }
                Ok(p)
            }
        }
        #[cfg(not(unix))]
        {
            let _ = name;
            unreachable!("Library cannot be constructed on non-Unix hosts")
        }
    }
}

impl Drop for Library {
    fn drop(&mut self) {
        #[cfg(unix)]
        // Safety: handle came from a successful dlopen and is dropped
        // exactly once.
        unsafe {
            ffi::dlclose(self.handle);
        }
    }
}

impl std::fmt::Debug for Library {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Library").field("path", &self.path).finish()
    }
}
