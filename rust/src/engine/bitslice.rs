//! Bitsliced execution of a compiled [`BitNetlist`]: 64 samples per word.
//!
//! A batch is cut into 64-sample blocks. Each block's quantized input
//! codes are transposed into bit-planes (one `u64` per wire, lane `s` =
//! sample `s` of the block), the levelized word-op program streams the
//! planes through every circuit layer, and the logit planes are transposed
//! back into per-sample signed codes. Every lane is independent, so a
//! ragged tail block simply ignores its unused lanes.
//!
//! Hot loop: one fused mux per op — `dst = lo ^ (sel & (hi ^ lo))` — over
//! a flat `u64` scratch buffer; no dispatch, no branches, working set =
//! the program (streamed sequentially) + one plane buffer (L1-resident
//! for paper-scale circuits). Blocks shard across threads with
//! [`crate::util::pool`], mirroring the scalar simulator's batching.

use std::sync::Arc;

use crate::luts::LutNetwork;
use crate::netlist::{quantize_input, SimResult};
use crate::util::pool;

use super::lower::{self, BitNetlist, W_INPUTS};

/// Batch size below which blocks run inline (thread spawn ~10 us doesn't
/// amortize over a handful of 64-sample blocks).
const PARALLEL_THRESHOLD: usize = 512;

/// The compiled-fabric inference engine: a cheap executor over a shared,
/// compile-once program. The expensive artifact is the [`BitNetlist`]
/// behind the `Arc` — N serving workers each hold their own
/// `BitslicedEngine` but stream the *same* compiled program, so a server
/// start runs the lowering pass exactly once regardless of worker count.
pub struct BitslicedEngine {
    nl: Arc<BitNetlist>,
}

/// Per-worker scratch: wire buffer + inter-level plane buffer.
struct Scratch {
    buf: Vec<u64>,
    planes: Vec<u64>,
}

impl Scratch {
    fn new(nl: &BitNetlist) -> Self {
        Scratch {
            buf: vec![0u64; nl.max_wires],
            planes: vec![0u64; nl.max_planes.max(1)],
        }
    }
}

impl BitslicedEngine {
    /// Compile a network — lowering pass plus the default-level
    /// [`opt`](super::opt) pipeline; see [`lower::lower`] for the
    /// conditions under which compilation fails.
    pub fn compile(net: &LutNetwork) -> crate::Result<Self> {
        let mut nl = lower::lower(net)?;
        super::opt::optimize(&mut nl, super::opt::OptLevel::default());
        Ok(Self::from_program(Arc::new(nl)))
    }

    /// Wrap an already-compiled program — the per-worker constructor: no
    /// lowering pass, no copies, just another reference to the shared
    /// `BitNetlist`. Debug builds re-check the program's structural
    /// invariants (the evaluator indexes scratch buffers with them).
    pub fn from_program(nl: Arc<BitNetlist>) -> Self {
        nl.debug_check();
        BitslicedEngine { nl }
    }

    /// The shared compiled program this executor streams.
    pub fn program(&self) -> &Arc<BitNetlist> {
        &self.nl
    }

    /// The compiled representation (inspection, cost reporting).
    pub fn netlist(&self) -> &BitNetlist {
        &self.nl
    }

    /// Pipeline latency in cycles — same fabric model as the scalar
    /// simulator: one cycle per L-LUT layer.
    pub fn latency_cycles(&self) -> usize {
        self.nl.levels.len()
    }

    /// Run a batch of raw feature rows (`[batch * input_size]` floats in
    /// [0, 1]); bit-exact against `netlist::Simulator::simulate_batch`.
    pub fn run_batch(&self, x: &[f32]) -> SimResult {
        let in_sz = self.nl.input_size;
        assert_eq!(x.len() % in_sz, 0, "ragged batch");
        let batch = x.len() / in_sz;
        let n_class = self.nl.n_class;
        let mut logit_codes = vec![0i16; batch * n_class];
        let n_blocks = batch.div_ceil(64);

        if batch < PARALLEL_THRESHOLD {
            let mut scratch = Scratch::new(&self.nl);
            for block in 0..n_blocks {
                let lanes = 64.min(batch - block * 64);
                let lo = block * 64 * n_class;
                self.run_block(x, block, lanes, &mut scratch,
                               &mut logit_codes[lo..lo + lanes * n_class]);
            }
        } else {
            let shards = pool::parallel_ranges(
                n_blocks,
                pool::num_threads(),
                |_, range| {
                    if range.is_empty() {
                        return (0, Vec::new());
                    }
                    let mut scratch = Scratch::new(&self.nl);
                    let first = range.start * 64;
                    let n = batch.min(range.end * 64) - first;
                    let mut out = vec![0i16; n * n_class];
                    for block in range {
                        let lanes = 64.min(batch - block * 64);
                        let lo = (block * 64 - first) * n_class;
                        self.run_block(x, block, lanes, &mut scratch,
                                       &mut out[lo..lo + lanes * n_class]);
                    }
                    (first, out)
                },
            );
            for (first, shard) in shards {
                logit_codes[first * n_class..first * n_class + shard.len()]
                    .copy_from_slice(&shard);
            }
        }

        SimResult::from_logit_codes(logit_codes, n_class, self.latency_cycles())
    }

    /// Evaluate one 64-sample block into `out` (`lanes * n_class` codes).
    fn run_block(&self, x: &[f32], block: usize, lanes: usize,
                 scratch: &mut Scratch, out: &mut [i16]) {
        let nl = &self.nl;
        let in_sz = nl.input_size;
        let in_bits = nl.input_bits;
        let planes = &mut scratch.planes;
        let buf = &mut scratch.buf;

        // Transpose: quantized input codes -> bit-planes.
        let n_in_planes = in_sz * in_bits;
        planes[..n_in_planes].fill(0);
        for s in 0..lanes {
            let row = &x[(block * 64 + s) * in_sz..(block * 64 + s + 1) * in_sz];
            let lane_bit = 1u64 << s;
            for (i, &v) in row.iter().enumerate() {
                let mut code = quantize_input(v, in_bits);
                let mut b = 0usize;
                while code != 0 {
                    if code & 1 == 1 {
                        planes[i * in_bits + b] |= lane_bit;
                    }
                    code >>= 1;
                    b += 1;
                }
            }
        }

        // Stream the levelized program.
        buf[0] = 0;
        buf[1] = !0u64;
        for level in &nl.levels {
            let base = W_INPUTS as usize;
            buf[base..base + level.n_in_planes]
                .copy_from_slice(&planes[..level.n_in_planes]);
            for op in &level.ops {
                let h = buf[op.hi as usize];
                let l = buf[op.lo as usize];
                buf[op.dst as usize] = l ^ (buf[op.sel as usize] & (h ^ l));
            }
            for (p, &w) in level.outputs.iter().enumerate() {
                planes[p] = buf[w as usize];
            }
        }

        // Transpose back: logit bit-planes -> per-sample signed codes.
        let lb = nl.logit_bits;
        let shift = 16 - lb as u32;
        for c in 0..nl.n_class {
            let mut raw = [0u16; 64];
            for b in 0..lb {
                let word = planes[c * lb + b];
                for (s, r) in raw.iter_mut().enumerate().take(lanes) {
                    *r |= (((word >> s) & 1) as u16) << b;
                }
            }
            for (s, &r) in raw.iter().enumerate().take(lanes) {
                out[s * nl.n_class + c] = if nl.signed_logits {
                    ((r << shift) as i16) >> shift
                } else {
                    r as i16
                };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;
    use crate::netlist::Simulator;

    fn assert_matches_scalar(seed: u64, input: usize, bits: usize,
                             widths: &[usize], fan_in: usize, beta: usize,
                             batch: usize) {
        let net = random_network(seed, input, bits, widths, fan_in, beta, 4);
        let sim = Simulator::new(&net);
        let eng = BitslicedEngine::compile(&net).unwrap();
        let x: Vec<f32> = (0..batch * input)
            .map(|i| (i % 89) as f32 / 89.0)
            .collect();
        let a = sim.simulate_batch(&x);
        let b = eng.run_batch(&x);
        assert_eq!(a.logit_codes, b.logit_codes);
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert_eq!(a.total_cycles, b.total_cycles);
    }

    #[test]
    fn matches_scalar_on_single_sample() {
        assert_matches_scalar(3, 12, 2, &[8, 4], 3, 2, 1);
    }

    #[test]
    fn matches_scalar_on_exact_block() {
        assert_matches_scalar(4, 10, 3, &[6, 5, 3], 2, 2, 64);
    }

    #[test]
    fn matches_scalar_on_ragged_blocks() {
        for batch in [63, 65, 130, 257] {
            assert_matches_scalar(5, 8, 2, &[6, 3], 3, 2, batch);
        }
    }

    #[test]
    fn matches_scalar_on_parallel_batches() {
        assert_matches_scalar(6, 16, 2, &[12, 6, 4], 3, 2, 1000);
    }

    #[test]
    fn empty_batch_is_well_formed() {
        let net = random_network(7, 6, 2, &[4, 2], 2, 2, 4);
        let eng = BitslicedEngine::compile(&net).unwrap();
        let r = eng.run_batch(&[]);
        assert!(r.predictions.is_empty() && r.logit_codes.is_empty());
    }

    #[test]
    fn executors_from_one_program_share_it_and_agree() {
        let net = random_network(8, 6, 2, &[4, 2], 2, 2, 4);
        let prog = Arc::new(lower::lower(&net).unwrap());
        let a = BitslicedEngine::from_program(prog.clone());
        let b = BitslicedEngine::from_program(prog.clone());
        assert!(Arc::ptr_eq(a.program(), b.program()));
        assert!(Arc::ptr_eq(a.program(), &prog));
        assert_eq!(Arc::strong_count(&prog), 3);
        let x: Vec<f32> = (0..6 * 65).map(|i| (i % 7) as f32 / 7.0).collect();
        let ra = a.run_batch(&x);
        let rb = b.run_batch(&x);
        assert_eq!(ra.logit_codes, rb.logit_codes);
        assert_eq!(ra.predictions, rb.predictions);
    }
}
