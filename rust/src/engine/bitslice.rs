//! Bitsliced execution of a compiled [`BitNetlist`]: SIMD-wide bit-plane
//! evaluation, 64·N samples per block.
//!
//! The plane word is `[u64; N]` with `N` ∈ {1, 2, 4, 8}, so one block
//! evaluates 64·N samples at once (64 for the classic `u64` engine, up
//! to 512 for `bitsliced-x8`). A batch is cut into blocks; each block's
//! quantized input codes are transposed into bit-planes (sample `s` of
//! the block lands in bit `s & 63` of word `s >> 6`), the levelized
//! word-op program streams the planes through every circuit layer, and
//! the logit planes are transposed back into per-sample signed codes.
//! Every lane is independent, so a ragged tail block simply ignores its
//! unused lanes.
//!
//! Hot loop: one fused mux per op — `dst = lo ^ (sel & (hi ^ lo))` —
//! applied word-wise across the `N` lanes of the plane. The inner loop
//! indexes fixed-size arrays element-by-element with no `unsafe`, which
//! lets the compiler autovectorize it onto whatever vector width the
//! target has (SSE2/NEON for x2, AVX2 for x4, AVX-512 for x8).
//!
//! Width selection: `N = 1` is always safe; wider planes divide the
//! per-sample interpreter overhead (op decode, wire loads) by `N` but
//! multiply live plane bytes by `N`, so on shallow nets where the
//! input/output transpose dominates, or on nets whose working set
//! already presses L2, wider is not automatically faster. The registry's
//! `bitsliced-auto` alias resolves to [`detect_lane_words`]'s pick for
//! the host CPU before anything is compiled or persisted.
//!
//! Batch execution is *level-blocked*: blocks are processed in
//! super-blocks of up to [`MAX_LEVEL_BLOCK`] blocks sized so the live
//! planes of the group fit a [`LEVEL_BLOCK_BUDGET`] cache budget, and
//! within a super-block the levels run on the *outside* — one level's op
//! list streams over every block of the group before the next level
//! starts, so on deep nets with large programs the ops (the big stream)
//! stay hot in L1/L2 across the group instead of being re-fetched per
//! block. Large batches additionally shard groups of blocks across
//! threads with [`crate::util::pool`]; every shard offset is derived
//! from the engine's `LANES` constant, never a literal word width.

use std::sync::Arc;

use crate::luts::LutNetwork;
use crate::netlist::{quantize_input, SimResult};
use crate::util::pool;

use super::lower::{self, BitNetlist, Level, W_INPUTS};

/// Block-count threshold at which `run_batch` shards across the worker
/// pool (thread spawn ~10 us doesn't amortize over a handful of
/// blocks). 8 blocks keeps the classic N = 1 cutover at batch 512 and
/// scales it with the lane width, so a wide engine does not pay thread
/// fan-out for a batch that fits a couple of its (larger) blocks.
const PARALLEL_BLOCK_THRESHOLD: usize = 8;

/// Cache budget (bytes) for the live planes of one level-blocked
/// super-block — roughly half a typical per-core L2, leaving room for
/// the op stream itself.
const LEVEL_BLOCK_BUDGET: usize = 256 * 1024;

/// Upper bound on blocks per super-block: past this the op stream is
/// amortized well enough that a larger group only grows latency jitter.
const MAX_LEVEL_BLOCK: usize = 8;

/// Every lane width with a registered backend, narrowest first.
pub const LANE_WIDTHS: [usize; 4] = [1, 2, 4, 8];

/// Registry name of the bitsliced backend with `lanes` `u64` words per
/// plane, or `None` if that width is not a supported instantiation.
pub fn lane_backend_name(lanes: usize) -> Option<&'static str> {
    match lanes {
        1 => Some("bitsliced"),
        2 => Some("bitsliced-x2"),
        4 => Some("bitsliced-x4"),
        8 => Some("bitsliced-x8"),
        _ => None,
    }
}

/// Default plane width (in `u64` words) for the host CPU, used to
/// resolve the `bitsliced-auto` registry alias.
///
/// Policy: on x86_64 an AVX2 machine gets 4 words (one 256-bit vector
/// per plane op); anything older gets 2 (SSE2 is baseline). aarch64
/// gets 2 (NEON is 128-bit). Other targets fall back to 1. The 8-word
/// engine is never auto-picked — 512-bit planes only win when the
/// program is op-streaming-bound and the working set stays small, which
/// is a case to opt into explicitly (`bitsliced-x8`) — but it is always
/// registered and bit-exact.
pub fn detect_lane_words() -> usize {
    detect_lane_words_impl()
}

#[cfg(target_arch = "x86_64")]
fn detect_lane_words_impl() -> usize {
    if std::arch::is_x86_feature_detected!("avx2") {
        4
    } else {
        2
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_lane_words_impl() -> usize {
    2
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_lane_words_impl() -> usize {
    1
}

/// The compiled-fabric inference engine with `N` `u64` words per
/// bit-plane (64·N samples per block): a cheap executor over a shared,
/// compile-once program. The expensive artifact is the [`BitNetlist`]
/// behind the `Arc` — N serving workers each hold their own executor
/// but stream the *same* compiled program, so a server start runs the
/// lowering pass exactly once regardless of worker count. All widths
/// are bit-exact with each other and with the scalar simulator; they
/// differ only in throughput.
pub struct BitslicedEngineN<const N: usize> {
    nl: Arc<BitNetlist>,
    /// Blocks per level-blocked super-block, derived from the program's
    /// peak plane count and the cache budget at construction time.
    level_block: usize,
}

/// The classic one-word engine (64 samples per block) — the default
/// `bitsliced` backend.
pub type BitslicedEngine = BitslicedEngineN<1>;

/// Per-worker scratch: wire buffer + inter-level plane buffers, one
/// `stride`-plane slot per block of a super-block.
struct Scratch<const N: usize> {
    buf: Vec<[u64; N]>,
    planes: Vec<[u64; N]>,
    stride: usize,
}

impl<const N: usize> Scratch<N> {
    fn new(nl: &BitNetlist, level_block: usize) -> Self {
        let stride = nl.max_planes.max(1);
        Scratch {
            buf: vec![[0u64; N]; nl.max_wires],
            planes: vec![[0u64; N]; stride * level_block],
            stride,
        }
    }
}

impl<const N: usize> BitslicedEngineN<N> {
    /// Samples evaluated per block: 64 per plane word.
    pub const LANES: usize = 64 * N;

    /// Compile a network — lowering pass plus the default-level
    /// [`opt`](super::opt) pipeline; see [`lower::lower`] for the
    /// conditions under which compilation fails.
    pub fn compile(net: &LutNetwork) -> crate::Result<Self> {
        let mut nl = lower::lower(net)?;
        super::opt::optimize(&mut nl, super::opt::OptLevel::default());
        Ok(Self::from_program(Arc::new(nl)))
    }

    /// Wrap an already-compiled program — the per-worker constructor: no
    /// lowering pass, no copies, just another reference to the shared
    /// `BitNetlist`. Debug builds re-check the program's structural
    /// invariants (the evaluator indexes scratch buffers with them).
    pub fn from_program(nl: Arc<BitNetlist>) -> Self {
        nl.debug_check();
        let plane_bytes = nl.max_planes.max(1) * N * 8;
        let level_block = (LEVEL_BLOCK_BUDGET / plane_bytes).clamp(1, MAX_LEVEL_BLOCK);
        BitslicedEngineN { nl, level_block }
    }

    /// The shared compiled program this executor streams.
    pub fn program(&self) -> &Arc<BitNetlist> {
        &self.nl
    }

    /// The compiled representation (inspection, cost reporting).
    pub fn netlist(&self) -> &BitNetlist {
        &self.nl
    }

    /// Plane width in `u64` words.
    pub fn lanes(&self) -> usize {
        N
    }

    /// Pipeline latency in cycles — same fabric model as the scalar
    /// simulator: one cycle per L-LUT layer.
    pub fn latency_cycles(&self) -> usize {
        self.nl.levels.len()
    }

    /// Run a batch of raw feature rows (`[batch * input_size]` floats in
    /// [0, 1]); bit-exact against `netlist::Simulator::simulate_batch`.
    /// Shards blocks across the worker pool when the batch spans at
    /// least [`PARALLEL_BLOCK_THRESHOLD`] blocks.
    pub fn run_batch(&self, x: &[f32]) -> SimResult {
        let in_sz = self.nl.input_size;
        assert_eq!(x.len() % in_sz, 0, "ragged batch");
        let batch = x.len() / in_sz;
        let n_blocks = batch.div_ceil(Self::LANES);
        if n_blocks >= PARALLEL_BLOCK_THRESHOLD {
            return self.run_batch_sharded(x, pool::num_threads());
        }
        let n_class = self.nl.n_class;
        let mut logit_codes = vec![0i16; batch * n_class];
        let mut scratch = Scratch::new(&self.nl, self.level_block);
        self.run_blocks(x, 0..n_blocks, batch, &mut scratch, &mut logit_codes);
        SimResult::from_logit_codes(logit_codes, n_class, self.latency_cycles())
    }

    /// Run a batch through the sharded path with an explicit worker
    /// count. Deterministic: shard boundaries depend only on the batch
    /// size, the engine's `LANES`, and `workers`, and every shard writes
    /// a disjoint output range — results are bit-identical to
    /// [`Self::run_batch`] for any worker count. Public so tests can pin
    /// shard-boundary behavior without manufacturing huge batches.
    pub fn run_batch_sharded(&self, x: &[f32], workers: usize) -> SimResult {
        let in_sz = self.nl.input_size;
        assert_eq!(x.len() % in_sz, 0, "ragged batch");
        let batch = x.len() / in_sz;
        let n_class = self.nl.n_class;
        let n_blocks = batch.div_ceil(Self::LANES);
        let mut logit_codes = vec![0i16; batch * n_class];
        let shards = pool::parallel_ranges(n_blocks, workers, |_, range| {
            if range.is_empty() {
                return (0, Vec::new());
            }
            let mut scratch = Scratch::new(&self.nl, self.level_block);
            let first = range.start * Self::LANES;
            let n = batch.min(range.end * Self::LANES) - first;
            let mut out = vec![0i16; n * n_class];
            self.run_blocks(x, range, batch, &mut scratch, &mut out);
            (first, out)
        });
        for (first, shard) in shards {
            logit_codes[first * n_class..first * n_class + shard.len()]
                .copy_from_slice(&shard);
        }
        SimResult::from_logit_codes(logit_codes, n_class, self.latency_cycles())
    }

    /// Evaluate a contiguous range of blocks into `out`, which covers
    /// samples `blocks.start * LANES .. min(batch, blocks.end * LANES)`.
    ///
    /// Blocks are grouped into super-blocks of up to `self.level_block`
    /// blocks; within a group, all inputs are transposed in first, then
    /// each level's op list streams across every block of the group
    /// (levels outer, blocks inner — the op stream stays cache-hot),
    /// then all outputs transpose back out.
    fn run_blocks(
        &self,
        x: &[f32],
        blocks: std::ops::Range<usize>,
        batch: usize,
        scratch: &mut Scratch<N>,
        out: &mut [i16],
    ) {
        let n_class = self.nl.n_class;
        let base_sample = blocks.start * Self::LANES;
        let stride = scratch.stride;
        let planes_all = &mut scratch.planes;
        let buf = &mut scratch.buf;
        let mut b0 = blocks.start;
        while b0 < blocks.end {
            let group = self.level_block.min(blocks.end - b0);
            for g in 0..group {
                let block = b0 + g;
                let lanes = Self::LANES.min(batch - block * Self::LANES);
                self.transpose_in(x, block, lanes, &mut planes_all[g * stride..]);
            }
            for level in &self.nl.levels {
                for g in 0..group {
                    run_level::<N>(level, &mut planes_all[g * stride..], buf);
                }
            }
            for g in 0..group {
                let block = b0 + g;
                let lanes = Self::LANES.min(batch - block * Self::LANES);
                let lo = (block * Self::LANES - base_sample) * n_class;
                self.transpose_out(
                    &planes_all[g * stride..],
                    lanes,
                    &mut out[lo..lo + lanes * n_class],
                );
            }
            b0 += group;
        }
    }

    /// Transpose: quantized input codes of one block -> bit-planes.
    /// Sample `s` of the block lands in bit `s & 63` of word `s >> 6`.
    fn transpose_in(&self, x: &[f32], block: usize, lanes: usize, planes: &mut [[u64; N]]) {
        let in_sz = self.nl.input_size;
        let in_bits = self.nl.input_bits;
        planes[..in_sz * in_bits].fill([0u64; N]);
        for s in 0..lanes {
            let sample = block * Self::LANES + s;
            let row = &x[sample * in_sz..(sample + 1) * in_sz];
            let word = s >> 6;
            let lane_bit = 1u64 << (s & 63);
            for (i, &v) in row.iter().enumerate() {
                let mut code = quantize_input(v, in_bits);
                let mut b = 0usize;
                while code != 0 {
                    if code & 1 == 1 {
                        planes[i * in_bits + b][word] |= lane_bit;
                    }
                    code >>= 1;
                    b += 1;
                }
            }
        }
    }

    /// Transpose back: logit bit-planes of one block -> per-sample
    /// signed codes (`lanes * n_class` entries of `out`).
    fn transpose_out(&self, planes: &[[u64; N]], lanes: usize, out: &mut [i16]) {
        let lb = self.nl.logit_bits;
        let n_class = self.nl.n_class;
        let shift = 16 - lb as u32;
        for c in 0..n_class {
            for w in 0..N {
                let lo_s = w * 64;
                if lo_s >= lanes {
                    break;
                }
                let n_here = 64.min(lanes - lo_s);
                let mut raw = [0u16; 64];
                for b in 0..lb {
                    let word = planes[c * lb + b][w];
                    for (s, r) in raw.iter_mut().enumerate().take(n_here) {
                        *r |= (((word >> s) & 1) as u16) << b;
                    }
                }
                for (s, &r) in raw.iter().enumerate().take(n_here) {
                    out[(lo_s + s) * n_class + c] = if self.nl.signed_logits {
                        ((r << shift) as i16) >> shift
                    } else {
                        r as i16
                    };
                }
            }
        }
    }
}

/// Stream one level's op list over a single block's planes. `buf` is the
/// wire file: wire 0 = all-zeros, wire 1 = all-ones, then the level's
/// input planes, then one wire per op in order. Levelized SSA guarantees
/// ops only read wires defined earlier in the same level, so nothing
/// stale from a previously-streamed block or level can leak in.
#[inline]
fn run_level<const N: usize>(level: &Level, planes: &mut [[u64; N]], buf: &mut [[u64; N]]) {
    buf[0] = [0u64; N];
    buf[1] = [!0u64; N];
    let base = W_INPUTS as usize;
    buf[base..base + level.n_in_planes].copy_from_slice(&planes[..level.n_in_planes]);
    for op in &level.ops {
        let hv = buf[op.hi as usize];
        let lv = buf[op.lo as usize];
        let sv = buf[op.sel as usize];
        let mut dv = [0u64; N];
        for j in 0..N {
            dv[j] = lv[j] ^ (sv[j] & (hv[j] ^ lv[j]));
        }
        buf[op.dst as usize] = dv;
    }
    for (p, &w) in level.outputs.iter().enumerate() {
        planes[p] = buf[w as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;
    use crate::netlist::Simulator;

    fn assert_matches_scalar(seed: u64, input: usize, bits: usize,
                             widths: &[usize], fan_in: usize, beta: usize,
                             batch: usize) {
        let net = random_network(seed, input, bits, widths, fan_in, beta, 4);
        let sim = Simulator::new(&net);
        let eng = BitslicedEngine::compile(&net).unwrap();
        let x: Vec<f32> = (0..batch * input)
            .map(|i| (i % 89) as f32 / 89.0)
            .collect();
        let a = sim.simulate_batch(&x);
        let b = eng.run_batch(&x);
        assert_eq!(a.logit_codes, b.logit_codes);
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert_eq!(a.total_cycles, b.total_cycles);
    }

    fn assert_matches_scalar_wide<const N: usize>(seed: u64, batches: &[usize]) {
        let net = random_network(seed, 9, 2, &[10, 6, 4], 3, 2, 4);
        let sim = Simulator::new(&net);
        let eng = BitslicedEngineN::<N>::compile(&net).unwrap();
        for &batch in batches {
            let x: Vec<f32> = (0..batch * 9)
                .map(|i| ((i * 29 + 5) % 97) as f32 / 97.0)
                .collect();
            let a = sim.simulate_batch(&x);
            let b = eng.run_batch(&x);
            assert_eq!(a.logit_codes, b.logit_codes, "N {N} seed {seed} batch {batch}");
            assert_eq!(a.predictions, b.predictions, "N {N} seed {seed} batch {batch}");
        }
    }

    #[test]
    fn matches_scalar_on_single_sample() {
        assert_matches_scalar(3, 12, 2, &[8, 4], 3, 2, 1);
    }

    #[test]
    fn matches_scalar_on_exact_block() {
        assert_matches_scalar(4, 10, 3, &[6, 5, 3], 2, 2, 64);
    }

    #[test]
    fn matches_scalar_on_ragged_blocks() {
        for batch in [63, 65, 130, 257] {
            assert_matches_scalar(5, 8, 2, &[6, 3], 3, 2, batch);
        }
    }

    #[test]
    fn matches_scalar_on_parallel_batches() {
        assert_matches_scalar(6, 16, 2, &[12, 6, 4], 3, 2, 1000);
    }

    #[test]
    fn wide_planes_match_scalar_on_boundary_batches() {
        // Every registered width × batches straddling each width's block
        // boundary (and the super-block grouping on the larger ones).
        let batches = [1usize, 63, 64, 65, 127, 128, 129, 255, 257, 511, 513];
        assert_matches_scalar_wide::<1>(10, &batches);
        assert_matches_scalar_wide::<2>(10, &batches);
        assert_matches_scalar_wide::<4>(10, &batches);
        assert_matches_scalar_wide::<8>(10, &batches);
    }

    #[test]
    fn sharded_path_is_bit_exact_at_every_shard_boundary() {
        // Regression pin for the shard-offset arithmetic: ragged tails
        // that straddle shard boundaries must land at the right output
        // offsets for any worker count, on the narrow and wide engines.
        let net = random_network(11, 7, 2, &[8, 4], 3, 2, 4);
        let sim = Simulator::new(&net);
        let e1 = BitslicedEngineN::<1>::compile(&net).unwrap();
        let e4 = BitslicedEngineN::<4>::compile(&net).unwrap();
        for batch in [63usize, 64, 65, 127, 129, 255, 257, 513, 1000] {
            let x: Vec<f32> = (0..batch * 7)
                .map(|i| ((i * 13 + 3) % 61) as f32 / 61.0)
                .collect();
            let want = sim.simulate_batch(&x);
            for workers in [1usize, 2, 8] {
                let got = e1.run_batch_sharded(&x, workers);
                assert_eq!(got.logit_codes, want.logit_codes,
                           "x1 batch {batch} workers {workers}");
                let got = e4.run_batch_sharded(&x, workers);
                assert_eq!(got.logit_codes, want.logit_codes,
                           "x4 batch {batch} workers {workers}");
            }
        }
    }

    #[test]
    fn empty_batch_is_well_formed() {
        let net = random_network(7, 6, 2, &[4, 2], 2, 2, 4);
        let eng = BitslicedEngine::compile(&net).unwrap();
        let r = eng.run_batch(&[]);
        assert!(r.predictions.is_empty() && r.logit_codes.is_empty());
    }

    #[test]
    fn detected_lane_width_is_a_registered_width() {
        let lanes = detect_lane_words();
        assert!(LANE_WIDTHS.contains(&lanes), "detected {lanes}");
        assert!(lane_backend_name(lanes).is_some());
    }

    #[test]
    fn lanes_constant_and_accessors_are_consistent() {
        let net = random_network(9, 8, 2, &[6, 3], 3, 2, 4);
        let e = BitslicedEngineN::<2>::compile(&net).unwrap();
        assert_eq!(e.lanes(), 2);
        assert_eq!(BitslicedEngineN::<2>::LANES, 128);
        assert_eq!(BitslicedEngine::LANES, 64);
        assert!(lane_backend_name(3).is_none());
        assert_eq!(lane_backend_name(8), Some("bitsliced-x8"));
    }

    #[test]
    fn executors_from_one_program_share_it_and_agree() {
        let net = random_network(8, 6, 2, &[4, 2], 2, 2, 4);
        let prog = Arc::new(lower::lower(&net).unwrap());
        let a = BitslicedEngine::from_program(prog.clone());
        let b = BitslicedEngine::from_program(prog.clone());
        assert!(Arc::ptr_eq(a.program(), b.program()));
        assert!(Arc::ptr_eq(a.program(), &prog));
        assert_eq!(Arc::strong_count(&prog), 3);
        let x: Vec<f32> = (0..6 * 65).map(|i| (i % 7) as f32 / 7.0).collect();
        let ra = a.run_batch(&x);
        let rb = b.run_batch(&x);
        assert_eq!(ra.logit_codes, rb.logit_codes);
        assert_eq!(ra.predictions, rb.predictions);
    }
}
