//! Compiled fabric engine: pluggable inference backends over a converted
//! [`LutNetwork`].
//!
//! The paper's premise is that an L-LUT network is a pure Boolean circuit
//! ("each L-LUT layer is evaluated in one clock cycle"). The scalar
//! simulator ([`crate::netlist::Simulator`]) honours that functionally but
//! executes it as per-sample table lookups. This subsystem instead
//! *compiles* the network once — [`lower`] expands every truth table into
//! per-output-bit Boolean functions (support reduction + ROBDD, shared
//! via structural hashing) and emits a levelized [`BitNetlist`] of fused
//! word ops — and then evaluates it bitsliced: 64 independent samples
//! packed per `u64`, batch inference as word-wide AND/OR/XOR streaming
//! ([`BitslicedEngine`]).
//!
//! Both execution strategies sit behind [`InferenceBackend`], so the
//! server, the CLI and the repro examples select a backend by
//! configuration ([`BackendKind`]) rather than by concrete type; future
//! device-specific lowerings slot in behind the same trait.
//!
//! Picking a backend: `Scalar` has zero compile cost and wins on tiny
//! batches and very wide tables; `Bitsliced` pays one lowering pass per
//! network and wins on batch workloads, increasingly so the more
//! structure (small support, shared logic, low fan-in × bit-width) the
//! trained tables carry.

pub mod bitslice;
pub mod lower;

pub use bitslice::BitslicedEngine;
pub use lower::{BitNetlist, Level, MuxOp};

use anyhow::bail;

use crate::luts::LutNetwork;
use crate::netlist::{SimResult, Simulator};

/// Which inference engine executes a converted network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Per-sample scalar table lookups (`netlist::Simulator`).
    #[default]
    Scalar,
    /// Compiled bit-level netlist, 64 samples per word.
    Bitsliced,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Scalar => "scalar",
            BackendKind::Bitsliced => "bitsliced",
        }
    }

    /// The kind selected by the `NEURALUT_ENGINE` environment variable
    /// (`Scalar` when unset) — one definition of the env protocol for
    /// the examples and any other env-driven entry point.
    pub fn from_env() -> crate::Result<BackendKind> {
        match std::env::var("NEURALUT_ENGINE") {
            Ok(v) => v.parse(),
            Err(_) => Ok(BackendKind::Scalar),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> crate::Result<Self> {
        match s {
            "scalar" => Ok(BackendKind::Scalar),
            "bitsliced" => Ok(BackendKind::Bitsliced),
            other => bail!("unknown engine '{other}' (scalar | bitsliced)"),
        }
    }
}

/// A batch-inference execution strategy for one converted network.
///
/// Implementations must be bit-exact with respect to the quantized
/// fabric semantics: identical logit codes, identical argmax predictions.
pub trait InferenceBackend: Send + Sync {
    /// Stable backend name for logs and reports.
    fn name(&self) -> &'static str;

    /// Pipeline latency in cycles (one per L-LUT layer).
    fn latency_cycles(&self) -> usize;

    /// Run raw feature rows (`[batch * input_size]` floats in [0, 1]).
    fn run_batch(&self, x: &[f32]) -> SimResult;

    /// Classification accuracy over a labelled set.
    fn accuracy(&self, x: &[f32], y: &[i32]) -> f64 {
        let r = self.run_batch(x);
        let correct = r
            .predictions
            .iter()
            .zip(y)
            .filter(|(&p, &t)| p as i32 == t)
            .count();
        correct as f64 / y.len().max(1) as f64
    }
}

impl<'a> InferenceBackend for Simulator<'a> {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn latency_cycles(&self) -> usize {
        Simulator::latency_cycles(self)
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        self.simulate_batch(x)
    }
}

impl InferenceBackend for BitslicedEngine {
    fn name(&self) -> &'static str {
        "bitsliced"
    }

    fn latency_cycles(&self) -> usize {
        BitslicedEngine::latency_cycles(self)
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        BitslicedEngine::run_batch(self, x)
    }
}

/// Construct the backend of the requested kind for `net`. `Bitsliced`
/// runs the lowering pass here and reports its failures (e.g. layers
/// with inconsistent bit-widths).
pub fn backend<'a>(
    kind: BackendKind,
    net: &'a LutNetwork,
) -> crate::Result<Box<dyn InferenceBackend + 'a>> {
    Ok(match kind {
        BackendKind::Scalar => Box::new(Simulator::new(net)),
        BackendKind::Bitsliced => Box::new(BitslicedEngine::compile(net)?),
    })
}

/// Backend selected by the `NEURALUT_ENGINE` environment variable
/// (`scalar` when unset) — how the repro examples opt into the compiled
/// engine without changing their code paths.
pub fn backend_from_env(net: &LutNetwork) -> crate::Result<Box<dyn InferenceBackend + '_>> {
    backend(BackendKind::from_env()?, net)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;

    #[test]
    fn kind_parses_and_displays() {
        assert_eq!("scalar".parse::<BackendKind>().unwrap(), BackendKind::Scalar);
        assert_eq!(
            "bitsliced".parse::<BackendKind>().unwrap(),
            BackendKind::Bitsliced
        );
        assert!("fpga".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::default(), BackendKind::Scalar);
        assert_eq!(BackendKind::Bitsliced.to_string(), "bitsliced");
    }

    #[test]
    fn both_backends_satisfy_the_trait_identically() {
        let net = random_network(31, 9, 2, &[6, 4], 3, 2, 4);
        let x: Vec<f32> = (0..9 * 100).map(|i| (i % 13) as f32 / 13.0).collect();
        let y: Vec<i32> = (0..100).map(|i| (i % 4) as i32).collect();
        let scalar = backend(BackendKind::Scalar, &net).unwrap();
        let bits = backend(BackendKind::Bitsliced, &net).unwrap();
        assert_eq!(scalar.name(), "scalar");
        assert_eq!(bits.name(), "bitsliced");
        assert_eq!(scalar.latency_cycles(), bits.latency_cycles());
        let a = scalar.run_batch(&x);
        let b = bits.run_batch(&x);
        assert_eq!(a.logit_codes, b.logit_codes);
        assert_eq!(a.predictions, b.predictions);
        assert!((scalar.accuracy(&x, &y) - bits.accuracy(&x, &y)).abs() < 1e-12);
    }
}
