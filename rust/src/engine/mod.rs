//! Execution backends over a converted [`LutNetwork`]: the compiled
//! fabric engine and the traits every backend implements.
//!
//! The paper's premise is that an L-LUT network is a pure Boolean circuit
//! ("each L-LUT layer is evaluated in one clock cycle"). The scalar
//! simulator ([`crate::netlist::Simulator`]) honours that functionally but
//! executes it as per-sample table lookups. This subsystem instead
//! *compiles* the network once — [`lower`] expands every truth table into
//! per-output-bit Boolean functions (support reduction + ROBDD, shared
//! via structural hashing) and emits a levelized [`BitNetlist`] of fused
//! word ops, the [`opt`] pass pipeline then sweeps it like a synthesis
//! flow would (constant folding, cross-level CSE, dead-wire elimination,
//! plane compaction — [`OptLevel`] picks how hard) — and then evaluates
//! it bitsliced: 64·N independent samples packed per `[u64; N]` plane
//! (N ∈ {1, 2, 4, 8}), batch inference as word-wide AND/OR/XOR streaming
//! ([`BitslicedEngineN`], with [`BitslicedEngine`] the classic N = 1).
//!
//! Two traits split the execution contract along the compile/run seam:
//!
//! * [`FabricProgram`] is the **compile-once artifact** — the expensive
//!   shared state (the network, and for the bitsliced backend the lowered
//!   program) held behind `Arc`s, from which any number of cheap
//!   [`executor`](FabricProgram::executor)s can be spawned. N serving
//!   workers share one program; one lowering pass per
//!   [`Model::compile`](crate::fabric::Model::compile).
//! * [`InferenceBackend`] is the **per-worker executor** — `'static`,
//!   owned outright by a worker thread, bit-exact against the scalar
//!   fabric semantics.
//!
//! Backends are selected *by name* through the
//! [`BackendRegistry`](crate::fabric::BackendRegistry); `scalar`
//! ([`ScalarProgram`]) and the `bitsliced` width family
//! (`bitsliced`, `bitsliced-x2`, `bitsliced-x4`, `bitsliced-x8` — all
//! [`BitslicedProgram`]s differing only in plane width) are the
//! registered built-ins, plus the `bitsliced-auto` alias that resolves
//! to [`detect_lane_words`]'s pick for the host CPU, plus the [`aot`]
//! native-code pair (`aot`, `aot-c`) that compiles the same lowered
//! netlist through the system compiler and degrades to `bitsliced`
//! when no toolchain is present. Nothing in this module enumerates
//! backends — a new execution strategy is a registry entry, not a
//! cross-crate surgery.
//!
//! Picking a backend: `scalar` has zero compile cost and wins on tiny
//! batches and very wide tables; the `bitsliced` widths pay one lowering
//! pass per network and win on batch workloads, increasingly so the more
//! structure (small support, shared logic, low fan-in × bit-width) the
//! trained tables carry. Wider planes divide interpreter overhead per
//! sample but grow the cache working set — see [`bitslice`] for the
//! trade-off and the auto-detection policy.

pub mod aot;
pub mod bitslice;
pub mod lower;
pub mod opt;

pub use aot::{AotProgram, AotProvider, Emitter};

pub use bitslice::{
    detect_lane_words, lane_backend_name, BitslicedEngine, BitslicedEngineN, LANE_WIDTHS,
};
pub use lower::{BitNetlist, Level, MuxOp};
pub use opt::{optimize, OptLevel, OptReport};

use std::sync::Arc;
use std::time::Instant;

use anyhow::bail;

use crate::luts::LutNetwork;
use crate::netlist::{ScalarPlan, SimResult, Simulator};
use crate::obs::{trace, PassReport};

/// A batch-inference execution strategy for one converted network.
///
/// Implementations must be bit-exact with respect to the quantized
/// fabric semantics: identical logit codes, identical argmax predictions.
pub trait InferenceBackend: Send + Sync {
    /// Stable backend name for logs and reports.
    fn name(&self) -> &'static str;

    /// Pipeline latency in cycles (one per L-LUT layer).
    fn latency_cycles(&self) -> usize;

    /// Run raw feature rows (`[batch * input_size]` floats in [0, 1]).
    fn run_batch(&self, x: &[f32]) -> SimResult;

    /// Classification accuracy over a labelled set.
    fn accuracy(&self, x: &[f32], y: &[i32]) -> f64 {
        let r = self.run_batch(x);
        let correct = r
            .predictions
            .iter()
            .zip(y)
            .filter(|(&p, &t)| p as i32 == t)
            .count();
        correct as f64 / y.len().max(1) as f64
    }
}

/// A compile-once execution artifact: everything expensive (network,
/// lowered program, flattened wiring) behind `Arc`s, spawning cheap
/// per-worker executors on demand.
///
/// This is the object a [`crate::fabric::BackendRegistry`] factory
/// returns and the serving runtime fans out across its worker pool.
/// Spawning an executor is cheap *by contract*: it must never re-run a
/// lowering pass, re-flatten wiring, or copy tables — `Arc` clones only.
pub trait FabricProgram: Send + Sync {
    /// Spawn one executor over the shared compiled state.
    fn executor(&self) -> Box<dyn InferenceBackend>;

    /// The shared lowered bit-netlist, for backends that have one
    /// (`None` for table-lookup backends with nothing compiled to share).
    fn bit_netlist(&self) -> Option<&Arc<BitNetlist>> {
        None
    }

    /// Timed per-pass compile telemetry (`lower`, `simplify`, `dce`),
    /// recorded while this program was compiled. Empty for backends with
    /// no compile step and for programs loaded from a `.nfab` artifact.
    fn pass_reports(&self) -> &[PassReport] {
        &[]
    }

    /// Plane width in `u64` words for word-parallel backends (64 samples
    /// per word per block), `None` for backends without a plane word.
    /// Persisted into `.nfab` artifacts so an artifact is never replayed
    /// by an executor with a different word format.
    fn plane_lanes(&self) -> Option<usize> {
        None
    }
}

impl<'a> InferenceBackend for Simulator<'a> {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn latency_cycles(&self) -> usize {
        Simulator::latency_cycles(self)
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        self.simulate_batch(x)
    }
}

impl<const N: usize> InferenceBackend for BitslicedEngineN<N> {
    fn name(&self) -> &'static str {
        // Registered widths get their registry name; an ad-hoc
        // instantiation at another width reports the generic family.
        lane_backend_name(N).unwrap_or("bitsliced-wide")
    }

    fn latency_cycles(&self) -> usize {
        BitslicedEngineN::latency_cycles(self)
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        BitslicedEngineN::run_batch(self, x)
    }
}

/// Owning scalar backend: shares the network through an `Arc` and reuses
/// the simulator's hot loop via [`ScalarPlan`]. This is the `'static`
/// sibling of the borrowing [`Simulator`] — what worker threads (which
/// outlive any borrow) execute.
pub struct ScalarEngine {
    net: Arc<LutNetwork>,
    plan: Arc<ScalarPlan>,
}

impl ScalarEngine {
    pub fn new(net: Arc<LutNetwork>) -> Self {
        let plan = Arc::new(ScalarPlan::new(&net));
        ScalarEngine { net, plan }
    }

    /// Per-worker constructor over an already-built plan — no re-flattening
    /// of the wiring; N workers share one plan like they share one program.
    pub fn from_parts(net: Arc<LutNetwork>, plan: Arc<ScalarPlan>) -> Self {
        ScalarEngine { net, plan }
    }
}

impl InferenceBackend for ScalarEngine {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn latency_cycles(&self) -> usize {
        self.net.layers.len()
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        self.plan.simulate_batch(&self.net, x)
    }
}

/// The `scalar` built-in's compile-once artifact: nothing to lower — the
/// shared state is the network plus its flattened wiring plan.
pub struct ScalarProgram {
    net: Arc<LutNetwork>,
    plan: Arc<ScalarPlan>,
}

impl ScalarProgram {
    /// Build the shared wiring plan (infallible — no lowering pass).
    pub fn new(net: Arc<LutNetwork>) -> Self {
        let plan = Arc::new(ScalarPlan::new(&net));
        ScalarProgram { net, plan }
    }
}

impl FabricProgram for ScalarProgram {
    fn executor(&self) -> Box<dyn InferenceBackend> {
        Box::new(ScalarEngine::from_parts(self.net.clone(), self.plan.clone()))
    }
}

/// The `bitsliced` width family's compile-once artifact: the lowered,
/// levelized word-op program every executor streams, plus the plane
/// width its executors run at. The program itself is width-agnostic —
/// only the executors are monomorphized per width — so the same
/// `Arc<BitNetlist>` can back programs of every lane count.
pub struct BitslicedProgram {
    program: Arc<BitNetlist>,
    passes: Vec<PassReport>,
    lanes: usize,
}

fn check_lanes(lanes: usize) -> crate::Result<()> {
    if lane_backend_name(lanes).is_none() {
        bail!("unsupported plane lane width {lanes} (supported: 1, 2, 4, 8)");
    }
    Ok(())
}

impl BitslicedProgram {
    /// Run the lowering pass once at the default [`OptLevel`], one-word
    /// planes. Fails on networks the pass rejects (e.g. signed codes on
    /// a non-final layer).
    pub fn compile(net: &LutNetwork) -> crate::Result<Self> {
        Self::compile_opt(net, OptLevel::default())
    }

    /// Lower and then run the [`opt`] pass pipeline at `level` — the
    /// registry factory path, where the level comes from
    /// [`FabricOptions`](crate::fabric::FabricOptions). Each pass is
    /// timed into [`pass_reports`](FabricProgram::pass_reports).
    pub fn compile_opt(net: &LutNetwork, level: OptLevel) -> crate::Result<Self> {
        let t0 = Instant::now();
        let mut nl = {
            let _span = trace::span("lower");
            lower::lower(net)?
        };
        let mut passes = vec![PassReport {
            name: "lower".into(),
            wall_s: t0.elapsed().as_secs_f64(),
            ops_before: 0,
            ops_after: nl.num_ops(),
            planes_removed: 0,
        }];
        let (_, opt_passes) = opt::optimize_traced(&mut nl, level);
        passes.extend(opt_passes);
        Ok(BitslicedProgram { program: Arc::new(nl), passes, lanes: 1 })
    }

    /// [`Self::compile_opt`] with an explicit plane width in `u64` words
    /// — the registry factory for the `bitsliced-x2/x4/x8` entries.
    /// Rejects widths without a registered engine instantiation.
    pub fn compile_opt_wide(net: &LutNetwork, level: OptLevel, lanes: usize)
                            -> crate::Result<Self> {
        check_lanes(lanes)?;
        let mut this = Self::compile_opt(net, level)?;
        this.lanes = lanes;
        Ok(this)
    }

    /// Wrap an already-lowered (and possibly persisted-and-reloaded)
    /// program, one-word planes. No passes ran here, so the pass
    /// telemetry is empty.
    pub fn from_netlist(program: Arc<BitNetlist>) -> Self {
        BitslicedProgram { program, passes: Vec::new(), lanes: 1 }
    }

    /// [`Self::from_netlist`] with an explicit plane width — the `.nfab`
    /// loader path for the wide entries, and the cheap way to re-width
    /// an already-compiled program without re-lowering it.
    pub fn from_netlist_wide(program: Arc<BitNetlist>, lanes: usize) -> crate::Result<Self> {
        check_lanes(lanes)?;
        Ok(BitslicedProgram { program, passes: Vec::new(), lanes })
    }

    /// Plane width in `u64` words executors of this program run at.
    pub fn lanes(&self) -> usize {
        self.lanes
    }
}

impl FabricProgram for BitslicedProgram {
    fn executor(&self) -> Box<dyn InferenceBackend> {
        match self.lanes {
            2 => Box::new(BitslicedEngineN::<2>::from_program(self.program.clone())),
            4 => Box::new(BitslicedEngineN::<4>::from_program(self.program.clone())),
            8 => Box::new(BitslicedEngineN::<8>::from_program(self.program.clone())),
            // Constructors validate the width, so 1 is the only other
            // reachable value.
            _ => Box::new(BitslicedEngineN::<1>::from_program(self.program.clone())),
        }
    }

    fn bit_netlist(&self) -> Option<&Arc<BitNetlist>> {
        Some(&self.program)
    }

    fn pass_reports(&self) -> &[PassReport] {
        &self.passes
    }

    fn plane_lanes(&self) -> Option<usize> {
        Some(self.lanes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;

    #[test]
    fn both_builtin_programs_are_bit_exact_and_trait_complete() {
        let net = Arc::new(random_network(31, 9, 2, &[6, 4], 3, 2, 4));
        let x: Vec<f32> = (0..9 * 100).map(|i| (i % 13) as f32 / 13.0).collect();
        let y: Vec<i32> = (0..100).map(|i| (i % 4) as i32).collect();
        let scalar = ScalarProgram::new(net.clone()).executor();
        let bits = BitslicedProgram::compile(&net).unwrap().executor();
        assert_eq!(scalar.name(), "scalar");
        assert_eq!(bits.name(), "bitsliced");
        assert_eq!(scalar.latency_cycles(), bits.latency_cycles());
        let a = scalar.run_batch(&x);
        let b = bits.run_batch(&x);
        assert_eq!(a.logit_codes, b.logit_codes);
        assert_eq!(a.predictions, b.predictions);
        assert!((scalar.accuracy(&x, &y) - bits.accuracy(&x, &y)).abs() < 1e-12);
    }

    #[test]
    fn owning_scalar_engine_matches_borrowing_simulator() {
        let net = Arc::new(random_network(33, 7, 2, &[5, 3], 2, 2, 4));
        let x: Vec<f32> = (0..7 * 90).map(|i| (i % 17) as f32 / 17.0).collect();
        let own = ScalarEngine::new(net.clone());
        let sim = Simulator::new(&net);
        assert_eq!(own.run_batch(&x).logit_codes,
                   sim.simulate_batch(&x).logit_codes);
        assert_eq!(own.latency_cycles(), sim.latency_cycles());
    }

    #[test]
    fn compile_records_chained_pass_reports() {
        let net = Arc::new(random_network(32, 8, 2, &[6, 3], 3, 2, 4));
        let prog = BitslicedProgram::compile_opt(&net, OptLevel::O2).unwrap();
        let passes = prog.pass_reports();
        assert_eq!(
            passes.iter().map(|p| p.name.as_str()).collect::<Vec<_>>(),
            ["lower", "simplify", "dce"]
        );
        assert_eq!(passes[0].ops_before, 0);
        for w in passes.windows(2) {
            assert_eq!(w[1].ops_before, w[0].ops_after, "pass chain must connect");
        }
        assert_eq!(
            passes.last().unwrap().ops_after,
            prog.bit_netlist().unwrap().num_ops(),
            "report must land on the executed op count"
        );
        // Loaded programs and the scalar backend carry no pass telemetry.
        let reloaded = BitslicedProgram::from_netlist(prog.bit_netlist().unwrap().clone());
        assert!(reloaded.pass_reports().is_empty());
        assert!(ScalarProgram::new(net).pass_reports().is_empty());
    }

    #[test]
    fn wide_programs_carry_their_width_and_stay_bit_exact() {
        let net = Arc::new(random_network(34, 8, 2, &[6, 4], 3, 2, 4));
        let x: Vec<f32> = (0..8 * 150).map(|i| (i % 19) as f32 / 19.0).collect();
        let narrow = BitslicedProgram::compile(&net).unwrap();
        assert_eq!(narrow.lanes(), 1);
        assert_eq!(narrow.plane_lanes(), Some(1));
        let want = narrow.executor().run_batch(&x);
        for (lanes, name) in [(2usize, "bitsliced-x2"), (4, "bitsliced-x4"), (8, "bitsliced-x8")] {
            // Re-width the compiled program without re-lowering.
            let wide =
                BitslicedProgram::from_netlist_wide(narrow.bit_netlist().unwrap().clone(), lanes)
                    .unwrap();
            assert_eq!(wide.plane_lanes(), Some(lanes));
            let exec = wide.executor();
            assert_eq!(exec.name(), name);
            assert_eq!(exec.run_batch(&x).logit_codes, want.logit_codes);
            let compiled = BitslicedProgram::compile_opt_wide(&net, OptLevel::O2, lanes).unwrap();
            assert_eq!(compiled.executor().name(), name);
            assert_eq!(compiled.executor().run_batch(&x).logit_codes, want.logit_codes);
        }
        assert!(BitslicedProgram::compile_opt_wide(&net, OptLevel::O2, 3).is_err());
        assert!(BitslicedProgram::from_netlist_wide(narrow.bit_netlist().unwrap().clone(), 0)
            .is_err());
    }

    #[test]
    fn programs_spawn_executors_without_recompiling() {
        let net = Arc::new(random_network(32, 8, 2, &[6, 3], 3, 2, 4));
        let fabric = BitslicedProgram::compile(&net).unwrap();
        let prog = fabric.bit_netlist().unwrap().clone();
        let a = fabric.executor();
        let b = fabric.executor();
        // ONE compiled instance, four holders: program + our clone + 2
        // executors.
        assert_eq!(Arc::strong_count(&prog), 4);
        let x: Vec<f32> = (0..8 * 70).map(|i| (i % 11) as f32 / 11.0).collect();
        assert_eq!(a.run_batch(&x).logit_codes, b.run_batch(&x).logit_codes);
        // The scalar program carries no lowered bit-netlist.
        let sp = ScalarProgram::new(net);
        assert!(sp.bit_netlist().is_none());
        assert_eq!(sp.executor().name(), "scalar");
    }
}
