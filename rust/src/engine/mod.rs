//! Compiled fabric engine: pluggable inference backends over a converted
//! [`LutNetwork`].
//!
//! The paper's premise is that an L-LUT network is a pure Boolean circuit
//! ("each L-LUT layer is evaluated in one clock cycle"). The scalar
//! simulator ([`crate::netlist::Simulator`]) honours that functionally but
//! executes it as per-sample table lookups. This subsystem instead
//! *compiles* the network once — [`lower`] expands every truth table into
//! per-output-bit Boolean functions (support reduction + ROBDD, shared
//! via structural hashing) and emits a levelized [`BitNetlist`] of fused
//! word ops — and then evaluates it bitsliced: 64 independent samples
//! packed per `u64`, batch inference as word-wide AND/OR/XOR streaming
//! ([`BitslicedEngine`]).
//!
//! Both execution strategies sit behind [`InferenceBackend`], so the
//! server, the CLI and the repro examples select a backend by
//! configuration ([`BackendKind`]) rather than by concrete type; future
//! device-specific lowerings slot in behind the same trait.
//!
//! Ownership: backends constructed through [`backend`] / [`SharedFabric`]
//! are `'static` — they share the network (and the compiled program)
//! through `Arc`s, so worker threads can own them outright. A
//! [`SharedFabric`] is the compile-once artifact; its
//! [`executor`](SharedFabric::executor)s are cheap per-worker handles — N
//! serving workers share one lowering pass instead of compiling N times.
//!
//! Picking a backend: `Scalar` has zero compile cost and wins on tiny
//! batches and very wide tables; `Bitsliced` pays one lowering pass per
//! network and wins on batch workloads, increasingly so the more
//! structure (small support, shared logic, low fan-in × bit-width) the
//! trained tables carry.

pub mod bitslice;
pub mod lower;

pub use bitslice::BitslicedEngine;
pub use lower::{BitNetlist, Level, MuxOp};

use std::sync::Arc;

use anyhow::bail;

use crate::luts::LutNetwork;
use crate::netlist::{ScalarPlan, SimResult, Simulator};

/// Which inference engine executes a converted network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Per-sample scalar table lookups (`netlist::Simulator`).
    #[default]
    Scalar,
    /// Compiled bit-level netlist, 64 samples per word.
    Bitsliced,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Scalar => "scalar",
            BackendKind::Bitsliced => "bitsliced",
        }
    }

    /// The kind selected by the `NEURALUT_ENGINE` environment variable
    /// (`Scalar` when unset) — one definition of the env protocol for
    /// the examples and any other env-driven entry point.
    pub fn from_env() -> crate::Result<BackendKind> {
        match std::env::var("NEURALUT_ENGINE") {
            Ok(v) => v.parse(),
            Err(_) => Ok(BackendKind::Scalar),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> crate::Result<Self> {
        match s {
            "scalar" => Ok(BackendKind::Scalar),
            "bitsliced" => Ok(BackendKind::Bitsliced),
            other => bail!("unknown engine '{other}' (scalar | bitsliced)"),
        }
    }
}

/// A batch-inference execution strategy for one converted network.
///
/// Implementations must be bit-exact with respect to the quantized
/// fabric semantics: identical logit codes, identical argmax predictions.
pub trait InferenceBackend: Send + Sync {
    /// Stable backend name for logs and reports.
    fn name(&self) -> &'static str;

    /// Pipeline latency in cycles (one per L-LUT layer).
    fn latency_cycles(&self) -> usize;

    /// Run raw feature rows (`[batch * input_size]` floats in [0, 1]).
    fn run_batch(&self, x: &[f32]) -> SimResult;

    /// Classification accuracy over a labelled set.
    fn accuracy(&self, x: &[f32], y: &[i32]) -> f64 {
        let r = self.run_batch(x);
        let correct = r
            .predictions
            .iter()
            .zip(y)
            .filter(|(&p, &t)| p as i32 == t)
            .count();
        correct as f64 / y.len().max(1) as f64
    }
}

impl<'a> InferenceBackend for Simulator<'a> {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn latency_cycles(&self) -> usize {
        Simulator::latency_cycles(self)
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        self.simulate_batch(x)
    }
}

impl InferenceBackend for BitslicedEngine {
    fn name(&self) -> &'static str {
        "bitsliced"
    }

    fn latency_cycles(&self) -> usize {
        BitslicedEngine::latency_cycles(self)
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        BitslicedEngine::run_batch(self, x)
    }
}

/// Owning scalar backend: shares the network through an `Arc` and reuses
/// the simulator's hot loop via [`ScalarPlan`]. This is the `'static`
/// sibling of the borrowing [`Simulator`] — what worker threads (which
/// outlive any borrow) execute.
pub struct ScalarEngine {
    net: Arc<LutNetwork>,
    plan: Arc<ScalarPlan>,
}

impl ScalarEngine {
    pub fn new(net: Arc<LutNetwork>) -> Self {
        let plan = Arc::new(ScalarPlan::new(&net));
        ScalarEngine { net, plan }
    }

    /// Per-worker constructor over an already-built plan — no re-flattening
    /// of the wiring; N workers share one plan like they share one program.
    pub fn from_parts(net: Arc<LutNetwork>, plan: Arc<ScalarPlan>) -> Self {
        ScalarEngine { net, plan }
    }
}

impl InferenceBackend for ScalarEngine {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn latency_cycles(&self) -> usize {
        self.net.layers.len()
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        self.plan.simulate_batch(&self.net, x)
    }
}

/// A compile-once, share-everywhere fabric: the expensive artifacts (the
/// network, and for `Bitsliced` the lowered program) held behind `Arc`s,
/// from which any number of cheap per-worker [`executor`](Self::executor)s
/// can be spawned. The serving runtime compiles one `SharedFabric` per
/// server start and hands every worker thread its own executor — N workers,
/// one lowering pass.
pub enum SharedFabric {
    Scalar { net: Arc<LutNetwork>, plan: Arc<ScalarPlan> },
    Bitsliced { program: Arc<BitNetlist> },
}

impl SharedFabric {
    /// The scalar fabric for `net` (infallible — nothing to lower; the
    /// shared artifact is the flattened wiring plan).
    pub fn scalar(net: Arc<LutNetwork>) -> SharedFabric {
        let plan = Arc::new(ScalarPlan::new(&net));
        SharedFabric::Scalar { net, plan }
    }

    /// Compile the fabric once. `Bitsliced` runs the lowering pass here
    /// and reports its failures (e.g. layers with inconsistent bit-widths).
    pub fn compile(kind: BackendKind, net: Arc<LutNetwork>) -> crate::Result<SharedFabric> {
        Ok(match kind {
            BackendKind::Scalar => Self::scalar(net),
            BackendKind::Bitsliced => SharedFabric::Bitsliced {
                program: Arc::new(lower::lower(&net)?),
            },
        })
    }

    pub fn kind(&self) -> BackendKind {
        match self {
            SharedFabric::Scalar { .. } => BackendKind::Scalar,
            SharedFabric::Bitsliced { .. } => BackendKind::Bitsliced,
        }
    }

    /// Spawn one executor. Cheap by contract: never re-runs the lowering
    /// pass, never re-flattens wiring, never copies tables — `Arc` clones
    /// only.
    pub fn executor(&self) -> Box<dyn InferenceBackend> {
        match self {
            SharedFabric::Scalar { net, plan } => {
                Box::new(ScalarEngine::from_parts(net.clone(), plan.clone()))
            }
            SharedFabric::Bitsliced { program } => {
                Box::new(BitslicedEngine::from_program(program.clone()))
            }
        }
    }

    /// The shared compiled program (`None` for the scalar fabric).
    pub fn program(&self) -> Option<&Arc<BitNetlist>> {
        match self {
            SharedFabric::Scalar { .. } => None,
            SharedFabric::Bitsliced { program } => Some(program),
        }
    }
}

/// Construct a `'static` backend of the requested kind for a shared
/// network — one compile, one executor. For a worker pool sharing a
/// single compile, use [`SharedFabric`] directly.
pub fn backend(
    kind: BackendKind,
    net: Arc<LutNetwork>,
) -> crate::Result<Box<dyn InferenceBackend>> {
    Ok(SharedFabric::compile(kind, net)?.executor())
}

/// Backend selected by the `NEURALUT_ENGINE` environment variable
/// (`scalar` when unset) — how the repro examples opt into the compiled
/// engine without changing their code paths.
pub fn backend_from_env(net: Arc<LutNetwork>) -> crate::Result<Box<dyn InferenceBackend>> {
    backend(BackendKind::from_env()?, net)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;

    #[test]
    fn kind_parses_and_displays() {
        assert_eq!("scalar".parse::<BackendKind>().unwrap(), BackendKind::Scalar);
        assert_eq!(
            "bitsliced".parse::<BackendKind>().unwrap(),
            BackendKind::Bitsliced
        );
        assert!("fpga".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::default(), BackendKind::Scalar);
        assert_eq!(BackendKind::Bitsliced.to_string(), "bitsliced");
    }

    #[test]
    fn both_backends_satisfy_the_trait_identically() {
        let net = Arc::new(random_network(31, 9, 2, &[6, 4], 3, 2, 4));
        let x: Vec<f32> = (0..9 * 100).map(|i| (i % 13) as f32 / 13.0).collect();
        let y: Vec<i32> = (0..100).map(|i| (i % 4) as i32).collect();
        let scalar = backend(BackendKind::Scalar, net.clone()).unwrap();
        let bits = backend(BackendKind::Bitsliced, net.clone()).unwrap();
        assert_eq!(scalar.name(), "scalar");
        assert_eq!(bits.name(), "bitsliced");
        assert_eq!(scalar.latency_cycles(), bits.latency_cycles());
        let a = scalar.run_batch(&x);
        let b = bits.run_batch(&x);
        assert_eq!(a.logit_codes, b.logit_codes);
        assert_eq!(a.predictions, b.predictions);
        assert!((scalar.accuracy(&x, &y) - bits.accuracy(&x, &y)).abs() < 1e-12);
    }

    #[test]
    fn owning_scalar_engine_matches_borrowing_simulator() {
        let net = Arc::new(random_network(33, 7, 2, &[5, 3], 2, 2, 4));
        let x: Vec<f32> = (0..7 * 90).map(|i| (i % 17) as f32 / 17.0).collect();
        let own = ScalarEngine::new(net.clone());
        let sim = Simulator::new(&net);
        assert_eq!(own.run_batch(&x).logit_codes,
                   sim.simulate_batch(&x).logit_codes);
        assert_eq!(own.latency_cycles(), sim.latency_cycles());
    }

    #[test]
    fn shared_fabric_spawns_executors_without_recompiling() {
        let net = Arc::new(random_network(32, 8, 2, &[6, 3], 3, 2, 4));
        let fabric = SharedFabric::compile(BackendKind::Bitsliced, net.clone()).unwrap();
        assert_eq!(fabric.kind(), BackendKind::Bitsliced);
        let prog = fabric.program().unwrap().clone();
        let a = fabric.executor();
        let b = fabric.executor();
        // ONE compiled instance, four holders: fabric + our clone + 2 executors.
        assert_eq!(Arc::strong_count(&prog), 4);
        let x: Vec<f32> = (0..8 * 70).map(|i| (i % 11) as f32 / 11.0).collect();
        assert_eq!(a.run_batch(&x).logit_codes, b.run_batch(&x).logit_codes);
        // Scalar fabric carries no compiled program.
        let sf = SharedFabric::compile(BackendKind::Scalar, net).unwrap();
        assert!(sf.program().is_none());
        assert_eq!(sf.executor().name(), "scalar");
    }
}
