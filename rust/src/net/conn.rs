//! TCP front door: accept loop, protocol sniffing, per-connection
//! reader/writer threads, connection cap and admission control.
//!
//! Each accepted connection gets its own handler thread. The first four
//! bytes pick the protocol: the [`frame::WIRE_PREAMBLE`] starts a binary
//! framed conversation; anything else is handed to the HTTP/1.1 path
//! ([`crate::net::http`]) with those bytes preserved.
//!
//! # Back-pressure contract
//!
//! A binary connection splits into a reader (the handler thread) and a
//! writer thread joined by a bounded job channel. The reader decodes
//! request frames and submits every row through the owning model's
//! non-blocking [`Client::try_infer`](crate::server::Client::try_infer)
//! — so the bounded worker queue, not the socket, is the admission
//! point: a full queue answers with a typed `Overloaded` error frame
//! (HTTP 429 on the JSON path) immediately, never a hang. Rows of one
//! frame land in the worker pool individually and ride whatever fabric
//! batches form — per-connection streaming micro-batching. The writer
//! awaits replies in submission order and streams reply frames back;
//! when it falls behind (slow consumer), the bounded job channel fills
//! and the reader stops reading, pushing back through TCP. Over the
//! connection cap, new connections are refused with the same typed
//! refusal (`Overloaded` frame / HTTP 429) and closed.
//!
//! Shutdown ([`NetServer::shutdown`], also run on drop) closes every
//! live socket, so reader threads unblock, writers drain, and the
//! no-request-left-behind invariant of the worker pool carries through
//! the network layer: every accepted frame is answered or the connection
//! is visibly closed — nothing hangs.

use std::collections::HashMap;
use std::io::Read;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::net::frame::{self, Frame, WireCode};
use crate::net::http;
use crate::net::manager::ModelManager;
use crate::obs::{Counter, Gauge, Histogram, MetricsRegistry, MetricsSnapshot};
use crate::server::PendingReply;

/// Upper bound on `max_connections` — more sockets than this is a
/// config bug, not a capacity plan.
pub const MAX_CONNECTIONS_LIMIT: usize = 1 << 16;
/// Request frames in flight per binary connection before the reader
/// stops reading (TCP back-pressure toward the client).
const MAX_PIPELINE: usize = 1024;
/// Log2 buckets for the rows-per-frame histogram.
const ROWS_BUCKETS: usize = 16;
/// How long a refusal handler waits for the preamble of an over-cap
/// connection before giving up on a typed goodbye.
const REFUSAL_READ_TIMEOUT: Duration = Duration::from_millis(500);

/// Network front-door knobs, resolved through
/// [`FabricOptions::resolve_net`](crate::fabric::FabricOptions::resolve_net)
/// (defaults < config file < env < builder/CLI — the one precedence
/// chain).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetConfig {
    /// `host:port` to bind; port 0 picks an ephemeral port (see
    /// [`NetServer::local_addr`]).
    pub listen_addr: String,
    /// Live-connection cap; connections over it are refused with a typed
    /// `Overloaded` / HTTP 429, never left hanging.
    pub max_connections: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { listen_addr: "127.0.0.1:0".into(), max_connections: 256 }
    }
}

/// `neuralut_net_*` counters shared by every connection of one listener.
pub(crate) struct NetStats {
    pub(crate) registry: MetricsRegistry,
    pub(crate) active: Gauge,
    refused: Counter,
    binary_conns: Counter,
    http_conns: Counter,
    binary_requests: Counter,
    pub(crate) http_requests: Counter,
    rows_hist: Histogram,
}

impl NetStats {
    fn new() -> NetStats {
        let registry = MetricsRegistry::new();
        for (name, help) in [
            ("neuralut_net_connections_total", "connections accepted, by protocol"),
            ("neuralut_net_active_connections", "connections currently open"),
            ("neuralut_net_connections_refused_total", "connections refused at the cap"),
            ("neuralut_net_requests_total", "request frames / HTTP requests handled, by protocol"),
            ("neuralut_net_request_rows", "feature rows per binary request frame"),
            ("neuralut_net_refusals_total", "typed request refusals, by wire-code tag"),
        ] {
            registry.describe(name, help);
        }
        NetStats {
            active: registry.gauge("neuralut_net_active_connections", &[]),
            refused: registry.counter("neuralut_net_connections_refused_total", &[]),
            binary_conns: registry.counter("neuralut_net_connections_total", &[("proto", "binary")]),
            http_conns: registry.counter("neuralut_net_connections_total", &[("proto", "http")]),
            binary_requests: registry.counter("neuralut_net_requests_total", &[("proto", "binary")]),
            http_requests: registry.counter("neuralut_net_requests_total", &[("proto", "http")]),
            rows_hist: registry.histogram("neuralut_net_request_rows", &[], ROWS_BUCKETS),
            registry,
        }
    }

    /// Count one typed refusal under its wire-code tag.
    pub(crate) fn count_refusal(&self, code: WireCode) {
        self.registry
            .counter("neuralut_net_refusals_total", &[("code", code.tag())])
            .inc();
    }
}

pub(crate) struct NetShared {
    pub(crate) manager: Arc<ModelManager>,
    pub(crate) stats: NetStats,
    max_connections: usize,
    shutdown: AtomicBool,
    active: AtomicUsize,
    next_conn_id: AtomicU64,
    /// Socket clones of live connections, so shutdown can unblock every
    /// reader (keyed by connection id; the handler deregisters on exit).
    conns: Mutex<HashMap<u64, TcpStream>>,
    /// Handler threads to join on shutdown (reaped as they finish).
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl NetShared {
    /// The `/metrics` payload: listener counters + manager counters +
    /// every model's server registry relabeled per model.
    pub(crate) fn full_metrics(&self) -> MetricsSnapshot {
        let mut snap = self.stats.registry.snapshot();
        snap.merge(self.manager.metrics());
        snap
    }
}

/// What submitting one request batch at the front door produced.
pub(crate) enum Submitted {
    /// Every row admitted; one pending reply per row, in row order.
    Pending(Vec<PendingReply>),
    /// Refused before (or while) submitting — typed, never silent.
    Refused { code: WireCode, message: String },
}

/// Admission control shared by the binary and HTTP paths: resolve the
/// model, then push every row through the non-blocking `try_infer`. The
/// first failure (queue full, stopped, bad feature count) refuses the
/// whole request with its typed code; already-admitted rows still get
/// served by the workers, their replies simply go unread.
pub(crate) fn submit(shared: &NetShared, model: &str, rows: usize, features: Vec<f32>) -> Submitted {
    let refuse = |code: WireCode, message: String| {
        shared.stats.count_refusal(code);
        Submitted::Refused { code, message }
    };
    let Some(m) = shared.manager.get(model) else {
        return refuse(
            WireCode::UnknownModel,
            format!("unknown model '{model}' (serving: {})", shared.manager.names().join(", ")),
        );
    };
    if rows == 0 || features.len() % rows != 0 {
        return refuse(
            WireCode::BadRequest,
            format!("{} features do not split into {rows} equal rows", features.len()),
        );
    }
    let cols = features.len() / rows;
    let mut pending = Vec::with_capacity(rows);
    for row in features.chunks(cols) {
        match m.client().try_infer(row.to_vec()) {
            Ok(p) => pending.push(p),
            Err(e) => return refuse(WireCode::classify(&e), format!("{e:#}")),
        }
    }
    m.count_rows(rows);
    Submitted::Pending(pending)
}

/// A running network front door over one [`ModelManager`]. Dropping it
/// stops accepting, closes every live connection, and joins all threads.
pub struct NetServer {
    shared: Arc<NetShared>,
    local: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `cfg.listen_addr` and start accepting.
    pub fn start(manager: Arc<ModelManager>, cfg: &NetConfig) -> Result<NetServer> {
        if cfg.max_connections == 0 || cfg.max_connections > MAX_CONNECTIONS_LIMIT {
            bail!(
                "max_connections = {} out of range (1..={MAX_CONNECTIONS_LIMIT})",
                cfg.max_connections
            );
        }
        let listener = TcpListener::bind(&cfg.listen_addr)
            .with_context(|| format!("binding {}", cfg.listen_addr))?;
        let local = listener.local_addr().context("resolving bound address")?;
        let shared = Arc::new(NetShared {
            manager,
            stats: NetStats::new(),
            max_connections: cfg.max_connections,
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            next_conn_id: AtomicU64::new(1),
            conns: Mutex::new(HashMap::new()),
            handles: Mutex::new(Vec::new()),
        });
        let sh = shared.clone();
        let accept = std::thread::spawn(move || accept_loop(listener, sh));
        Ok(NetServer { shared, local, accept: Some(accept) })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// The model manager this front door serves from.
    pub fn manager(&self) -> &Arc<ModelManager> {
        &self.shared.manager
    }

    /// Connections currently open.
    pub fn active_connections(&self) -> usize {
        self.shared.active.load(Ordering::Acquire)
    }

    /// Exactly what `GET /metrics` serves: listener + per-model counters.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.full_metrics()
    }

    /// Stop accepting and close every live connection (idempotent; the
    /// threads are joined by `Drop`).
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Wake the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.local);
        let conns = self.shared.conns.lock().unwrap_or_else(|e| e.into_inner());
        for stream in conns.values() {
            let _ = stream.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut guard = self.shared.handles.lock().unwrap_or_else(|e| e.into_inner());
            guard.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Deregisters a connection and releases its cap slot even if the
/// handler unwinds.
struct ConnGuard {
    id: u64,
    shared: Arc<NetShared>,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.shared.conns.lock().unwrap_or_else(|e| e.into_inner()).remove(&self.id);
        self.shared.active.fetch_sub(1, Ordering::AcqRel);
        self.shared.stats.active.dec();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<NetShared>) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(_) => {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
        };
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        // Reap finished handler threads so long-lived listeners don't
        // accumulate handles.
        {
            let mut handles = shared.handles.lock().unwrap_or_else(|e| e.into_inner());
            handles.retain(|h| !h.is_finished());
        }
        if shared.active.load(Ordering::Acquire) >= shared.max_connections {
            let sh = shared.clone();
            let h = std::thread::spawn(move || refuse_conn(stream, &sh));
            shared.handles.lock().unwrap_or_else(|e| e.into_inner()).push(h);
            continue;
        }
        let id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.conns.lock().unwrap_or_else(|e| e.into_inner()).insert(id, clone);
        }
        shared.active.fetch_add(1, Ordering::AcqRel);
        shared.stats.active.inc();
        let sh = shared.clone();
        let h = std::thread::spawn(move || {
            let guard = ConnGuard { id, shared: sh };
            handle_conn(stream, &guard.shared);
        });
        shared.handles.lock().unwrap_or_else(|e| e.into_inner()).push(h);
    }
}

/// Over the cap: say a typed goodbye in whichever protocol the client
/// speaks, then close. Bounded by a read timeout so a silent client
/// cannot pin this thread.
fn refuse_conn(mut stream: TcpStream, shared: &NetShared) {
    shared.stats.refused.inc();
    shared.stats.count_refusal(WireCode::Overloaded);
    let _ = stream.set_read_timeout(Some(REFUSAL_READ_TIMEOUT));
    let mut first = [0u8; 4];
    let is_binary = read_prefix(&mut stream, &mut first) && first == frame::WIRE_PREAMBLE;
    if is_binary {
        let _ = frame::write_frame(
            &mut stream,
            &Frame::Error {
                id: 0,
                code: WireCode::Overloaded.code(),
                message: "connection limit reached".into(),
            },
        );
    } else {
        let _ = http::write_refusal(&mut stream, WireCode::Overloaded, "connection limit reached");
    }
    // Drain whatever the client already pipelined before closing: a close
    // with unread bytes in the receive buffer turns into an RST, which
    // can destroy the refusal we just wrote before the client reads it.
    // Bounded by the armed read timeout and a fixed byte budget.
    let mut sink = [0u8; 4096];
    for _ in 0..16 {
        match stream.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// Best-effort exact read of the 4-byte protocol sniff.
fn read_prefix(stream: &mut TcpStream, buf: &mut [u8; 4]) -> bool {
    let mut got = 0;
    while got < 4 {
        match stream.read(&mut buf[got..]) {
            Ok(0) => return false,
            Ok(n) => got += n,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}

fn handle_conn(mut stream: TcpStream, shared: &Arc<NetShared>) {
    let _ = stream.set_nodelay(true);
    let mut first = [0u8; 4];
    if !read_prefix(&mut stream, &mut first) {
        return;
    }
    if first == frame::WIRE_PREAMBLE {
        shared.stats.binary_conns.inc();
        binary_conn(stream, shared);
    } else {
        shared.stats.http_conns.inc();
        http::serve_http(stream, first, shared);
    }
}

/// One writer job: a request's ordered pending replies, or an immediate
/// typed refusal.
enum Job {
    Replies { id: u32, pending: Vec<PendingReply> },
    Refuse { id: u32, code: WireCode, message: String },
}

/// Binary conversation: this thread reads and submits; a writer thread
/// streams replies back in submission order.
fn binary_conn(mut reader: TcpStream, shared: &Arc<NetShared>) {
    let Ok(writer_stream) = reader.try_clone() else { return };
    let (tx, rx) = mpsc::sync_channel::<Job>(MAX_PIPELINE);
    let writer = std::thread::spawn(move || writer_loop(writer_stream, rx));
    loop {
        match frame::read_frame(&mut reader) {
            // Clean EOF between frames: the client is done.
            Ok(None) => break,
            Ok(Some(Frame::Request { id, model, rows, features })) => {
                shared.stats.binary_requests.inc();
                shared.stats.rows_hist.observe(rows as u64);
                let job = match submit(shared, &model, rows, features) {
                    Submitted::Pending(pending) => Job::Replies { id, pending },
                    Submitted::Refused { code, message } => Job::Refuse { id, code, message },
                };
                if tx.send(job).is_err() {
                    break;
                }
            }
            Ok(Some(_)) => {
                let _ = tx.send(Job::Refuse {
                    id: 0,
                    code: WireCode::BadRequest,
                    message: "only request frames flow client->server".into(),
                });
                break;
            }
            // Malformed/oversized/torn frame: framing is lost, so answer
            // id 0 and close rather than guess at resynchronization.
            Err(e) => {
                shared.stats.count_refusal(WireCode::BadRequest);
                let _ = tx.send(Job::Refuse {
                    id: 0,
                    code: WireCode::BadRequest,
                    message: format!("{e:#}"),
                });
                break;
            }
        }
    }
    // Channel closes; the writer drains queued jobs, then exits.
    drop(tx);
    let _ = writer.join();
    let _ = reader.shutdown(Shutdown::Both);
}

fn writer_loop(mut stream: TcpStream, rx: Receiver<Job>) {
    // After a write failure the socket is dead; keep draining jobs (so
    // the reader never blocks on a full channel) without writing.
    let mut dead = false;
    while let Ok(job) = rx.recv() {
        let frame = match job {
            Job::Refuse { id, code, message } => {
                Frame::Error { id, code: code.code(), message }
            }
            Job::Replies { id, pending } => {
                let mut predictions = Vec::with_capacity(pending.len());
                let mut failed: Option<(WireCode, String)> = None;
                for p in &pending {
                    match p.recv() {
                        Ok(reply) => predictions.push(reply.prediction),
                        Err(e) => {
                            failed = Some((WireCode::classify(&e), format!("{e:#}")));
                            break;
                        }
                    }
                }
                match failed {
                    None => Frame::Reply { id, predictions },
                    Some((code, message)) => {
                        Frame::Error { id, code: code.code(), message }
                    }
                }
            }
        };
        if !dead && frame::write_frame(&mut stream, &frame).is_err() {
            dead = true;
            let _ = stream.shutdown(Shutdown::Both);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricOptions;
    use crate::luts::random_network;
    use std::path::PathBuf;

    fn models_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("neuralut_conn_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        random_network(11, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("m.nlut")).unwrap();
        dir
    }

    #[test]
    fn config_bounds_are_enforced() {
        let dir = models_dir("cfg");
        let mgr = ModelManager::open(&dir, &FabricOptions::new()).unwrap();
        let bad = NetConfig { listen_addr: "127.0.0.1:0".into(), max_connections: 0 };
        assert!(NetServer::start(mgr.clone(), &bad).is_err());
        let bad = NetConfig {
            listen_addr: "127.0.0.1:0".into(),
            max_connections: MAX_CONNECTIONS_LIMIT + 1,
        };
        assert!(NetServer::start(mgr, &bad).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn submit_refuses_unknown_models_and_ragged_batches() {
        let dir = models_dir("submit");
        let mgr = ModelManager::open(&dir, &FabricOptions::new()).unwrap();
        let srv = NetServer::start(mgr, &NetConfig::default()).unwrap();
        match submit(&srv.shared, "nope", 1, vec![0.0; 8]) {
            Submitted::Refused { code, message } => {
                assert_eq!(code, WireCode::UnknownModel);
                assert!(message.contains("serving: m"), "{message}");
            }
            Submitted::Pending(_) => panic!("unknown model must refuse"),
        }
        match submit(&srv.shared, "m", 3, vec![0.0; 8]) {
            Submitted::Refused { code, .. } => assert_eq!(code, WireCode::BadRequest),
            Submitted::Pending(_) => panic!("ragged batch must refuse"),
        }
        // Wrong per-row feature count refuses through try_infer's check.
        match submit(&srv.shared, "m", 1, vec![0.0; 5]) {
            Submitted::Refused { code, .. } => assert_eq!(code, WireCode::BadRequest),
            Submitted::Pending(_) => panic!("wrong feature count must refuse"),
        }
        // A well-formed batch is admitted row by row.
        match submit(&srv.shared, "m", 2, vec![0.25; 16]) {
            Submitted::Pending(pending) => {
                assert_eq!(pending.len(), 2);
                for p in pending {
                    p.recv().unwrap();
                }
            }
            Submitted::Refused { message, .. } => panic!("{message}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
