//! Minimal HTTP/1.1 path of the network front door.
//!
//! Just enough of the protocol to be curl-able — no chunked encoding,
//! no TLS, no pipelining beyond keep-alive:
//!
//! - `POST /v1/infer` — JSON body `{"model": "name", "features": [...]}`
//!   where `features` is one flat row or an array of equal-length rows;
//!   replies `{"model", "rows", "predictions"}`. Errors carry the same
//!   stable numeric codes as the binary protocol
//!   ([`WireCode`](crate::net::frame::WireCode)) plus the matching HTTP
//!   status: queue-full maps to 429, unknown model to 404, a missed
//!   deadline to 504 — never a hang.
//! - `GET /metrics` — Prometheus exposition of the listener, manager,
//!   and per-model server registries (via [`crate::obs::expo`]).
//! - `GET /healthz` — liveness plus the served-model count.
//! - `GET /v1/models` — the manifest as JSON: name, digest, generation.
//!
//! Request heads are capped at [`MAX_HEAD`] bytes and bodies at
//! [`MAX_BODY`] bytes, both rejected before buffering the excess.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::net::conn::{submit, NetShared, Submitted};
use crate::net::frame::WireCode;
use crate::obs::expo;
use crate::util::json::{obj, Json};

/// Request-head cap (request line + headers).
pub const MAX_HEAD: usize = 16 * 1024;
/// Request-body cap; `Content-Length` above this is refused unread.
pub const MAX_BODY: usize = 8 * 1024 * 1024;

const CONTENT_JSON: &str = "application/json";
const CONTENT_TEXT: &str = "text/plain; charset=utf-8";

struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
}

/// Serve HTTP on a sniffed connection. `prefix` is the four bytes the
/// protocol sniff consumed; they are the start of the first request.
pub(crate) fn serve_http(mut stream: TcpStream, prefix: [u8; 4], shared: &Arc<NetShared>) {
    let mut buf: Vec<u8> = prefix.to_vec();
    loop {
        let req = match read_request(&mut stream, &mut buf) {
            Ok(Some(req)) => req,
            Ok(None) => break,
            Err(e) => {
                shared.stats.count_refusal(WireCode::BadRequest);
                let body = error_body(WireCode::BadRequest, &format!("{e:#}"));
                let _ = write_response(&mut stream, 400, CONTENT_JSON, &body, false);
                break;
            }
        };
        shared.stats.http_requests.inc();
        let keep = req.keep_alive;
        let (status, ctype, body) = route(&req, shared);
        if write_response(&mut stream, status, ctype, &body, keep).is_err() || !keep {
            break;
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// One-shot refusal used by the connection-cap path before any routing.
pub(crate) fn write_refusal(w: &mut dyn Write, code: WireCode, message: &str) -> std::io::Result<()> {
    write_response(w, code.http_status(), CONTENT_JSON, &error_body(code, message), false)
}

fn route(req: &HttpRequest, shared: &Arc<NetShared>) -> (u16, &'static str, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            (200, CONTENT_TEXT, format!("ok: serving {} models\n", shared.manager.len()))
        }
        ("GET", "/metrics") => (200, CONTENT_TEXT, expo::to_prometheus(&shared.full_metrics())),
        ("GET", "/v1/models") => (200, CONTENT_JSON, models_body(shared)),
        ("POST", "/v1/infer") => match infer_body(&req.body, shared) {
            Ok(body) => (200, CONTENT_JSON, body),
            Err((code, message)) => (code.http_status(), CONTENT_JSON, error_body(code, &message)),
        },
        ("GET" | "POST" | "HEAD" | "PUT" | "DELETE", _) => {
            let code = WireCode::BadRequest;
            (404, CONTENT_JSON, error_body(code, &format!("no route for {} {}", req.method, req.path)))
        }
        _ => (405, CONTENT_JSON, error_body(WireCode::BadRequest, "method not supported")),
    }
}

fn models_body(shared: &Arc<NetShared>) -> String {
    let models: Vec<Json> = shared
        .manager
        .snapshot()
        .iter()
        .map(|m| {
            obj(vec![
                ("name", Json::Str(m.name().to_string())),
                ("digest", Json::Str(format!("{:016x}", m.digest()))),
                ("generation", Json::Num(m.generation() as f64)),
                ("input_size", Json::Num(m.info().input_size as f64)),
                ("n_class", Json::Num(m.info().n_class as f64)),
            ])
        })
        .collect();
    obj(vec![("models", Json::Arr(models))]).to_string()
}

/// Parse the infer body, submit through the shared admission path, and
/// await the replies. Errors come back typed so the route can pick the
/// HTTP status off the wire code.
fn infer_body(body: &[u8], shared: &Arc<NetShared>) -> std::result::Result<String, (WireCode, String)> {
    let bad = |msg: String| (WireCode::BadRequest, msg);
    let text = std::str::from_utf8(body).map_err(|_| bad("body is not UTF-8".into()))?;
    let json = Json::parse(text).map_err(|e| bad(format!("{e:#}")))?;
    let model = json
        .get("model")
        .and_then(Json::as_str)
        .map_err(|e| bad(format!("{e:#}")))?
        .to_string();
    let (rows, features) = parse_features(&json).map_err(|e| bad(format!("{e:#}")))?;
    match submit(shared, &model, rows, features) {
        Submitted::Refused { code, message } => Err((code, message)),
        Submitted::Pending(pending) => {
            let mut predictions = Vec::with_capacity(pending.len());
            for p in &pending {
                let reply = p.recv().map_err(|e| (WireCode::classify(&e), format!("{e:#}")))?;
                predictions.push(Json::Num(reply.prediction as f64));
            }
            Ok(obj(vec![
                ("model", Json::Str(model)),
                ("rows", Json::Num(rows as f64)),
                ("predictions", Json::Arr(predictions)),
            ])
            .to_string())
        }
    }
}

/// `features` is either one flat row (`[0.1, 0.2, ...]`) or a batch of
/// equal-length rows (`[[...], [...]]`). Returns (rows, flat features).
fn parse_features(json: &Json) -> Result<(usize, Vec<f32>)> {
    let arr = json.get("features").and_then(Json::as_arr).context("request field 'features'")?;
    if arr.is_empty() {
        bail!("'features' must not be empty");
    }
    let mut features = Vec::new();
    if matches!(arr[0], Json::Arr(_)) {
        let mut cols = None;
        for (i, row) in arr.iter().enumerate() {
            let row = row.as_arr().with_context(|| format!("'features' row {i}"))?;
            match cols {
                None => cols = Some(row.len()),
                Some(c) if c != row.len() => bail!(
                    "'features' row {i} has {} values, row 0 has {c}",
                    row.len()
                ),
                Some(_) => {}
            }
            for v in row {
                features.push(v.as_f64().with_context(|| format!("'features' row {i}"))? as f32);
            }
        }
        Ok((arr.len(), features))
    } else {
        for v in arr {
            features.push(v.as_f64().context("'features' value")? as f32);
        }
        Ok((1, features))
    }
}

fn error_body(code: WireCode, message: &str) -> String {
    obj(vec![
        ("error", Json::Str(message.to_string())),
        ("code", Json::Num(code.code() as f64)),
        ("kind", Json::Str(code.tag().to_string())),
    ])
    .to_string()
}

/// Read one request from the stream; `buf` carries bytes left over from
/// the previous keep-alive request. `Ok(None)` is a clean close between
/// requests.
fn read_request<R: Read>(stream: &mut R, buf: &mut Vec<u8>) -> Result<Option<HttpRequest>> {
    let head_end = loop {
        if let Some(pos) = find_subslice(buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            bail!("request head exceeds {MAX_HEAD} bytes");
        }
        let mut chunk = [0u8; 1024];
        let n = match stream.read(&mut chunk) {
            Ok(n) => n,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading request head"),
        };
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            bail!("connection closed mid-request-head");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head =
        std::str::from_utf8(&buf[..head_end]).context("request head is not UTF-8")?.to_string();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    let version = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/") {
        bail!("malformed request line {request_line:?}");
    }
    let mut content_length = 0usize;
    let mut keep_alive = version == "HTTP/1.1";
    for line in lines {
        let Some((key, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        if key.eq_ignore_ascii_case("content-length") {
            content_length = value
                .parse()
                .with_context(|| format!("content-length {value:?}"))?;
        } else if key.eq_ignore_ascii_case("connection") {
            keep_alive = if version == "HTTP/1.1" {
                !value.eq_ignore_ascii_case("close")
            } else {
                value.eq_ignore_ascii_case("keep-alive")
            };
        }
    }
    if content_length > MAX_BODY {
        bail!("request body of {content_length} bytes exceeds cap {MAX_BODY}");
    }
    let body_start = head_end + 4;
    while buf.len() < body_start + content_length {
        let mut chunk = [0u8; 4096];
        let n = match stream.read(&mut chunk) {
            Ok(n) => n,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading request body"),
        };
        if n == 0 {
            bail!("connection closed mid-request-body");
        }
        buf.extend_from_slice(&chunk[..n]);
    }
    let body = buf[body_start..body_start + content_length].to_vec();
    buf.drain(..body_start + content_length);
    Ok(Some(HttpRequest { method, path, body, keep_alive }))
}

fn write_response(
    w: &mut dyn Write,
    status: u16,
    ctype: &str,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Error",
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(text: &str) -> Result<Option<HttpRequest>> {
        let mut cursor = Cursor::new(text.as_bytes().to_vec());
        let mut buf = Vec::new();
        read_request(&mut cursor, &mut buf)
    }

    #[test]
    fn requests_parse_with_bodies_and_keep_alive() {
        let r = req("POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd")
            .unwrap()
            .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/infer");
        assert_eq!(r.body, b"abcd");
        assert!(r.keep_alive, "HTTP/1.1 defaults to keep-alive");

        let r = req("GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(r.body.is_empty());
        assert!(!r.keep_alive);

        let r = req("GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive, "HTTP/1.0 defaults to close");
    }

    #[test]
    fn two_pipelined_requests_come_out_of_one_buffer() {
        let text = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let mut cursor = Cursor::new(text.as_bytes().to_vec());
        let mut buf = Vec::new();
        let a = read_request(&mut cursor, &mut buf).unwrap().unwrap();
        let b = read_request(&mut cursor, &mut buf).unwrap().unwrap();
        assert_eq!((a.path.as_str(), b.path.as_str()), ("/a", "/b"));
        assert!(read_request(&mut cursor, &mut buf).unwrap().is_none(), "clean EOF after");
    }

    #[test]
    fn malformed_and_oversized_requests_are_rejected() {
        assert!(req("nonsense\r\n\r\n").is_err(), "bad request line");
        assert!(req("GET / HTTP/1.1\r\nContent-Length: pony\r\n\r\n").is_err());
        let err = req(&format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY + 1
        ))
        .unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "{err:#}");
        // Truncated mid-head errors rather than returning a phantom request.
        assert!(req("GET / HTTP/1.1\r\nHost:").is_err());
    }

    #[test]
    fn feature_batches_parse_flat_and_nested() {
        let j = Json::parse(r#"{"features": [1, 2, 3]}"#).unwrap();
        assert_eq!(parse_features(&j).unwrap(), (1, vec![1.0, 2.0, 3.0]));
        let j = Json::parse(r#"{"features": [[1, 2], [3, 4]]}"#).unwrap();
        assert_eq!(parse_features(&j).unwrap(), (2, vec![1.0, 2.0, 3.0, 4.0]));
        let j = Json::parse(r#"{"features": [[1, 2], [3]]}"#).unwrap();
        assert!(parse_features(&j).is_err(), "ragged rows must fail");
        let j = Json::parse(r#"{"features": []}"#).unwrap();
        assert!(parse_features(&j).is_err(), "empty batch must fail");
    }

    #[test]
    fn responses_carry_status_line_and_content_length() {
        let mut out = Vec::new();
        write_response(&mut out, 429, CONTENT_JSON, "{}", false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
    }
}
