//! Network serving front-end: binary wire protocol, HTTP/JSON, and
//! multi-model hot-swap.
//!
//! The crate's serving stack ends at [`crate::server`] — an in-process
//! bounded worker pool behind a [`Client`](crate::server::Client). This
//! module puts a socket in front of it:
//!
//! - [`frame`] — the length-prefixed binary wire protocol + a tiny
//!   blocking [`WireClient`](frame::WireClient);
//! - [`http`] — a curl-able HTTP/1.1 path (`POST /v1/infer` JSON,
//!   `GET /metrics` Prometheus, `GET /healthz`, `GET /v1/models`);
//! - [`manager`] — a [`ModelManager`](manager::ModelManager) serving
//!   several named models from a manifest directory, with zero-downtime
//!   hot-swap when a `.nlut`/`.nfab` changes on disk;
//! - [`conn`] — the accept loop tying it together: one listener, both
//!   protocols sniffed on the same port, a connection cap, and typed
//!   admission control.
//!
//! # Framing grammar
//!
//! All integers little-endian. A binary connection opens with the 4-byte
//! preamble `"NLW1"` ([`frame::WIRE_PREAMBLE`]) — this is what lets one
//! port speak both protocols, since no HTTP method starts with it. After
//! the preamble, the stream is a sequence of frames:
//!
//! ```text
//! frame   := len:u32 payload          ; len = payload byte count,
//!                                     ; 1 ..= MAX_FRAME_LEN
//! payload := request | reply | error
//! request := 0x01 id:u32 name_len:u16 name:bytes rows:u32 cols:u32
//!            features:f32[rows*cols]  ; client -> server
//! reply   := 0x02 id:u32 rows:u32 predictions:u32[rows]
//! error   := 0x03 id:u32 code:u16 msg_len:u16 msg:bytes
//! ```
//!
//! Requests may be pipelined; replies come back in submission order
//! carrying the request's `id`. An `error` frame with `id = 0` is a
//! connection-level fault (malformed frame, over-cap refusal) and the
//! server closes the connection after sending it. `code` values are
//! stable ([`frame::WireCode`]) and shared with the HTTP status mapping:
//! overload is `1`/429, a missed deadline `4`/504, an unknown model
//! `5`/404.
//!
//! # Back-pressure contract
//!
//! The worker pool's bounded queue is the single admission point. Every
//! row of every request goes through the non-blocking
//! [`Client::try_infer`](crate::server::Client::try_infer): when the
//! queue is full the request is *refused* with a typed `Overloaded`
//! error (HTTP 429) immediately — the front door never blocks a
//! connection on queue space, and an accepted request is always
//! answered. Slow readers fill the per-connection reply pipeline and
//! then stop being read from (TCP back-pressure); connections over
//! [`NetConfig::max_connections`](conn::NetConfig) are refused with the
//! same typed overload before any work is admitted.
//!
//! Hot-swap rides the same guarantees: [`manager::ModelManager`]
//! re-loads a changed model file, atomically swaps the serving fabric
//! behind the name, and drops its handle on the old generation — whose
//! worker pool drains (answering everything already admitted) before
//! shutting down. In-flight requests finish on the generation that
//! admitted them; new requests land on the new one.

pub mod conn;
pub mod frame;
pub mod http;
pub mod manager;

pub use conn::{NetConfig, NetServer, MAX_CONNECTIONS_LIMIT};
pub use frame::{Frame, WireClient, WireCode, WireRefusal, MAX_FRAME_LEN, WIRE_PREAMBLE};
pub use manager::{ModelManager, Rescan, ServedModel};
