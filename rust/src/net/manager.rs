//! [`ModelManager`]: several named models served side by side from one
//! manifest directory, with zero-downtime hot-swap.
//!
//! The manager scans a directory for `*.nlut` networks; each one becomes
//! a named model (the file stem) backed by its own supervised
//! [`Server`] worker pool over a fabric compiled through
//! [`Model::compile_cached`] into a sibling `.nfab` artifact (plain
//! `compile` for non-persistable backends such as `scalar`). Lookups go
//! through an `RwLock<BTreeMap<..>>` of `Arc` entries, so the serving
//! hot path takes one read lock per request frame.
//!
//! # Hot-swap semantics
//!
//! [`rescan`](ModelManager::rescan) — called periodically by the
//! background digest watcher, or directly by tests/operators — fingerprints
//! every model's `.nlut` and sibling `.nfab` bytes (FNV-1a). A changed
//! fingerprint rebuilds the entry *outside* the map lock (traffic keeps
//! being served by the old fabric during the compile), then atomically
//! swaps the `Arc` in. In-flight requests hold the old entry's `Arc` and
//! drain on the old server; when the last reference drops, the old
//! worker pool shuts down gracefully (its queue drains — accepted
//! requests are answered, never dropped). A build failure (e.g. a
//! half-written file caught mid-copy) keeps the old entry serving and is
//! reported in the [`Rescan`] summary instead of taking the model down.
//!
//! Per-model counters (`neuralut_net_model_requests_total`,
//! `neuralut_net_hot_swaps_total`, `neuralut_net_model_generation`) live
//! in the manager's registry; [`metrics`](ModelManager::metrics) merges
//! them with every model's `neuralut_server_*` registry, each series
//! relabeled with `model="<name>"` so `/metrics` tells the per-model
//! story without collisions.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::fabric::{BackendRegistry, FabricOptions, Model, ModelInfo};
use crate::obs::{Counter, Gauge, MetricsRegistry, MetricsSnapshot};
use crate::server::{Client, Server};

/// One model being served: the compiled fabric's worker pool plus the
/// fingerprints the digest watcher compares against. Handed out as an
/// `Arc` so hot-swap is an atomic pointer swap and in-flight requests
/// drain on the generation they started on.
pub struct ServedModel {
    name: String,
    info: ModelInfo,
    /// Structural digest of the loaded network ([`crate::luts::LutNetwork::digest`]).
    digest: u64,
    /// FNV-1a of the `.nlut` file bytes at load time.
    nlut_sig: u64,
    /// FNV-1a of the sibling `.nfab` bytes (0 = absent).
    nfab_sig: u64,
    /// 1 for the first load, +1 per hot-swap.
    generation: u64,
    /// Keeps the worker pool alive; dropped last, which drains the queue.
    _server: Server,
    client: Client,
    /// Front-door accepted-rows counter (`model` label).
    requests: Counter,
}

impl ServedModel {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn info(&self) -> &ModelInfo {
        &self.info
    }

    /// Structural digest of the network this generation serves.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// Load generation: 1 initially, bumped by every hot-swap.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Submission handle into this model's worker pool.
    pub fn client(&self) -> &Client {
        &self.client
    }

    /// Count `rows` front-door-accepted feature rows for this model.
    pub fn count_rows(&self, rows: usize) {
        self.requests.add(rows as u64);
    }
}

/// Outcome of one [`ModelManager::rescan`] pass.
#[derive(Debug, Default, Clone)]
pub struct Rescan {
    /// Models loaded for the first time.
    pub added: Vec<String>,
    /// Models whose files changed and were atomically swapped.
    pub swapped: Vec<String>,
    /// Models whose files disappeared and were retired.
    pub removed: Vec<String>,
    /// `(name, error)` for files that failed to load/compile; the prior
    /// generation (if any) keeps serving.
    pub failed: Vec<(String, String)>,
}

/// Serves every `*.nlut` under a directory as a named model; see the
/// module docs for the hot-swap contract.
pub struct ModelManager {
    dir: PathBuf,
    opts: FabricOptions,
    /// Whether `opts`' backend can persist `.nfab` artifacts — decided
    /// once at open so rescan never re-resolves.
    persistable: bool,
    models: RwLock<BTreeMap<String, Arc<ServedModel>>>,
    /// Serializes rescans (watcher vs. explicit calls) without blocking
    /// the read-path map lock during compiles.
    scan_lock: Mutex<()>,
    registry: MetricsRegistry,
    models_gauge: Gauge,
    shutdown: Arc<AtomicBool>,
    watcher: Mutex<Option<JoinHandle<()>>>,
}

impl ModelManager {
    /// Scan `dir` and serve every `*.nlut` in it. Fails if the directory
    /// is unreadable or any initial model fails to load/compile — a bad
    /// manifest should fail at startup, loudly (later, while *serving*,
    /// the same failure merely keeps the old generation).
    pub fn open(dir: &Path, opts: &FabricOptions) -> Result<Arc<ModelManager>> {
        let persistable = BackendRegistry::global()
            .resolve(opts.backend_or_default())?
            .capabilities()
            .persistable;
        let registry = MetricsRegistry::new();
        for (name, help) in [
            ("neuralut_net_models", "models currently being served"),
            ("neuralut_net_model_requests_total", "feature rows accepted per model"),
            ("neuralut_net_hot_swaps_total", "zero-downtime model reloads per model"),
            ("neuralut_net_model_generation", "load generation per model (1 = first load)"),
        ] {
            registry.describe(name, help);
        }
        let models_gauge = registry.gauge("neuralut_net_models", &[]);
        let mgr = Arc::new(ModelManager {
            dir: dir.to_path_buf(),
            opts: opts.clone(),
            persistable,
            models: RwLock::new(BTreeMap::new()),
            scan_lock: Mutex::new(()),
            registry,
            models_gauge,
            shutdown: Arc::new(AtomicBool::new(false)),
            watcher: Mutex::new(None),
        });
        let first = mgr.rescan()?;
        if let Some((name, err)) = first.failed.first() {
            anyhow::bail!("model '{name}' failed to load: {err}");
        }
        Ok(mgr)
    }

    /// The manifest directory being watched.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Look up a model by name; the returned `Arc` pins its generation
    /// for the caller's lifetime (hot-swaps never yank it mid-request).
    pub fn get(&self, name: &str) -> Option<Arc<ServedModel>> {
        self.models.read().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
    }

    /// Currently served model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .cloned()
            .collect()
    }

    /// Every currently served model, sorted by name — a point-in-time
    /// snapshot; later hot-swaps do not disturb the returned `Arc`s.
    pub fn snapshot(&self) -> Vec<Arc<ServedModel>> {
        self.models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .cloned()
            .collect()
    }

    /// Number of models currently served.
    pub fn len(&self) -> usize {
        self.models.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One watcher pass: fingerprint every `*.nlut` (+ sibling `.nfab`)
    /// under the directory, build changed/new entries outside the map
    /// lock, swap them in atomically, retire entries whose files are
    /// gone. Never takes a healthy model down: per-file failures land in
    /// [`Rescan::failed`] while the old generation keeps serving.
    pub fn rescan(&self) -> Result<Rescan> {
        let _scan = self.scan_lock.lock().unwrap_or_else(|e| e.into_inner());
        let mut report = Rescan::default();
        let mut on_disk: Vec<(String, PathBuf)> = Vec::new();
        let entries = std::fs::read_dir(&self.dir)
            .with_context(|| format!("reading models dir {}", self.dir.display()))?;
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("nlut") {
                continue;
            }
            let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else { continue };
            on_disk.push((stem.to_string(), path.clone()));
        }
        on_disk.sort();
        for (name, path) in &on_disk {
            let nlut_sig = file_sig(path);
            let nfab_sig = file_sig(&path.with_extension("nfab"));
            let current = self.get(name);
            let changed = match &current {
                None => true,
                Some(cur) => cur.nlut_sig != nlut_sig || cur.nfab_sig != nfab_sig,
            };
            if !changed {
                continue;
            }
            let generation = current.as_ref().map_or(1, |c| c.generation + 1);
            match self.build(name, path, generation) {
                Ok(entry) => {
                    self.models
                        .write()
                        .unwrap_or_else(|e| e.into_inner())
                        .insert(name.clone(), Arc::new(entry));
                    if current.is_some() {
                        self.registry
                            .counter("neuralut_net_hot_swaps_total", &[("model", name)])
                            .inc();
                        report.swapped.push(name.clone());
                    } else {
                        report.added.push(name.clone());
                    }
                    // `current` (the displaced generation, if any) drops
                    // here — or later, when its last in-flight request
                    // finishes — draining the old worker pool gracefully.
                }
                Err(e) => report.failed.push((name.clone(), format!("{e:#}"))),
            }
        }
        let present: std::collections::BTreeSet<&str> =
            on_disk.iter().map(|(n, _)| n.as_str()).collect();
        let retired: Vec<String> = {
            let mut map = self.models.write().unwrap_or_else(|e| e.into_inner());
            let gone: Vec<String> = map
                .keys()
                .filter(|k| !present.contains(k.as_str()))
                .cloned()
                .collect();
            for name in &gone {
                map.remove(name);
            }
            gone
        };
        report.removed = retired;
        self.models_gauge.set(self.len() as f64);
        Ok(report)
    }

    /// Load + compile one model file into a fresh serving entry.
    fn build(&self, name: &str, path: &Path, generation: u64) -> Result<ServedModel> {
        let nlut_sig = file_sig(path);
        let model = Model::load(path)?;
        let nfab_path = path.with_extension("nfab");
        let fabric = if self.persistable {
            model.compile_cached(&self.opts, &nfab_path)?
        } else {
            model.compile(&self.opts)?
        };
        // Fingerprint the artifact *after* compile_cached may have
        // (re)written it, so an unchanged artifact doesn't re-trigger the
        // watcher on the next pass.
        let nfab_sig = file_sig(&nfab_path);
        let server = fabric.serve();
        let client = server.client();
        let requests =
            self.registry.counter("neuralut_net_model_requests_total", &[("model", name)]);
        self.registry
            .gauge("neuralut_net_model_generation", &[("model", name)])
            .set(generation as f64);
        Ok(ServedModel {
            name: name.to_string(),
            info: model.info(),
            digest: model.digest(),
            nlut_sig,
            nfab_sig,
            generation,
            _server: server,
            client,
            requests,
        })
    }

    /// Start the background digest watcher: every `interval` it rescans
    /// the directory and hot-swaps what changed. The thread holds only a
    /// `Weak` reference, so dropping the last manager `Arc` (or
    /// [`stop_watcher`](Self::stop_watcher)) winds it down.
    pub fn start_watcher(self: &Arc<Self>, interval: Duration) {
        let weak: Weak<ModelManager> = Arc::downgrade(self);
        let shutdown = self.shutdown.clone();
        let handle = std::thread::spawn(move || loop {
            // Sleep in slices so shutdown is prompt even for long intervals.
            let mut slept = Duration::ZERO;
            while slept < interval {
                if shutdown.load(Ordering::Acquire) {
                    return;
                }
                let slice = Duration::from_millis(50).min(interval - slept);
                std::thread::sleep(slice);
                slept += slice;
            }
            let Some(mgr) = weak.upgrade() else { return };
            if shutdown.load(Ordering::Acquire) {
                return;
            }
            if let Err(e) = mgr.rescan() {
                eprintln!("neuralut net: model rescan failed: {e:#}");
            }
        });
        *self.watcher.lock().unwrap_or_else(|e| e.into_inner()) = Some(handle);
    }

    /// Stop the digest watcher (idempotent; also runs on drop).
    pub fn stop_watcher(&self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.watcher.lock().unwrap_or_else(|e| e.into_inner()).take() {
            let _ = h.join();
        }
    }

    /// The manager's own registry snapshot (per-model request counters,
    /// hot-swap counters, generation gauges, model-count gauge) merged
    /// with every served model's `neuralut_server_*` registry, each
    /// server series relabeled with `model="<name>"` — the `/metrics`
    /// payload.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.registry.snapshot();
        let models: Vec<Arc<ServedModel>> = {
            let map = self.models.read().unwrap_or_else(|e| e.into_inner());
            map.values().cloned().collect()
        };
        for m in models {
            snap.merge(relabel(m.client.metrics(), "model", &m.name));
        }
        snap
    }
}

impl Drop for ModelManager {
    fn drop(&mut self) {
        self.stop_watcher();
    }
}

/// Add one label pair to every series in a snapshot (keeping label lists
/// sorted, as the registry does), so per-model server registries merge
/// without colliding.
fn relabel(mut snap: MetricsSnapshot, key: &str, value: &str) -> MetricsSnapshot {
    let pair = (key.to_string(), value.to_string());
    for c in &mut snap.counters {
        c.labels.push(pair.clone());
        c.labels.sort();
    }
    for g in &mut snap.gauges {
        g.labels.push(pair.clone());
        g.labels.sort();
    }
    for h in &mut snap.histograms {
        h.labels.push(pair.clone());
        h.labels.sort();
    }
    snap
}

/// FNV-1a fingerprint of a file's bytes; 0 when the file is missing or
/// unreadable (so "absent" and "appeared" always compare as a change).
fn file_sig(path: &Path) -> u64 {
    match std::fs::read(path) {
        Ok(bytes) => {
            let mut h = 0xcbf2_9ce4_8422_2325u64;
            for &b in &bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            // Reserve 0 for "missing".
            if h == 0 { 1 } else { h }
        }
        Err(_) => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("neuralut_mgr_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn serves_every_nlut_in_the_directory_by_stem() {
        let dir = tmp_dir("scan");
        random_network(1, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("alpha.nlut")).unwrap();
        random_network(2, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("beta.nlut")).unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let mgr = ModelManager::open(&dir, &FabricOptions::new()).unwrap();
        assert_eq!(mgr.names(), vec!["alpha".to_string(), "beta".to_string()]);
        assert_eq!(mgr.len(), 2);
        let alpha = mgr.get("alpha").unwrap();
        assert_eq!(alpha.generation(), 1);
        assert!(mgr.get("gamma").is_none());
        let snap = mgr.metrics();
        assert_eq!(snap.gauge("neuralut_net_models", &[]).unwrap().value, 2.0);
        // Per-model server registries arrive relabeled, not colliding.
        assert!(snap
            .counter("neuralut_server_requests_served_total", &[("model", "alpha")])
            .is_some());
        assert!(snap
            .counter("neuralut_server_requests_served_total", &[("model", "beta")])
            .is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rescan_adds_swaps_and_removes() {
        let dir = tmp_dir("swap");
        random_network(3, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("m.nlut")).unwrap();
        let mgr = ModelManager::open(&dir, &FabricOptions::new()).unwrap();
        let before = mgr.get("m").unwrap();
        // No change -> no churn.
        let r = mgr.rescan().unwrap();
        assert!(r.added.is_empty() && r.swapped.is_empty() && r.removed.is_empty());
        assert!(Arc::ptr_eq(&before, &mgr.get("m").unwrap()));
        // Overwrite with a different network -> swapped, generation bumps.
        random_network(4, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("m.nlut")).unwrap();
        let r = mgr.rescan().unwrap();
        assert_eq!(r.swapped, vec!["m".to_string()]);
        let after = mgr.get("m").unwrap();
        assert_eq!(after.generation(), 2);
        assert_ne!(after.digest(), before.digest());
        // The displaced generation still answers its own client.
        assert!(before.client().infer(vec![0.5; 8]).is_ok());
        // New file -> added; deleted file -> removed.
        random_network(5, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("n.nlut")).unwrap();
        std::fs::remove_file(dir.join("m.nlut")).unwrap();
        let r = mgr.rescan().unwrap();
        assert_eq!(r.added, vec!["n".to_string()]);
        assert_eq!(r.removed, vec!["m".to_string()]);
        assert!(mgr.get("m").is_none());
        let snap = mgr.metrics();
        assert_eq!(
            snap.counter("neuralut_net_hot_swaps_total", &[("model", "m")]).unwrap().value,
            1
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn a_corrupt_file_fails_open_but_not_a_running_manager() {
        let dir = tmp_dir("corrupt");
        random_network(6, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("ok.nlut")).unwrap();
        std::fs::write(dir.join("bad.nlut"), b"not a network").unwrap();
        // Startup: loud failure naming the model.
        let err = ModelManager::open(&dir, &FabricOptions::new()).unwrap_err().to_string();
        assert!(err.contains("bad"), "{err}");
        // Running: the corrupt file is reported, healthy models serve on.
        std::fs::remove_file(dir.join("bad.nlut")).unwrap();
        let mgr = ModelManager::open(&dir, &FabricOptions::new()).unwrap();
        std::fs::write(dir.join("bad.nlut"), b"still not a network").unwrap();
        let r = mgr.rescan().unwrap();
        assert_eq!(r.failed.len(), 1);
        assert_eq!(r.failed[0].0, "bad");
        assert!(mgr.get("ok").is_some());
        assert!(mgr.get("bad").is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistable_backends_compile_through_the_nfab_cache() {
        let dir = tmp_dir("cache");
        random_network(7, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("c.nlut")).unwrap();
        let opts = FabricOptions::new().backend("bitsliced");
        let mgr = ModelManager::open(&dir, &opts).unwrap();
        assert!(dir.join("c.nfab").exists(), "compile_cached writes the sibling artifact");
        // The artifact write itself must not read back as a change.
        let r = mgr.rescan().unwrap();
        assert!(r.swapped.is_empty(), "{r:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
