//! Length-prefixed binary wire codec: the framing grammar of the TCP
//! front door, plus the typed error-code mapping shared with the HTTP
//! path.
//!
//! # Framing grammar
//!
//! A binary connection opens with the 4-byte preamble [`WIRE_PREAMBLE`]
//! (`"NLW1"`), which is also how the listener distinguishes binary
//! clients from HTTP ones. After the preamble, both directions carry a
//! stream of frames:
//!
//! ```text
//! frame    := len:u32le payload            ; len = payload byte count,
//!                                          ;   1 ..= MAX_FRAME_LEN
//! payload  := request | reply | error      ; first byte discriminates
//! request  := 0x01 id:u32le name_len:u16le name:bytes
//!             rows:u32le cols:u32le feats:(rows*cols)*f32le
//! reply    := 0x02 id:u32le rows:u32le preds:rows*u32le
//! error    := 0x03 id:u32le code:u16le msg_len:u16le msg:bytes
//! ```
//!
//! `id` is a client-chosen correlation id echoed verbatim in the reply,
//! so a pipelining client can keep many requests in flight on one
//! connection. All integers are little-endian; features are IEEE-754
//! `f32`. The declared `len` is validated against [`MAX_FRAME_LEN`]
//! *before* any payload allocation, and every count inside the payload
//! (`name_len`, `rows`, `cols`) is checked against both its own cap and
//! the bytes actually present before the corresponding buffer is built —
//! the same reject-before-allocate discipline as the `.nfab`/`.nlut`
//! artifact readers. Decode errors carry the payload offset of the field
//! that failed.
//!
//! # Error codes
//!
//! [`WireCode`] assigns every [`ServerError`] variant a stable numeric
//! code and an HTTP status, plus front-door-only codes for requests that
//! never reach a server (unknown model, malformed request). The codes
//! are part of the wire contract: they never change meaning across
//! releases (new ones may be appended).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::server::ServerError;
use crate::util::faults;

/// First four bytes a binary client sends after connecting. Anything
/// else makes the listener treat the connection as HTTP.
pub const WIRE_PREAMBLE: [u8; 4] = *b"NLW1";
/// Hard cap on one frame's payload (16 MiB) — a declared length above
/// this is rejected before any allocation.
pub const MAX_FRAME_LEN: usize = 1 << 24;
/// Hard cap on a request's model-name length.
pub const MAX_MODEL_NAME: usize = 256;
/// Hard cap on feature rows in one request frame.
pub const MAX_ROWS_PER_FRAME: usize = 1 << 16;
/// Hard cap on features per row in one request frame.
pub const MAX_COLS_PER_ROW: usize = 1 << 20;

const KIND_REQUEST: u8 = 0x01;
const KIND_REPLY: u8 = 0x02;
const KIND_ERROR: u8 = 0x03;

// ---------------------------------------------------------------------------
// Error codes

/// Stable numeric refusal codes carried in `error` frames and mirrored
/// as HTTP statuses. Codes 1–4 are the [`ServerError`] variants
/// one-to-one; 5–7 are front-door conditions a request can hit before it
/// ever reaches a worker queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireCode {
    /// [`ServerError::Overloaded`] — also the connection-cap refusal.
    Overloaded,
    /// [`ServerError::Stopped`].
    Stopped,
    /// [`ServerError::WorkerCrashed`].
    WorkerCrashed,
    /// [`ServerError::DeadlineExceeded`].
    DeadlineExceeded,
    /// The request named a model this server is not serving.
    UnknownModel,
    /// The request was malformed (bad frame, wrong feature count,
    /// unparsable JSON body).
    BadRequest,
    /// Anything else — an untyped internal failure.
    Internal,
}

impl WireCode {
    /// The stable numeric code carried on the wire.
    pub fn code(self) -> u16 {
        match self {
            WireCode::Overloaded => 1,
            WireCode::Stopped => 2,
            WireCode::WorkerCrashed => 3,
            WireCode::DeadlineExceeded => 4,
            WireCode::UnknownModel => 5,
            WireCode::BadRequest => 6,
            WireCode::Internal => 7,
        }
    }

    /// Inverse of [`code`](Self::code); `None` for unassigned numbers.
    pub fn from_code(code: u16) -> Option<WireCode> {
        Some(match code {
            1 => WireCode::Overloaded,
            2 => WireCode::Stopped,
            3 => WireCode::WorkerCrashed,
            4 => WireCode::DeadlineExceeded,
            5 => WireCode::UnknownModel,
            6 => WireCode::BadRequest,
            7 => WireCode::Internal,
            _ => return None,
        })
    }

    /// The HTTP status the JSON path answers with for this refusal.
    pub fn http_status(self) -> u16 {
        match self {
            WireCode::Overloaded => 429,
            WireCode::Stopped => 503,
            WireCode::WorkerCrashed => 500,
            WireCode::DeadlineExceeded => 504,
            WireCode::UnknownModel => 404,
            WireCode::BadRequest => 400,
            WireCode::Internal => 500,
        }
    }

    /// Short machine-readable tag for JSON error bodies and metric labels.
    pub fn tag(self) -> &'static str {
        match self {
            WireCode::Overloaded => "overloaded",
            WireCode::Stopped => "stopped",
            WireCode::WorkerCrashed => "worker_crashed",
            WireCode::DeadlineExceeded => "deadline_exceeded",
            WireCode::UnknownModel => "unknown_model",
            WireCode::BadRequest => "bad_request",
            WireCode::Internal => "internal",
        }
    }

    /// The wire code for a typed [`ServerError`] — every variant maps.
    pub fn from_server_error(e: ServerError) -> WireCode {
        match e {
            ServerError::Overloaded => WireCode::Overloaded,
            ServerError::Stopped => WireCode::Stopped,
            ServerError::WorkerCrashed => WireCode::WorkerCrashed,
            ServerError::DeadlineExceeded => WireCode::DeadlineExceeded,
        }
    }

    /// Classify an `anyhow` error from the serving runtime: a
    /// downcastable [`ServerError`] keeps its typed code; anything else
    /// from the submission path is a malformed request (the only other
    /// thing `try_infer` rejects is a wrong feature count).
    pub fn classify(e: &anyhow::Error) -> WireCode {
        match e.downcast_ref::<ServerError>() {
            Some(&se) => WireCode::from_server_error(se),
            None => WireCode::BadRequest,
        }
    }
}

/// A typed refusal received over the wire — what [`WireClient::infer`]
/// returns inside the `anyhow` chain so callers can downcast and react,
/// mirroring how [`ServerError`] travels in-process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireRefusal {
    /// Numeric code; [`WireCode::from_code`] recovers the typed variant.
    pub code: u16,
    /// Server-provided human-readable detail.
    pub message: String,
}

impl std::fmt::Display for WireRefusal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match WireCode::from_code(self.code) {
            Some(c) => write!(f, "wire refusal {} ({}): {}", self.code, c.tag(), self.message),
            None => write!(f, "wire refusal {} (unknown code): {}", self.code, self.message),
        }
    }
}

impl std::error::Error for WireRefusal {}

// ---------------------------------------------------------------------------
// Frames

/// One decoded wire frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server: run `rows` feature rows (`features.len() ==
    /// rows * cols`) through the named model.
    Request { id: u32, model: String, rows: usize, features: Vec<f32> },
    /// Server → client: one prediction per request row.
    Reply { id: u32, predictions: Vec<u32> },
    /// Server → client: typed refusal; `id` echoes the request (0 when
    /// the failure predates a parsable id, e.g. a malformed frame).
    Error { id: u32, code: u16, message: String },
}

/// Byte cursor over one frame payload; every read carries the payload
/// offset into its error so truncation points are named exactly.
struct Cur<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let remain = self.buf.len() - self.off;
        if remain < n {
            bail!(
                "truncated frame: '{what}' at payload offset {} needs {n} bytes, \
                 {remain} remain",
                self.off
            );
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

impl Frame {
    /// Encode as a complete frame: length prefix + payload. Fails (rather
    /// than emitting an undecodable frame) when a field exceeds its wire
    /// cap.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let payload = match self {
            Frame::Request { id, model, rows, features } => {
                let name = model.as_bytes();
                if name.len() > MAX_MODEL_NAME {
                    bail!("model name is {} bytes (cap {MAX_MODEL_NAME})", name.len());
                }
                if *rows == 0 {
                    bail!("request frame needs at least one feature row");
                }
                if *rows > MAX_ROWS_PER_FRAME {
                    bail!("request has {rows} rows (cap {MAX_ROWS_PER_FRAME})");
                }
                if features.len() % rows != 0 {
                    bail!(
                        "feature count {} is not a multiple of rows {rows}",
                        features.len()
                    );
                }
                let cols = features.len() / rows;
                if cols == 0 || cols > MAX_COLS_PER_ROW {
                    bail!("request has {cols} features per row (1..={MAX_COLS_PER_ROW})");
                }
                let mut p = Vec::with_capacity(15 + name.len() + features.len() * 4);
                p.push(KIND_REQUEST);
                p.extend_from_slice(&id.to_le_bytes());
                p.extend_from_slice(&(name.len() as u16).to_le_bytes());
                p.extend_from_slice(name);
                p.extend_from_slice(&(*rows as u32).to_le_bytes());
                p.extend_from_slice(&(cols as u32).to_le_bytes());
                for f in features {
                    p.extend_from_slice(&f.to_le_bytes());
                }
                p
            }
            Frame::Reply { id, predictions } => {
                if predictions.len() > MAX_ROWS_PER_FRAME {
                    bail!(
                        "reply has {} predictions (cap {MAX_ROWS_PER_FRAME})",
                        predictions.len()
                    );
                }
                let mut p = Vec::with_capacity(9 + predictions.len() * 4);
                p.push(KIND_REPLY);
                p.extend_from_slice(&id.to_le_bytes());
                p.extend_from_slice(&(predictions.len() as u32).to_le_bytes());
                for pred in predictions {
                    p.extend_from_slice(&pred.to_le_bytes());
                }
                p
            }
            Frame::Error { id, code, message } => {
                let msg = message.as_bytes();
                // Truncate rather than fail: refusal detail is advisory.
                let msg = &msg[..msg.len().min(u16::MAX as usize)];
                let mut p = Vec::with_capacity(9 + msg.len());
                p.push(KIND_ERROR);
                p.extend_from_slice(&id.to_le_bytes());
                p.extend_from_slice(&code.to_le_bytes());
                p.extend_from_slice(&(msg.len() as u16).to_le_bytes());
                p.extend_from_slice(msg);
                p
            }
        };
        debug_assert!(payload.len() <= MAX_FRAME_LEN);
        let mut out = Vec::with_capacity(4 + payload.len());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        Ok(out)
    }

    /// Decode one frame payload (the bytes after the length prefix).
    /// Every count is validated against its cap and the bytes actually
    /// present *before* the corresponding buffer is allocated; errors
    /// carry the payload offset of the offending field.
    pub fn decode(payload: &[u8]) -> Result<Frame> {
        let mut c = Cur { buf: payload, off: 0 };
        let kind = c.u8("frame kind")?;
        match kind {
            KIND_REQUEST => {
                let id = c.u32("request id")?;
                let name_len = c.u16("name length")? as usize;
                if name_len > MAX_MODEL_NAME {
                    bail!(
                        "model name length {name_len} at payload offset 5 exceeds \
                         cap {MAX_MODEL_NAME}"
                    );
                }
                let name = c.take(name_len, "model name")?;
                let model = std::str::from_utf8(name)
                    .context("model name is not UTF-8")?
                    .to_string();
                let rows_off = c.off;
                let rows = c.u32("row count")? as usize;
                let cols = c.u32("column count")? as usize;
                if rows == 0 || rows > MAX_ROWS_PER_FRAME {
                    bail!(
                        "row count {rows} at payload offset {rows_off} out of range \
                         (1..={MAX_ROWS_PER_FRAME})"
                    );
                }
                if cols == 0 || cols > MAX_COLS_PER_ROW {
                    bail!(
                        "column count {cols} at payload offset {} out of range \
                         (1..={MAX_COLS_PER_ROW})",
                        rows_off + 4
                    );
                }
                // Reject-before-allocate: the feature buffer is sized from
                // rows*cols only after proving exactly that many bytes are
                // actually present (checked_mul so absurd counts cannot
                // wrap into a small allocation).
                let n_feats = rows
                    .checked_mul(cols)
                    .and_then(|n| n.checked_mul(4))
                    .with_context(|| format!("feature count {rows}x{cols} overflows"))?
                    / 4;
                let remain = payload.len() - c.off;
                if remain != n_feats * 4 {
                    bail!(
                        "request declares {rows}x{cols} features ({} bytes) at payload \
                         offset {}, but {remain} bytes remain",
                        n_feats * 4,
                        c.off
                    );
                }
                let bytes = c.take(n_feats * 4, "feature data")?;
                let features = bytes
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Ok(Frame::Request { id, model, rows, features })
            }
            KIND_REPLY => {
                let id = c.u32("reply id")?;
                let rows_off = c.off;
                let rows = c.u32("prediction count")? as usize;
                if rows > MAX_ROWS_PER_FRAME {
                    bail!(
                        "prediction count {rows} at payload offset {rows_off} exceeds \
                         cap {MAX_ROWS_PER_FRAME}"
                    );
                }
                let remain = payload.len() - c.off;
                if remain != rows * 4 {
                    bail!(
                        "reply declares {rows} predictions ({} bytes) at payload \
                         offset {}, but {remain} bytes remain",
                        rows * 4,
                        c.off
                    );
                }
                let bytes = c.take(rows * 4, "prediction data")?;
                let predictions = bytes
                    .chunks_exact(4)
                    .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Ok(Frame::Reply { id, predictions })
            }
            KIND_ERROR => {
                let id = c.u32("error id")?;
                let code = c.u16("error code")?;
                let msg_len = c.u16("message length")? as usize;
                let msg = c.take(msg_len, "error message")?;
                if c.off != payload.len() {
                    bail!(
                        "error frame has {} trailing bytes at payload offset {}",
                        payload.len() - c.off,
                        c.off
                    );
                }
                let message = String::from_utf8_lossy(msg).into_owned();
                Ok(Frame::Error { id, code, message })
            }
            other => bail!("unknown frame kind 0x{other:02x} at payload offset 0"),
        }
    }
}

/// Fill `buf` from `r`, riding out partial reads. `Ok(false)` = clean
/// EOF before the first byte; an EOF mid-buffer is an error.
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) if got == 0 => return Ok(false),
            Ok(0) => bail!(
                "connection closed mid-frame: got {got} of {} bytes",
                buf.len()
            ),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading from connection"),
        }
    }
    Ok(true)
}

/// Read one frame off `r`. `Ok(None)` = the peer closed cleanly between
/// frames. The declared payload length is bounds-checked against
/// [`MAX_FRAME_LEN`] *before* the payload buffer is allocated, so an
/// absurd prefix cannot trigger a giant allocation. The
/// [`faults::point::NET_READ`] fault point fires after the prefix is on
/// hand — an armed `error` here simulates a torn read.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let mut len_buf = [0u8; 4];
    if !read_full(r, &mut len_buf)? {
        return Ok(None);
    }
    faults::inject(faults::point::NET_READ)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 {
        bail!("frame declares an empty payload");
    }
    if len > MAX_FRAME_LEN {
        bail!("frame declares a {len}-byte payload (cap {MAX_FRAME_LEN}); rejected before allocation");
    }
    let mut payload = vec![0u8; len];
    if !read_full(r, &mut payload)? {
        bail!("connection closed before the {len}-byte frame payload");
    }
    Frame::decode(&payload).map(Some)
}

/// Encode and write one frame.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    w.write_all(&frame.encode()?).context("writing frame")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Client

/// Minimal blocking binary-protocol client: sends the preamble on
/// connect, then frames. Used by the example, the loopback tests and
/// `bench_net`; real clients in other languages only need the grammar in
/// the module docs.
pub struct WireClient {
    stream: TcpStream,
    next_id: u32,
}

impl WireClient {
    /// Connect and send the [`WIRE_PREAMBLE`].
    pub fn connect(addr: std::net::SocketAddr) -> Result<WireClient> {
        let mut stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.write_all(&WIRE_PREAMBLE).context("sending preamble")?;
        Ok(WireClient { stream, next_id: 1 })
    }

    /// Bound every receive so a dead server surfaces as an error, not a
    /// hung client.
    pub fn set_read_timeout(&self, timeout: Duration) -> Result<()> {
        self.stream.set_read_timeout(Some(timeout))?;
        Ok(())
    }

    /// Send one request frame without waiting for the reply (pipelining);
    /// returns the correlation id to match against.
    pub fn send(&mut self, model: &str, features: &[f32], rows: usize) -> Result<u32> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1).max(1);
        let frame = Frame::Request {
            id,
            model: model.to_string(),
            rows,
            features: features.to_vec(),
        };
        write_frame(&mut self.stream, &frame)
            .with_context(|| format!("sending request {id}"))?;
        Ok(id)
    }

    /// Read the next frame; an EOF here means the server hung up.
    pub fn recv(&mut self) -> Result<Frame> {
        read_frame(&mut self.stream)?
            .context("server closed the connection mid-conversation")
    }

    /// One full round trip: send a request, wait for its reply, return
    /// one prediction per row. A typed server refusal surfaces as a
    /// downcastable [`WireRefusal`].
    pub fn infer(&mut self, model: &str, features: &[f32], rows: usize) -> Result<Vec<u32>> {
        let want = self.send(model, features, rows)?;
        loop {
            match self.recv()? {
                Frame::Reply { id, predictions } if id == want => return Ok(predictions),
                Frame::Error { id, code, message } if id == want || id == 0 => {
                    return Err(WireRefusal { code, message }.into());
                }
                // A reply to an earlier pipelined request someone else
                // abandoned; skip it.
                Frame::Reply { .. } | Frame::Error { .. } => continue,
                Frame::Request { .. } => bail!("server sent a request frame"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(rows: usize, cols: usize) -> Frame {
        Frame::Request {
            id: 7,
            model: "digits".into(),
            rows,
            features: (0..rows * cols).map(|i| i as f32 / 10.0).collect(),
        }
    }

    #[test]
    fn frames_round_trip() {
        for frame in [
            req(1, 8),
            req(3, 4),
            Frame::Reply { id: 42, predictions: vec![0, 3, 1] },
            Frame::Reply { id: 1, predictions: vec![] },
            Frame::Error { id: 9, code: 1, message: "queue full".into() },
        ] {
            let bytes = frame.encode().unwrap();
            let mut r = &bytes[..];
            let back = read_frame(&mut r).unwrap().unwrap();
            assert_eq!(back, frame);
            assert!(r.is_empty(), "decoder must consume the whole frame");
        }
    }

    #[test]
    fn absurd_length_prefix_is_rejected_before_allocation() {
        // 4 GiB-ish declared payload; if the reader allocated first this
        // would OOM rather than error.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(u32::MAX).to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        let err = read_frame(&mut &bytes[..]).unwrap_err().to_string();
        assert!(err.contains("rejected before allocation"), "{err}");
        // Zero-length payloads are equally malformed.
        let err = read_frame(&mut &0u32.to_le_bytes()[..]).unwrap_err().to_string();
        assert!(err.contains("empty payload"), "{err}");
    }

    #[test]
    fn truncations_carry_offsets() {
        let full = req(2, 3).encode().unwrap();
        // Cut the stream mid-payload: read_frame reports how far it got.
        let err = read_frame(&mut &full[..10]).unwrap_err().to_string();
        assert!(err.contains("closed mid-frame"), "{err}");
        // Cut a *field* short inside an intact-length frame: decode names
        // the field and payload offset.
        let payload = &full[4..];
        let err = Frame::decode(&payload[..5]).unwrap_err().to_string();
        assert!(err.contains("name length") && err.contains("offset 5"), "{err}");
        // Declared feature block vs bytes present mismatch.
        let err = Frame::decode(&payload[..payload.len() - 4]).unwrap_err().to_string();
        assert!(err.contains("bytes remain"), "{err}");
    }

    #[test]
    fn absurd_counts_inside_the_payload_are_rejected() {
        // rows = u32::MAX with a tiny payload: checked_mul + presence
        // check must fire before the feature Vec is sized.
        let mut p = vec![KIND_REQUEST];
        p.extend_from_slice(&1u32.to_le_bytes()); // id
        p.extend_from_slice(&1u16.to_le_bytes()); // name_len
        p.push(b'm');
        p.extend_from_slice(&u32::MAX.to_le_bytes()); // rows
        p.extend_from_slice(&u32::MAX.to_le_bytes()); // cols
        let err = Frame::decode(&p).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // Oversized name length.
        let mut p = vec![KIND_REQUEST];
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&u16::MAX.to_le_bytes());
        let err = Frame::decode(&p).unwrap_err().to_string();
        assert!(err.contains("name length 65535"), "{err}");
        // Unknown kind.
        let err = Frame::decode(&[0x7f]).unwrap_err().to_string();
        assert!(err.contains("unknown frame kind 0x7f"), "{err}");
        // Zero rows is not a request.
        let mut p = vec![KIND_REQUEST];
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&1u16.to_le_bytes());
        p.push(b'm');
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&1u32.to_le_bytes());
        let err = Frame::decode(&p).unwrap_err().to_string();
        assert!(err.contains("row count 0"), "{err}");
    }

    /// Reader that returns at most `chunk` bytes per syscall, exercising
    /// the partial-read loop.
    struct Trickle<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
    }

    impl Read for Trickle<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.chunk.min(buf.len()).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn partial_reads_across_syscall_boundaries_reassemble() {
        let frame = req(4, 5);
        let bytes = frame.encode().unwrap();
        for chunk in [1, 2, 3, 7] {
            let mut r = Trickle { data: &bytes, pos: 0, chunk };
            assert_eq!(read_frame(&mut r).unwrap().unwrap(), frame);
        }
    }

    #[test]
    fn net_read_fault_point_poisons_the_read() {
        let guard = faults::arm_scoped("net.read:1:error", 3).unwrap();
        let bytes = req(1, 2).encode().unwrap();
        let err = read_frame(&mut &bytes[..]).unwrap_err().to_string();
        assert!(err.contains("net.read"), "{err}");
        assert_eq!(guard.fired(faults::point::NET_READ), 1);
        drop(guard);
        assert!(read_frame(&mut &bytes[..]).unwrap().is_some());
    }

    #[test]
    fn every_server_error_has_a_stable_wire_code_and_http_status() {
        for se in ServerError::ALL {
            let wc = WireCode::from_server_error(se);
            assert_eq!(WireCode::from_code(wc.code()), Some(wc), "{se}");
            let anyhow_err = anyhow::Error::from(se);
            assert_eq!(WireCode::classify(&anyhow_err), wc);
        }
        // The contract pins: codes and statuses are wire-stable.
        assert_eq!(WireCode::from_server_error(ServerError::Overloaded).code(), 1);
        assert_eq!(WireCode::from_server_error(ServerError::Stopped).code(), 2);
        assert_eq!(WireCode::from_server_error(ServerError::WorkerCrashed).code(), 3);
        assert_eq!(WireCode::from_server_error(ServerError::DeadlineExceeded).code(), 4);
        assert_eq!(WireCode::Overloaded.http_status(), 429);
        assert_eq!(WireCode::Stopped.http_status(), 503);
        assert_eq!(WireCode::WorkerCrashed.http_status(), 500);
        assert_eq!(WireCode::DeadlineExceeded.http_status(), 504);
        assert_eq!(WireCode::UnknownModel.http_status(), 404);
        assert_eq!(WireCode::BadRequest.http_status(), 400);
        // Non-ServerError submission failures classify as bad requests.
        assert_eq!(WireCode::classify(&anyhow::anyhow!("wrong length")), WireCode::BadRequest);
        assert_eq!(WireCode::from_code(0), None);
        assert_eq!(WireCode::from_code(99), None);
    }
}
