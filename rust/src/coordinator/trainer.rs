//! The training driver: Rust owns the event loop, seeding, batch order,
//! the SGDR schedule, metric logging and best-model tracking; XLA (via the
//! AOT `train_step.hlo.txt`) owns the math. Python is not involved.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::schedule::sgdr_lr;
use crate::data::Dataset;
use crate::manifest::Manifest;
use crate::nn::metrics::argmax_rows;
use crate::nn::params::ParamStore;
use crate::runtime::{from_literal, to_literal, HostTensor, Runtime};
use crate::util::rng::Rng;

/// Per-epoch training statistics.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub loss: f64,
    pub train_acc: f64,
    pub test_acc: f64,
    pub lr_last: f64,
    pub seconds: f64,
}

/// Output of a training run.
pub struct TrainResult {
    pub params: ParamStore,
    pub history: Vec<EpochStats>,
    pub test_acc: f64,
    pub steps: usize,
}

/// Training options (overrides on top of the manifest's recipe).
#[derive(Debug, Clone, Default)]
pub struct TrainOpts {
    pub epochs: Option<usize>,
    pub max_train: Option<usize>,
    pub max_test: Option<usize>,
    pub quiet: bool,
    /// Evaluate the test set every `eval_every` epochs (0 = only after the
    /// final epoch — sweeps use this: per-epoch eval costs ~15 fwd
    /// executions per epoch and is monitoring, not result).
    pub eval_every: usize,
}

/// The coordinator's training loop for one (manifest, dataset, seed).
pub struct Trainer<'a> {
    rt: &'a Runtime,
    m: &'a Manifest,
    ds: &'a Dataset,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, m: &'a Manifest, ds: &'a Dataset) -> Result<Self> {
        if ds.n_feat != m.input_size {
            bail!(
                "dataset has {} features, model expects {}",
                ds.n_feat,
                m.input_size
            );
        }
        Ok(Trainer { rt, m, ds })
    }

    /// Run training; returns trained parameters + history.
    pub fn run(&self, seed: u64, opts: &TrainOpts) -> Result<TrainResult> {
        let m = self.m;
        let init = self.rt.load_artifact(m, "init")?;
        let step_exe = self.rt.load_artifact(m, "train_step")?;
        let n = m.params.len();
        let b = m.batch;
        let n_train = self
            .ds
            .n_train()
            .min(opts.max_train.unwrap_or(usize::MAX));
        let steps_per_epoch = n_train / b;
        if steps_per_epoch == 0 {
            bail!("batch {} larger than training set {}", b, n_train);
        }
        let epochs = opts.epochs.unwrap_or(m.epochs);

        // --- init params from the seed (jax.random inside the HLO) --------
        let mut state = init
            .run_raw(&[to_literal(&HostTensor::scalar_i32(seed as i32))?])
            .context("running init")?;
        if state.len() != n {
            bail!("init returned {} tensors, expected {n}", state.len());
        }
        // Optimizer state m, v start at zero: build zero literals matching
        // the param shapes.
        let zeros: Vec<xla::Literal> = m
            .params
            .iter()
            .map(|p| {
                to_literal(&HostTensor::f32(
                    p.shape.clone(),
                    vec![0.0; p.elem_count()],
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let mut opt_m: Vec<xla::Literal> = zeros.clone();
        let mut opt_v: Vec<xla::Literal> = zeros;

        let mut rng = Rng::new(seed ^ 0x5EED);
        let mut history = Vec::new();
        let mut step = 0usize;
        let mut order: Vec<usize> = (0..n_train).collect();

        for epoch in 0..epochs {
            let t0 = Instant::now();
            rng.shuffle(&mut order);
            let mut loss_sum = 0.0;
            let mut acc_sum = 0.0;
            let mut lr_last = 0.0;
            for batch_i in 0..steps_per_epoch {
                let rows = &order[batch_i * b..(batch_i + 1) * b];
                let (x, y) = self.gather_batch(rows);
                let lr = sgdr_lr(
                    m.lr_min,
                    m.lr_max,
                    m.sgdr_t0,
                    m.sgdr_mult,
                    steps_per_epoch,
                    step,
                );
                lr_last = lr;
                // Flat ABI: params..., m..., v..., step, lr, x, y.
                let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * n + 4);
                args.extend(state.iter());
                args.extend(opt_m.iter());
                args.extend(opt_v.iter());
                let step_lit =
                    to_literal(&HostTensor::scalar_f32((step + 1) as f32))?;
                let lr_lit = to_literal(&HostTensor::scalar_f32(lr as f32))?;
                let x_lit =
                    to_literal(&HostTensor::f32(vec![b, m.input_size], x))?;
                let y_lit = to_literal(&HostTensor::i32(vec![b], y))?;
                args.push(&step_lit);
                args.push(&lr_lit);
                args.push(&x_lit);
                args.push(&y_lit);

                let mut out = step_exe
                    .run_literals_refs(&args)
                    .with_context(|| format!("train step {step}"))?;
                if out.len() != 3 * n + 2 {
                    bail!("train step returned {} outputs", out.len());
                }
                let acc = from_literal(&out.pop().unwrap())?.as_f32()?[0];
                let loss = from_literal(&out.pop().unwrap())?.as_f32()?[0];
                opt_v = out.split_off(2 * n);
                opt_m = out.split_off(n);
                state = out;
                loss_sum += loss as f64;
                acc_sum += acc as f64;
                step += 1;
            }

            let do_eval = opts.eval_every > 0 && (epoch + 1) % opts.eval_every == 0;
            let test_acc = if do_eval {
                let params = self.literals_to_store(&state)?;
                self.evaluate(&params, opts.max_test)?
            } else {
                f64::NAN
            };
            let stats = EpochStats {
                epoch,
                loss: loss_sum / steps_per_epoch as f64,
                train_acc: acc_sum / steps_per_epoch as f64,
                test_acc,
                lr_last,
                seconds: t0.elapsed().as_secs_f64(),
            };
            if !opts.quiet {
                println!(
                    "[train {}] epoch {:>3}: loss {:.4} train_acc {:.4} test_acc {:.4} lr {:.2e} ({:.1}s)",
                    m.name, epoch, stats.loss, stats.train_acc, stats.test_acc,
                    stats.lr_last, stats.seconds
                );
            }
            history.push(stats);
        }

        let params = self.literals_to_store(&state)?;
        let test_acc = self.evaluate(&params, opts.max_test)?;
        Ok(TrainResult { params, history, test_acc, steps: step })
    }

    fn gather_batch(&self, rows: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let nf = self.ds.n_feat;
        let mut x = Vec::with_capacity(rows.len() * nf);
        let mut y = Vec::with_capacity(rows.len());
        for &r in rows {
            x.extend_from_slice(self.ds.train_row(r));
            y.push(self.ds.train_y[r]);
        }
        (x, y)
    }

    fn literals_to_store(&self, lits: &[xla::Literal]) -> Result<ParamStore> {
        let tensors = lits
            .iter()
            .map(from_literal)
            .collect::<Result<Vec<_>>>()?;
        ParamStore::new(self.m, tensors)
    }

    /// Quantized-model test accuracy via the AOT `fwd` program.
    pub fn evaluate(&self, params: &ParamStore, max_test: Option<usize>) -> Result<f64> {
        let m = self.m;
        let fwd = self.rt.load_artifact(m, "fwd")?;
        let b = m.batch;
        let n_test = self.ds.n_test().min(max_test.unwrap_or(usize::MAX));
        let param_lits: Vec<xla::Literal> = params
            .tensors
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>>>()?;
        let mut hits = 0usize;
        let mut total = 0usize;
        let mut i = 0;
        while i < n_test {
            let take = b.min(n_test - i);
            // Pad the final batch to the compiled batch size.
            let mut x = Vec::with_capacity(b * m.input_size);
            for j in 0..take {
                x.extend_from_slice(self.ds.test_row(i + j));
            }
            x.resize(b * m.input_size, 0.0);
            let x_lit = to_literal(&HostTensor::f32(vec![b, m.input_size], x))?;
            let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
            args.push(&x_lit);
            let out = fwd.run_literals_refs(&args)?;
            let logits = from_literal(&out[0])?;
            let preds = argmax_rows(logits.as_f32()?, m.n_class);
            for j in 0..take {
                if preds[j] as i32 == self.ds.test_y[i + j] {
                    hits += 1;
                }
            }
            total += take;
            i += take;
        }
        Ok(hits as f64 / total.max(1) as f64)
    }

    /// Full-test-set logits via the AOT `fwd` program (for the exactness
    /// integration test against the netlist simulator).
    pub fn predict(&self, params: &ParamStore, x_rows: &[f32]) -> Result<Vec<u32>> {
        let m = self.m;
        let fwd = self.rt.load_artifact(m, "fwd")?;
        let b = m.batch;
        let n = x_rows.len() / m.input_size;
        let param_lits: Vec<xla::Literal> = params
            .tensors
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>>>()?;
        let mut preds = Vec::with_capacity(n);
        let mut i = 0;
        while i < n {
            let take = b.min(n - i);
            let mut x = x_rows[i * m.input_size..(i + take) * m.input_size].to_vec();
            x.resize(b * m.input_size, 0.0);
            let x_lit = to_literal(&HostTensor::f32(vec![b, m.input_size], x))?;
            let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
            args.push(&x_lit);
            let out = fwd.run_literals_refs(&args)?;
            let logits = from_literal(&out[0])?;
            let p = argmax_rows(logits.as_f32()?, m.n_class);
            preds.extend_from_slice(&p[..take]);
            i += take;
        }
        Ok(preds)
    }
}
