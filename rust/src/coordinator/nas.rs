//! Circuit-topology search — the paper's §V future-work direction
//! ("explore automated search techniques like NAS to optimize NeuraLUT's
//! circuit-level topology"), implemented as successive-halving random
//! search over the *already-lowered* artifact bundles.
//!
//! Because shapes are baked into the AOT programs, the search space here is
//! the set of built bundles (plus seeds) rather than free-form widths —
//! candidates are (config, seed) pairs, scored by an accuracy / area-delay
//! trade-off. Successive halving trains every candidate for a small epoch
//! budget, keeps the top half, doubles the budget, and repeats — so poor
//! topologies cost little. For a free-form space, regenerate bundles with
//! `python -m compile.aot --configs ...` from a generated config list.

use anyhow::Result;

use super::experiments::{run_config, RunSummary};
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// A scored candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub config: String,
    pub seed: u64,
    pub summary: Option<RunSummary>,
    pub score: f64,
}

/// Search options.
#[derive(Debug, Clone)]
pub struct NasOpts {
    /// Starting epoch budget per candidate.
    pub base_epochs: usize,
    /// Number of halving rounds (budget doubles each round).
    pub rounds: usize,
    /// Trade-off weight: score = accuracy − lambda · log10(area_delay).
    pub lambda: f64,
    /// Seeds sampled per config.
    pub seeds_per_config: usize,
}

impl Default for NasOpts {
    fn default() -> Self {
        NasOpts { base_epochs: 2, rounds: 3, lambda: 0.02, seeds_per_config: 2 }
    }
}

/// Score an evaluated run (higher is better).
pub fn score(summary: &RunSummary, lambda: f64) -> f64 {
    summary.fabric_acc - lambda * summary.area_delay.max(1.0).log10()
}

/// Successive-halving search over `configs`; returns candidates sorted by
/// final score (best first). Only survivors of the last round carry a
/// full-budget summary.
pub fn search(rt: &Runtime, configs: &[String], opts: &NasOpts, seed: u64)
              -> Result<Vec<Candidate>> {
    let mut rng = Rng::new(seed);
    let mut pool: Vec<Candidate> = configs
        .iter()
        .flat_map(|c| {
            (0..opts.seeds_per_config).map(|_| Candidate {
                config: c.clone(),
                seed: rng.next_u64() % 1000,
                summary: None,
                score: f64::NEG_INFINITY,
            }).collect::<Vec<_>>()
        })
        .collect();

    let mut epochs = opts.base_epochs;
    for round in 0..opts.rounds {
        for cand in pool.iter_mut() {
            let s = run_config(rt, &cand.config, cand.seed, Some(epochs))?;
            cand.score = score(&s, opts.lambda);
            cand.summary = Some(s);
        }
        pool.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        let keep = (pool.len() / 2).max(1);
        if round + 1 < opts.rounds {
            pool.truncate(keep);
            epochs *= 2;
        }
    }
    Ok(pool)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(acc: f64, adp: f64) -> RunSummary {
        RunSummary {
            config: "x".into(),
            mode: "neuralut".into(),
            seed: 0,
            fabric_acc: acc,
            model_acc: acc,
            luts: 100,
            ffs: 10,
            fmax_mhz: 500.0,
            latency_ns: 4.0,
            latency_cycles: 2,
            area_delay: adp,
            l_luts: 10,
            bdd_nodes: 100,
            train_seconds: 1.0,
        }
    }

    #[test]
    fn score_prefers_accuracy_then_area() {
        let better_acc = score(&summary(0.95, 1e4), 0.02);
        let worse_acc = score(&summary(0.90, 1e4), 0.02);
        assert!(better_acc > worse_acc);
        let small = score(&summary(0.90, 1e3), 0.02);
        let large = score(&summary(0.90, 1e6), 0.02);
        assert!(small > large);
    }

    #[test]
    fn default_opts_sane() {
        let o = NasOpts::default();
        assert!(o.rounds >= 1 && o.base_epochs >= 1);
    }
}
