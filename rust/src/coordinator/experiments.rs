//! Shared harness for the paper-reproduction experiment binaries
//! (`examples/repro_*.rs`): run the full codesign pipeline for a named
//! artifact bundle and collect the metrics every table/figure needs.

use std::path::PathBuf;

use anyhow::{Context, Result};

use super::pipeline::{self, PipelineOpts, PipelineResult};
use super::trainer::TrainOpts;
use crate::data::Dataset;
use crate::manifest::Manifest;
use crate::runtime::Runtime;
use crate::util::json::{obj, Json};

/// One experiment run's summary row.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub config: String,
    pub mode: String,
    pub seed: u64,
    pub fabric_acc: f64,
    pub model_acc: f64,
    pub luts: usize,
    pub ffs: usize,
    pub fmax_mhz: f64,
    pub latency_ns: f64,
    pub latency_cycles: usize,
    pub area_delay: f64,
    pub l_luts: usize,
    pub bdd_nodes: usize,
    pub train_seconds: f64,
}

impl RunSummary {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("config", Json::Str(self.config.clone())),
            ("mode", Json::Str(self.mode.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("fabric_acc", Json::Num(self.fabric_acc)),
            ("model_acc", Json::Num(self.model_acc)),
            ("luts", Json::Num(self.luts as f64)),
            ("ffs", Json::Num(self.ffs as f64)),
            ("fmax_mhz", Json::Num(self.fmax_mhz)),
            ("latency_ns", Json::Num(self.latency_ns)),
            ("latency_cycles", Json::Num(self.latency_cycles as f64)),
            ("area_delay", Json::Num(self.area_delay)),
            ("l_luts", Json::Num(self.l_luts as f64)),
            ("bdd_nodes", Json::Num(self.bdd_nodes as f64)),
            ("train_seconds", Json::Num(self.train_seconds)),
        ])
    }
}

/// Execute the pipeline for `config` and summarize. Evicts previously
/// cached executables first: sweeps visit many configs and compiled XLA
/// programs are memory-heavy.
pub fn run_config(rt: &Runtime, config: &str, seed: u64,
                  epochs: Option<usize>) -> Result<RunSummary> {
    let dir = crate::artifacts_dir().join(config);
    rt.evict_other_bundles(&dir);
    let m = Manifest::load(&dir)
        .with_context(|| format!("bundle '{config}' (run `make artifacts`)"))?;
    let ds = Dataset::load_named(&m.dataset)?;
    let t0 = std::time::Instant::now();
    let opts = PipelineOpts {
        train: TrainOpts { epochs, quiet: true, ..Default::default() },
        verify_samples: Some(2048),
        out_dir: None,
        emit_rtl: false,
    };
    let r: PipelineResult = pipeline::run(rt, &m, &ds, seed, &opts)?;
    pipeline::verify_consistent(&r, 0.05)?;
    Ok(RunSummary {
        config: config.to_string(),
        mode: m.mode.clone(),
        seed,
        fabric_acc: r.sim_acc,
        model_acc: r.model_acc,
        luts: r.synth.luts,
        ffs: r.synth.ffs,
        fmax_mhz: r.synth.fmax_mhz,
        latency_ns: r.synth.latency_ns,
        latency_cycles: r.synth.latency_cycles,
        area_delay: r.synth.area_delay,
        l_luts: r.net.num_luts(),
        bdd_nodes: r.synth.bdd_nodes,
        train_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Number of seeds for sweep experiments (`NEURALUT_SEEDS`, default 3).
pub fn n_seeds() -> usize {
    std::env::var("NEURALUT_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
}

/// Optional epoch override for quick runs (`NEURALUT_EPOCHS`).
pub fn epochs_override() -> Option<usize> {
    std::env::var("NEURALUT_EPOCHS").ok().and_then(|v| v.parse().ok())
}

/// Append result rows to `artifacts/results/<experiment>.json`.
pub fn save_results(experiment: &str, rows: &[RunSummary]) -> Result<PathBuf> {
    let dir = crate::artifacts_dir().join("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{experiment}.json"));
    let arr = Json::Arr(rows.iter().map(|r| r.to_json()).collect());
    std::fs::write(&path, arr.to_string())?;
    Ok(path)
}

/// Mean ± std of a metric across seeds.
pub fn mean_std(rows: &[RunSummary], f: impl Fn(&RunSummary) -> f64) -> (f64, f64) {
    let s = crate::util::stats::summarize(
        &rows.iter().map(f).collect::<Vec<_>>(),
    );
    (s.mean, if s.std.is_nan() { 0.0 } else { s.std })
}
