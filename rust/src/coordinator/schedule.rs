//! SGDR — Stochastic Gradient Descent with Warm Restarts [24].
//!
//! The coordinator owns the learning-rate schedule and feeds the per-step
//! LR into the AOT train step as a scalar. This must match
//! `python/compile/train.py::sgdr_lr` exactly (the Python copy exists for
//! tests/documentation; this one is the one that runs).

/// Cosine schedule with warm restarts: period starts at
/// `t0_epochs * steps_per_epoch` steps and multiplies by `mult` after each
/// restart. `step` counts from 0.
pub fn sgdr_lr(
    lr_min: f64,
    lr_max: f64,
    t0_epochs: usize,
    mult: usize,
    steps_per_epoch: usize,
    step: usize,
) -> f64 {
    let mut t = step;
    let mut period = (t0_epochs * steps_per_epoch).max(1);
    while t >= period {
        t -= period;
        period *= mult.max(1);
    }
    let frac = t as f64 / period as f64;
    lr_min + 0.5 * (lr_max - lr_min) * (1.0 + (std::f64::consts::PI * frac).cos())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_max_and_decays() {
        let lr0 = sgdr_lr(1e-4, 1e-2, 5, 2, 100, 0);
        assert!((lr0 - 1e-2).abs() < 1e-12);
        let mid = sgdr_lr(1e-4, 1e-2, 5, 2, 100, 250);
        assert!((mid - (1e-4 + 0.5 * (1e-2 - 1e-4))).abs() < 1e-9);
        let end = sgdr_lr(1e-4, 1e-2, 5, 2, 100, 499);
        assert!(end < 2e-4);
    }

    #[test]
    fn warm_restart_resets_to_max() {
        // First period: 500 steps; at step 500 the LR jumps back to max.
        let just_before = sgdr_lr(1e-4, 1e-2, 5, 2, 100, 499);
        let at_restart = sgdr_lr(1e-4, 1e-2, 5, 2, 100, 500);
        assert!(at_restart > just_before * 10.0);
        assert!((at_restart - 1e-2).abs() < 1e-12);
        // Second period is twice as long: next restart at 500 + 1000.
        let second = sgdr_lr(1e-4, 1e-2, 5, 2, 100, 1500);
        assert!((second - 1e-2).abs() < 1e-12);
    }

    #[test]
    fn never_outside_bounds() {
        for step in 0..5000 {
            let lr = sgdr_lr(1e-4, 1e-2, 3, 2, 37, step);
            assert!(lr >= 1e-4 - 1e-12 && lr <= 1e-2 + 1e-12);
        }
    }
}
