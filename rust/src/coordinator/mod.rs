//! Layer-3 coordination: the training driver that executes AOT train
//! steps, the SGDR schedule, and the end-to-end codesign pipeline
//! (train → convert → verify → RTL → synth).

pub mod experiments;
pub mod nas;
pub mod pipeline;
pub mod schedule;
pub mod trainer;
