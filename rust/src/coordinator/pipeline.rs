//! The end-to-end codesign pipeline (paper Fig. 4): QAT training →
//! sub-network → L-LUT conversion → bit-exactness verification → RTL
//! generation → synthesis estimation. One call drives the whole toolflow
//! and returns everything the experiment harnesses need.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::trainer::{TrainOpts, Trainer, TrainResult};
use crate::data::Dataset;
use crate::luts::{convert, LutNetwork};
use crate::manifest::Manifest;
use crate::netlist::Simulator;
use crate::runtime::Runtime;
use crate::synth::{synthesize, SynthReport};
use crate::util::json::{obj, Json};

/// Everything one pipeline run produces.
///
/// Accuracy semantics (DESIGN.md §3): the converted L-LUT fabric is the
/// *authoritative* model — `sim_acc` is the number every experiment
/// reports, exactly as the paper reports post-conversion hardware results.
/// `model_acc` is the float (fwd HLO) monitoring number; it can diverge
/// from the fabric on samples whose activations land within an ULP of a
/// quantizer decision boundary (the two AOT programs are compiled
/// separately and transcendental ops differ at ULP level), and those flips
/// cascade through deep circuits. `divergence = mismatches / n_verified`
/// quantifies this; within one toolchain the conversion itself is exact
/// (pytest `test_exactness.py` proves fwd ≡ table-replay bit-for-bit).
pub struct PipelineResult {
    pub train: TrainResult,
    pub net: LutNetwork,
    pub synth: SynthReport,
    /// Float-model (XLA fwd) test accuracy — training-time monitoring.
    pub model_acc: f64,
    /// Fabric (netlist simulator) test accuracy — the authoritative number.
    pub sim_acc: f64,
    /// Prediction flips between the float monitor and the fabric.
    pub mismatches: usize,
    pub n_verified: usize,
}

/// Options for a pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineOpts {
    pub train: TrainOpts,
    /// Cap on test samples used for the exactness verification.
    pub verify_samples: Option<usize>,
    /// Where to persist params / network / RTL (None = don't persist).
    pub out_dir: Option<PathBuf>,
    /// Emit the RTL bundle as part of the run.
    pub emit_rtl: bool,
}

/// Run the full codesign loop for one (bundle, dataset, seed).
pub fn run(rt: &Runtime, m: &Manifest, ds: &Dataset, seed: u64,
           opts: &PipelineOpts) -> Result<PipelineResult> {
    let trainer = Trainer::new(rt, m, ds)?;
    let train = trainer.run(seed, &opts.train).context("training")?;

    let net = convert::convert(rt, m, &train.params).context("conversion")?;

    // Bit-exactness verification: quantized XLA model vs netlist sim.
    let n_verify = ds
        .n_test()
        .min(opts.verify_samples.unwrap_or(usize::MAX));
    let x = &ds.test_x[..n_verify * ds.n_feat];
    let model_preds = trainer.predict(&train.params, x)?;
    let sim = Simulator::new(&net);
    let sim_res = sim.simulate_batch(x);
    let mismatches = model_preds
        .iter()
        .zip(&sim_res.predictions)
        .filter(|(a, b)| a != b)
        .count();
    let labels = &ds.test_y[..n_verify];
    let model_acc = crate::nn::metrics::accuracy(&model_preds, labels);
    let sim_acc = crate::nn::metrics::accuracy(&sim_res.predictions, labels);

    let synth = synthesize(&net);

    if let Some(dir) = &opts.out_dir {
        std::fs::create_dir_all(dir)?;
        train.params.save(&dir.join("params.nprm"))?;
        net.save(&dir.join("network.nlut"))?;
        if opts.emit_rtl {
            crate::rtl::write_rtl_bundle(&net, &dir.join("rtl"), x, 64.min(n_verify))?;
        }
        let report = result_json(m, &train, &synth, model_acc, sim_acc, mismatches, n_verify);
        std::fs::write(dir.join("result.json"), report.to_string())?;
    }

    Ok(PipelineResult {
        train,
        net,
        synth,
        model_acc,
        sim_acc,
        mismatches,
        n_verified: n_verify,
    })
}

/// Sanity-check float-monitor vs fabric agreement: the two may flip
/// quantizer-boundary samples (see [`PipelineResult`] docs), but their
/// *accuracies* must agree closely — a large gap indicates a real
/// conversion bug rather than boundary noise.
pub fn verify_consistent(r: &PipelineResult, max_acc_gap: f64) -> Result<()> {
    let gap = (r.model_acc - r.sim_acc).abs();
    if gap > max_acc_gap {
        bail!(
            "float-model accuracy {:.4} and fabric accuracy {:.4} differ by \
             {:.4} (> {:.4}): conversion is suspect",
            r.model_acc,
            r.sim_acc,
            gap,
            max_acc_gap
        );
    }
    Ok(())
}

fn result_json(m: &Manifest, train: &TrainResult, synth: &SynthReport,
               model_acc: f64, sim_acc: f64, mismatches: usize,
               n_verified: usize) -> Json {
    obj(vec![
        ("name", Json::Str(m.name.clone())),
        ("mode", Json::Str(m.mode.clone())),
        ("test_acc", Json::Num(train.test_acc)),
        ("model_acc", Json::Num(model_acc)),
        ("sim_acc", Json::Num(sim_acc)),
        ("mismatches", Json::Num(mismatches as f64)),
        ("n_verified", Json::Num(n_verified as f64)),
        ("steps", Json::Num(train.steps as f64)),
        ("luts", Json::Num(synth.luts as f64)),
        ("ffs", Json::Num(synth.ffs as f64)),
        ("fmax_mhz", Json::Num(synth.fmax_mhz)),
        ("latency_ns", Json::Num(synth.latency_ns)),
        ("latency_cycles", Json::Num(synth.latency_cycles as f64)),
        ("area_delay", Json::Num(synth.area_delay)),
        ("bdd_nodes", Json::Num(synth.bdd_nodes as f64)),
    ])
}
