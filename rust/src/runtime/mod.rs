//! PJRT runtime: load AOT-compiled HLO text, compile once, execute many.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute`). All executables of a bundle
//! share one client; compiled executables are cached by path. Outputs
//! arrive as a single tuple buffer (the XLA root tuple), which we fetch and
//! decompose into host literals — on the CPU backend this is a memcpy.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::manifest::Manifest;

/// A tensor on the host, mirrored to/from XLA literals.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

/// Element storage (only the dtypes the ABI uses).
#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::i32(vec![], vec![v])
    }

    pub fn elem_count(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        if self.shape.is_empty() {
            // vec1 of len 1 -> reshape to scalar.
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            ty => bail!("unsupported output element type {ty:?}"),
        };
        Ok(HostTensor { shape: dims, data })
    }
}

/// A compiled executable with a fixed flat signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Executable {
    /// Execute on host tensors, returning the decomposed output tuple.
    pub fn run(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        self.run_literals(&literals)
    }

    /// Execute on pre-marshalled literals (hot loop: avoids re-marshalling
    /// tensors that don't change between calls).
    pub fn run_literals(&self, args: &[xla::Literal]) -> Result<Vec<HostTensor>> {
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.path.display()))?;
        let tuple = out[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute and keep outputs as raw literals (for feeding the next call
    /// without a HostTensor round-trip).
    pub fn run_raw(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.path.display()))?;
        let tuple = out[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Like [`Self::run_raw`] but borrowing the argument literals — the hot
    /// loop keeps long-lived state literals and only rebuilds the small
    /// per-step inputs.
    pub fn run_literals_refs(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("executing {}", self.path.display()))?;
        let tuple = out[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

/// Marshal a HostTensor into a literal (public for hot-loop callers).
pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    t.to_literal()
}

/// Read a HostTensor back out of a literal.
pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    HostTensor::from_literal(lit)
}

/// Shared PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by canonical path).
    pub fn load_hlo(&self, path: &Path) -> Result<Arc<Executable>> {
        let key = path.to_path_buf();
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let arc = Arc::new(Executable { exe, path: key.clone() });
        self.cache.lock().unwrap().insert(key, arc.clone());
        Ok(arc)
    }

    /// Load a bundle artifact by stem name ("init", "train_step", "fwd",
    /// "tt_layer0", ...).
    pub fn load_artifact(&self, m: &Manifest, stem: &str) -> Result<Arc<Executable>> {
        self.load_hlo(&m.hlo_path(stem))
    }

    /// Drop all cached executables (sweep binaries call this between model
    /// configs — compiled XLA programs hold large buffers).
    pub fn clear_cache(&self) {
        self.cache.lock().unwrap().clear();
    }

    /// Evict cached executables that do NOT live under `keep_dir` — sweeps
    /// call this when switching configs, so per-seed reruns of the same
    /// config still hit the cache.
    pub fn evict_other_bundles(&self, keep_dir: &Path) {
        self.cache
            .lock()
            .unwrap()
            .retain(|path, _| path.starts_with(keep_dir));
    }
}
