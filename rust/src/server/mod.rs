//! Multi-worker sharded inference serving runtime: a bounded request
//! queue fanned out to N batcher threads over one shared compiled fabric.
//!
//! Architecture (vLLM-router-like, scaled to this system): clients submit
//! feature vectors into a bounded MPMC queue ([`crate::util::pool::BoundedQueue`]);
//! each of `workers` batcher threads pulls requests up to `max_batch` or
//! `batch_window`, runs one batched fabric inference through its own
//! executor of the *shared* [`FabricProgram`] (compiled exactly once per
//! [`Model::compile`](crate::fabric::Model::compile), then referenced by
//! every worker), and replies through per-request channels.
//!
//! Servers are started through the fabric API —
//! [`CompiledFabric::serve`](crate::fabric::CompiledFabric::serve) — which
//! resolves the backend by name, validates the tuning, and hands this
//! module an already-compiled program; `Server::start` is a thin
//! crate-internal shim under it.
//!
//! The runtime is *supervised*: each worker slot runs under a supervisor
//! thread that wraps batch execution in `catch_unwind`. A drop-guard over
//! the in-flight batch answers every request with a typed
//! [`ServerError::WorkerCrashed`] the moment a worker unwinds — a panic
//! can never strand a reply channel — and the supervisor respawns the
//! slot with bounded, shutdown-aware backoff (at most
//! [`MAX_WORKER_RESTARTS`] times, counted in
//! `neuralut_server_worker_panics_total` / `_respawns_total`). If every
//! slot dies, the last supervisor out closes the queue and answers the
//! backlog, so no accepted request can hang even in a crash storm.
//!
//! Requests may carry a deadline: [`Client::infer_deadline`] per call, or
//! a server-wide default via `request_timeout_ms`
//! ([`ServerConfig::request_timeout`], `NEURALUT_REQUEST_TIMEOUT_MS`,
//! `--request-timeout` — the usual
//! [`FabricOptions`](crate::fabric::FabricOptions) precedence). Expired
//! requests are shed *at dequeue*, before any execute cost is paid, with
//! [`ServerError::DeadlineExceeded`] (counted and overrun-histogrammed).
//!
//! Backpressure is explicit: [`Client::try_infer`] never blocks and
//! returns [`ServerError::Overloaded`] when the queue is full (counted in
//! [`ServerStats::rejected`]); the blocking [`Client::infer`] /
//! [`Client::infer_async`] paths wait for queue space instead, and
//! [`Client::try_infer_retry`] layers an opt-in jittered-backoff
//! [`RetryPolicy`] over the non-blocking edge. Shutdown is
//! graceful: dropping the [`Server`] closes the queue (new submissions
//! fail fast with [`ServerError::Stopped`]), workers drain and answer the
//! backlog, then join. Serving counters live in a per-server
//! [`MetricsRegistry`] of lock-free atomics (one relaxed RMW per event):
//! requests served/rejected, batch-size histogram, per-worker throughput,
//! queue-depth / in-flight gauges — and the end-to-end latency is
//! decomposed per request into its **queue-wait** (enqueue→dequeue),
//! **batch-formation** (dequeue→execute start) and **execute**
//! (`run_batch`) stages, each a log2 histogram. [`Server::stats`]
//! snapshots the familiar [`ServerStats`] view; [`Server::metrics`]
//! exposes the raw registry snapshot for the Prometheus / JSON encoders
//! in [`crate::obs::expo`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::TomlDoc;
use crate::engine::{BitNetlist, FabricProgram, InferenceBackend, OptLevel};
use crate::fabric::{BackendRegistry, FabricTuning, DEFAULT_BACKEND};
use crate::obs::{Counter, Gauge, Histogram, MetricsRegistry, MetricsSnapshot};
use crate::util::faults;
use crate::util::pool::{BoundedQueue, Pop, PushError};
use crate::util::rng::Rng;

/// Upper bound on `workers` — more threads than this is a config bug.
pub const MAX_WORKERS: usize = 512;
/// Upper bound on `queue_depth` — a deeper queue only hides overload.
pub const MAX_QUEUE_DEPTH: usize = 1 << 20;
/// How many times the supervisor respawns one crashed worker slot before
/// declaring it dead. Bounded so a deterministic crash (bad batch shape,
/// poisoned model) degrades into typed errors instead of a respawn storm.
pub const MAX_WORKER_RESTARTS: u32 = 16;
/// First respawn backoff; doubles per consecutive crash of the slot.
const RESTART_BACKOFF_BASE: Duration = Duration::from_millis(1);
/// Backoff ceiling, so a crash-looping slot still retries a few times per
/// second rather than going dark for minutes.
const RESTART_BACKOFF_CAP: Duration = Duration::from_millis(64);

/// A parsed server-config *file*: the on-disk tuning format. Feed it to
/// [`FabricOptions::from_env_and_config`](crate::fabric::FabricOptions::from_env_and_config)
/// — the one resolution path every entry point shares — rather than
/// consuming it directly.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Maximum requests folded into one fabric batch.
    pub max_batch: usize,
    /// How long a batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Registry name of the backend executing the batches.
    pub backend: String,
    /// Netlist optimization level the backend compiles at. `None` when
    /// the file omits the key — the compile-time default then applies,
    /// and (unlike an explicit level) a `.nfab` fabric cache built at any
    /// level is still accepted.
    pub opt_level: Option<OptLevel>,
    /// Optional `.nfab` path: load the precompiled program when fresh,
    /// compile-and-save otherwise (persistable backends only).
    pub fabric_cache: Option<std::path::PathBuf>,
    /// Batcher threads sharing the request queue (and the compiled fabric).
    pub workers: usize,
    /// Bounded request-queue depth — the backpressure limit.
    pub queue_depth: usize,
    /// Default per-request deadline (`request_timeout_ms` in the file).
    /// `None` = requests never expire unless a client stamps its own
    /// deadline via [`Client::infer_deadline`].
    pub request_timeout: Option<Duration>,
    /// `host:port` the network front door (`neuralut serve --listen`)
    /// binds. `None` when the file omits the key.
    pub listen_addr: Option<String>,
    /// Live-connection cap for the network front door.
    pub max_connections: Option<usize>,
    /// Manifest directory of `.nlut` models the front door serves and
    /// hot-swaps.
    pub models_dir: Option<std::path::PathBuf>,
    /// Directory where the AOT backends cache compiled `.so` objects
    /// (`aot_cache_dir` in the file). `None` = beside the `.nfab`
    /// artifact, else a per-user temp directory.
    pub aot_cache_dir: Option<std::path::PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        // One source of truth for the knob defaults: `FabricTuning`.
        let t = FabricTuning::default();
        ServerConfig {
            max_batch: t.max_batch,
            batch_window: t.batch_window,
            backend: DEFAULT_BACKEND.to_string(),
            opt_level: None,
            fabric_cache: None,
            workers: t.workers,
            queue_depth: t.queue_depth,
            request_timeout: t.request_timeout,
            listen_addr: None,
            max_connections: None,
            models_dir: None,
            aot_cache_dir: None,
        }
    }
}

impl ServerConfig {
    /// Parse a server-config file in the `config` module's TOML subset:
    ///
    /// ```toml
    /// max_batch = 512
    /// batch_window_us = 100
    /// backend = "bitsliced"       # any registered backend name
    /// opt_level = "O2"            # netlist optimization: "O0"/"O1"/"O2" (or 0/1/2)
    /// fabric_cache = "net.nfab"   # precompiled-fabric artifact path
    /// workers = 4
    /// queue_depth = 2048
    /// request_timeout_ms = 50     # default per-request deadline (omit: none)
    /// listen_addr = "0.0.0.0:7878"  # network front door bind address
    /// max_connections = 256       # live-connection cap at that address
    /// models_dir = "models"       # .nlut manifest directory to serve
    /// aot_cache_dir = "aot"       # compiled-.so cache for the aot backends
    /// ```
    ///
    /// All keys are optional; unknown keys are rejected so typos fail
    /// loudly, zero or absurd `workers` / `queue_depth` values are
    /// config errors (not clamped surprises), and `backend` must name a
    /// registered backend — the error for an unknown name lists what is
    /// registered.
    ///
    /// Resolution is against [`BackendRegistry::global`], deliberately at
    /// parse time so a typo'd name fails where the file is read. Register
    /// custom backends before parsing config files that name them; an
    /// embedder driving an isolated registry through
    /// [`Model::compile_with`](crate::fabric::Model::compile_with) should
    /// set [`FabricOptions`](crate::fabric::FabricOptions) directly
    /// rather than round-tripping names through a config file.
    pub fn parse_toml(text: &str) -> Result<ServerConfig> {
        let doc = TomlDoc::parse(text)?;
        for key in doc.root.keys() {
            if !matches!(
                key.as_str(),
                "max_batch"
                    | "batch_window_us"
                    | "backend"
                    | "opt_level"
                    | "fabric_cache"
                    | "workers"
                    | "queue_depth"
                    | "request_timeout_ms"
                    | "listen_addr"
                    | "max_connections"
                    | "models_dir"
                    | "aot_cache_dir"
            ) {
                bail!("unknown server config key '{key}'");
            }
        }
        if let Some(name) = doc.tables.keys().next() {
            bail!("unexpected table '[[{name}]]' in server config");
        }
        let mut cfg = ServerConfig::default();
        if let Some(v) = doc.root.get("max_batch") {
            cfg.max_batch = v.as_usize()?.max(1);
        }
        if let Some(v) = doc.root.get("batch_window_us") {
            cfg.batch_window = Duration::from_micros(v.as_usize()? as u64);
        }
        if let Some(v) = doc.root.get("backend") {
            // Resolve now so a bad name fails at parse time with the
            // registry's uniform name-listing error; store canonical.
            cfg.backend = BackendRegistry::global()
                .resolve(v.as_str()?)?
                .name()
                .to_string();
        }
        if let Some(v) = doc.root.get("opt_level") {
            // Accept both `opt_level = "O2"` and `opt_level = 2`.
            cfg.opt_level = Some(match v.as_str() {
                Ok(s) => s.parse().context("server config key 'opt_level'")?,
                Err(_) => OptLevel::from_index(v.as_usize()? as u32)
                    .context("server config key 'opt_level'")?,
            });
        }
        if let Some(v) = doc.root.get("fabric_cache") {
            cfg.fabric_cache = Some(std::path::PathBuf::from(v.as_str()?));
        }
        if let Some(v) = doc.root.get("workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = doc.root.get("queue_depth") {
            cfg.queue_depth = v.as_usize()?;
        }
        if let Some(v) = doc.root.get("request_timeout_ms") {
            cfg.request_timeout = Some(Duration::from_millis(v.as_usize()? as u64));
        }
        if let Some(v) = doc.root.get("listen_addr") {
            cfg.listen_addr = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.root.get("max_connections") {
            let n = v.as_usize()?;
            if n == 0 {
                bail!("max_connections = 0 would refuse every connection");
            }
            cfg.max_connections = Some(n);
        }
        if let Some(v) = doc.root.get("models_dir") {
            cfg.models_dir = Some(std::path::PathBuf::from(v.as_str()?));
        }
        if let Some(v) = doc.root.get("aot_cache_dir") {
            cfg.aot_cache_dir = Some(std::path::PathBuf::from(v.as_str()?));
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Range-check the knobs — zero/absurd values fail loudly at parse
    /// time instead of being clamped downstream. Delegates to
    /// [`FabricTuning::validate`], the one range check both the config
    /// file and the builder path share.
    pub fn validate(&self) -> Result<()> {
        FabricTuning {
            max_batch: self.max_batch,
            batch_window: self.batch_window,
            workers: self.workers,
            queue_depth: self.queue_depth,
            request_timeout: self.request_timeout,
        }
        .validate()
    }

    /// Load a server-config file from disk.
    pub fn load(path: &std::path::Path) -> Result<ServerConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse_toml(&text)
            .with_context(|| format!("parsing {}", path.display()))
    }
}

/// Why the serving runtime did not (or could not) answer a request with a
/// prediction. Carried inside the `anyhow` error chain so callers can
/// downcast and react (shed vs retry vs resubmit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerError {
    /// The bounded request queue is full — explicit backpressure; shed
    /// the request or retry later (see [`Client::try_infer_retry`]).
    Overloaded,
    /// The server has stopped (or is draining for shutdown).
    Stopped,
    /// The worker executing this request's batch panicked. The request
    /// was *not* served; the supervisor answers every in-flight request
    /// of a crashed batch with this error (never a hung channel) and
    /// respawns the worker. Safe to resubmit.
    WorkerCrashed,
    /// The request's deadline passed before a worker started executing
    /// it, so it was shed at dequeue without paying any execute cost.
    DeadlineExceeded,
}

impl ServerError {
    /// Every variant, for exhaustiveness-style tests: the wire-protocol
    /// layer ([`crate::net::frame::WireCode`]) maps each one to a stable
    /// numeric code + HTTP status, and its round-trip test iterates this
    /// list so adding a variant without a wire mapping fails loudly.
    pub const ALL: [ServerError; 4] = [
        ServerError::Overloaded,
        ServerError::Stopped,
        ServerError::WorkerCrashed,
        ServerError::DeadlineExceeded,
    ];
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerError::Overloaded => {
                write!(f, "server overloaded: request queue is full")
            }
            ServerError::Stopped => write!(f, "server stopped"),
            ServerError::WorkerCrashed => {
                write!(f, "worker crashed while serving this request")
            }
            ServerError::DeadlineExceeded => {
                write!(f, "request deadline exceeded before execution")
            }
        }
    }
}

impl std::error::Error for ServerError {}

struct Request {
    features: Vec<f32>,
    enqueued: Instant,
    /// Shed at dequeue once this instant passes (see
    /// [`ServerError::DeadlineExceeded`]); `None` = never expires.
    deadline: Option<Instant>,
    reply: Sender<Result<Reply, ServerError>>,
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Reply {
    pub prediction: u32,
    pub latency: Duration,
    /// Size of the fabric batch this request rode in.
    pub batch_size: usize,
    /// Which worker thread served the batch.
    pub worker: usize,
}

/// Receiver half of a submitted request: resolves to the [`Reply`] or the
/// typed [`ServerError`] the runtime answered with. The supervised worker
/// pool guarantees every accepted request is answered — a crash, deadline
/// or shutdown surfaces as an error here, never as a hang.
pub struct PendingReply {
    rx: Receiver<Result<Reply, ServerError>>,
}

impl PendingReply {
    /// Block until the server answers.
    pub fn recv(&self) -> Result<Reply> {
        match self.rx.recv() {
            Ok(Ok(reply)) => Ok(reply),
            Ok(Err(e)) => Err(e.into()),
            // The sender vanishing without an answer means the server was
            // torn down around us; report it as the crash it is.
            Err(_) => Err(ServerError::WorkerCrashed.into()),
        }
    }

    /// [`recv`](Self::recv) with a local wait bound. Timing out here does
    /// not cancel the request server-side — pair it with a submission
    /// deadline ([`Client::infer_deadline`]) to bound both ends.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Reply> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(reply)) => Ok(reply),
            Ok(Err(e)) => Err(e.into()),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ServerError::DeadlineExceeded.into()),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServerError::WorkerCrashed.into()),
        }
    }
}

/// Opt-in jittered exponential backoff for [`Client::try_infer_retry`]:
/// on [`ServerError::Overloaded`] the client sleeps
/// `min(base_backoff · 2^attempt, max_backoff)` scaled by a deterministic
/// jitter in `[0.5, 1.0)` (seeded, so tests reproduce), then resubmits —
/// up to `max_retries` times. Other errors are never retried.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Resubmissions after the first attempt (0 = plain `try_infer`).
    pub max_retries: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub base_backoff: Duration,
    /// Ceiling on any single backoff sleep.
    pub max_backoff: Duration,
    /// Jitter seed — vary per client to decorrelate retry herds.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 6,
            base_backoff: Duration::from_micros(50),
            max_backoff: Duration::from_millis(5),
            seed: 0x7E7E_CAFE,
        }
    }
}

// ---------------------------------------------------------------------------
// Stats

/// Log2 latency buckets: bucket `i` covers `[2^i, 2^{i+1})` microseconds.
const LAT_BUCKETS: usize = 32;
/// Log2 batch-size buckets: bucket `i` covers sizes `[2^i, 2^{i+1})`.
const BATCH_BUCKETS: usize = 16;

/// Serving telemetry: typed handles into a per-server [`MetricsRegistry`]
/// (`neuralut_server_*` metric family), written by workers and clients
/// with one relaxed atomic RMW per event, snapshot on demand.
struct StatsInner {
    started: Instant,
    registry: MetricsRegistry,
    served: Counter,
    rejected: Counter,
    batches: Counter,
    batch_hist: Histogram,
    lat_hist: Histogram,
    queue_wait: Histogram,
    batch_form: Histogram,
    execute: Histogram,
    queue_depth: Gauge,
    in_flight: Gauge,
    per_worker: Vec<Counter>,
    failed: Counter,
    deadline_exceeded: Counter,
    deadline_overrun: Histogram,
    worker_panics: Counter,
    worker_respawns: Counter,
    retries: Counter,
    degraded: Gauge,
}

impl StatsInner {
    fn new(workers: usize, degraded: bool) -> Self {
        let registry = MetricsRegistry::new();
        for (name, help) in [
            ("neuralut_server_requests_served_total", "requests answered across all workers"),
            ("neuralut_server_requests_rejected_total", "requests shed by try_infer backpressure"),
            ("neuralut_server_batches_total", "fabric batches executed"),
            ("neuralut_server_worker_served_total", "requests served per worker thread"),
            ("neuralut_server_batch_size", "requests folded into one fabric batch"),
            ("neuralut_server_latency_us", "end-to-end enqueue->reply latency, microseconds"),
            ("neuralut_server_queue_wait_us", "enqueue->dequeue stage of the latency, microseconds"),
            ("neuralut_server_batch_formation_us", "dequeue->execute-start stage of the latency, microseconds"),
            ("neuralut_server_execute_us", "fabric run_batch stage of the latency, microseconds"),
            ("neuralut_server_queue_depth", "requests waiting in the bounded queue"),
            ("neuralut_server_in_flight", "requests accepted but not yet answered"),
            ("neuralut_server_requests_failed_total", "accepted requests answered with a typed error (crash or shutdown)"),
            ("neuralut_server_deadline_exceeded_total", "requests shed at dequeue because their deadline had passed"),
            ("neuralut_server_deadline_overrun_us", "how far past its deadline a shed request was, microseconds"),
            ("neuralut_server_worker_panics_total", "worker crashes caught by the supervisor"),
            ("neuralut_server_worker_respawns_total", "crashed worker slots respawned by the supervisor"),
            ("neuralut_server_client_retries_total", "Overloaded submissions resubmitted by a client RetryPolicy"),
            ("neuralut_degraded", "1 when serving on a degraded fallback backend, else 0"),
        ] {
            registry.describe(name, help);
        }
        let per_worker = (0..workers)
            .map(|w| {
                let id = w.to_string();
                registry.counter("neuralut_server_worker_served_total", &[("worker", &id)])
            })
            .collect();
        let degraded_gauge = registry.gauge("neuralut_degraded", &[]);
        degraded_gauge.set(if degraded { 1.0 } else { 0.0 });
        StatsInner {
            started: Instant::now(),
            served: registry.counter("neuralut_server_requests_served_total", &[]),
            rejected: registry.counter("neuralut_server_requests_rejected_total", &[]),
            batches: registry.counter("neuralut_server_batches_total", &[]),
            batch_hist: registry.histogram("neuralut_server_batch_size", &[], BATCH_BUCKETS),
            lat_hist: registry.histogram("neuralut_server_latency_us", &[], LAT_BUCKETS),
            queue_wait: registry.histogram("neuralut_server_queue_wait_us", &[], LAT_BUCKETS),
            batch_form: registry
                .histogram("neuralut_server_batch_formation_us", &[], LAT_BUCKETS),
            execute: registry.histogram("neuralut_server_execute_us", &[], LAT_BUCKETS),
            queue_depth: registry.gauge("neuralut_server_queue_depth", &[]),
            in_flight: registry.gauge("neuralut_server_in_flight", &[]),
            per_worker,
            failed: registry.counter("neuralut_server_requests_failed_total", &[]),
            deadline_exceeded: registry
                .counter("neuralut_server_deadline_exceeded_total", &[]),
            deadline_overrun: registry
                .histogram("neuralut_server_deadline_overrun_us", &[], LAT_BUCKETS),
            worker_panics: registry.counter("neuralut_server_worker_panics_total", &[]),
            worker_respawns: registry.counter("neuralut_server_worker_respawns_total", &[]),
            retries: registry.counter("neuralut_server_client_retries_total", &[]),
            degraded: degraded_gauge,
            registry,
        }
    }

    /// A request made it past backpressure into the queue.
    fn record_accepted(&self) {
        self.queue_depth.inc();
        self.in_flight.inc();
    }

    /// A worker pulled a request out of the queue after `waited`.
    fn record_dequeued(&self, waited: Duration) {
        self.queue_depth.dec();
        self.queue_wait.observe(waited.as_micros() as u64);
    }

    fn record_batch(&self, worker: usize, size: usize) {
        self.batches.inc();
        self.served.add(size as u64);
        self.per_worker[worker].add(size as u64);
        self.batch_hist.observe(size as u64);
    }

    /// One request answered: its end-to-end latency plus the
    /// batch-formation and execute stage shares.
    fn record_served(&self, latency: Duration, formation: Duration, execute: Duration) {
        self.lat_hist.observe(latency.as_micros() as u64);
        self.batch_form.observe(formation.as_micros() as u64);
        self.execute.observe(execute.as_micros() as u64);
        self.in_flight.dec();
    }

    fn record_rejected(&self) {
        self.rejected.inc();
    }

    /// An in-flight (already dequeued) request answered with a typed
    /// error — worker crash or shutdown drain.
    fn record_failed(&self) {
        self.in_flight.dec();
        self.failed.inc();
    }

    /// A request drained straight out of the queue (never dequeued by a
    /// worker) and answered with a typed error.
    fn record_drained_failed(&self) {
        self.queue_depth.dec();
        self.record_failed();
    }

    /// A request shed at dequeue because its deadline had passed.
    fn record_deadline_exceeded(&self, overrun: Duration) {
        self.in_flight.dec();
        self.deadline_exceeded.inc();
        self.deadline_overrun.observe(overrun.as_micros() as u64);
    }

    fn record_worker_panic(&self) {
        self.worker_panics.inc();
    }

    fn record_worker_respawn(&self) {
        self.worker_respawns.inc();
    }

    fn record_retry(&self) {
        self.retries.inc();
    }

    fn snapshot(&self) -> ServerStats {
        let served = self.served.get();
        let batches = self.batches.get();
        let uptime_s = self.started.elapsed().as_secs_f64();
        let per_worker_served: Vec<u64> = self.per_worker.iter().map(|c| c.get()).collect();
        ServerStats {
            served,
            rejected: self.rejected.get(),
            batches,
            mean_batch: served as f64 / batches.max(1) as f64,
            batch_hist: self.batch_hist.buckets(),
            per_worker_rps: per_worker_served
                .iter()
                .map(|&s| s as f64 / uptime_s.max(1e-9))
                .collect(),
            per_worker_served,
            latency_p50_us: self.lat_hist.percentile(0.50),
            latency_p95_us: self.lat_hist.percentile(0.95),
            latency_p99_us: self.lat_hist.percentile(0.99),
            queue_wait_p50_us: self.queue_wait.percentile(0.50),
            queue_wait_p95_us: self.queue_wait.percentile(0.95),
            queue_wait_p99_us: self.queue_wait.percentile(0.99),
            batch_form_p50_us: self.batch_form.percentile(0.50),
            batch_form_p95_us: self.batch_form.percentile(0.95),
            batch_form_p99_us: self.batch_form.percentile(0.99),
            execute_p50_us: self.execute.percentile(0.50),
            execute_p95_us: self.execute.percentile(0.95),
            execute_p99_us: self.execute.percentile(0.99),
            queue_depth: self.queue_depth.get() as i64,
            in_flight: self.in_flight.get() as i64,
            failed: self.failed.get(),
            deadline_exceeded: self.deadline_exceeded.get(),
            worker_panics: self.worker_panics.get(),
            worker_respawns: self.worker_respawns.get(),
            retries: self.retries.get(),
            degraded: self.degraded.get() != 0.0,
            uptime_s,
        }
    }
}

/// Point-in-time snapshot of the serving counters.
#[derive(Debug, Clone)]
pub struct ServerStats {
    /// Requests answered (across all workers).
    pub served: u64,
    /// Requests shed by [`Client::try_infer`] backpressure.
    pub rejected: u64,
    /// Fabric batches executed.
    pub batches: u64,
    /// served / batches.
    pub mean_batch: f64,
    /// Batches per log2 size bucket (bucket `i` = sizes `[2^i, 2^{i+1})`).
    pub batch_hist: Vec<u64>,
    /// Requests served per worker thread.
    pub per_worker_served: Vec<u64>,
    /// Per-worker served-requests/s over the server's uptime.
    pub per_worker_rps: Vec<f64>,
    /// Approximate enqueue→reply latency percentiles (log2-bucketed), us.
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    /// Queue-wait stage (enqueue→dequeue) percentiles, us.
    pub queue_wait_p50_us: f64,
    pub queue_wait_p95_us: f64,
    pub queue_wait_p99_us: f64,
    /// Batch-formation stage (dequeue→execute start) percentiles, us.
    pub batch_form_p50_us: f64,
    pub batch_form_p95_us: f64,
    pub batch_form_p99_us: f64,
    /// Execute stage (`run_batch`, shared by the whole batch) percentiles, us.
    pub execute_p50_us: f64,
    pub execute_p95_us: f64,
    pub execute_p99_us: f64,
    /// Requests waiting in the bounded queue right now (approximate:
    /// client increments and worker decrements race benignly).
    pub queue_depth: i64,
    /// Requests accepted but not yet answered right now (approximate).
    pub in_flight: i64,
    /// Accepted requests answered with a typed error (crash/shutdown).
    pub failed: u64,
    /// Requests shed at dequeue because their deadline had passed.
    pub deadline_exceeded: u64,
    /// Worker crashes caught by the supervisor.
    pub worker_panics: u64,
    /// Crashed worker slots respawned by the supervisor.
    pub worker_respawns: u64,
    /// `Overloaded` submissions resubmitted by a client [`RetryPolicy`].
    pub retries: u64,
    /// True when serving on a degraded fallback backend (see
    /// [`CompileReport::degraded_from`](crate::fabric::CompileReport)).
    pub degraded: bool,
    pub uptime_s: f64,
}

// ---------------------------------------------------------------------------
// Client / Server

struct ServerShared {
    queue: BoundedQueue<Request>,
    stats: StatsInner,
    /// Worker slots still running (or backing off toward a respawn). The
    /// last one to exit closes the queue and answers the backlog, so a
    /// crash storm that kills every slot can never strand a request.
    live_workers: AtomicUsize,
    /// Default deadline stamped on requests submitted without one
    /// (`request_timeout_ms`); `None` = requests never expire.
    default_timeout: Option<Duration>,
}

/// Handle for submitting requests; cheap to clone, usable from any thread,
/// outlives the `Server` (submissions after shutdown fail with
/// [`ServerError::Stopped`]).
#[derive(Clone)]
pub struct Client {
    shared: Arc<ServerShared>,
    input_size: usize,
}

impl Client {
    fn check_features(&self, features: &[f32]) -> Result<()> {
        if features.len() != self.input_size {
            bail!(
                "feature vector has {} values, model expects {}",
                features.len(),
                self.input_size
            );
        }
        Ok(())
    }

    fn request(
        &self,
        features: Vec<f32>,
        timeout: Option<Duration>,
    ) -> (Request, PendingReply) {
        let (reply_tx, reply_rx) = mpsc::channel();
        let now = Instant::now();
        let deadline = timeout.or(self.shared.default_timeout).map(|t| now + t);
        (
            Request { features, enqueued: now, deadline, reply: reply_tx },
            PendingReply { rx: reply_rx },
        )
    }

    /// Blocking-push submit shared by every deadline-optional entry point.
    fn submit(&self, features: Vec<f32>, timeout: Option<Duration>) -> Result<PendingReply> {
        self.check_features(&features)?;
        let (req, rx) = self.request(features, timeout);
        self.shared
            .queue
            .push(req)
            .map_err(|_| anyhow::Error::from(ServerError::Stopped))?;
        self.shared.stats.record_accepted();
        Ok(rx)
    }

    /// Submit one request; applies backpressure (blocks while the queue is
    /// full) and then blocks until the prediction is ready.
    pub fn infer(&self, features: Vec<f32>) -> Result<Reply> {
        self.infer_async(features)?.recv()
    }

    /// [`infer`](Self::infer) with an explicit per-request deadline: if
    /// no worker has started executing the request `timeout` after
    /// submission, it is shed with [`ServerError::DeadlineExceeded`]
    /// instead of being served late. Overrides the server-wide
    /// `request_timeout_ms` default for this request.
    pub fn infer_deadline(&self, features: Vec<f32>, timeout: Duration) -> Result<Reply> {
        self.submit(features, Some(timeout))?.recv()
    }

    /// Submit asynchronously; returns the pending reply handle. Blocks
    /// only while the queue is full.
    pub fn infer_async(&self, features: Vec<f32>) -> Result<PendingReply> {
        self.submit(features, None)
    }

    /// Non-blocking submit — the backpressure edge. A full queue returns
    /// [`ServerError::Overloaded`] (counted in [`ServerStats::rejected`]);
    /// a stopped server returns [`ServerError::Stopped`]. Both downcast
    /// from the `anyhow` error.
    pub fn try_infer(&self, features: Vec<f32>) -> Result<PendingReply> {
        self.check_features(&features)?;
        let (req, rx) = self.request(features, None);
        match self.shared.queue.try_push(req) {
            Ok(()) => {
                self.shared.stats.record_accepted();
                Ok(rx)
            }
            Err(PushError::Full(_)) => {
                self.shared.stats.record_rejected();
                Err(ServerError::Overloaded.into())
            }
            Err(PushError::Closed(_)) => Err(ServerError::Stopped.into()),
        }
    }

    /// [`try_infer`](Self::try_infer) wrapped in the opt-in
    /// [`RetryPolicy`]: [`ServerError::Overloaded`] triggers a jittered
    /// exponential-backoff sleep and a resubmission (counted in
    /// [`ServerStats::retries`]), up to `policy.max_retries` times; any
    /// other outcome — success or error — is returned as-is.
    pub fn try_infer_retry(
        &self,
        features: Vec<f32>,
        policy: &RetryPolicy,
    ) -> Result<PendingReply> {
        let mut rng = Rng::new(policy.seed);
        let mut attempt = 0u32;
        loop {
            match self.try_infer(features.clone()) {
                Err(e)
                    if attempt < policy.max_retries
                        && e.downcast_ref::<ServerError>()
                            == Some(&ServerError::Overloaded) =>
                {
                    attempt += 1;
                    self.shared.stats.record_retry();
                    let exp = policy
                        .base_backoff
                        .saturating_mul(1u32 << (attempt - 1).min(16));
                    let capped = exp.min(policy.max_backoff);
                    // Jitter in [0.5, 1.0)× so synchronized clients
                    // don't re-collide on the same backoff schedule.
                    std::thread::sleep(capped.mul_f64(0.5 + 0.5 * rng.f64()));
                }
                other => return other,
            }
        }
    }

    /// Serving counters (shared with [`Server::stats`]).
    pub fn stats(&self) -> ServerStats {
        self.shared.stats.snapshot()
    }

    /// Raw metrics snapshot (shared with [`Server::metrics`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.stats.registry.snapshot()
    }
}

/// The running server; dropping it closes the queue, drains and answers
/// the backlog, and joins every worker.
///
/// Started via [`CompiledFabric::serve`](crate::fabric::CompiledFabric::serve);
/// there is no public constructor here — compilation, backend resolution
/// and tuning validation all live in the fabric layer.
pub struct Server {
    shared: Arc<ServerShared>,
    program: Arc<dyn FabricProgram>,
    handles: Vec<JoinHandle<()>>,
    input_size: usize,
}

impl Server {
    /// Spawn `tuning.workers` supervised batcher slots over an
    /// already-compiled program. Crate-internal shim under
    /// [`CompiledFabric::serve`](crate::fabric::CompiledFabric::serve):
    /// by the time control reaches here the backend factory has run
    /// (exactly once) and the tuning has been range-checked, so starting
    /// cannot fail. Each worker only gets a cheap executor of `program`;
    /// `degraded` marks a fabric that fell back to the scalar backend so
    /// the `neuralut_degraded` gauge travels with the serving metrics.
    pub(crate) fn start(
        program: Arc<dyn FabricProgram>,
        input_size: usize,
        tuning: &FabricTuning,
        degraded: bool,
    ) -> Server {
        let shared = Arc::new(ServerShared {
            queue: BoundedQueue::new(tuning.queue_depth),
            stats: StatsInner::new(tuning.workers, degraded),
            live_workers: AtomicUsize::new(tuning.workers),
            default_timeout: tuning.request_timeout,
        });
        let max_batch = tuning.max_batch;
        let window = tuning.batch_window;
        // First executors are built here, synchronously, before any thread
        // spawns — so the compile-exactly-once property is a
        // construction-time invariant, not a runtime race. A respawn after
        // a crash builds a replacement executor from the same shared
        // program: a cheap handle, never a recompile.
        let handles = (0..tuning.workers)
            .map(|w| {
                let exec = program.executor();
                let prog = program.clone();
                let sh = shared.clone();
                std::thread::spawn(move || supervise(w, prog, exec, sh, max_batch, window))
            })
            .collect();
        Server { shared, program, handles, input_size }
    }

    pub fn client(&self) -> Client {
        Client { shared: self.shared.clone(), input_size: self.input_size }
    }

    /// Number of worker threads actually running.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Snapshot of the serving counters.
    pub fn stats(&self) -> ServerStats {
        self.shared.stats.snapshot()
    }

    /// Snapshot of the full `neuralut_server_*` metrics registry —
    /// counters, gauges and the per-stage latency histograms — for the
    /// exposition encoders in [`crate::obs::expo`].
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.stats.registry.snapshot()
    }

    /// The lowered bit-netlist every worker shares (`None` for backends
    /// with nothing compiled to share, e.g. `scalar`).
    pub fn shared_program(&self) -> Option<Arc<BitNetlist>> {
        self.program.bit_netlist().cloned()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Every supervisor has exited. If a crash storm had already
        // killed all slots, requests accepted in the window before the
        // close are still queued — answer them rather than strand them.
        for req in self.shared.queue.close_and_drain() {
            self.shared.stats.record_drained_failed();
            let _ = req.reply.send(Err(ServerError::Stopped));
        }
    }
}

/// Supervisor for one worker slot: runs [`worker_loop`] under
/// `catch_unwind`, and on a crash respawns it — bounded by
/// [`MAX_WORKER_RESTARTS`], with shutdown-aware exponential backoff —
/// with a fresh executor of the shared program. The last supervisor to
/// exit (gracefully or not) closes the queue and answers whatever is
/// still queued, so no accepted request can ever hang.
fn supervise(
    worker: usize,
    program: Arc<dyn FabricProgram>,
    first_exec: Box<dyn InferenceBackend>,
    shared: Arc<ServerShared>,
    max_batch: usize,
    window: Duration,
) {
    let mut exec = Some(first_exec);
    let mut restarts = 0u32;
    loop {
        let backend = exec.take().unwrap_or_else(|| program.executor());
        let sh = shared.clone();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            worker_loop(worker, backend, sh, max_batch, window)
        }));
        match outcome {
            // Graceful: queue closed and drained.
            Ok(()) => break,
            Err(_) => {
                shared.stats.record_worker_panic();
                if restarts >= MAX_WORKER_RESTARTS {
                    eprintln!(
                        "neuralut server: worker {worker} crashed {} times; \
                         slot abandoned",
                        restarts + 1
                    );
                    break;
                }
                restarts += 1;
                crash_backoff(&shared.queue, restarts);
                shared.stats.record_worker_respawn();
            }
        }
    }
    if shared.live_workers.fetch_sub(1, Ordering::AcqRel) == 1 {
        // Last slot out. On graceful shutdown the queue is already
        // closed and drained (this returns nothing); after a crash storm
        // it answers the stranded backlog with a typed error.
        for req in shared.queue.close_and_drain() {
            shared.stats.record_drained_failed();
            let _ = req.reply.send(Err(ServerError::WorkerCrashed));
        }
    }
}

/// Exponential backoff before a respawn, slept in 1 ms slices so
/// `Server::drop` never waits out a backoff ladder: the moment the queue
/// closes, the supervisor wakes and respawns immediately to drain.
fn crash_backoff(queue: &BoundedQueue<Request>, restarts: u32) {
    let exp = RESTART_BACKOFF_BASE.saturating_mul(1u32 << restarts.min(16));
    let deadline = Instant::now() + exp.min(RESTART_BACKOFF_CAP);
    while Instant::now() < deadline {
        if queue.is_closed() {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Drop-guard over a batch's reply channels: while the batch is being
/// formed and executed it lives in here, and if the worker unwinds
/// (backend panic, armed fault point), `Drop` answers every in-flight
/// request with [`ServerError::WorkerCrashed`] instead of leaving hung
/// channels behind. The happy path `mem::take`s the batch out first,
/// making the drop a no-op.
struct InFlight<'a> {
    batch: Vec<(Request, Instant)>,
    stats: &'a StatsInner,
}

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        for (req, _) in self.batch.drain(..) {
            self.stats.record_failed();
            let _ = req.reply.send(Err(ServerError::WorkerCrashed));
        }
    }
}

/// Shed `req` with [`ServerError::DeadlineExceeded`] if its deadline has
/// passed at `now` (the dequeue instant — before any execute cost is
/// paid); hands the request back otherwise.
fn shed_if_expired(stats: &StatsInner, req: Request, now: Instant) -> Option<Request> {
    match req.deadline {
        Some(dl) if now >= dl => {
            stats.record_deadline_exceeded(now.duration_since(dl));
            let _ = req.reply.send(Err(ServerError::DeadlineExceeded));
            None
        }
        _ => Some(req),
    }
}

fn worker_loop(
    worker: usize,
    backend: Box<dyn InferenceBackend>,
    shared: Arc<ServerShared>,
    max_batch: usize,
    window: Duration,
) {
    loop {
        // Block for the first request of a batch; `None` = closed + drained.
        let Some(first) = shared.queue.pop() else { return };
        let popped = Instant::now();
        shared.stats.record_dequeued(popped.duration_since(first.enqueued));
        let Some(first) = shed_if_expired(&shared.stats, first, popped) else { continue };
        let in_sz = first.features.len();
        // Each request carries the instant it left the queue so its
        // batch-formation share (dequeue → execute start) can be split
        // out of the end-to-end latency below. From here until the
        // replies go out the batch lives inside the `InFlight` guard: an
        // unwind anywhere below answers every request it holds.
        let mut guard = InFlight { batch: vec![(first, popped)], stats: &shared.stats };
        let deadline = popped + window;
        while guard.batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match shared.queue.pop_timeout(deadline - now) {
                Pop::Item(r) => {
                    let t = Instant::now();
                    shared.stats.record_dequeued(t.duration_since(r.enqueued));
                    if let Some(r) = shed_if_expired(&shared.stats, r, t) {
                        guard.batch.push((r, t));
                    }
                }
                // Closed: finish this batch; the outer pop() exits once
                // the backlog is drained.
                Pop::TimedOut | Pop::Closed => break,
            }
        }
        // One fabric run for the whole batch.
        let mut x = Vec::with_capacity(guard.batch.len() * in_sz);
        for (r, _) in &guard.batch {
            x.extend_from_slice(&r.features);
        }
        faults::panic_point(faults::point::WORKER_EXECUTE);
        let exec_start = Instant::now();
        let result = backend.run_batch(&x);
        let exec_time = exec_start.elapsed();
        // Execution succeeded: disarm the guard and answer normally.
        let batch = std::mem::take(&mut guard.batch);
        drop(guard);
        let bs = batch.len();
        shared.stats.record_batch(worker, bs);
        for ((req, left_queue), &pred) in batch.into_iter().zip(&result.predictions) {
            let latency = req.enqueued.elapsed();
            shared.stats.record_served(
                latency,
                exec_start.duration_since(left_queue),
                exec_time,
            );
            let _ = req.reply.send(Ok(Reply {
                prediction: pred,
                latency,
                batch_size: bs,
                worker,
            }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricOptions, Model};
    use crate::luts::random_network;
    use crate::netlist::Simulator;

    /// Compile-and-serve helper for these tests: the fabric API path
    /// every caller uses.
    fn serve(net: Arc<crate::luts::LutNetwork>, opts: &FabricOptions) -> Server {
        Model::from_arc(net).compile(opts).unwrap().serve()
    }

    #[test]
    fn serves_and_matches_direct_simulation() {
        let net = Arc::new(random_network(21, 8, 2, &[6, 3], 3, 2, 4));
        let sim = Simulator::new(&net);
        let server = serve(net.clone(), &FabricOptions::new());
        let client = server.client();
        for i in 0..20 {
            let feats: Vec<f32> = (0..8).map(|j| ((i + j) % 5) as f32 / 5.0).collect();
            let want = sim.simulate_batch(&feats).predictions[0];
            let got = client.infer(feats).unwrap();
            assert_eq!(got.prediction, want);
            assert!(got.batch_size >= 1);
        }
    }

    #[test]
    fn bitsliced_backend_serves_identical_predictions() {
        let net = Arc::new(random_network(24, 8, 2, &[6, 3], 3, 2, 4));
        let sim = Simulator::new(&net);
        let server = serve(net.clone(), &FabricOptions::new().backend("bitsliced"));
        let client = server.client();
        for i in 0..20 {
            let feats: Vec<f32> = (0..8).map(|j| ((i + j) % 6) as f32 / 6.0).collect();
            let want = sim.simulate_batch(&feats).predictions[0];
            assert_eq!(client.infer(feats).unwrap().prediction, want);
        }
    }

    #[test]
    fn config_parses_from_toml_subset() {
        let cfg = ServerConfig::parse_toml(
            "max_batch = 512\nbatch_window_us = 100\nbackend = \"bitsliced\"\n\
             opt_level = \"O2\"\nfabric_cache = \"net.nfab\"\n\
             workers = 4\nqueue_depth = 64\nrequest_timeout_ms = 50\n\
             aot_cache_dir = \"aot\"",
        )
        .unwrap();
        assert_eq!(cfg.max_batch, 512);
        assert_eq!(cfg.batch_window, Duration::from_micros(100));
        assert_eq!(cfg.backend, "bitsliced");
        assert_eq!(cfg.opt_level, Some(OptLevel::O2));
        assert_eq!(cfg.fabric_cache.as_deref(),
                   Some(std::path::Path::new("net.nfab")));
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.queue_depth, 64);
        assert_eq!(cfg.request_timeout, Some(Duration::from_millis(50)));
        assert_eq!(cfg.aot_cache_dir.as_deref(), Some(std::path::Path::new("aot")));
        // Numeric opt levels parse too; unknown ones fail loudly.
        assert_eq!(ServerConfig::parse_toml("opt_level = 0").unwrap().opt_level,
                   Some(OptLevel::O0));
        assert!(ServerConfig::parse_toml("opt_level = \"O9\"").is_err());
        assert!(ServerConfig::parse_toml("opt_level = 3").is_err());
        // Backend names normalize to the registry's canonical form.
        let cfg = ServerConfig::parse_toml("backend = \" Bitsliced \"").unwrap();
        assert_eq!(cfg.backend, "bitsliced");
        // All keys optional -> defaults (backend defaults to scalar).
        let d = ServerConfig::parse_toml("").unwrap();
        assert_eq!(d.backend, "scalar");
        // An omitted opt_level stays unset — it must not later masquerade
        // as an explicit pin that rejects cached .nfab artifacts.
        assert!(d.opt_level.is_none());
        assert!(d.fabric_cache.is_none());
        assert_eq!(d.max_batch, ServerConfig::default().max_batch);
        assert_eq!(d.workers, 1);
        assert_eq!(d.queue_depth, 1024);
        // Typos and bad values fail loudly.
        assert!(ServerConfig::parse_toml("max_bach = 4").is_err());
        let err = ServerConfig::parse_toml("backend = \"fpga\"").unwrap_err().to_string();
        assert!(err.contains("unknown backend 'fpga'"), "{err}");
        assert!(err.contains("registered:"), "{err}");
        assert!(ServerConfig::parse_toml("[[run]]\nconfig = \"x\"").is_err());
        assert!(ServerConfig::parse_toml("workers = 0").is_err());
        assert!(ServerConfig::parse_toml("workers = 100000").is_err());
        assert!(ServerConfig::parse_toml("queue_depth = 0").is_err());
        // An omitted timeout stays unset (requests never expire); an
        // explicit zero is a config error, not an everything-sheds server.
        assert!(ServerConfig::parse_toml("").unwrap().request_timeout.is_none());
        assert!(ServerConfig::parse_toml("request_timeout_ms = 0").is_err());
    }

    #[test]
    fn rejects_bad_feature_length() {
        let net = Arc::new(random_network(22, 8, 2, &[4, 2], 3, 2, 4));
        let server = serve(net, &FabricOptions::new());
        assert!(server.client().infer(vec![0.0; 3]).is_err());
    }

    #[test]
    fn concurrent_clients_all_get_replies() {
        let net = Arc::new(random_network(23, 4, 2, &[4, 2], 2, 2, 4));
        let server = serve(
            net,
            &FabricOptions::new()
                .max_batch(16)
                .batch_window(Duration::from_micros(500)),
        );
        let client = server.client();
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for i in 0..25 {
                        let feats: Vec<f32> =
                            (0..4).map(|j| ((t + i + j) % 7) as f32 / 7.0).collect();
                        let r = c.infer(feats).unwrap();
                        assert!(r.prediction < 2);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn worker_pool_shares_one_compiled_program() {
        let net = Arc::new(random_network(41, 8, 2, &[6, 3], 3, 2, 4));
        let server = serve(
            net.clone(),
            &FabricOptions::new().backend("bitsliced").workers(4),
        );
        assert_eq!(server.workers(), 4);
        let prog = server.shared_program().expect("bitsliced fabric has a program");
        // ONE compiled BitNetlist, referenced by: the program held by the
        // server + this clone + each of the 4 worker executors. If any
        // worker had compiled its own program, this count (and the
        // program identity) would differ.
        assert_eq!(Arc::strong_count(&prog), 4 + 2);
        // The scalar program has nothing compiled to share.
        let scalar = serve(net, &FabricOptions::new().workers(3));
        assert!(scalar.shared_program().is_none());
        assert_eq!(scalar.workers(), 3);
    }

    #[test]
    fn multi_worker_serving_matches_direct_simulation() {
        let net = Arc::new(random_network(42, 8, 2, &[6, 3], 3, 2, 4));
        let sim = Simulator::new(&net);
        let server = serve(
            net.clone(),
            &FabricOptions::new().backend("bitsliced").workers(4),
        );
        let client = server.client();
        for i in 0..64 {
            let feats: Vec<f32> = (0..8).map(|j| ((i * 3 + j) % 9) as f32 / 9.0).collect();
            let want = sim.simulate_batch(&feats).predictions[0];
            let got = client.infer(feats).unwrap();
            assert_eq!(got.prediction, want);
            assert!(got.worker < 4);
        }
    }

    #[test]
    fn try_infer_sheds_with_overloaded_when_queue_is_full() {
        let net = Arc::new(random_network(44, 6, 2, &[4, 2], 2, 2, 4));
        let server = serve(
            net,
            &FabricOptions::new()
                .workers(1)
                .queue_depth(1)
                .max_batch(1)
                .batch_window(Duration::ZERO),
        );
        let client = server.client();
        let feats = vec![0.5f32; 6];
        let mut pending = Vec::new();
        let mut rejected = 0u64;
        let t0 = Instant::now();
        // Flood a depth-1 queue; the single worker cannot keep up with a
        // tight submit loop, so Overloaded must surface quickly.
        while rejected == 0 && t0.elapsed() < Duration::from_secs(10) {
            match client.try_infer(feats.clone()) {
                Ok(rx) => pending.push(rx),
                Err(e) => {
                    assert_eq!(
                        e.downcast_ref::<ServerError>(),
                        Some(&ServerError::Overloaded)
                    );
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "depth-1 queue never reported Overloaded");
        assert_eq!(server.stats().rejected, rejected);
        // Every accepted request is still answered.
        for rx in pending {
            rx.recv().unwrap();
        }
    }

    #[test]
    fn stats_account_served_requests_batches_and_latency() {
        let net = Arc::new(random_network(45, 6, 2, &[4, 2], 2, 2, 4));
        let server = serve(net, &FabricOptions::new().workers(2));
        let client = server.client();
        for i in 0..40 {
            let feats: Vec<f32> = (0..6).map(|j| ((i + j) % 5) as f32 / 5.0).collect();
            client.infer(feats).unwrap();
        }
        let s = server.stats();
        assert_eq!(s.served, 40);
        assert_eq!(s.rejected, 0);
        assert!(s.batches >= 1 && s.batches <= 40);
        assert!((s.mean_batch - s.served as f64 / s.batches as f64).abs() < 1e-9);
        assert_eq!(s.per_worker_served.len(), 2);
        assert_eq!(s.per_worker_served.iter().sum::<u64>(), 40);
        assert_eq!(s.batch_hist.iter().sum::<u64>(), s.batches);
        assert!(s.latency_p50_us.is_finite() && s.latency_p50_us > 0.0);
        assert!(s.latency_p99_us >= s.latency_p50_us);
        assert!(s.uptime_s > 0.0);
        // The stage decomposition covers every served request, and the
        // gauges settle back to zero once everything is answered.
        assert!(s.queue_wait_p50_us.is_finite());
        assert!(s.batch_form_p50_us.is_finite());
        assert!(s.execute_p50_us.is_finite() && s.execute_p50_us > 0.0);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.in_flight, 0);
        // Client sees the same counters.
        assert_eq!(client.stats().served, 40);
        // The raw registry snapshot exposes the same story for the
        // exposition encoders, one histogram sample per request.
        let snap = server.metrics();
        assert_eq!(
            snap.counter("neuralut_server_requests_served_total", &[]).unwrap().value,
            40
        );
        for name in [
            "neuralut_server_latency_us",
            "neuralut_server_queue_wait_us",
            "neuralut_server_batch_formation_us",
            "neuralut_server_execute_us",
        ] {
            let h = snap.histogram(name, &[]).unwrap();
            assert_eq!(h.count, 40, "{name}");
        }
        let w0 = snap
            .counter("neuralut_server_worker_served_total", &[("worker", "0")])
            .unwrap();
        let w1 = snap
            .counter("neuralut_server_worker_served_total", &[("worker", "1")])
            .unwrap();
        assert_eq!(w0.value + w1.value, 40);
    }

    #[test]
    fn stopped_server_fails_fast_with_explicit_error() {
        let net = Arc::new(random_network(46, 6, 2, &[4, 2], 2, 2, 4));
        let server = serve(net, &FabricOptions::new());
        let client = server.client();
        drop(server);
        let err = client.infer(vec![0.0; 6]).unwrap_err();
        assert_eq!(err.downcast_ref::<ServerError>(), Some(&ServerError::Stopped));
        assert_eq!(err.to_string(), "server stopped");
        let err = client.try_infer(vec![0.0; 6]).unwrap_err();
        assert_eq!(err.downcast_ref::<ServerError>(), Some(&ServerError::Stopped));
    }

    #[test]
    fn crashed_worker_answers_in_flight_requests_and_respawns() {
        let net = Arc::new(random_network(47, 6, 2, &[4, 2], 2, 2, 4));
        let sim = Simulator::new(&net);
        let server = serve(net, &FabricOptions::new().workers(1));
        let client = server.client();
        let feats = vec![0.25f32; 6];
        // First batch crashes: the armed fault fires once at execute.
        {
            let guard =
                crate::util::faults::arm_scoped("worker.execute:1:panic:0", 21).unwrap();
            let err = client.infer(feats.clone()).unwrap_err();
            assert_eq!(
                err.downcast_ref::<ServerError>(),
                Some(&ServerError::WorkerCrashed),
                "{err}"
            );
            assert_eq!(guard.fired(crate::util::faults::point::WORKER_EXECUTE), 1);
        }
        // Disarmed: the respawned worker serves correct answers again.
        let want = sim.simulate_batch(&feats).predictions[0];
        assert_eq!(client.infer(feats).unwrap().prediction, want);
        let s = server.stats();
        assert_eq!(s.worker_panics, 1);
        assert_eq!(s.worker_respawns, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.served, 1);
        assert_eq!(s.in_flight, 0);
    }

    #[test]
    fn expired_requests_are_shed_with_deadline_exceeded() {
        let net = Arc::new(random_network(48, 6, 2, &[4, 2], 2, 2, 4));
        let server = serve(
            net,
            &FabricOptions::new().workers(1).max_batch(4).batch_window(Duration::ZERO),
        );
        let client = server.client();
        let feats = vec![0.5f32; 6];
        // Stall the single worker with a delay fault so queued requests
        // age past an (aggressively short) deadline before dequeue.
        let _guard = crate::util::faults::arm_scoped("worker.execute:1:delay:40", 22).unwrap();
        let mut pending = Vec::new();
        // The first request occupies the worker; the rest queue behind it
        // with ~zero deadlines and must be shed at dequeue.
        pending.push(client.infer_async(feats.clone()).unwrap());
        for _ in 0..4 {
            let (req, rx) = client.request(feats.clone(), Some(Duration::from_nanos(1)));
            assert!(client.shared.queue.push(req).is_ok());
            client.shared.stats.record_accepted();
            pending.push(rx);
        }
        let mut shed = 0u64;
        for rx in pending {
            match rx.recv() {
                Ok(_) => {}
                Err(e) => {
                    assert_eq!(
                        e.downcast_ref::<ServerError>(),
                        Some(&ServerError::DeadlineExceeded),
                        "{e}"
                    );
                    shed += 1;
                }
            }
        }
        assert!(shed >= 1, "nanosecond deadlines behind a stalled worker must shed");
        let s = server.stats();
        assert_eq!(s.deadline_exceeded, shed);
        assert_eq!(s.in_flight, 0);
        assert_eq!(s.queue_depth, 0);
    }

    #[test]
    fn retry_policy_rides_out_overload() {
        let net = Arc::new(random_network(49, 6, 2, &[4, 2], 2, 2, 4));
        let server = serve(
            net,
            &FabricOptions::new()
                .workers(1)
                .queue_depth(1)
                .max_batch(1)
                .batch_window(Duration::ZERO),
        );
        let client = server.client();
        let feats = vec![0.5f32; 6];
        let policy = RetryPolicy {
            max_retries: 64,
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(2),
            seed: 7,
        };
        // Flood a depth-1 queue through the retry path: every submission
        // must eventually land (or prove Overloaded was never hit).
        let mut pending = Vec::new();
        for _ in 0..50 {
            pending.push(client.try_infer_retry(feats.clone(), &policy).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap();
        }
        let s = server.stats();
        assert_eq!(s.served, 50);
        // Whenever backpressure fired, the retry counter saw it.
        assert_eq!(s.retries >= 1, s.rejected >= 1);
    }

    #[test]
    fn log2_histogram_percentiles_are_sane() {
        // The bucketing/percentile math now lives in `obs::metrics` —
        // same semantics the serving runtime always had.
        use crate::obs::{hist_percentile, log2_bucket};
        // 100 samples in bucket 3 ([8, 16)): every percentile lands there.
        let mut hist = vec![0u64; 8];
        hist[3] = 100;
        let p50 = hist_percentile(&hist, 0.50);
        assert!((8.0..16.0).contains(&p50), "p50 = {p50}");
        assert!(hist_percentile(&hist, 0.99) >= p50);
        assert!(hist_percentile(&[0u64; 8], 0.5).is_nan());
        assert_eq!(log2_bucket(0, 8), 0);
        assert_eq!(log2_bucket(1, 8), 0);
        assert_eq!(log2_bucket(9, 8), 3);
        assert_eq!(log2_bucket(u64::MAX, 8), 7);
    }
}
