//! Threaded inference server: request router + dynamic batcher over a
//! configurable inference backend (the deployed "fabric").
//!
//! Architecture (vLLM-router-like, scaled to this system): clients submit
//! feature vectors through a channel; the batcher thread collects requests
//! up to `max_batch` or `batch_window`, runs one batched fabric inference
//! through the configured [`engine::InferenceBackend`] (scalar simulator
//! or the compiled bitsliced engine), and replies through per-request
//! channels. Latency percentiles come from enqueue→reply timestamps.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::TomlDoc;
use crate::engine::{self, BackendKind, InferenceBackend};
use crate::luts::LutNetwork;
use crate::netlist::Simulator;

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Maximum requests folded into one fabric batch.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Which inference engine executes the batches.
    pub backend: BackendKind,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 256,
            batch_window: Duration::from_micros(200),
            backend: BackendKind::Scalar,
        }
    }
}

impl ServerConfig {
    /// Parse a server-config file in the `config` module's TOML subset:
    ///
    /// ```toml
    /// max_batch = 512
    /// batch_window_us = 100
    /// backend = "bitsliced"   # or "scalar" (the default)
    /// ```
    ///
    /// All keys are optional; unknown keys are rejected so typos fail
    /// loudly.
    pub fn parse_toml(text: &str) -> Result<ServerConfig> {
        let doc = TomlDoc::parse(text)?;
        for key in doc.root.keys() {
            if !matches!(key.as_str(), "max_batch" | "batch_window_us" | "backend") {
                bail!("unknown server config key '{key}'");
            }
        }
        if let Some(name) = doc.tables.keys().next() {
            bail!("unexpected table '[[{name}]]' in server config");
        }
        let mut cfg = ServerConfig::default();
        if let Some(v) = doc.root.get("max_batch") {
            cfg.max_batch = v.as_usize()?.max(1);
        }
        if let Some(v) = doc.root.get("batch_window_us") {
            cfg.batch_window = Duration::from_micros(v.as_usize()? as u64);
        }
        if let Some(v) = doc.root.get("backend") {
            cfg.backend = v.as_str()?.parse()?;
        }
        Ok(cfg)
    }

    /// Load a server-config file from disk.
    pub fn load(path: &std::path::Path) -> Result<ServerConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse_toml(&text)
            .with_context(|| format!("parsing {}", path.display()))
    }
}

struct Request {
    features: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Reply>,
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Reply {
    pub prediction: u32,
    pub latency: Duration,
    /// Size of the fabric batch this request rode in.
    pub batch_size: usize,
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    input_size: usize,
}

impl Client {
    /// Submit one request; blocks until the prediction is ready.
    pub fn infer(&self, features: Vec<f32>) -> Result<Reply> {
        let (reply_tx, reply_rx) = mpsc::channel();
        if features.len() != self.input_size {
            bail!(
                "feature vector has {} values, model expects {}",
                features.len(),
                self.input_size
            );
        }
        self.tx
            .send(Request { features, enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped request"))
    }

    /// Submit asynchronously; returns the receiver.
    pub fn infer_async(&self, features: Vec<f32>) -> Result<Receiver<Reply>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        if features.len() != self.input_size {
            bail!("bad feature length");
        }
        self.tx
            .send(Request { features, enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(reply_rx)
    }
}

/// The running server; dropping it stops the batcher thread.
pub struct Server {
    tx: Option<Sender<Request>>,
    handle: Option<JoinHandle<()>>,
    input_size: usize,
}

impl Server {
    /// Start serving a converted network.
    pub fn start(net: Arc<LutNetwork>, cfg: ServerConfig) -> Server {
        let (tx, rx) = mpsc::channel::<Request>();
        let input_size = net.input_size;
        let handle = std::thread::spawn(move || batcher_loop(net, cfg, rx));
        Server { tx: Some(tx), handle: Some(handle), input_size }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone().unwrap(), input_size: self.input_size }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn batcher_loop(net: Arc<LutNetwork>, cfg: ServerConfig, rx: Receiver<Request>) {
    // Build the configured backend inside the serving thread (compilation
    // of the bitsliced engine happens once, before the first request).
    // A network the lowering pass rejects still serves — on the scalar
    // fallback — rather than taking the server down.
    let backend: Box<dyn InferenceBackend + '_> =
        match engine::backend(cfg.backend, &net) {
        Ok(b) => b,
        Err(e) => {
            eprintln!(
                "server: {} backend unavailable ({e:#}); falling back to scalar",
                cfg.backend
            );
            Box::new(Simulator::new(&net))
        }
    };
    let in_sz = net.input_size;
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone -> shutdown
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.batch_window;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // One fabric run for the whole batch.
        let mut x = Vec::with_capacity(batch.len() * in_sz);
        for r in &batch {
            x.extend_from_slice(&r.features);
        }
        let result = backend.run_batch(&x);
        let bs = batch.len();
        for (req, &pred) in batch.into_iter().zip(&result.predictions) {
            let _ = req.reply.send(Reply {
                prediction: pred,
                latency: req.enqueued.elapsed(),
                batch_size: bs,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;

    #[test]
    fn serves_and_matches_direct_simulation() {
        let net = Arc::new(random_network(21, 8, 2, &[6, 3], 3, 2, 4));
        let sim = Simulator::new(&net);
        let server = Server::start(net.clone(), ServerConfig::default());
        let client = server.client();
        for i in 0..20 {
            let feats: Vec<f32> = (0..8).map(|j| ((i + j) % 5) as f32 / 5.0).collect();
            let want = sim.simulate_batch(&feats).predictions[0];
            let got = client.infer(feats).unwrap();
            assert_eq!(got.prediction, want);
            assert!(got.batch_size >= 1);
        }
    }

    #[test]
    fn bitsliced_backend_serves_identical_predictions() {
        let net = Arc::new(random_network(24, 8, 2, &[6, 3], 3, 2, 4));
        let sim = Simulator::new(&net);
        let server = Server::start(net.clone(), ServerConfig {
            backend: BackendKind::Bitsliced,
            ..Default::default()
        });
        let client = server.client();
        for i in 0..20 {
            let feats: Vec<f32> = (0..8).map(|j| ((i + j) % 6) as f32 / 6.0).collect();
            let want = sim.simulate_batch(&feats).predictions[0];
            assert_eq!(client.infer(feats).unwrap().prediction, want);
        }
    }

    #[test]
    fn config_parses_from_toml_subset() {
        let cfg = ServerConfig::parse_toml(
            "max_batch = 512\nbatch_window_us = 100\nbackend = \"bitsliced\"",
        )
        .unwrap();
        assert_eq!(cfg.max_batch, 512);
        assert_eq!(cfg.batch_window, Duration::from_micros(100));
        assert_eq!(cfg.backend, BackendKind::Bitsliced);
        // All keys optional -> defaults (backend defaults to Scalar).
        let d = ServerConfig::parse_toml("").unwrap();
        assert_eq!(d.backend, BackendKind::Scalar);
        assert_eq!(d.max_batch, ServerConfig::default().max_batch);
        // Typos and bad values fail loudly.
        assert!(ServerConfig::parse_toml("max_bach = 4").is_err());
        assert!(ServerConfig::parse_toml("backend = \"fpga\"").is_err());
        assert!(ServerConfig::parse_toml("[[run]]\nconfig = \"x\"").is_err());
    }

    #[test]
    fn rejects_bad_feature_length() {
        let net = Arc::new(random_network(22, 8, 2, &[4, 2], 3, 2, 4));
        let server = Server::start(net, ServerConfig::default());
        assert!(server.client().infer(vec![0.0; 3]).is_err());
    }

    #[test]
    fn concurrent_clients_all_get_replies() {
        let net = Arc::new(random_network(23, 4, 2, &[4, 2], 2, 2, 4));
        let server = Server::start(net, ServerConfig {
            max_batch: 16,
            batch_window: Duration::from_micros(500),
            ..Default::default()
        });
        let client = server.client();
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for i in 0..25 {
                        let feats: Vec<f32> =
                            (0..4).map(|j| ((t + i + j) % 7) as f32 / 7.0).collect();
                        let r = c.infer(feats).unwrap();
                        assert!(r.prediction < 2);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
