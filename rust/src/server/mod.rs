//! Multi-worker sharded inference serving runtime: a bounded request
//! queue fanned out to N batcher threads over one shared compiled fabric.
//!
//! Architecture (vLLM-router-like, scaled to this system): clients submit
//! feature vectors into a bounded MPMC queue ([`crate::util::pool::BoundedQueue`]);
//! each of `workers` batcher threads pulls requests up to `max_batch` or
//! `batch_window`, runs one batched fabric inference through its own
//! executor of the *shared* [`FabricProgram`] (compiled exactly once per
//! [`Model::compile`](crate::fabric::Model::compile), then referenced by
//! every worker), and replies through per-request channels.
//!
//! Servers are started through the fabric API —
//! [`CompiledFabric::serve`](crate::fabric::CompiledFabric::serve) — which
//! resolves the backend by name, validates the tuning, and hands this
//! module an already-compiled program; `Server::start` is a thin
//! crate-internal shim under it.
//!
//! Backpressure is explicit: [`Client::try_infer`] never blocks and
//! returns [`ServerError::Overloaded`] when the queue is full (counted in
//! [`ServerStats::rejected`]); the blocking [`Client::infer`] /
//! [`Client::infer_async`] paths wait for queue space instead. Shutdown is
//! graceful: dropping the [`Server`] closes the queue (new submissions
//! fail fast with [`ServerError::Stopped`]), workers drain and answer the
//! backlog, then join. Serving counters live in a per-server
//! [`MetricsRegistry`] of lock-free atomics (one relaxed RMW per event):
//! requests served/rejected, batch-size histogram, per-worker throughput,
//! queue-depth / in-flight gauges — and the end-to-end latency is
//! decomposed per request into its **queue-wait** (enqueue→dequeue),
//! **batch-formation** (dequeue→execute start) and **execute**
//! (`run_batch`) stages, each a log2 histogram. [`Server::stats`]
//! snapshots the familiar [`ServerStats`] view; [`Server::metrics`]
//! exposes the raw registry snapshot for the Prometheus / JSON encoders
//! in [`crate::obs::expo`].

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::TomlDoc;
use crate::engine::{BitNetlist, FabricProgram, InferenceBackend, OptLevel};
use crate::fabric::{BackendRegistry, FabricTuning, DEFAULT_BACKEND};
use crate::obs::{Counter, Gauge, Histogram, MetricsRegistry, MetricsSnapshot};
use crate::util::pool::{BoundedQueue, Pop, PushError};

/// Upper bound on `workers` — more threads than this is a config bug.
pub const MAX_WORKERS: usize = 512;
/// Upper bound on `queue_depth` — a deeper queue only hides overload.
pub const MAX_QUEUE_DEPTH: usize = 1 << 20;

/// A parsed server-config *file*: the on-disk tuning format. Feed it to
/// [`FabricOptions::from_env_and_config`](crate::fabric::FabricOptions::from_env_and_config)
/// — the one resolution path every entry point shares — rather than
/// consuming it directly.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Maximum requests folded into one fabric batch.
    pub max_batch: usize,
    /// How long a batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Registry name of the backend executing the batches.
    pub backend: String,
    /// Netlist optimization level the backend compiles at. `None` when
    /// the file omits the key — the compile-time default then applies,
    /// and (unlike an explicit level) a `.nfab` fabric cache built at any
    /// level is still accepted.
    pub opt_level: Option<OptLevel>,
    /// Optional `.nfab` path: load the precompiled program when fresh,
    /// compile-and-save otherwise (persistable backends only).
    pub fabric_cache: Option<std::path::PathBuf>,
    /// Batcher threads sharing the request queue (and the compiled fabric).
    pub workers: usize,
    /// Bounded request-queue depth — the backpressure limit.
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        // One source of truth for the knob defaults: `FabricTuning`.
        let t = FabricTuning::default();
        ServerConfig {
            max_batch: t.max_batch,
            batch_window: t.batch_window,
            backend: DEFAULT_BACKEND.to_string(),
            opt_level: None,
            fabric_cache: None,
            workers: t.workers,
            queue_depth: t.queue_depth,
        }
    }
}

impl ServerConfig {
    /// Parse a server-config file in the `config` module's TOML subset:
    ///
    /// ```toml
    /// max_batch = 512
    /// batch_window_us = 100
    /// backend = "bitsliced"       # any registered backend name
    /// opt_level = "O2"            # netlist optimization: "O0"/"O1"/"O2" (or 0/1/2)
    /// fabric_cache = "net.nfab"   # precompiled-fabric artifact path
    /// workers = 4
    /// queue_depth = 2048
    /// ```
    ///
    /// All keys are optional; unknown keys are rejected so typos fail
    /// loudly, zero or absurd `workers` / `queue_depth` values are
    /// config errors (not clamped surprises), and `backend` must name a
    /// registered backend — the error for an unknown name lists what is
    /// registered.
    ///
    /// Resolution is against [`BackendRegistry::global`], deliberately at
    /// parse time so a typo'd name fails where the file is read. Register
    /// custom backends before parsing config files that name them; an
    /// embedder driving an isolated registry through
    /// [`Model::compile_with`](crate::fabric::Model::compile_with) should
    /// set [`FabricOptions`](crate::fabric::FabricOptions) directly
    /// rather than round-tripping names through a config file.
    pub fn parse_toml(text: &str) -> Result<ServerConfig> {
        let doc = TomlDoc::parse(text)?;
        for key in doc.root.keys() {
            if !matches!(
                key.as_str(),
                "max_batch"
                    | "batch_window_us"
                    | "backend"
                    | "opt_level"
                    | "fabric_cache"
                    | "workers"
                    | "queue_depth"
            ) {
                bail!("unknown server config key '{key}'");
            }
        }
        if let Some(name) = doc.tables.keys().next() {
            bail!("unexpected table '[[{name}]]' in server config");
        }
        let mut cfg = ServerConfig::default();
        if let Some(v) = doc.root.get("max_batch") {
            cfg.max_batch = v.as_usize()?.max(1);
        }
        if let Some(v) = doc.root.get("batch_window_us") {
            cfg.batch_window = Duration::from_micros(v.as_usize()? as u64);
        }
        if let Some(v) = doc.root.get("backend") {
            // Resolve now so a bad name fails at parse time with the
            // registry's uniform name-listing error; store canonical.
            cfg.backend = BackendRegistry::global()
                .resolve(v.as_str()?)?
                .name()
                .to_string();
        }
        if let Some(v) = doc.root.get("opt_level") {
            // Accept both `opt_level = "O2"` and `opt_level = 2`.
            cfg.opt_level = Some(match v.as_str() {
                Ok(s) => s.parse().context("server config key 'opt_level'")?,
                Err(_) => OptLevel::from_index(v.as_usize()? as u32)
                    .context("server config key 'opt_level'")?,
            });
        }
        if let Some(v) = doc.root.get("fabric_cache") {
            cfg.fabric_cache = Some(std::path::PathBuf::from(v.as_str()?));
        }
        if let Some(v) = doc.root.get("workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = doc.root.get("queue_depth") {
            cfg.queue_depth = v.as_usize()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Range-check the knobs — zero/absurd values fail loudly at parse
    /// time instead of being clamped downstream. Delegates to
    /// [`FabricTuning::validate`], the one range check both the config
    /// file and the builder path share.
    pub fn validate(&self) -> Result<()> {
        FabricTuning {
            max_batch: self.max_batch,
            batch_window: self.batch_window,
            workers: self.workers,
            queue_depth: self.queue_depth,
        }
        .validate()
    }

    /// Load a server-config file from disk.
    pub fn load(path: &std::path::Path) -> Result<ServerConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse_toml(&text)
            .with_context(|| format!("parsing {}", path.display()))
    }
}

/// Why the serving runtime did not accept a request. Carried inside the
/// `anyhow` error chain so callers can downcast and react (shed vs retry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerError {
    /// The bounded request queue is full — explicit backpressure; shed
    /// the request or retry later.
    Overloaded,
    /// The server has stopped (or is draining for shutdown).
    Stopped,
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerError::Overloaded => {
                write!(f, "server overloaded: request queue is full")
            }
            ServerError::Stopped => write!(f, "server stopped"),
        }
    }
}

impl std::error::Error for ServerError {}

struct Request {
    features: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Reply>,
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct Reply {
    pub prediction: u32,
    pub latency: Duration,
    /// Size of the fabric batch this request rode in.
    pub batch_size: usize,
    /// Which worker thread served the batch.
    pub worker: usize,
}

// ---------------------------------------------------------------------------
// Stats

/// Log2 latency buckets: bucket `i` covers `[2^i, 2^{i+1})` microseconds.
const LAT_BUCKETS: usize = 32;
/// Log2 batch-size buckets: bucket `i` covers sizes `[2^i, 2^{i+1})`.
const BATCH_BUCKETS: usize = 16;

/// Serving telemetry: typed handles into a per-server [`MetricsRegistry`]
/// (`neuralut_server_*` metric family), written by workers and clients
/// with one relaxed atomic RMW per event, snapshot on demand.
struct StatsInner {
    started: Instant,
    registry: MetricsRegistry,
    served: Counter,
    rejected: Counter,
    batches: Counter,
    batch_hist: Histogram,
    lat_hist: Histogram,
    queue_wait: Histogram,
    batch_form: Histogram,
    execute: Histogram,
    queue_depth: Gauge,
    in_flight: Gauge,
    per_worker: Vec<Counter>,
}

impl StatsInner {
    fn new(workers: usize) -> Self {
        let registry = MetricsRegistry::new();
        for (name, help) in [
            ("neuralut_server_requests_served_total", "requests answered across all workers"),
            ("neuralut_server_requests_rejected_total", "requests shed by try_infer backpressure"),
            ("neuralut_server_batches_total", "fabric batches executed"),
            ("neuralut_server_worker_served_total", "requests served per worker thread"),
            ("neuralut_server_batch_size", "requests folded into one fabric batch"),
            ("neuralut_server_latency_us", "end-to-end enqueue->reply latency, microseconds"),
            ("neuralut_server_queue_wait_us", "enqueue->dequeue stage of the latency, microseconds"),
            ("neuralut_server_batch_formation_us", "dequeue->execute-start stage of the latency, microseconds"),
            ("neuralut_server_execute_us", "fabric run_batch stage of the latency, microseconds"),
            ("neuralut_server_queue_depth", "requests waiting in the bounded queue"),
            ("neuralut_server_in_flight", "requests accepted but not yet answered"),
        ] {
            registry.describe(name, help);
        }
        let per_worker = (0..workers)
            .map(|w| {
                let id = w.to_string();
                registry.counter("neuralut_server_worker_served_total", &[("worker", &id)])
            })
            .collect();
        StatsInner {
            started: Instant::now(),
            served: registry.counter("neuralut_server_requests_served_total", &[]),
            rejected: registry.counter("neuralut_server_requests_rejected_total", &[]),
            batches: registry.counter("neuralut_server_batches_total", &[]),
            batch_hist: registry.histogram("neuralut_server_batch_size", &[], BATCH_BUCKETS),
            lat_hist: registry.histogram("neuralut_server_latency_us", &[], LAT_BUCKETS),
            queue_wait: registry.histogram("neuralut_server_queue_wait_us", &[], LAT_BUCKETS),
            batch_form: registry
                .histogram("neuralut_server_batch_formation_us", &[], LAT_BUCKETS),
            execute: registry.histogram("neuralut_server_execute_us", &[], LAT_BUCKETS),
            queue_depth: registry.gauge("neuralut_server_queue_depth", &[]),
            in_flight: registry.gauge("neuralut_server_in_flight", &[]),
            per_worker,
            registry,
        }
    }

    /// A request made it past backpressure into the queue.
    fn record_accepted(&self) {
        self.queue_depth.inc();
        self.in_flight.inc();
    }

    /// A worker pulled a request out of the queue after `waited`.
    fn record_dequeued(&self, waited: Duration) {
        self.queue_depth.dec();
        self.queue_wait.observe(waited.as_micros() as u64);
    }

    fn record_batch(&self, worker: usize, size: usize) {
        self.batches.inc();
        self.served.add(size as u64);
        self.per_worker[worker].add(size as u64);
        self.batch_hist.observe(size as u64);
    }

    /// One request answered: its end-to-end latency plus the
    /// batch-formation and execute stage shares.
    fn record_served(&self, latency: Duration, formation: Duration, execute: Duration) {
        self.lat_hist.observe(latency.as_micros() as u64);
        self.batch_form.observe(formation.as_micros() as u64);
        self.execute.observe(execute.as_micros() as u64);
        self.in_flight.dec();
    }

    fn record_rejected(&self) {
        self.rejected.inc();
    }

    fn snapshot(&self) -> ServerStats {
        let served = self.served.get();
        let batches = self.batches.get();
        let uptime_s = self.started.elapsed().as_secs_f64();
        let per_worker_served: Vec<u64> = self.per_worker.iter().map(|c| c.get()).collect();
        ServerStats {
            served,
            rejected: self.rejected.get(),
            batches,
            mean_batch: served as f64 / batches.max(1) as f64,
            batch_hist: self.batch_hist.buckets(),
            per_worker_rps: per_worker_served
                .iter()
                .map(|&s| s as f64 / uptime_s.max(1e-9))
                .collect(),
            per_worker_served,
            latency_p50_us: self.lat_hist.percentile(0.50),
            latency_p95_us: self.lat_hist.percentile(0.95),
            latency_p99_us: self.lat_hist.percentile(0.99),
            queue_wait_p50_us: self.queue_wait.percentile(0.50),
            queue_wait_p95_us: self.queue_wait.percentile(0.95),
            queue_wait_p99_us: self.queue_wait.percentile(0.99),
            batch_form_p50_us: self.batch_form.percentile(0.50),
            batch_form_p95_us: self.batch_form.percentile(0.95),
            batch_form_p99_us: self.batch_form.percentile(0.99),
            execute_p50_us: self.execute.percentile(0.50),
            execute_p95_us: self.execute.percentile(0.95),
            execute_p99_us: self.execute.percentile(0.99),
            queue_depth: self.queue_depth.get() as i64,
            in_flight: self.in_flight.get() as i64,
            uptime_s,
        }
    }
}

/// Point-in-time snapshot of the serving counters.
#[derive(Debug, Clone)]
pub struct ServerStats {
    /// Requests answered (across all workers).
    pub served: u64,
    /// Requests shed by [`Client::try_infer`] backpressure.
    pub rejected: u64,
    /// Fabric batches executed.
    pub batches: u64,
    /// served / batches.
    pub mean_batch: f64,
    /// Batches per log2 size bucket (bucket `i` = sizes `[2^i, 2^{i+1})`).
    pub batch_hist: Vec<u64>,
    /// Requests served per worker thread.
    pub per_worker_served: Vec<u64>,
    /// Per-worker served-requests/s over the server's uptime.
    pub per_worker_rps: Vec<f64>,
    /// Approximate enqueue→reply latency percentiles (log2-bucketed), us.
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    /// Queue-wait stage (enqueue→dequeue) percentiles, us.
    pub queue_wait_p50_us: f64,
    pub queue_wait_p95_us: f64,
    pub queue_wait_p99_us: f64,
    /// Batch-formation stage (dequeue→execute start) percentiles, us.
    pub batch_form_p50_us: f64,
    pub batch_form_p95_us: f64,
    pub batch_form_p99_us: f64,
    /// Execute stage (`run_batch`, shared by the whole batch) percentiles, us.
    pub execute_p50_us: f64,
    pub execute_p95_us: f64,
    pub execute_p99_us: f64,
    /// Requests waiting in the bounded queue right now (approximate:
    /// client increments and worker decrements race benignly).
    pub queue_depth: i64,
    /// Requests accepted but not yet answered right now (approximate).
    pub in_flight: i64,
    pub uptime_s: f64,
}

// ---------------------------------------------------------------------------
// Client / Server

struct ServerShared {
    queue: BoundedQueue<Request>,
    stats: StatsInner,
}

/// Handle for submitting requests; cheap to clone, usable from any thread,
/// outlives the `Server` (submissions after shutdown fail with
/// [`ServerError::Stopped`]).
#[derive(Clone)]
pub struct Client {
    shared: Arc<ServerShared>,
    input_size: usize,
}

impl Client {
    fn check_features(&self, features: &[f32]) -> Result<()> {
        if features.len() != self.input_size {
            bail!(
                "feature vector has {} values, model expects {}",
                features.len(),
                self.input_size
            );
        }
        Ok(())
    }

    fn request(&self, features: Vec<f32>) -> (Request, Receiver<Reply>) {
        let (reply_tx, reply_rx) = mpsc::channel();
        (
            Request { features, enqueued: Instant::now(), reply: reply_tx },
            reply_rx,
        )
    }

    /// Submit one request; applies backpressure (blocks while the queue is
    /// full) and then blocks until the prediction is ready.
    pub fn infer(&self, features: Vec<f32>) -> Result<Reply> {
        let rx = self.infer_async(features)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("server dropped request"))
    }

    /// Submit asynchronously; returns the reply receiver. Blocks only
    /// while the queue is full.
    pub fn infer_async(&self, features: Vec<f32>) -> Result<Receiver<Reply>> {
        self.check_features(&features)?;
        let (req, rx) = self.request(features);
        self.shared
            .queue
            .push(req)
            .map_err(|_| anyhow::Error::from(ServerError::Stopped))?;
        self.shared.stats.record_accepted();
        Ok(rx)
    }

    /// Non-blocking submit — the backpressure edge. A full queue returns
    /// [`ServerError::Overloaded`] (counted in [`ServerStats::rejected`]);
    /// a stopped server returns [`ServerError::Stopped`]. Both downcast
    /// from the `anyhow` error.
    pub fn try_infer(&self, features: Vec<f32>) -> Result<Receiver<Reply>> {
        self.check_features(&features)?;
        let (req, rx) = self.request(features);
        match self.shared.queue.try_push(req) {
            Ok(()) => {
                self.shared.stats.record_accepted();
                Ok(rx)
            }
            Err(PushError::Full(_)) => {
                self.shared.stats.record_rejected();
                Err(ServerError::Overloaded.into())
            }
            Err(PushError::Closed(_)) => Err(ServerError::Stopped.into()),
        }
    }

    /// Serving counters (shared with [`Server::stats`]).
    pub fn stats(&self) -> ServerStats {
        self.shared.stats.snapshot()
    }

    /// Raw metrics snapshot (shared with [`Server::metrics`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.stats.registry.snapshot()
    }
}

/// The running server; dropping it closes the queue, drains and answers
/// the backlog, and joins every worker.
///
/// Started via [`CompiledFabric::serve`](crate::fabric::CompiledFabric::serve);
/// there is no public constructor here — compilation, backend resolution
/// and tuning validation all live in the fabric layer.
pub struct Server {
    shared: Arc<ServerShared>,
    program: Arc<dyn FabricProgram>,
    handles: Vec<JoinHandle<()>>,
    input_size: usize,
}

impl Server {
    /// Spawn `tuning.workers` batcher threads over an already-compiled
    /// program. Crate-internal shim under
    /// [`CompiledFabric::serve`](crate::fabric::CompiledFabric::serve):
    /// by the time control reaches here the backend factory has run
    /// (exactly once) and the tuning has been range-checked, so starting
    /// cannot fail. Each worker only gets a cheap executor of `program`.
    pub(crate) fn start(
        program: Arc<dyn FabricProgram>,
        input_size: usize,
        tuning: &FabricTuning,
    ) -> Server {
        let shared = Arc::new(ServerShared {
            queue: BoundedQueue::new(tuning.queue_depth),
            stats: StatsInner::new(tuning.workers),
        });
        let max_batch = tuning.max_batch;
        let window = tuning.batch_window;
        // Executors are built here, synchronously, before any thread spawns
        // — so the compile-exactly-once property is a construction-time
        // invariant, not a runtime race.
        let handles = (0..tuning.workers)
            .map(|w| {
                let exec = program.executor();
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(w, exec, sh, max_batch, window))
            })
            .collect();
        Server { shared, program, handles, input_size }
    }

    pub fn client(&self) -> Client {
        Client { shared: self.shared.clone(), input_size: self.input_size }
    }

    /// Number of worker threads actually running.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Snapshot of the serving counters.
    pub fn stats(&self) -> ServerStats {
        self.shared.stats.snapshot()
    }

    /// Snapshot of the full `neuralut_server_*` metrics registry —
    /// counters, gauges and the per-stage latency histograms — for the
    /// exposition encoders in [`crate::obs::expo`].
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.stats.registry.snapshot()
    }

    /// The lowered bit-netlist every worker shares (`None` for backends
    /// with nothing compiled to share, e.g. `scalar`).
    pub fn shared_program(&self) -> Option<Arc<BitNetlist>> {
        self.program.bit_netlist().cloned()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    worker: usize,
    backend: Box<dyn InferenceBackend>,
    shared: Arc<ServerShared>,
    max_batch: usize,
    window: Duration,
) {
    loop {
        // Block for the first request of a batch; `None` = closed + drained.
        let Some(first) = shared.queue.pop() else { return };
        let popped = Instant::now();
        shared.stats.record_dequeued(popped.duration_since(first.enqueued));
        let in_sz = first.features.len();
        // Each request carries the instant it left the queue so its
        // batch-formation share (dequeue → execute start) can be split
        // out of the end-to-end latency below.
        let mut batch = vec![(first, popped)];
        let deadline = popped + window;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match shared.queue.pop_timeout(deadline - now) {
                Pop::Item(r) => {
                    let t = Instant::now();
                    shared.stats.record_dequeued(t.duration_since(r.enqueued));
                    batch.push((r, t));
                }
                // Closed: finish this batch; the outer pop() exits once
                // the backlog is drained.
                Pop::TimedOut | Pop::Closed => break,
            }
        }
        // One fabric run for the whole batch.
        let mut x = Vec::with_capacity(batch.len() * in_sz);
        for (r, _) in &batch {
            x.extend_from_slice(&r.features);
        }
        let exec_start = Instant::now();
        let result = backend.run_batch(&x);
        let exec_time = exec_start.elapsed();
        let bs = batch.len();
        shared.stats.record_batch(worker, bs);
        for ((req, left_queue), &pred) in batch.into_iter().zip(&result.predictions) {
            let latency = req.enqueued.elapsed();
            shared.stats.record_served(
                latency,
                exec_start.duration_since(left_queue),
                exec_time,
            );
            let _ = req.reply.send(Reply {
                prediction: pred,
                latency,
                batch_size: bs,
                worker,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricOptions, Model};
    use crate::luts::random_network;
    use crate::netlist::Simulator;

    /// Compile-and-serve helper for these tests: the fabric API path
    /// every caller uses.
    fn serve(net: Arc<crate::luts::LutNetwork>, opts: &FabricOptions) -> Server {
        Model::from_arc(net).compile(opts).unwrap().serve()
    }

    #[test]
    fn serves_and_matches_direct_simulation() {
        let net = Arc::new(random_network(21, 8, 2, &[6, 3], 3, 2, 4));
        let sim = Simulator::new(&net);
        let server = serve(net.clone(), &FabricOptions::new());
        let client = server.client();
        for i in 0..20 {
            let feats: Vec<f32> = (0..8).map(|j| ((i + j) % 5) as f32 / 5.0).collect();
            let want = sim.simulate_batch(&feats).predictions[0];
            let got = client.infer(feats).unwrap();
            assert_eq!(got.prediction, want);
            assert!(got.batch_size >= 1);
        }
    }

    #[test]
    fn bitsliced_backend_serves_identical_predictions() {
        let net = Arc::new(random_network(24, 8, 2, &[6, 3], 3, 2, 4));
        let sim = Simulator::new(&net);
        let server = serve(net.clone(), &FabricOptions::new().backend("bitsliced"));
        let client = server.client();
        for i in 0..20 {
            let feats: Vec<f32> = (0..8).map(|j| ((i + j) % 6) as f32 / 6.0).collect();
            let want = sim.simulate_batch(&feats).predictions[0];
            assert_eq!(client.infer(feats).unwrap().prediction, want);
        }
    }

    #[test]
    fn config_parses_from_toml_subset() {
        let cfg = ServerConfig::parse_toml(
            "max_batch = 512\nbatch_window_us = 100\nbackend = \"bitsliced\"\n\
             opt_level = \"O2\"\nfabric_cache = \"net.nfab\"\n\
             workers = 4\nqueue_depth = 64",
        )
        .unwrap();
        assert_eq!(cfg.max_batch, 512);
        assert_eq!(cfg.batch_window, Duration::from_micros(100));
        assert_eq!(cfg.backend, "bitsliced");
        assert_eq!(cfg.opt_level, Some(OptLevel::O2));
        assert_eq!(cfg.fabric_cache.as_deref(),
                   Some(std::path::Path::new("net.nfab")));
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.queue_depth, 64);
        // Numeric opt levels parse too; unknown ones fail loudly.
        assert_eq!(ServerConfig::parse_toml("opt_level = 0").unwrap().opt_level,
                   Some(OptLevel::O0));
        assert!(ServerConfig::parse_toml("opt_level = \"O9\"").is_err());
        assert!(ServerConfig::parse_toml("opt_level = 3").is_err());
        // Backend names normalize to the registry's canonical form.
        let cfg = ServerConfig::parse_toml("backend = \" Bitsliced \"").unwrap();
        assert_eq!(cfg.backend, "bitsliced");
        // All keys optional -> defaults (backend defaults to scalar).
        let d = ServerConfig::parse_toml("").unwrap();
        assert_eq!(d.backend, "scalar");
        // An omitted opt_level stays unset — it must not later masquerade
        // as an explicit pin that rejects cached .nfab artifacts.
        assert!(d.opt_level.is_none());
        assert!(d.fabric_cache.is_none());
        assert_eq!(d.max_batch, ServerConfig::default().max_batch);
        assert_eq!(d.workers, 1);
        assert_eq!(d.queue_depth, 1024);
        // Typos and bad values fail loudly.
        assert!(ServerConfig::parse_toml("max_bach = 4").is_err());
        let err = ServerConfig::parse_toml("backend = \"fpga\"").unwrap_err().to_string();
        assert!(err.contains("unknown backend 'fpga'"), "{err}");
        assert!(err.contains("registered:"), "{err}");
        assert!(ServerConfig::parse_toml("[[run]]\nconfig = \"x\"").is_err());
        assert!(ServerConfig::parse_toml("workers = 0").is_err());
        assert!(ServerConfig::parse_toml("workers = 100000").is_err());
        assert!(ServerConfig::parse_toml("queue_depth = 0").is_err());
    }

    #[test]
    fn rejects_bad_feature_length() {
        let net = Arc::new(random_network(22, 8, 2, &[4, 2], 3, 2, 4));
        let server = serve(net, &FabricOptions::new());
        assert!(server.client().infer(vec![0.0; 3]).is_err());
    }

    #[test]
    fn concurrent_clients_all_get_replies() {
        let net = Arc::new(random_network(23, 4, 2, &[4, 2], 2, 2, 4));
        let server = serve(
            net,
            &FabricOptions::new()
                .max_batch(16)
                .batch_window(Duration::from_micros(500)),
        );
        let client = server.client();
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for i in 0..25 {
                        let feats: Vec<f32> =
                            (0..4).map(|j| ((t + i + j) % 7) as f32 / 7.0).collect();
                        let r = c.infer(feats).unwrap();
                        assert!(r.prediction < 2);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn worker_pool_shares_one_compiled_program() {
        let net = Arc::new(random_network(41, 8, 2, &[6, 3], 3, 2, 4));
        let server = serve(
            net.clone(),
            &FabricOptions::new().backend("bitsliced").workers(4),
        );
        assert_eq!(server.workers(), 4);
        let prog = server.shared_program().expect("bitsliced fabric has a program");
        // ONE compiled BitNetlist, referenced by: the program held by the
        // server + this clone + each of the 4 worker executors. If any
        // worker had compiled its own program, this count (and the
        // program identity) would differ.
        assert_eq!(Arc::strong_count(&prog), 4 + 2);
        // The scalar program has nothing compiled to share.
        let scalar = serve(net, &FabricOptions::new().workers(3));
        assert!(scalar.shared_program().is_none());
        assert_eq!(scalar.workers(), 3);
    }

    #[test]
    fn multi_worker_serving_matches_direct_simulation() {
        let net = Arc::new(random_network(42, 8, 2, &[6, 3], 3, 2, 4));
        let sim = Simulator::new(&net);
        let server = serve(
            net.clone(),
            &FabricOptions::new().backend("bitsliced").workers(4),
        );
        let client = server.client();
        for i in 0..64 {
            let feats: Vec<f32> = (0..8).map(|j| ((i * 3 + j) % 9) as f32 / 9.0).collect();
            let want = sim.simulate_batch(&feats).predictions[0];
            let got = client.infer(feats).unwrap();
            assert_eq!(got.prediction, want);
            assert!(got.worker < 4);
        }
    }

    #[test]
    fn try_infer_sheds_with_overloaded_when_queue_is_full() {
        let net = Arc::new(random_network(44, 6, 2, &[4, 2], 2, 2, 4));
        let server = serve(
            net,
            &FabricOptions::new()
                .workers(1)
                .queue_depth(1)
                .max_batch(1)
                .batch_window(Duration::ZERO),
        );
        let client = server.client();
        let feats = vec![0.5f32; 6];
        let mut pending = Vec::new();
        let mut rejected = 0u64;
        let t0 = Instant::now();
        // Flood a depth-1 queue; the single worker cannot keep up with a
        // tight submit loop, so Overloaded must surface quickly.
        while rejected == 0 && t0.elapsed() < Duration::from_secs(10) {
            match client.try_infer(feats.clone()) {
                Ok(rx) => pending.push(rx),
                Err(e) => {
                    assert_eq!(
                        e.downcast_ref::<ServerError>(),
                        Some(&ServerError::Overloaded)
                    );
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "depth-1 queue never reported Overloaded");
        assert_eq!(server.stats().rejected, rejected);
        // Every accepted request is still answered.
        for rx in pending {
            rx.recv().unwrap();
        }
    }

    #[test]
    fn stats_account_served_requests_batches_and_latency() {
        let net = Arc::new(random_network(45, 6, 2, &[4, 2], 2, 2, 4));
        let server = serve(net, &FabricOptions::new().workers(2));
        let client = server.client();
        for i in 0..40 {
            let feats: Vec<f32> = (0..6).map(|j| ((i + j) % 5) as f32 / 5.0).collect();
            client.infer(feats).unwrap();
        }
        let s = server.stats();
        assert_eq!(s.served, 40);
        assert_eq!(s.rejected, 0);
        assert!(s.batches >= 1 && s.batches <= 40);
        assert!((s.mean_batch - s.served as f64 / s.batches as f64).abs() < 1e-9);
        assert_eq!(s.per_worker_served.len(), 2);
        assert_eq!(s.per_worker_served.iter().sum::<u64>(), 40);
        assert_eq!(s.batch_hist.iter().sum::<u64>(), s.batches);
        assert!(s.latency_p50_us.is_finite() && s.latency_p50_us > 0.0);
        assert!(s.latency_p99_us >= s.latency_p50_us);
        assert!(s.uptime_s > 0.0);
        // The stage decomposition covers every served request, and the
        // gauges settle back to zero once everything is answered.
        assert!(s.queue_wait_p50_us.is_finite());
        assert!(s.batch_form_p50_us.is_finite());
        assert!(s.execute_p50_us.is_finite() && s.execute_p50_us > 0.0);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.in_flight, 0);
        // Client sees the same counters.
        assert_eq!(client.stats().served, 40);
        // The raw registry snapshot exposes the same story for the
        // exposition encoders, one histogram sample per request.
        let snap = server.metrics();
        assert_eq!(
            snap.counter("neuralut_server_requests_served_total", &[]).unwrap().value,
            40
        );
        for name in [
            "neuralut_server_latency_us",
            "neuralut_server_queue_wait_us",
            "neuralut_server_batch_formation_us",
            "neuralut_server_execute_us",
        ] {
            let h = snap.histogram(name, &[]).unwrap();
            assert_eq!(h.count, 40, "{name}");
        }
        let w0 = snap
            .counter("neuralut_server_worker_served_total", &[("worker", "0")])
            .unwrap();
        let w1 = snap
            .counter("neuralut_server_worker_served_total", &[("worker", "1")])
            .unwrap();
        assert_eq!(w0.value + w1.value, 40);
    }

    #[test]
    fn stopped_server_fails_fast_with_explicit_error() {
        let net = Arc::new(random_network(46, 6, 2, &[4, 2], 2, 2, 4));
        let server = serve(net, &FabricOptions::new());
        let client = server.client();
        drop(server);
        let err = client.infer(vec![0.0; 6]).unwrap_err();
        assert_eq!(err.downcast_ref::<ServerError>(), Some(&ServerError::Stopped));
        assert_eq!(err.to_string(), "server stopped");
        let err = client.try_infer(vec![0.0; 6]).unwrap_err();
        assert_eq!(err.downcast_ref::<ServerError>(), Some(&ServerError::Stopped));
    }

    #[test]
    fn log2_histogram_percentiles_are_sane() {
        // The bucketing/percentile math now lives in `obs::metrics` —
        // same semantics the serving runtime always had.
        use crate::obs::{hist_percentile, log2_bucket};
        // 100 samples in bucket 3 ([8, 16)): every percentile lands there.
        let mut hist = vec![0u64; 8];
        hist[3] = 100;
        let p50 = hist_percentile(&hist, 0.50);
        assert!((8.0..16.0).contains(&p50), "p50 = {p50}");
        assert!(hist_percentile(&hist, 0.99) >= p50);
        assert!(hist_percentile(&[0u64; 8], 0.5).is_nan());
        assert_eq!(log2_bucket(0, 8), 0);
        assert_eq!(log2_bucket(1, 8), 0);
        assert_eq!(log2_bucket(9, 8), 3);
        assert_eq!(log2_bucket(u64::MAX, 8), 7);
    }
}
