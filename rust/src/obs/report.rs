//! Structured compile telemetry: what each lowering / optimization pass
//! cost and what it removed, attached to every
//! [`CompiledFabric`](crate::fabric::CompiledFabric) and persisted next
//! to `.nfab` artifacts as `*.report.json`.
//!
//! A [`CompileReport`] is a chain of [`PassReport`]s — `lower`, then the
//! optimizer's `simplify` and `dce` (which also packs planes at O2) —
//! plus the final netlist shape. The chain is checkable:
//! `passes[i].ops_before == passes[i-1].ops_after` and the last
//! `ops_after` equals the executed op count, which is exactly the
//! "O2 report ops == executed ops" invariant the test suite pins.

use std::fmt;

use crate::util::json::{obj, Json};

use super::metrics::MetricsRegistry;

/// One timed compile pass and its op-count delta.
#[derive(Debug, Clone, PartialEq)]
pub struct PassReport {
    /// Pass name (`lower`, `simplify`, `dce`; the AOT backends append
    /// `codegen`, `cc`, `dlopen`).
    pub name: String,
    /// Wall time of the pass in seconds.
    pub wall_s: f64,
    /// Word-op count entering the pass (0 for `lower`: nothing exists yet).
    pub ops_before: usize,
    /// Word-op count leaving the pass.
    pub ops_after: usize,
    /// Input planes removed by interface compaction (dce at O2; 0 elsewhere).
    pub planes_removed: usize,
}

impl PassReport {
    /// Signed op delta: positive when the pass removed ops (`lower` is
    /// negative — it creates the netlist).
    pub fn ops_removed(&self) -> i64 {
        self.ops_before as i64 - self.ops_after as i64
    }

    /// JSON object for persistence / bench rows.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("wall_s", Json::Num(self.wall_s)),
            ("ops_before", Json::Num(self.ops_before as f64)),
            ("ops_after", Json::Num(self.ops_after as f64)),
            ("planes_removed", Json::Num(self.planes_removed as f64)),
        ])
    }

    /// Parse one pass object (inverse of [`to_json`](Self::to_json)).
    pub fn from_json(j: &Json) -> crate::Result<PassReport> {
        Ok(PassReport {
            name: j.get("name")?.as_str()?.to_string(),
            wall_s: j.get("wall_s")?.as_f64()?,
            ops_before: j.get_usize("ops_before")?,
            ops_after: j.get_usize("ops_after")?,
            planes_removed: j.get_usize("planes_removed")?,
        })
    }
}

/// Everything one compile did, with per-pass attribution. Obtained from
/// [`CompiledFabric::report`](crate::fabric::CompiledFabric::report).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CompileReport {
    /// Model name.
    pub model: String,
    /// Backend compiled (registry name).
    pub backend: String,
    /// Optimization level as text (`O0`/`O1`/`O2`).
    pub opt_level: String,
    /// End-to-end compile (or artifact load) wall time in seconds.
    pub total_s: f64,
    /// True when the program came from a `.nfab` fabric cache (per-pass
    /// data is absent: nothing was lowered or optimized).
    pub from_cache: bool,
    /// Timed passes in execution order.
    pub passes: Vec<PassReport>,
    /// Final executed word-op count (0 for backends without a netlist).
    pub ops: usize,
    /// Final pipeline depth in levels.
    pub levels: usize,
    /// Widest input-plane interface across levels.
    pub max_planes: usize,
    /// Widest wire frame across levels.
    pub max_wires: usize,
    /// `u64` words per bit-plane of the compiled program (1 for the
    /// classic bitsliced engine, 2/4/8 for the wide variants); 0 for
    /// backends without a plane word (e.g. `scalar`).
    pub lanes: usize,
    /// When graceful degradation kicked in — the requested backend
    /// failed to compile (or its artifact failed to load) and the
    /// fabric fell back to the backend's declared fallback (`bitsliced`
    /// for the AOT backends, the reference `scalar` otherwise) — this
    /// records the backend name that *was* requested. `None` for a
    /// healthy compile. Mirrored into the `neuralut_degraded` gauge by
    /// [`export`](Self::export).
    pub degraded_from: Option<String>,
}

impl CompileReport {
    /// Total ops removed by optimization: ops lowered minus ops kept.
    pub fn ops_removed(&self) -> i64 {
        match self.passes.first() {
            Some(lower) => lower.ops_after as i64 - self.ops as i64,
            None => 0,
        }
    }

    /// Check the pass chain: deltas must connect (`ops_before` of each
    /// pass equals `ops_after` of the previous) and the last pass must
    /// land on the final op count. Errors name the broken link.
    pub fn check(&self) -> Result<(), String> {
        for (i, p) in self.passes.iter().enumerate() {
            if !p.wall_s.is_finite() || p.wall_s < 0.0 {
                return Err(format!("pass '{}' has bad wall time {}", p.name, p.wall_s));
            }
            if i > 0 {
                let prev = &self.passes[i - 1];
                if p.ops_before != prev.ops_after {
                    return Err(format!(
                        "pass chain broken: '{}' enters with {} ops but '{}' left {}",
                        p.name, p.ops_before, prev.name, prev.ops_after
                    ));
                }
            }
        }
        if let Some(last) = self.passes.last() {
            if last.ops_after != self.ops {
                return Err(format!(
                    "last pass '{}' left {} ops but the report claims {}",
                    last.name, last.ops_after, self.ops
                ));
            }
        }
        Ok(())
    }

    /// JSON object (persisted as the `.report.json` artifact sibling).
    /// `degraded_from` is written only when set, so healthy reports stay
    /// byte-compatible with readers that predate degradation.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", Json::Str(self.model.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("opt_level", Json::Str(self.opt_level.clone())),
            ("total_s", Json::Num(self.total_s)),
            ("from_cache", Json::Bool(self.from_cache)),
            (
                "passes",
                Json::Arr(self.passes.iter().map(|p| p.to_json()).collect()),
            ),
            ("ops", Json::Num(self.ops as f64)),
            ("levels", Json::Num(self.levels as f64)),
            ("max_planes", Json::Num(self.max_planes as f64)),
            ("max_wires", Json::Num(self.max_wires as f64)),
            ("lanes", Json::Num(self.lanes as f64)),
        ];
        if let Some(from) = &self.degraded_from {
            fields.push(("degraded_from", Json::Str(from.clone())));
        }
        obj(fields)
    }

    /// Parse a report back (inverse of [`to_json`](Self::to_json)).
    pub fn from_json(j: &Json) -> crate::Result<CompileReport> {
        Ok(CompileReport {
            model: j.get("model")?.as_str()?.to_string(),
            backend: j.get("backend")?.as_str()?.to_string(),
            opt_level: j.get("opt_level")?.as_str()?.to_string(),
            total_s: j.get("total_s")?.as_f64()?,
            from_cache: j.get("from_cache")?.as_bool()?,
            passes: j
                .get("passes")?
                .as_arr()?
                .iter()
                .map(PassReport::from_json)
                .collect::<crate::Result<Vec<_>>>()?,
            ops: j.get_usize("ops")?,
            levels: j.get_usize("levels")?,
            max_planes: j.get_usize("max_planes")?,
            max_wires: j.get_usize("max_wires")?,
            // Reports written before the wide-plane formats carry no
            // `lanes` key; read those as 0 ("width unknown").
            lanes: match j.get("lanes") {
                Ok(v) => v.as_usize()?,
                Err(_) => 0,
            },
            // Healthy reports (and reports written before degradation
            // existed) carry no `degraded_from` key at all.
            degraded_from: match j.get("degraded_from") {
                Ok(v) => Some(v.as_str()?.to_string()),
                Err(_) => None,
            },
        })
    }

    /// Export the report into a [`MetricsRegistry`] so the same numbers
    /// ride the Prometheus text / JSON snapshot expositions:
    /// `neuralut_compile_pass_seconds{pass=...}`,
    /// `neuralut_compile_pass_ops_removed{pass=...}`, plus final-shape
    /// gauges and a `neuralut_compile_info` series carrying the labels.
    pub fn export(&self, reg: &MetricsRegistry) {
        reg.describe("neuralut_compile_info", "compile identity (model/backend/opt level)");
        reg.gauge(
            "neuralut_compile_info",
            &[
                ("model", &self.model),
                ("backend", &self.backend),
                ("opt_level", &self.opt_level),
            ],
        )
        .set(1.0);
        reg.describe("neuralut_compile_total_seconds", "end-to-end compile wall time");
        reg.gauge("neuralut_compile_total_seconds", &[]).set(self.total_s);
        reg.gauge("neuralut_compile_from_cache", &[])
            .set(if self.from_cache { 1.0 } else { 0.0 });
        for p in &self.passes {
            reg.gauge("neuralut_compile_pass_seconds", &[("pass", &p.name)]).set(p.wall_s);
            reg.gauge("neuralut_compile_pass_ops_removed", &[("pass", &p.name)])
                .set(p.ops_removed() as f64);
        }
        reg.describe("neuralut_compile_ops", "executed word ops after optimization");
        reg.gauge("neuralut_compile_ops", &[]).set(self.ops as f64);
        reg.gauge("neuralut_compile_levels", &[]).set(self.levels as f64);
        reg.gauge("neuralut_compile_max_planes", &[]).set(self.max_planes as f64);
        reg.gauge("neuralut_compile_max_wires", &[]).set(self.max_wires as f64);
        reg.describe("neuralut_compile_lanes", "u64 words per bit-plane (0 = no plane word)");
        reg.gauge("neuralut_compile_lanes", &[]).set(self.lanes as f64);
        reg.describe(
            "neuralut_degraded",
            "1 when the fabric fell back to another backend after a compile/load failure",
        );
        reg.gauge("neuralut_degraded", &[])
            .set(if self.degraded_from.is_some() { 1.0 } else { 0.0 });
        let cold: f64 = self
            .passes
            .iter()
            .filter(|p| matches!(p.name.as_str(), "codegen" | "cc" | "dlopen"))
            .map(|p| p.wall_s)
            .sum();
        if self.passes.iter().any(|p| matches!(p.name.as_str(), "codegen" | "cc" | "dlopen")) {
            reg.describe(
                "neuralut_aot_cold_start_seconds",
                "native codegen + system compiler + dlopen wall time of the AOT backend",
            );
            reg.gauge("neuralut_aot_cold_start_seconds", &[]).set(cold);
        }
    }
}

impl fmt::Display for CompileReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "compile report: {} ({} at {}{})  total {:.3} ms",
            self.model,
            self.backend,
            self.opt_level,
            if self.from_cache { ", cached" } else { "" },
            self.total_s * 1e3
        )?;
        if let Some(from) = &self.degraded_from {
            writeln!(
                f,
                "  DEGRADED: '{from}' failed to compile; serving on the '{}' backend",
                self.backend
            )?;
        }
        if self.passes.is_empty() {
            writeln!(f, "  passes : none (loaded precompiled program)")?;
        } else {
            writeln!(
                f,
                "  {:<10} {:>10} {:>10} {:>10} {:>8}",
                "pass", "wall_ms", "ops_in", "ops_out", "removed"
            )?;
            for p in &self.passes {
                writeln!(
                    f,
                    "  {:<10} {:>10.3} {:>10} {:>10} {:>8}",
                    p.name,
                    p.wall_s * 1e3,
                    p.ops_before,
                    p.ops_after,
                    p.ops_removed()
                )?;
            }
        }
        write!(
            f,
            "  final  : {} word ops over {} levels (max {} planes, {} wires)",
            self.ops, self.levels, self.max_planes, self.max_wires
        )?;
        if self.lanes > 0 {
            write!(f, " [{}-word planes, {} samples/block]", self.lanes, self.lanes * 64)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CompileReport {
        CompileReport {
            model: "m".into(),
            backend: "bitsliced".into(),
            opt_level: "O2".into(),
            total_s: 0.25,
            from_cache: false,
            passes: vec![
                PassReport {
                    name: "lower".into(),
                    wall_s: 0.2,
                    ops_before: 0,
                    ops_after: 100,
                    planes_removed: 0,
                },
                PassReport {
                    name: "simplify".into(),
                    wall_s: 0.03,
                    ops_before: 100,
                    ops_after: 60,
                    planes_removed: 0,
                },
                PassReport {
                    name: "dce".into(),
                    wall_s: 0.02,
                    ops_before: 60,
                    ops_after: 55,
                    planes_removed: 7,
                },
            ],
            ops: 55,
            levels: 3,
            max_planes: 12,
            max_wires: 40,
            lanes: 1,
            degraded_from: None,
        }
    }

    #[test]
    fn chain_check_and_removed() {
        let r = sample();
        r.check().unwrap();
        assert_eq!(r.ops_removed(), 45);
        let mut broken = r.clone();
        broken.passes[2].ops_before = 61;
        assert!(broken.check().unwrap_err().contains("chain broken"));
        let mut off = r.clone();
        off.ops = 54;
        assert!(off.check().unwrap_err().contains("claims"));
    }

    #[test]
    fn json_roundtrip() {
        let r = sample();
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let back = CompileReport::from_json(&j).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn export_lands_in_registry() {
        let reg = MetricsRegistry::new();
        sample().export(&reg);
        let snap = reg.snapshot();
        assert_eq!(snap.gauge("neuralut_compile_ops", &[]).unwrap().value, 55.0);
        let pass = snap
            .gauge("neuralut_compile_pass_ops_removed", &[("pass", "simplify")])
            .unwrap();
        assert_eq!(pass.value, 40.0);
        assert!(snap.gauge("neuralut_compile_info", &[("model", "m")]).is_some());
    }

    #[test]
    fn display_mentions_every_pass() {
        let text = sample().to_string();
        for name in ["lower", "simplify", "dce"] {
            assert!(text.contains(name), "{text}");
        }
        assert!(text.contains("55 word ops over 3 levels"), "{text}");
        assert!(text.contains("[1-word planes, 64 samples/block]"), "{text}");
        let mut scalar = sample();
        scalar.lanes = 0;
        assert!(!scalar.to_string().contains("planes,"), "{scalar}");
    }

    #[test]
    fn degraded_reports_round_trip_and_export_the_gauge() {
        // A healthy report omits the key entirely and exports gauge 0.
        let healthy = sample();
        assert!(!healthy.to_json().to_string().contains("degraded_from"));
        let reg = MetricsRegistry::new();
        healthy.export(&reg);
        assert_eq!(reg.snapshot().gauge("neuralut_degraded", &[]).unwrap().value, 0.0);
        // A degraded report round-trips the origin backend and flips the
        // gauge; Display calls the degradation out loudly.
        let mut degraded = sample();
        degraded.backend = "scalar".into();
        degraded.passes.clear();
        degraded.ops = 0;
        degraded.degraded_from = Some("bitsliced-x4".into());
        let j = Json::parse(&degraded.to_json().to_string()).unwrap();
        let back = CompileReport::from_json(&j).unwrap();
        assert_eq!(back.degraded_from.as_deref(), Some("bitsliced-x4"));
        assert_eq!(back, degraded);
        let reg = MetricsRegistry::new();
        degraded.export(&reg);
        assert_eq!(reg.snapshot().gauge("neuralut_degraded", &[]).unwrap().value, 1.0);
        let text = degraded.to_string();
        assert!(text.contains("DEGRADED"), "{text}");
        assert!(text.contains("bitsliced-x4"), "{text}");
    }

    #[test]
    fn lanes_default_to_zero_for_pre_width_reports() {
        // A report serialized before the wide-plane formats has no
        // `lanes` key; parsing must not fail and must read it as 0.
        let mut j = sample().to_json().to_string();
        j = j.replace(",\"lanes\":1", "").replace("\"lanes\":1,", "");
        let back = CompileReport::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.lanes, 0);
        let reg = MetricsRegistry::new();
        sample().export(&reg);
        let snap = reg.snapshot();
        assert_eq!(snap.gauge("neuralut_compile_lanes", &[]).unwrap().value, 1.0);
    }
}
