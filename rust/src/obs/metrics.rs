//! Metrics registry: named counters, gauges and log2-bucket histograms
//! with label sets, built on `std::sync::atomic` only.
//!
//! Registration (name + labels -> handle) takes a mutex once; the handles
//! returned are `Arc`-backed and every hot-path operation (`inc`,
//! `observe`, `set`) is a single atomic RMW — no locks, no allocation.
//! [`MetricsRegistry::snapshot`] freezes everything into plain data for
//! the exposition encoders in [`expo`](crate::obs::expo).
//!
//! Histograms use the same log2 bucketing as the serving runtime always
//! has: value `v` lands in bucket `floor(log2(max(v, 1)))`, clamped to
//! the last bucket, so bucket `i` covers `[2^i, 2^(i+1))` and 32 buckets
//! span 1 µs .. ~71 min when observations are microseconds.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Log2 bucket index of `v` in an `n`-bucket histogram: bucket `i`
/// covers `[2^i, 2^(i+1))`; `0` maps with `1`; overflow clamps to the
/// last bucket (the saturated bucket keeps counting, it never drops).
pub fn log2_bucket(v: u64, n_buckets: usize) -> usize {
    (63 - v.max(1).leading_zeros() as usize).min(n_buckets - 1)
}

/// Linear-interpolated percentile (`q` in 0..=1) from log2 bucket counts,
/// assuming observations are uniform inside a bucket. Returns NaN on an
/// empty histogram. The saturated last bucket reports its lower bound's
/// doubling (capped so the width math cannot overflow `u64`).
pub fn hist_percentile(hist: &[u64], q: f64) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return f64::NAN;
    }
    let target = q * total as f64;
    let mut acc = 0.0;
    for (i, &c) in hist.iter().enumerate() {
        let next = acc + c as f64;
        if next >= target && c > 0 {
            let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
            let hi = (1u64 << (i + 1).min(63)) as f64;
            let frac = ((target - acc) / c as f64).clamp(0.0, 1.0);
            return lo + frac * (hi - lo);
        }
        acc = next;
    }
    (1u64 << hist.len().min(63)) as f64
}

/// A monotonically increasing counter. Cheap to clone; all clones share
/// the same cell.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    fn new() -> Counter {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge: a settable `f64` (stored as bits in an `AtomicU64`). Cheap to
/// clone; all clones share the same cell.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    fn new() -> Gauge {
        Gauge(Arc::new(AtomicU64::new(0f64.to_bits())))
    }

    /// Overwrite the value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Add `d` (CAS loop; gauges are low-frequency by design).
    pub fn add(&self, d: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self.0.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1.0);
    }

    /// Subtract 1.
    pub fn dec(&self) {
        self.add(-1.0);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct HistCore {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
}

/// A log2-bucket histogram of `u64` observations. Cheap to clone; all
/// clones share the same cells. `observe` is three relaxed `fetch_add`s.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistCore>);

impl Histogram {
    fn new(n_buckets: usize) -> Histogram {
        let buckets: Vec<AtomicU64> =
            (0..n_buckets.max(1)).map(|_| AtomicU64::new(0)).collect();
        Histogram(Arc::new(HistCore {
            buckets: buckets.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }))
    }

    /// Record one observation.
    pub fn observe(&self, v: u64) {
        let i = log2_bucket(v, self.0.buckets.len());
        self.0.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Number of observations so far.
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of observations so far.
    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket counts, frozen.
    pub fn buckets(&self) -> Vec<u64> {
        self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Interpolated percentile (`q` in 0..=1); NaN when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        hist_percentile(&self.buckets(), q)
    }
}

/// Label pairs, kept sorted by key so the same set always maps to the
/// same time series regardless of call-site order.
pub type Labels = Vec<(String, String)>;

#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct MetricKey {
    name: String,
    labels: Labels,
}

impl MetricKey {
    fn new(name: &str, labels: &[(&str, &str)]) -> MetricKey {
        let mut labels: Labels = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        MetricKey { name: name.to_string(), labels }
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<MetricKey, Counter>,
    gauges: BTreeMap<MetricKey, Gauge>,
    histograms: BTreeMap<MetricKey, Histogram>,
    help: BTreeMap<String, String>,
}

/// One frozen counter time series.
#[derive(Debug, Clone, PartialEq)]
pub struct CounterSample {
    /// Metric name.
    pub name: String,
    /// Sorted label pairs.
    pub labels: Labels,
    /// Value at snapshot time.
    pub value: u64,
}

/// One frozen gauge time series.
#[derive(Debug, Clone, PartialEq)]
pub struct GaugeSample {
    /// Metric name.
    pub name: String,
    /// Sorted label pairs.
    pub labels: Labels,
    /// Value at snapshot time.
    pub value: f64,
}

/// One frozen histogram time series.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSample {
    /// Metric name.
    pub name: String,
    /// Sorted label pairs.
    pub labels: Labels,
    /// Per-bucket (non-cumulative) counts; bucket `i` covers
    /// `[2^i, 2^(i+1))` with bucket 0 also absorbing 0.
    pub buckets: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of observations.
    pub sum: u64,
}

impl HistogramSample {
    /// Interpolated percentile (`q` in 0..=1); NaN when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        hist_percentile(&self.buckets, q)
    }
}

/// Plain-data view of a whole registry at one instant, sorted by metric
/// name then labels — the input to both exposition encoders.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// All counters.
    pub counters: Vec<CounterSample>,
    /// All gauges.
    pub gauges: Vec<GaugeSample>,
    /// All histograms.
    pub histograms: Vec<HistogramSample>,
    /// Help text by metric name (from [`MetricsRegistry::describe`]).
    pub help: BTreeMap<String, String>,
}

impl MetricsSnapshot {
    /// Fold another snapshot into this one (series are appended; help
    /// strings merge, other-snapshot entries win on name clashes).
    pub fn merge(&mut self, other: MetricsSnapshot) {
        self.counters.extend(other.counters);
        self.gauges.extend(other.gauges);
        self.histograms.extend(other.histograms);
        self.help.extend(other.help);
        self.counters.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
        self.gauges.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
        self.histograms.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
    }

    /// Find a counter by name and label subset (all given pairs present).
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Option<&CounterSample> {
        self.counters.iter().find(|c| c.name == name && has_labels(&c.labels, labels))
    }

    /// Find a gauge by name and label subset.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<&GaugeSample> {
        self.gauges.iter().find(|g| g.name == name && has_labels(&g.labels, labels))
    }

    /// Find a histogram by name and label subset.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<&HistogramSample> {
        self.histograms.iter().find(|h| h.name == name && has_labels(&h.labels, labels))
    }
}

fn has_labels(have: &Labels, want: &[(&str, &str)]) -> bool {
    want.iter().all(|(k, v)| have.iter().any(|(hk, hv)| hk == k && hv == v))
}

/// Get-or-register store of named metrics. Registration locks a mutex;
/// the returned handles never do.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<RegistryInner>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Get or register the counter `name{labels}`.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let key = MetricKey::new(name, labels);
        let mut inner = self.inner.lock().unwrap();
        inner.counters.entry(key).or_insert_with(Counter::new).clone()
    }

    /// Get or register the gauge `name{labels}`.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        let key = MetricKey::new(name, labels);
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.entry(key).or_insert_with(Gauge::new).clone()
    }

    /// Get or register the histogram `name{labels}` with `n_buckets`
    /// log2 buckets. A later call with a different bucket count returns
    /// the series registered first.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)], n_buckets: usize) -> Histogram {
        let key = MetricKey::new(name, labels);
        let mut inner = self.inner.lock().unwrap();
        inner
            .histograms
            .entry(key)
            .or_insert_with(|| Histogram::new(n_buckets))
            .clone()
    }

    /// Attach help text to a metric name (rendered as `# HELP` lines).
    pub fn describe(&self, name: &str, help: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.help.insert(name.to_string(), help.to_string());
    }

    /// Freeze every registered series into plain data.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        MetricsSnapshot {
            counters: inner
                .counters
                .iter()
                .map(|(k, c)| CounterSample {
                    name: k.name.clone(),
                    labels: k.labels.clone(),
                    value: c.get(),
                })
                .collect(),
            gauges: inner
                .gauges
                .iter()
                .map(|(k, g)| GaugeSample {
                    name: k.name.clone(),
                    labels: k.labels.clone(),
                    value: g.get(),
                })
                .collect(),
            histograms: inner
                .histograms
                .iter()
                .map(|(k, h)| HistogramSample {
                    name: k.name.clone(),
                    labels: k.labels.clone(),
                    buckets: h.buckets(),
                    count: h.count(),
                    sum: h.sum(),
                })
                .collect(),
            help: inner.help.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_bucket_boundaries() {
        assert_eq!(log2_bucket(0, 8), 0);
        assert_eq!(log2_bucket(1, 8), 0);
        assert_eq!(log2_bucket(2, 8), 1);
        assert_eq!(log2_bucket(3, 8), 1);
        assert_eq!(log2_bucket(4, 8), 2);
        assert_eq!(log2_bucket(u64::MAX, 8), 7); // saturates, never drops
        assert_eq!(log2_bucket(u64::MAX, 64), 63); // full-width histogram
    }

    #[test]
    fn hist_percentile_edge_cases() {
        // Empty histogram: NaN, no panic.
        assert!(hist_percentile(&[0, 0, 0], 0.5).is_nan());
        // Single sample: every percentile lands inside its bucket.
        let mut h = vec![0u64; 8];
        h[log2_bucket(5, 8)] += 1;
        for q in [0.0, 0.5, 0.99, 1.0] {
            let p = hist_percentile(&h, q);
            assert!((4.0..=8.0).contains(&p), "q={q} p={p}");
        }
        // Saturated last bucket of a 64-wide histogram must not overflow.
        let mut h = vec![0u64; 64];
        h[63] = 10;
        let p = hist_percentile(&h, 0.5);
        assert!(p.is_finite() && p > 0.0, "p={p}");
    }

    #[test]
    fn handles_share_cells_and_labels_are_order_insensitive() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("c", &[("x", "1"), ("y", "2")]);
        let b = reg.counter("c", &[("y", "2"), ("x", "1")]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let snap = reg.snapshot();
        assert_eq!(snap.counters.len(), 1);
        assert_eq!(snap.counter("c", &[("x", "1")]).unwrap().value, 3);
    }

    #[test]
    fn gauge_add_and_set() {
        let reg = MetricsRegistry::new();
        let g = reg.gauge("g", &[]);
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1.0);
        g.set(-2.5);
        assert_eq!(g.get(), -2.5);
    }

    #[test]
    fn histogram_counts_and_sum() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("h", &[], 8);
        for v in [0, 1, 2, 3, 300] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 306);
        let b = h.buckets();
        assert_eq!(b[0], 2); // 0 and 1
        assert_eq!(b[1], 2); // 2 and 3
        assert_eq!(b[7], 1); // 300 clamps into the last bucket
        assert!(h.percentile(0.5).is_finite());
    }

    #[test]
    fn concurrent_increments_are_exact() {
        let reg = std::sync::Arc::new(MetricsRegistry::new());
        let n_threads = 8;
        let per_thread = 10_000u64;
        let mut joins = Vec::new();
        for t in 0..n_threads {
            let reg = reg.clone();
            joins.push(std::thread::spawn(move || {
                let c = reg.counter("hits", &[]);
                let h = reg.histogram("lat", &[], 16);
                let g = reg.gauge("depth", &[]);
                for i in 0..per_thread {
                    c.inc();
                    h.observe(i % 1024);
                    if i % 2 == 0 {
                        g.inc();
                    } else {
                        g.dec();
                    }
                }
                let _ = t;
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = reg.snapshot();
        assert_eq!(snap.counter("hits", &[]).unwrap().value, n_threads * per_thread);
        let h = snap.histogram("lat", &[]).unwrap();
        assert_eq!(h.count, n_threads * per_thread);
        assert_eq!(h.buckets.iter().sum::<u64>(), h.count);
        assert_eq!(snap.gauge("depth", &[]).unwrap().value, 0.0);
    }
}
