//! Exposition encoders: one [`MetricsSnapshot`] in, Prometheus-style
//! text or a JSON document out.
//!
//! The text format follows the Prometheus exposition conventions —
//! `# HELP` / `# TYPE` headers, cumulative `_bucket{le=...}` series with
//! a closing `+Inf` bucket, `_sum` / `_count` — so the output scrapes
//! cleanly, while the JSON form (built on [`util::json`](crate::util::json))
//! additionally carries interpolated p50/p95/p99 per histogram so
//! dashboards and `BENCH_*.json` consumers need no bucket math.

use crate::util::json::{obj, Json};

use super::metrics::{Labels, MetricsSnapshot};

fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn render_labels(labels: &Labels, extra: Option<(&str, &str)>) -> String {
    let mut pairs: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        pairs.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if pairs.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", pairs.join(","))
    }
}

fn header(out: &mut String, snap: &MetricsSnapshot, name: &str, kind: &str, seen: &mut Vec<String>) {
    if seen.iter().any(|s| s == name) {
        return;
    }
    seen.push(name.to_string());
    if let Some(help) = snap.help.get(name) {
        out.push_str(&format!("# HELP {name} {help}\n"));
    }
    out.push_str(&format!("# TYPE {name} {kind}\n"));
}

/// Encode a snapshot as Prometheus exposition text.
pub fn to_prometheus(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let mut seen = Vec::new();
    for c in &snap.counters {
        header(&mut out, snap, &c.name, "counter", &mut seen);
        out.push_str(&format!("{}{} {}\n", c.name, render_labels(&c.labels, None), c.value));
    }
    for g in &snap.gauges {
        header(&mut out, snap, &g.name, "gauge", &mut seen);
        out.push_str(&format!(
            "{}{} {}\n",
            g.name,
            render_labels(&g.labels, None),
            fmt_f64(g.value)
        ));
    }
    for h in &snap.histograms {
        header(&mut out, snap, &h.name, "histogram", &mut seen);
        let mut cum = 0u64;
        for (i, &c) in h.buckets.iter().enumerate() {
            cum += c;
            let le = if i + 1 == h.buckets.len() {
                "+Inf".to_string()
            } else {
                format!("{}", 1u64 << (i + 1).min(63))
            };
            out.push_str(&format!(
                "{}_bucket{} {}\n",
                h.name,
                render_labels(&h.labels, Some(("le", &le))),
                cum
            ));
        }
        out.push_str(&format!("{}_sum{} {}\n", h.name, render_labels(&h.labels, None), h.sum));
        out.push_str(&format!(
            "{}_count{} {}\n",
            h.name,
            render_labels(&h.labels, None),
            h.count
        ));
    }
    out
}

fn labels_json(labels: &Labels) -> Json {
    Json::Obj(labels.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect())
}

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Interpolated percentiles are emitted rounded to 3 decimals: they are
/// already bucket estimates, and rounding keeps the JSON free of float
/// noise like `14.799999999999997`.
fn pctl_json(v: f64) -> Json {
    num_or_null((v * 1e3).round() / 1e3)
}

/// Encode a snapshot as a JSON document. Histograms carry interpolated
/// `p50`/`p95`/`p99` (JSON `null` while empty — NaN is not valid JSON).
pub fn to_json(snap: &MetricsSnapshot) -> Json {
    obj(vec![
        (
            "counters",
            Json::Arr(
                snap.counters
                    .iter()
                    .map(|c| {
                        obj(vec![
                            ("name", Json::Str(c.name.clone())),
                            ("labels", labels_json(&c.labels)),
                            ("value", Json::Num(c.value as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gauges",
            Json::Arr(
                snap.gauges
                    .iter()
                    .map(|g| {
                        obj(vec![
                            ("name", Json::Str(g.name.clone())),
                            ("labels", labels_json(&g.labels)),
                            ("value", num_or_null(g.value)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "histograms",
            Json::Arr(
                snap.histograms
                    .iter()
                    .map(|h| {
                        obj(vec![
                            ("name", Json::Str(h.name.clone())),
                            ("labels", labels_json(&h.labels)),
                            ("count", Json::Num(h.count as f64)),
                            ("sum", Json::Num(h.sum as f64)),
                            ("p50", pctl_json(h.percentile(0.50))),
                            ("p95", pctl_json(h.percentile(0.95))),
                            ("p99", pctl_json(h.percentile(0.99))),
                            (
                                "buckets",
                                Json::Arr(
                                    h.buckets.iter().map(|&b| Json::Num(b as f64)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::MetricsRegistry;

    fn golden_registry() -> MetricsRegistry {
        let reg = MetricsRegistry::new();
        reg.describe("requests_total", "requests accepted");
        reg.counter("requests_total", &[("worker", "0")]).add(3);
        reg.counter("requests_total", &[("worker", "1")]).add(4);
        reg.gauge("queue_depth", &[]).set(2.0);
        let h = reg.histogram("latency_us", &[], 4);
        h.observe(1);
        h.observe(3);
        h.observe(100); // clamps into the last bucket
        reg
    }

    #[test]
    fn prometheus_text_golden() {
        let text = to_prometheus(&golden_registry().snapshot());
        let want = "\
# HELP requests_total requests accepted
# TYPE requests_total counter
requests_total{worker=\"0\"} 3
requests_total{worker=\"1\"} 4
# TYPE queue_depth gauge
queue_depth 2
# TYPE latency_us histogram
latency_us_bucket{le=\"2\"} 1
latency_us_bucket{le=\"4\"} 2
latency_us_bucket{le=\"8\"} 2
latency_us_bucket{le=\"+Inf\"} 3
latency_us_sum 104
latency_us_count 3
";
        assert_eq!(text, want);
    }

    #[test]
    fn json_golden() {
        let j = to_json(&golden_registry().snapshot());
        let text = j.to_string();
        let want = concat!(
            "{\"counters\":[",
            "{\"labels\":{\"worker\":\"0\"},\"name\":\"requests_total\",\"value\":3},",
            "{\"labels\":{\"worker\":\"1\"},\"name\":\"requests_total\",\"value\":4}],",
            "\"gauges\":[{\"labels\":{},\"name\":\"queue_depth\",\"value\":2}],",
            "\"histograms\":[{\"buckets\":[1,1,0,1],\"count\":3,",
            "\"labels\":{},\"name\":\"latency_us\",",
            "\"p50\":3,\"p95\":14.8,\"p99\":15.76,\"sum\":104}]}",
        );
        assert_eq!(text, want);
        // And it parses back.
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn empty_histogram_percentiles_are_null_json() {
        let reg = MetricsRegistry::new();
        reg.histogram("h", &[], 4);
        let j = to_json(&reg.snapshot());
        let h = &j.get("histograms").unwrap().as_arr().unwrap()[0];
        assert_eq!(h.get("p50").unwrap(), &Json::Null);
        // The whole document is still valid JSON.
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
