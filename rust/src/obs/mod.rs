//! Observability: metrics registry, compile-pass tracing and exposition.
//!
//! The telemetry substrate for the whole stack, built on `std` only (no
//! external crates — CI lints that this module stays dependency-free):
//!
//! * [`metrics`] — a [`MetricsRegistry`] of named counters, gauges and
//!   log2-bucket histograms with labels. Registration takes a mutex
//!   once; every hot-path update is one relaxed atomic RMW, so the
//!   serving workers pay nanoseconds per request.
//! * [`report`] — structured compile telemetry: a [`CompileReport`]
//!   chains timed [`PassReport`]s (`lower` → `simplify` → `dce`) with
//!   op/plane deltas, is attached to every
//!   [`CompiledFabric`](crate::fabric::CompiledFabric), and is persisted
//!   as `*.report.json` next to `.nfab` artifacts.
//! * [`trace`] — `NEURALUT_TRACE=1` turns on a hierarchical stderr span
//!   log around the same passes.
//! * [`expo`] — encoders from a [`MetricsSnapshot`] to Prometheus-style
//!   text and to JSON (via [`util::json`](crate::util::json)); the CLI
//!   `stats` subcommand and the benches print these.
//!
//! Quickstart — compile a model and print where the time and ops went:
//!
//! ```ignore
//! use neuralut::fabric::{FabricOptions, Model};
//!
//! let model = Model::load("network.nlut".as_ref())?;
//! let fabric = model.compile(&FabricOptions::new().backend("bitsliced"))?;
//! // Per-pass wall time, op deltas and the final netlist shape:
//! println!("{}", fabric.report());
//!
//! // Serve, then read the request-path metrics the same way:
//! let server = fabric.serve();
//! /* ... drive requests ... */
//! let snap = server.metrics(); // queue-wait / batch-formation / execute
//! println!("{}", neuralut::obs::expo::to_prometheus(&snap));
//! println!("{}", neuralut::obs::expo::to_json(&snap).to_string());
//! ```

pub mod expo;
pub mod metrics;
pub mod report;
pub mod trace;

pub use metrics::{
    hist_percentile, log2_bucket, Counter, CounterSample, Gauge, GaugeSample, Histogram,
    HistogramSample, Labels, MetricsRegistry, MetricsSnapshot,
};
pub use report::{CompileReport, PassReport};
