//! `NEURALUT_TRACE` stderr span log: hierarchical wall-time spans around
//! compile passes (and anything else worth timing), gated by one
//! environment check per process.
//!
//! Set `NEURALUT_TRACE=1` (any non-empty value other than `0`) and every
//! [`span`] prints one line to stderr when it closes:
//!
//! ```text
//! neuralut-trace: compile/bitsliced 812.402 ms
//! neuralut-trace:   lower 641.513 ms
//! neuralut-trace:   opt/simplify 84.781 ms
//! ```
//!
//! Spans nest per thread (the indent is a thread-local depth counter) and
//! cost nothing when tracing is off: the guard holds no allocation and
//! `Drop` is a no-op.

use std::cell::Cell;
use std::sync::OnceLock;
use std::time::Instant;

static ENABLED: OnceLock<bool> = OnceLock::new();

thread_local! {
    static DEPTH: Cell<usize> = const { Cell::new(0) };
}

/// Whether `NEURALUT_TRACE` enables the span log (checked once per
/// process; empty or `0` means off).
pub fn enabled() -> bool {
    *ENABLED.get_or_init(|| {
        std::env::var("NEURALUT_TRACE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// An open span; prints its duration to stderr on drop when tracing is
/// enabled. Obtain one with [`span`].
pub struct Span {
    inner: Option<(String, Instant)>,
}

/// Open a timed span. When tracing is disabled this is free (no clock
/// read, no allocation).
pub fn span(name: &str) -> Span {
    if !enabled() {
        return Span { inner: None };
    }
    DEPTH.with(|d| d.set(d.get() + 1));
    Span { inner: Some((name.to_string(), Instant::now())) }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((name, started)) = self.inner.take() {
            let depth = DEPTH.with(|d| {
                let v = d.get();
                d.set(v.saturating_sub(1));
                v
            });
            eprintln!(
                "{}",
                format_line(depth.saturating_sub(1), &name, started.elapsed().as_secs_f64())
            );
        }
    }
}

/// One `neuralut-trace:` line (separate from emission so the format is
/// testable without touching process-global env state).
pub(crate) fn format_line(depth: usize, name: &str, secs: f64) -> String {
    format!(
        "neuralut-trace: {:indent$}{name} {:.3} ms",
        "",
        secs * 1e3,
        indent = depth * 2
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_format_is_stable() {
        assert_eq!(format_line(0, "lower", 0.641513), "neuralut-trace: lower 641.513 ms");
        assert_eq!(
            format_line(2, "opt/dce", 0.0005),
            "neuralut-trace:     opt/dce 0.500 ms"
        );
    }

    #[test]
    fn spans_are_safe_regardless_of_env() {
        // Whatever NEURALUT_TRACE is set to in the test environment, the
        // guard must nest and drop cleanly.
        let outer = span("outer");
        let inner = span("inner");
        drop(inner);
        drop(outer);
    }
}
