//! VCD (Value Change Dump, IEEE 1364) waveform writer for fabric traces.
//!
//! The simulator's cycle model is "registers at every L-LUT output, one
//! circuit layer per clock": feeding a sample stream through the pipeline
//! produces per-cycle register values, which this module dumps as a VCD
//! file viewable in GTKWave next to the generated Verilog — closing the
//! debug loop between the netlist simulator and the RTL.

use std::fmt::Write as _;

use crate::luts::LutNetwork;

use super::quantize_input;
#[cfg(test)]
use super::Simulator;

/// Pipeline register trace: `stages[cycle][layer][lut]` holds the signed
/// code latched at that cycle (layer 0 slot = quantized inputs).
pub struct Trace {
    pub cycles: usize,
    /// Per cycle: per pipeline stage (input stage + one per layer), the
    /// register values (i32 codes; inputs and hidden are unsigned).
    pub stages: Vec<Vec<Vec<i32>>>,
}

/// Simulate a sample stream cycle-by-cycle through the pipeline and record
/// every register. Sample `i` enters at cycle `i`; the pipeline is deep
/// enough that `cycles = samples + layers`.
pub fn trace_pipeline(net: &LutNetwork, samples: &[Vec<f32>]) -> Trace {
    let n_layers = net.layers.len();
    let cycles = samples.len() + n_layers + 1;
    // Register file: stage 0 = input regs, stage l+1 = layer l outputs.
    let mut widths = vec![net.input_size];
    widths.extend(net.layers.iter().map(|l| l.num_luts()));
    let mut regs: Vec<Vec<i32>> = widths.iter().map(|&w| vec![0; w]).collect();
    let mut stages = Vec::with_capacity(cycles);

    for cycle in 0..cycles {
        // Combinational evaluation uses the *previous* register values;
        // compute next state back-to-front so each stage reads its input
        // stage's pre-edge value.
        let mut next = regs.clone();
        for (li, layer) in net.layers.iter().enumerate().rev() {
            let entries = layer.entries();
            let bits = layer.in_bits;
            for (lut, idx) in layer.indices.iter().enumerate() {
                let mut addr = 0usize;
                for (j, &src) in idx.iter().enumerate() {
                    addr |= (regs[li][src as usize] as usize) << (bits * j);
                }
                next[li + 1][lut] = layer.tables[lut * entries + addr] as i32;
            }
        }
        // Input registers latch the new sample (or hold 0 when drained).
        if cycle < samples.len() {
            for (i, &v) in samples[cycle].iter().enumerate() {
                next[0][i] = quantize_input(v, net.input_bits) as i32;
            }
        } else {
            next[0].iter_mut().for_each(|v| *v = 0);
        }
        regs = next;
        stages.push(regs.clone());
    }
    Trace { cycles, stages }
}

/// Serialize a [`Trace`] as a VCD document.
pub fn to_vcd(net: &LutNetwork, trace: &Trace, timescale_ns: f64) -> String {
    let mut v = String::new();
    let _ = writeln!(v, "$date neuralut fabric trace $end");
    let _ = writeln!(v, "$version neuralut::netlist::vcd $end");
    let _ = writeln!(v, "$timescale {}ps $end", (timescale_ns * 1000.0) as u64);
    let _ = writeln!(v, "$scope module {} $end", net.name.replace('-', "_"));

    // Identifier codes: printable ASCII starting at '!'.
    let mut ids: Vec<Vec<String>> = Vec::new();
    let mut next_id = 0usize;
    let mut make_id = || {
        let mut n = next_id;
        next_id += 1;
        let mut s = String::new();
        loop {
            s.push((33 + (n % 94)) as u8 as char);
            n /= 94;
            if n == 0 {
                break;
            }
        }
        s
    };
    let mut widths = vec![(net.input_size, net.input_bits, "in".to_string())];
    for (l, layer) in net.layers.iter().enumerate() {
        widths.push((layer.num_luts(), layer.out_bits, format!("l{l}")));
    }
    for (stage, (count, bits, prefix)) in widths.iter().enumerate() {
        let mut stage_ids = Vec::with_capacity(*count);
        for i in 0..*count {
            let id = make_id();
            let _ = writeln!(v, "$var wire {bits} {id} {prefix}_n{i} $end");
            stage_ids.push(id);
        }
        let _ = stage;
        ids.push(stage_ids);
    }
    let _ = writeln!(v, "$upscope $end");
    let _ = writeln!(v, "$enddefinitions $end");

    let mut prev: Option<&Vec<Vec<i32>>> = None;
    for (cycle, stage_vals) in trace.stages.iter().enumerate() {
        let _ = writeln!(v, "#{cycle}");
        for (s, vals) in stage_vals.iter().enumerate() {
            let bits = widths[s].1;
            for (i, &val) in vals.iter().enumerate() {
                let changed = prev
                    .map(|p| p[s][i] != val)
                    .unwrap_or(true);
                if changed {
                    let enc = (val as u32) & ((1u32 << bits) - 1);
                    let _ = writeln!(v, "b{enc:0width$b} {}", ids[s][i],
                                     width = bits);
                }
            }
        }
        prev = Some(stage_vals);
    }
    v
}

/// Convenience: trace `n` test samples and write `trace.vcd`.
pub fn write_vcd(net: &LutNetwork, test_x: &[f32], n: usize,
                 path: &std::path::Path) -> crate::Result<()> {
    let in_sz = net.input_size;
    let n = n.min(test_x.len() / in_sz);
    let samples: Vec<Vec<f32>> = (0..n)
        .map(|i| test_x[i * in_sz..(i + 1) * in_sz].to_vec())
        .collect();
    let trace = trace_pipeline(net, &samples);
    let synth_period = 1.0; // ns per cycle for display purposes
    std::fs::write(path, to_vcd(net, &trace, synth_period))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;

    #[test]
    fn pipeline_trace_matches_batch_simulation() {
        // After the pipeline fill latency, the last stage of the trace must
        // equal the batch simulator's logit codes, sample by sample.
        let net = random_network(31, 6, 2, &[5, 3], 2, 2, 4);
        let sim = Simulator::new(&net);
        let samples: Vec<Vec<f32>> = (0..8)
            .map(|i| (0..6).map(|j| ((i * 7 + j * 3) % 11) as f32 / 11.0).collect())
            .collect();
        let trace = trace_pipeline(&net, &samples);
        let n_layers = net.layers.len();
        for (i, s) in samples.iter().enumerate() {
            let want = sim.simulate_batch(s).logit_codes;
            // Sample i is latched into stage 0 at the end of cycle i and
            // reaches the last stage at cycle i + n_layers.
            let got: Vec<i16> = trace.stages[i + n_layers]
                .last()
                .unwrap()
                .iter()
                .map(|&v| v as i16)
                .collect();
            assert_eq!(got, want, "sample {i}");
        }
    }

    #[test]
    fn vcd_structure_is_valid() {
        let net = random_network(32, 4, 2, &[3, 2], 2, 2, 4);
        let samples: Vec<Vec<f32>> = vec![vec![0.1, 0.9, 0.4, 0.6]];
        let trace = trace_pipeline(&net, &samples);
        let vcd = to_vcd(&net, &trace, 1.0);
        assert!(vcd.contains("$enddefinitions $end"));
        assert!(vcd.contains("$var wire 2"));
        assert!(vcd.contains("#0"));
        // one $var per register
        let vars = vcd.matches("$var wire").count();
        assert_eq!(vars, 4 + 3 + 2);
    }

    #[test]
    fn write_vcd_creates_file() {
        let net = random_network(33, 4, 2, &[3, 2], 2, 2, 4);
        let x: Vec<f32> = (0..4 * 5).map(|i| (i % 3) as f32 / 3.0).collect();
        let path = std::env::temp_dir().join("neuralut_test.vcd");
        write_vcd(&net, &x, 5, &path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().starts_with("$date"));
    }
}
