//! Cycle-accurate LUT-network fabric simulator — the FPGA substitute.
//!
//! Functional model: every L-LUT output is registered, each circuit layer
//! evaluates in one clock cycle (exactly the paper's hardware: "each L-LUT
//! layer is evaluated in one clock cycle"), the pipeline accepts one sample
//! per cycle. The simulator is bit-exact against the quantized JAX model
//! (integration-tested) and doubles as the inference backend of the server.
//!
//! Hot path: `simulate_batch` — flat `u16` activation buffers, address
//! accumulation by shifts, contiguous table slices, sharded across threads
//! over the batch (`util::pool`).

use crate::luts::LutNetwork;
use crate::util::pool;

pub mod vcd;

/// Quantize a [0, 1] feature to its `bits`-bit input code.
///
/// Identical to `python/compile/quant.py::quant_input_code`:
/// `floor(clip(x, 0, 1) * (2^bits - 1) + 0.5)`.
#[inline]
pub fn quantize_input(x: f32, bits: usize) -> u16 {
    let levels = ((1u32 << bits) - 1) as f32;
    (x.clamp(0.0, 1.0) * levels + 0.5).floor() as u16
}

/// Result of simulating a batch.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Predicted class per sample (argmax of signed logit codes;
    /// ties break toward the lowest class index, as in the JAX argmax).
    pub predictions: Vec<u32>,
    /// Raw signed logit codes, `[batch * n_class]`.
    pub logit_codes: Vec<i16>,
    /// Pipeline latency in cycles (= number of L-LUT layers).
    pub latency_cycles: usize,
    /// Total cycles to drain the batch through the pipeline.
    pub total_cycles: usize,
}

impl SimResult {
    /// Assemble a result from raw logit codes: argmax predictions (ties
    /// break toward the lowest class index, as in the JAX argmax) and the
    /// pipeline cycle accounting — first result after `latency_cycles`,
    /// then one sample per cycle. Shared by every inference backend so
    /// the bit-exactness contract has a single definition.
    pub fn from_logit_codes(
        logit_codes: Vec<i16>,
        n_class: usize,
        latency_cycles: usize,
    ) -> SimResult {
        let n_class = n_class.max(1);
        let batch = logit_codes.len() / n_class;
        let predictions = logit_codes
            .chunks_exact(n_class)
            .map(|row| {
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                best as u32
            })
            .collect();
        SimResult {
            predictions,
            logit_codes,
            latency_cycles,
            total_cycles: latency_cycles + batch.saturating_sub(1),
        }
    }
}

/// Precomputed evaluation plan for one network: scratch sizing + dense
/// wiring. Split out of [`Simulator`] so backends with different network
/// ownership (the borrowing `Simulator`, the `Arc`-owning
/// `engine::ScalarEngine` the serving workers use) share one hot loop.
///
/// Every method takes the network again; it must be the same network the
/// plan was built from (the plan caches only derived shapes and wiring).
#[derive(Debug, Clone)]
pub struct ScalarPlan {
    /// Widest layer (for scratch sizing).
    max_width: usize,
    /// Per layer: wiring flattened to `[num_luts * fan_in]` (dense, cache-
    /// friendly — avoids the `Vec<Vec<u32>>` pointer chase in the hot loop).
    flat_indices: Vec<Vec<u32>>,
}

impl ScalarPlan {
    pub fn new(net: &LutNetwork) -> Self {
        let max_width = net
            .layers
            .iter()
            .map(|l| l.num_luts())
            .chain([net.input_size])
            .max()
            .unwrap_or(0);
        let flat_indices = net
            .layers
            .iter()
            .map(|l| l.indices.iter().flatten().copied().collect())
            .collect();
        ScalarPlan { max_width, flat_indices }
    }

    /// Simulate a batch of raw feature rows (`[batch * input_size]` floats
    /// in [0, 1]); multi-threaded over the batch when it is large enough
    /// to amortize thread spawn (~10 us each on this substrate — small
    /// batches run inline, which keeps single-sample serving latency low).
    pub fn simulate_batch(&self, net: &LutNetwork, x: &[f32]) -> SimResult {
        let in_sz = net.input_size;
        assert_eq!(x.len() % in_sz, 0, "ragged batch");
        let batch = x.len() / in_sz;
        let n_class = net.n_class;
        let mut logit_codes = vec![0i16; batch * n_class];

        const PARALLEL_THRESHOLD: usize = 64;
        if batch < PARALLEL_THRESHOLD {
            let mut cur = vec![0u16; self.max_width];
            let mut nxt = vec![0u16; self.max_width];
            for sample in 0..batch {
                let row = &x[sample * in_sz..(sample + 1) * in_sz];
                self.simulate_one(net, row, &mut cur, &mut nxt,
                    &mut logit_codes[sample * n_class..(sample + 1) * n_class]);
            }
        } else {
            // Shard the batch across threads; each worker owns two scratch
            // activation buffers (current/next layer) reused across rows.
            let shards = pool::parallel_ranges(
                batch,
                pool::num_threads(),
                |_, range| {
                    let mut out = vec![0i16; range.len() * n_class];
                    let mut cur = vec![0u16; self.max_width];
                    let mut nxt = vec![0u16; self.max_width];
                    for (row_i, sample) in range.clone().enumerate() {
                        let row = &x[sample * in_sz..(sample + 1) * in_sz];
                        self.simulate_one(net, row, &mut cur, &mut nxt,
                            &mut out[row_i * n_class..(row_i + 1) * n_class]);
                    }
                    (range.start, out)
                },
            );
            for (start, shard) in shards {
                logit_codes[start * n_class..start * n_class + shard.len()]
                    .copy_from_slice(&shard);
            }
        }

        SimResult::from_logit_codes(logit_codes, n_class, net.layers.len())
    }

    /// Evaluate one sample through all layers into `logits`.
    fn simulate_one(&self, net: &LutNetwork, row: &[f32], cur: &mut Vec<u16>,
                    nxt: &mut Vec<u16>, logits: &mut [i16]) {
        let in_bits = net.input_bits;
        for (i, &v) in row.iter().enumerate() {
            cur[i] = quantize_input(v, in_bits);
        }
        let n_layers = net.layers.len();
        for (li, layer) in net.layers.iter().enumerate() {
            let entries = layer.entries();
            let bits = layer.in_bits;
            let fan_in = layer.fan_in;
            let last = li == n_layers - 1;
            let wires = &self.flat_indices[li];
            let tables = &layer.tables;
            for lut in 0..layer.num_luts() {
                let mut addr = 0usize;
                for (j, &src) in
                    wires[lut * fan_in..(lut + 1) * fan_in].iter().enumerate()
                {
                    addr |= (cur[src as usize] as usize) << (bits * j);
                }
                let code = tables[lut * entries + addr];
                if last {
                    logits[lut] = code;
                } else {
                    nxt[lut] = code as u16;
                }
            }
            if !last {
                std::mem::swap(cur, nxt);
            }
        }
    }
}

/// The fabric simulator for one converted network (borrowing; for an
/// owning, `'static` backend see `engine::ScalarEngine`).
pub struct Simulator<'a> {
    net: &'a LutNetwork,
    plan: ScalarPlan,
}

impl<'a> Simulator<'a> {
    pub fn new(net: &'a LutNetwork) -> Self {
        Simulator { net, plan: ScalarPlan::new(net) }
    }

    /// Latency in cycles of one sample (registered output per layer).
    pub fn latency_cycles(&self) -> usize {
        self.net.layers.len()
    }

    /// Simulate a batch of raw feature rows; see [`ScalarPlan::simulate_batch`].
    pub fn simulate_batch(&self, x: &[f32]) -> SimResult {
        self.plan.simulate_batch(self.net, x)
    }

    /// Classification accuracy over a labelled set.
    pub fn accuracy(&self, x: &[f32], y: &[i32]) -> f64 {
        let r = self.simulate_batch(x);
        let correct = r
            .predictions
            .iter()
            .zip(y)
            .filter(|(&p, &t)| p as i32 == t)
            .count();
        correct as f64 / y.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;

    #[test]
    fn input_quantization_matches_python_convention() {
        // floor(x * levels + 0.5)
        assert_eq!(quantize_input(0.0, 2), 0);
        assert_eq!(quantize_input(1.0, 2), 3);
        assert_eq!(quantize_input(0.5, 2), 2); // 1.5 + 0.5 -> floor(2.0) = 2
        assert_eq!(quantize_input(0.49, 2), 1);
        assert_eq!(quantize_input(-1.0, 3), 0);
        assert_eq!(quantize_input(2.0, 3), 7);
    }

    #[test]
    fn simulator_is_deterministic_and_shaped() {
        let net = random_network(5, 12, 2, &[8, 4], 3, 2, 4);
        let sim = Simulator::new(&net);
        let x: Vec<f32> = (0..12 * 10).map(|i| (i % 7) as f32 / 7.0).collect();
        let a = sim.simulate_batch(&x);
        let b = sim.simulate_batch(&x);
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.logit_codes, b.logit_codes);
        assert_eq!(a.predictions.len(), 10);
        assert_eq!(a.latency_cycles, 2);
        assert_eq!(a.total_cycles, 2 + 9);
    }

    #[test]
    fn hand_built_identity_network() {
        // One layer, one LUT with fan_in=1, 2 input bits, table[i] = i.
        use crate::luts::{LutLayer, LutNetwork};
        let net = LutNetwork {
            name: "id".into(),
            input_size: 1,
            input_bits: 2,
            n_class: 1,
            layers: vec![LutLayer {
                indices: vec![vec![0]],
                tables: (0..4).map(|i| i as i16).collect(),
                fan_in: 1,
                in_bits: 2,
                out_bits: 4,
                signed_out: true,
            }],
        };
        net.validate().unwrap();
        let sim = Simulator::new(&net);
        let r = sim.simulate_batch(&[0.0, 0.34, 0.67, 1.0]);
        assert_eq!(r.logit_codes, vec![0, 1, 2, 3]);
    }

    #[test]
    fn address_bit_order_lsb_first() {
        // fan_in=2, 1 bit each: input0 -> addr bit0, input1 -> addr bit1.
        use crate::luts::{LutLayer, LutNetwork};
        let net = LutNetwork {
            name: "addr".into(),
            input_size: 2,
            input_bits: 1,
            n_class: 1,
            layers: vec![LutLayer {
                indices: vec![vec![0, 1]],
                tables: vec![10, 11, 12, 13], // addr 0..3
                fan_in: 2,
                in_bits: 1,
                out_bits: 5,
                signed_out: true,
            }],
        };
        let sim = Simulator::new(&net);
        // x = (1, 0) -> codes (1, 0) -> addr = 1 -> 11
        assert_eq!(sim.simulate_batch(&[1.0, 0.0]).logit_codes, vec![11]);
        // x = (0, 1) -> addr = 2 -> 12
        assert_eq!(sim.simulate_batch(&[0.0, 1.0]).logit_codes, vec![12]);
    }

    #[test]
    fn argmax_tie_breaks_low_index() {
        use crate::luts::{LutLayer, LutNetwork};
        let net = LutNetwork {
            name: "tie".into(),
            input_size: 1,
            input_bits: 1,
            n_class: 2,
            layers: vec![LutLayer {
                indices: vec![vec![0], vec![0]],
                tables: vec![3, 3, 3, 3],
                fan_in: 1,
                in_bits: 1,
                out_bits: 4,
                signed_out: true,
            }],
        };
        let sim = Simulator::new(&net);
        assert_eq!(sim.simulate_batch(&[0.0]).predictions, vec![0]);
    }
}
