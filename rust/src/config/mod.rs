//! Experiment-suite configuration: a TOML-subset parser (offline image —
//! no `toml` crate) and the suite schema consumed by `neuralut suite`.
//!
//! A suite file declares a batch of pipeline runs:
//!
//! ```toml
//! # suite.toml
//! name = "nightly"
//! seeds = 3
//! out_dir = "runs/nightly"
//!
//! [[run]]
//! config = "jsc-2l"
//! epochs = 40
//!
//! [[run]]
//! config = "hdr-mini"
//! rtl = true
//! ```
//!
//! Supported TOML subset: top-level `key = value` pairs, `[[table]]`
//! arrays, strings / integers / floats / booleans, `#` comments. That is
//! all the schema needs; unknown keys are rejected so typos fail loudly.
//!
//! The same subset also backs `server::ServerConfig` files
//! (`neuralut serve --server-config`):
//!
//! ```toml
//! # server.toml
//! max_batch = 512
//! batch_window_us = 100
//! backend = "bitsliced"   # inference engine: "scalar" | "bitsliced"
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One scalar TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Parsed TOML-subset document: top-level pairs + arrays of tables.
#[derive(Debug, Default)]
pub struct TomlDoc {
    pub root: BTreeMap<String, TomlValue>,
    pub tables: BTreeMap<String, Vec<BTreeMap<String, TomlValue>>>,
}

impl TomlDoc {
    /// Parse the subset described in the module docs.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        // None = root; Some(name) = the latest [[name]] table.
        let mut current: Option<String> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[") {
                let name = name
                    .strip_suffix("]]")
                    .with_context(|| format!("line {}: bad table header", lineno + 1))?
                    .trim()
                    .to_string();
                doc.tables.entry(name.clone()).or_default().push(BTreeMap::new());
                current = Some(name);
                continue;
            }
            if line.starts_with('[') {
                bail!("line {}: plain [tables] are not supported (use [[{}]])",
                      lineno + 1, line.trim_matches(['[', ']']));
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim().to_string();
            let value = parse_value(value.trim())
                .with_context(|| format!("line {}", lineno + 1))?;
            match &current {
                None => {
                    doc.root.insert(key, value);
                }
                Some(name) => {
                    doc.tables
                        .get_mut(name)
                        .unwrap()
                        .last_mut()
                        .unwrap()
                        .insert(key, value);
                }
            }
        }
        Ok(doc)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue> {
    if let Some(s) = v.strip_prefix('"') {
        let s = s.strip_suffix('"').context("unterminated string")?;
        return Ok(TomlValue::Str(s.to_string()));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{v}'")
}

/// One run declaration in a suite.
#[derive(Debug, Clone)]
pub struct SuiteRun {
    pub config: String,
    pub epochs: Option<usize>,
    pub seeds: Option<usize>,
    pub rtl: bool,
}

/// A parsed experiment suite.
#[derive(Debug, Clone)]
pub struct Suite {
    pub name: String,
    pub seeds: usize,
    pub out_dir: Option<String>,
    pub runs: Vec<SuiteRun>,
}

impl Suite {
    /// Load and validate a suite file.
    pub fn load(path: &Path) -> Result<Suite> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Suite> {
        let doc = TomlDoc::parse(text)?;
        for key in doc.root.keys() {
            if !matches!(key.as_str(), "name" | "seeds" | "out_dir") {
                bail!("unknown top-level key '{key}'");
            }
        }
        for name in doc.tables.keys() {
            if name != "run" {
                bail!("unknown table '[[{name}]]'");
            }
        }
        let runs = doc
            .tables
            .get("run")
            .map(|rows| {
                rows.iter()
                    .map(|row| {
                        for key in row.keys() {
                            if !matches!(key.as_str(),
                                         "config" | "epochs" | "seeds" | "rtl") {
                                bail!("unknown run key '{key}'");
                            }
                        }
                        Ok(SuiteRun {
                            config: row
                                .get("config")
                                .context("run missing 'config'")?
                                .as_str()?
                                .to_string(),
                            epochs: row.get("epochs").map(|v| v.as_usize()).transpose()?,
                            seeds: row.get("seeds").map(|v| v.as_usize()).transpose()?,
                            rtl: row.get("rtl").map(|v| v.as_bool()).transpose()?.unwrap_or(false),
                        })
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .transpose()?
            .unwrap_or_default();
        if runs.is_empty() {
            bail!("suite declares no [[run]] entries");
        }
        Ok(Suite {
            name: doc
                .root
                .get("name")
                .map(|v| Ok::<_, anyhow::Error>(v.as_str()?.to_string()))
                .transpose()?
                .unwrap_or_else(|| "suite".into()),
            seeds: doc.root.get("seeds").map(|v| v.as_usize()).transpose()?.unwrap_or(1),
            out_dir: doc
                .root
                .get("out_dir")
                .map(|v| Ok::<_, anyhow::Error>(v.as_str()?.to_string()))
                .transpose()?,
            runs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # nightly sweep
        name = "nightly"
        seeds = 3
        out_dir = "runs/nightly"

        [[run]]
        config = "jsc-2l"
        epochs = 40

        [[run]]
        config = "hdr-mini"  # trailing comment
        rtl = true
    "#;

    #[test]
    fn parses_full_suite() {
        let s = Suite::parse(SAMPLE).unwrap();
        assert_eq!(s.name, "nightly");
        assert_eq!(s.seeds, 3);
        assert_eq!(s.out_dir.as_deref(), Some("runs/nightly"));
        assert_eq!(s.runs.len(), 2);
        assert_eq!(s.runs[0].config, "jsc-2l");
        assert_eq!(s.runs[0].epochs, Some(40));
        assert!(!s.runs[0].rtl);
        assert!(s.runs[1].rtl);
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(Suite::parse("bogus = 1\n[[run]]\nconfig = \"a\"").is_err());
        assert!(Suite::parse("[[run]]\nconfig = \"a\"\ntypo = 2").is_err());
        assert!(Suite::parse("[[walk]]\nconfig = \"a\"").is_err());
    }

    #[test]
    fn rejects_empty_suite() {
        assert!(Suite::parse("name = \"x\"").is_err());
    }

    #[test]
    fn value_types_roundtrip() {
        let doc = TomlDoc::parse(
            "a = 1\nb = 1.5\nc = true\nd = \"x # not a comment\"",
        )
        .unwrap();
        assert_eq!(doc.root["a"], TomlValue::Int(1));
        assert_eq!(doc.root["b"], TomlValue::Float(1.5));
        assert_eq!(doc.root["c"], TomlValue::Bool(true));
        assert_eq!(doc.root["d"], TomlValue::Str("x # not a comment".into()));
    }

    #[test]
    fn plain_tables_rejected_with_hint() {
        let e = TomlDoc::parse("[run]\nconfig = \"a\"").unwrap_err();
        assert!(e.to_string().contains("[[run]]"));
    }
}
