//! Boolean-function utilities over flat truth tables.
//!
//! A function of `k` variables is a `Vec<u8>` of length `2^k` holding 0/1,
//! indexed by the address whose bit `j` is variable `j` (LSB-first, the
//! same convention as the L-LUT addresses).

/// Exact support: the variables that actually affect the function.
pub fn support(bits: &[u8], k: usize) -> Vec<usize> {
    debug_assert_eq!(bits.len(), 1usize << k);
    let mut vars = Vec::new();
    for v in 0..k {
        let stride = 1usize << v;
        let mut affects = false;
        'outer: for base in (0..bits.len()).step_by(stride << 1) {
            for off in 0..stride {
                if bits[base + off] != bits[base + off + stride] {
                    affects = true;
                    break 'outer;
                }
            }
        }
        if affects {
            vars.push(v);
        }
    }
    vars
}

/// Project a function onto a subset of its variables (which must contain
/// the true support): returns the table over `vars.len()` address bits,
/// with `vars[j]` mapped to new address bit `j`.
pub fn project(bits: &[u8], _k: usize, vars: &[usize]) -> Vec<u8> {
    let k_new = vars.len();
    let mut out = vec![0u8; 1usize << k_new];
    for (new_addr, slot) in out.iter_mut().enumerate() {
        let mut addr = 0usize;
        for (j, &v) in vars.iter().enumerate() {
            if (new_addr >> j) & 1 == 1 {
                addr |= 1 << v;
            }
        }
        *slot = bits[addr];
    }
    out
}

/// Is the function constant?
pub fn is_constant(bits: &[u8]) -> bool {
    bits.windows(2).all(|w| w[0] == w[1])
}

/// The function's constant value, when it has one (`None` otherwise).
/// Lets callers fold constants without a separate support pass.
pub fn const_value(bits: &[u8]) -> Option<u8> {
    is_constant(bits).then(|| bits.first().copied().unwrap_or(0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn support_of_projection_functions() {
        // f(a, b, c) = b (address bit 1)
        let bits: Vec<u8> = (0..8u32).map(|a| ((a >> 1) & 1) as u8).collect();
        assert_eq!(support(&bits, 3), vec![1]);
        let p = project(&bits, 3, &[1]);
        assert_eq!(p, vec![0, 1]);
    }

    #[test]
    fn support_of_xor_is_everything() {
        let bits: Vec<u8> = (0..16u32).map(|a| (a.count_ones() & 1) as u8).collect();
        assert_eq!(support(&bits, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn constant_has_empty_support() {
        let bits = vec![1u8; 32];
        assert!(support(&bits, 5).is_empty());
        assert!(is_constant(&bits));
        assert_eq!(const_value(&bits), Some(1));
        assert_eq!(const_value(&vec![0u8; 4]), Some(0));
        assert_eq!(const_value(&[0, 1]), None);
    }

    #[test]
    fn projection_preserves_function() {
        // f(a,b,c,d) = a AND c; project onto {0, 2}.
        let bits: Vec<u8> = (0..16u32)
            .map(|a| ((a & 1) & ((a >> 2) & 1)) as u8)
            .collect();
        assert_eq!(support(&bits, 4), vec![0, 2]);
        let p = project(&bits, 4, &[0, 2]);
        assert_eq!(p, vec![0, 0, 0, 1]); // AND truth table
    }
}
