//! Synthesis + P&R cost model — the Vivado 2020.1 substitute (DESIGN.md §4).
//!
//! Operates on the *actual trained truth tables*, so relative results
//! (NeuraLUT vs LogicNets vs PolyLUT, Pareto shapes, crossovers) come from
//! real logic structure; absolute constants are calibrated once against the
//! paper's Table III (xcvu9p-2, Flow_PerfOptimized_high, OOC).
//!
//! Per L-LUT output bit:
//!  1. exact support reduction ([`boolfn::support`]);
//!  2. if the reduced support fits a physical 6-LUT → one P-LUT, depth 1;
//!  3. otherwise Shannon-style decomposition: distinct non-constant
//!     cofactors on the bottom 6 support variables become leaf P-LUTs and a
//!     4:1-mux tree (one 6-LUT per 4:1 mux; F7/F8 muxes modelled free at
//!     the first level) selects among them — capped by the ROM upper bound;
//!  4. an ROBDD node count ([`robdd`]) is kept as the logic-complexity
//!     metric (reported, and used by the ablation bench).

pub mod boolfn;
pub mod robdd;

use crate::luts::{LutLayer, LutNetwork};

/// Physical LUT input width of the target fabric (UltraScale+ 6-LUT).
pub const K_PLUT: usize = 6;

// Timing model constants, calibrated against the paper's Table III designs
// (see DESIGN.md §4): logic+route per P-LUT level, register overhead, and a
// congestion term that grows sub-linearly with design size.
pub const T_LEVEL_NS: f64 = 0.20;
pub const T_BASE_NS: f64 = 0.30;
pub const CONGESTION_A: f64 = 0.0011;
pub const CONGESTION_EXP: f64 = 0.65;

/// Cost of one L-LUT (all of its output bits).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LutCost {
    pub p_luts: usize,
    /// Logic depth in P-LUT levels.
    pub depth: usize,
    /// Total ROBDD nodes across output bits (complexity metric).
    pub bdd_nodes: usize,
}

/// Synthesis report for a whole network.
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub name: String,
    pub luts: usize,
    pub ffs: usize,
    pub max_depth: usize,
    pub period_ns: f64,
    pub fmax_mhz: f64,
    pub latency_ns: f64,
    pub latency_cycles: usize,
    pub area_delay: f64,
    pub bdd_nodes: usize,
    pub per_layer: Vec<LayerCost>,
}

/// Aggregate cost of one circuit layer.
#[derive(Debug, Clone)]
pub struct LayerCost {
    pub luts: usize,
    pub depth: usize,
    pub bdd_nodes: usize,
    pub ffs: usize,
}

/// ROM (mux-tree) upper bound on 6-LUTs for one k-input output bit,
/// with F7/F8 muxes free: ceil((2^(k-4) - 1) / 3) for k > 6, else 1.
pub fn rom_upper_bound(k: usize) -> usize {
    if k <= K_PLUT {
        return 1;
    }
    ((1usize << (k - 4)) - 1).div_ceil(3)
}

/// Cost one single-output Boolean function given as a truth table over
/// `k` address bits (`table[addr] & 1`).
pub fn cost_function(bits: &[u8], k: usize) -> (usize, usize) {
    debug_assert_eq!(bits.len(), 1usize << k);
    let support = boolfn::support(bits, k);
    let k_red = support.len();
    if k_red == 0 {
        return (0, 0); // constant output: free (absorbed into routing)
    }
    if k_red <= K_PLUT {
        return (1, 1);
    }
    // Project onto the reduced support, bottom K_PLUT vars as cofactor vars.
    let reduced = boolfn::project(bits, k, &support);
    let t = k_red - K_PLUT; // select bits
    let n_cof = 1usize << t;
    let cof_len = 1usize << K_PLUT;
    let mut distinct = std::collections::HashSet::new();
    let mut non_constant = 0usize;
    for c in 0..n_cof {
        let cof = &reduced[c * cof_len..(c + 1) * cof_len];
        let first = cof[0];
        if cof.iter().any(|&b| b != first) {
            if distinct.insert(cof.to_vec()) {
                non_constant += 1;
            }
        }
    }
    // Mux tree over 2^t cofactor outputs built from 4:1 muxes (one 6-LUT
    // each); the first mux level rides the free F7/F8 muxes, so the select
    // width seen by LUT-muxes is t - 2. A 4:1-mux tree over n leaves needs
    // ceil((n - 1) / 3) muxes.
    let mux_t = t.saturating_sub(2);
    let mux_luts = if mux_t == 0 {
        0
    } else {
        ((1usize << mux_t) - 1).div_ceil(3).max(1)
    };
    let luts = (non_constant + mux_luts).clamp(1, rom_upper_bound(k_red));
    // Depth: leaf LUT level + LUT-mux levels (each 6-LUT muxes 2 select
    // bits); the free F7/F8 level adds no LUT depth.
    let depth = 1 + mux_t.div_ceil(2);
    (luts, depth)
}

/// Cost one L-LUT: every output bit independently (Vivado shares logic
/// across bits; the shared-logic discount is folded into the calibrated
/// timing/area constants).
pub fn cost_lut(layer: &LutLayer, lut: usize) -> LutCost {
    let k = layer.in_bits * layer.fan_in;
    let table = layer.table(lut);
    let mut p_luts = 0;
    let mut depth = 0;
    let mut bdd_nodes = 0;
    for bit in 0..layer.out_bits {
        let bits: Vec<u8> = table
            .iter()
            .map(|&code| ((code as u16) >> bit) as u8 & 1)
            .collect();
        let (l, d) = cost_function(&bits, k);
        p_luts += l;
        depth = depth.max(d);
        bdd_nodes += robdd::node_count(&bits, k);
    }
    LutCost { p_luts, depth, bdd_nodes }
}

/// Synthesize a full network into a [`SynthReport`].
pub fn synthesize(net: &LutNetwork) -> SynthReport {
    use crate::util::pool;
    let mut per_layer = Vec::new();
    for layer in &net.layers {
        let costs: Vec<LutCost> = pool::parallel_ranges(
            layer.num_luts(),
            pool::num_threads(),
            |_, range| range.map(|i| cost_lut(layer, i)).collect::<Vec<_>>(),
        )
        .into_iter()
        .flatten()
        .collect();
        per_layer.push(LayerCost {
            luts: costs.iter().map(|c| c.p_luts).sum(),
            depth: costs.iter().map(|c| c.depth).max().unwrap_or(0),
            bdd_nodes: costs.iter().map(|c| c.bdd_nodes).sum(),
            ffs: layer.num_luts() * layer.out_bits,
        });
    }
    let luts: usize = per_layer.iter().map(|l| l.luts).sum();
    let ffs: usize = per_layer.iter().map(|l| l.ffs).sum::<usize>()
        + net.input_size * net.input_bits; // registered input stage
    let max_depth = per_layer.iter().map(|l| l.depth).max().unwrap_or(1);
    let period_ns = T_BASE_NS
        + max_depth as f64 * T_LEVEL_NS
        + CONGESTION_A * (luts.max(1) as f64).powf(CONGESTION_EXP);
    let latency_cycles = net.layers.len();
    let latency_ns = latency_cycles as f64 * period_ns;
    SynthReport {
        name: net.name.clone(),
        luts,
        ffs,
        max_depth,
        period_ns,
        fmax_mhz: 1000.0 / period_ns,
        latency_ns,
        latency_cycles,
        area_delay: luts as f64 * latency_ns,
        bdd_nodes: per_layer.iter().map(|l| l.bdd_nodes).sum(),
        per_layer,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::luts::random_network;

    #[test]
    fn constant_function_is_free() {
        let bits = vec![1u8; 1 << 8];
        assert_eq!(cost_function(&bits, 8), (0, 0));
    }

    #[test]
    fn small_support_is_one_lut() {
        // f = x0 over 8 address bits: support {0} -> 1 P-LUT.
        let bits: Vec<u8> = (0..1u32 << 8).map(|a| (a & 1) as u8).collect();
        assert_eq!(cost_function(&bits, 8), (1, 1));
    }

    #[test]
    fn dense_function_respects_rom_bound() {
        // Pseudo-random 12-input function: cost must stay within the ROM
        // mux-tree bound and be at least 1.
        let mut state = 0x12345u64;
        let bits: Vec<u8> = (0..1usize << 12)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 33) & 1) as u8
            })
            .collect();
        let (luts, depth) = cost_function(&bits, 12);
        assert!(luts >= 1 && luts <= rom_upper_bound(12), "luts = {luts}");
        assert!(depth >= 2);
    }

    #[test]
    fn rom_bound_values() {
        assert_eq!(rom_upper_bound(6), 1);
        assert_eq!(rom_upper_bound(7), 3); // (2^3 - 1)/3 = 2.33 -> 3
        assert_eq!(rom_upper_bound(12), 85);
    }

    #[test]
    fn synthesize_produces_consistent_report() {
        let net = random_network(7, 16, 2, &[8, 4, 3], 3, 2, 4);
        let r = synthesize(&net);
        assert_eq!(r.latency_cycles, 3);
        assert!(r.fmax_mhz > 0.0);
        assert!((r.area_delay - r.luts as f64 * r.latency_ns).abs() < 1e-9);
        assert_eq!(r.per_layer.len(), 3);
        assert_eq!(
            r.luts,
            r.per_layer.iter().map(|l| l.luts).sum::<usize>()
        );
    }

    #[test]
    fn simpler_tables_cost_less() {
        // A linear-ish table (few distinct cofactors) must cost no more
        // than a random table of the same size.
        let k = 12;
        let linear: Vec<u8> = (0..1usize << k)
            .map(|a| ((a.count_ones()) & 1) as u8) // parity: extreme BDD but
            .collect(); // cheap cofactors? parity has 2 distinct cofactors.
        let mut state = 99u64;
        let random: Vec<u8> = (0..1usize << k)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 40) & 1) as u8
            })
            .collect();
        let (l_lin, _) = cost_function(&linear, k);
        let (l_rnd, _) = cost_function(&random, k);
        assert!(l_lin <= l_rnd, "linear {l_lin} vs random {l_rnd}");
    }
}
