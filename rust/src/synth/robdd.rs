//! Reduced Ordered BDD node counting over flat truth tables.
//!
//! Variable order is address-bit order (LSB split last). The count is
//! computed by the level-merge construction: level `j` nodes are the
//! distinct, non-redundant (lo != hi) sub-functions of `2^j` entries.
//! This is exactly the ROBDD size for the fixed order and runs in
//! O(2^k · k) with hashing — fast enough to BDD every L-LUT in a design.
//!
//! The node count is the logic-complexity metric of the synthesis model:
//! structured functions (LogicNets' thresholded linear maps) collapse to
//! few nodes, dense NeuraLUT sub-network tables stay near-random — the
//! paper's observation that NeuraLUT tables "offer less opportunity for
//! logic simplification".

use std::collections::HashMap;

/// Number of ROBDD nodes (internal decision nodes, terminals excluded).
pub fn node_count(bits: &[u8], k: usize) -> usize {
    debug_assert_eq!(bits.len(), 1usize << k);
    // ids of current level's sub-functions; start with terminal ids 0/1.
    let mut ids: Vec<u32> = bits.iter().map(|&b| b as u32).collect();
    let mut next_id = 2u32;
    let mut total = 0usize;
    for _level in 0..k {
        let mut memo: HashMap<(u32, u32), u32> = HashMap::new();
        let mut merged = Vec::with_capacity(ids.len() / 2);
        for pair in ids.chunks_exact(2) {
            let (lo, hi) = (pair[0], pair[1]);
            if lo == hi {
                merged.push(lo); // redundant test: skip node
                continue;
            }
            let id = *memo.entry((lo, hi)).or_insert_with(|| {
                let id = next_id;
                next_id += 1;
                id
            });
            merged.push(id);
        }
        total += memo.len();
        ids = merged;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_have_zero_nodes() {
        assert_eq!(node_count(&vec![0u8; 16], 4), 0);
        assert_eq!(node_count(&vec![1u8; 16], 4), 0);
    }

    #[test]
    fn single_variable_is_one_node() {
        let bits: Vec<u8> = (0..8u32).map(|a| ((a >> 1) & 1) as u8).collect();
        assert_eq!(node_count(&bits, 3), 1);
    }

    #[test]
    fn parity_is_linear_in_k() {
        // XOR of k vars has exactly 2k - 1 ROBDD nodes for any order.
        for k in 2..=10 {
            let bits: Vec<u8> =
                (0..1u32 << k).map(|a| (a.count_ones() & 1) as u8).collect();
            assert_eq!(node_count(&bits, k), 2 * k - 1, "k = {k}");
        }
    }

    #[test]
    fn random_function_is_near_maximal() {
        let k = 10;
        let mut state = 7u64;
        let bits: Vec<u8> = (0..1usize << k)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 35) & 1) as u8
            })
            .collect();
        let n = node_count(&bits, k);
        // A random 10-input function has close to the maximum ~2^(k-log k)
        // nodes; definitely far more than any structured function.
        assert!(n > 100, "n = {n}");
    }

    #[test]
    fn majority_is_quadratic() {
        let k = 9;
        let bits: Vec<u8> = (0..1u32 << k)
            .map(|a| (a.count_ones() as usize > k / 2) as u8)
            .collect();
        let n = node_count(&bits, k);
        // Threshold functions have O(k^2) BDDs: must be tiny vs random.
        assert!(n <= k * k, "n = {n}");
    }
}
