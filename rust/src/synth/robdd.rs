//! Reduced Ordered BDDs over flat truth tables: node counting (the
//! synthesis complexity metric) and graph construction (the compiled-
//! engine lowering substrate).
//!
//! Variable order is address-bit order (LSB split last). The structure is
//! computed by the level-merge construction: level `j` nodes are the
//! distinct, non-redundant (lo != hi) sub-functions of `2^j` entries.
//! This is exactly the ROBDD for the fixed order and runs in
//! O(2^k · k) with hashing — fast enough to BDD every L-LUT in a design.
//!
//! The node count is the logic-complexity metric of the synthesis model:
//! structured functions (LogicNets' thresholded linear maps) collapse to
//! few nodes, dense NeuraLUT sub-network tables stay near-random — the
//! paper's observation that NeuraLUT tables "offer less opportunity for
//! logic simplification". The same graph drives `engine::lower`, which
//! maps every decision node onto one word-wide mux op.

use std::collections::HashMap;

/// One internal decision node: test variable `var`; follow `hi` when the
/// variable is 1, `lo` when it is 0. Child ids `0`/`1` are the terminal
/// constants; id `n >= 2` is `nodes[n - 2]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BddNode {
    pub var: u32,
    pub lo: u32,
    pub hi: u32,
}

/// A reduced ordered BDD of one single-output function. `nodes` is in
/// bottom-up topological order (children always precede parents), so a
/// single forward pass evaluates or lowers the whole graph.
#[derive(Debug, Clone)]
pub struct Robdd {
    pub nodes: Vec<BddNode>,
    /// Root id: `0`/`1` for constant functions, else `index + 2`.
    pub root: u32,
}

/// Build the ROBDD of a function given as a `2^k`-entry 0/1 truth table
/// (address bit `j` is variable `j`; variable `k-1` is tested first).
pub fn build(bits: &[u8], k: usize) -> Robdd {
    debug_assert_eq!(bits.len(), 1usize << k);
    // ids of current level's sub-functions; start with terminal ids 0/1.
    let mut ids: Vec<u32> = bits.iter().map(|&b| b as u32).collect();
    let mut nodes: Vec<BddNode> = Vec::new();
    for level in 0..k {
        let mut memo: HashMap<(u32, u32), u32> = HashMap::new();
        let mut merged = Vec::with_capacity(ids.len() / 2);
        for pair in ids.chunks_exact(2) {
            let (lo, hi) = (pair[0], pair[1]);
            if lo == hi {
                merged.push(lo); // redundant test: skip node
                continue;
            }
            let id = *memo.entry((lo, hi)).or_insert_with(|| {
                nodes.push(BddNode { var: level as u32, lo, hi });
                (nodes.len() + 1) as u32
            });
            merged.push(id);
        }
        ids = merged;
    }
    debug_assert_eq!(ids.len(), 1);
    Robdd { nodes, root: ids[0] }
}

/// Number of ROBDD nodes (internal decision nodes, terminals excluded).
pub fn node_count(bits: &[u8], k: usize) -> usize {
    build(bits, k).nodes.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_have_zero_nodes() {
        assert_eq!(node_count(&vec![0u8; 16], 4), 0);
        assert_eq!(node_count(&vec![1u8; 16], 4), 0);
    }

    #[test]
    fn single_variable_is_one_node() {
        let bits: Vec<u8> = (0..8u32).map(|a| ((a >> 1) & 1) as u8).collect();
        assert_eq!(node_count(&bits, 3), 1);
    }

    #[test]
    fn parity_is_linear_in_k() {
        // XOR of k vars has exactly 2k - 1 ROBDD nodes for any order.
        for k in 2..=10 {
            let bits: Vec<u8> =
                (0..1u32 << k).map(|a| (a.count_ones() & 1) as u8).collect();
            assert_eq!(node_count(&bits, k), 2 * k - 1, "k = {k}");
        }
    }

    #[test]
    fn random_function_is_near_maximal() {
        let k = 10;
        let mut state = 7u64;
        let bits: Vec<u8> = (0..1usize << k)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 35) & 1) as u8
            })
            .collect();
        let n = node_count(&bits, k);
        // A random 10-input function has close to the maximum ~2^(k-log k)
        // nodes; definitely far more than any structured function.
        assert!(n > 100, "n = {n}");
    }

    #[test]
    fn build_graph_evaluates_back_to_the_table() {
        // Walking the node graph must reproduce the function on every
        // address, and the node order must be bottom-up topological.
        let eval = |r: &Robdd, addr: usize| -> u8 {
            let mut id = r.root;
            while id >= 2 {
                let n = r.nodes[(id - 2) as usize];
                id = if (addr >> n.var) & 1 == 1 { n.hi } else { n.lo };
            }
            id as u8
        };
        let mut state = 0xC0FFEEu64;
        for k in 0..=8usize {
            let bits: Vec<u8> = (0..1usize << k)
                .map(|_| {
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((state >> 37) & 1) as u8
                })
                .collect();
            let r = build(&bits, k);
            assert_eq!(r.nodes.len(), node_count(&bits, k));
            for (i, n) in r.nodes.iter().enumerate() {
                assert!((n.lo as usize) < i + 2 && (n.hi as usize) < i + 2,
                        "child precedes parent");
            }
            for (addr, &b) in bits.iter().enumerate() {
                assert_eq!(eval(&r, addr), b, "k={k} addr={addr}");
            }
        }
    }

    #[test]
    fn majority_is_quadratic() {
        let k = 9;
        let bits: Vec<u8> = (0..1u32 << k)
            .map(|a| (a.count_ones() as usize > k / 2) as u8)
            .collect();
        let n = node_count(&bits, k);
        // Threshold functions have O(k^2) BDDs: must be tiny vs random.
        assert!(n <= k * k, "n = {n}");
    }
}
