//! `neuralut` — the Layer-3 coordinator CLI.
//!
//! Drives the full NeuraLUT codesign toolflow against the AOT artifact
//! bundles produced by `make artifacts`:
//!
//! ```text
//! neuralut list
//! neuralut train    <config> [--seed N] [--epochs N] [--out DIR]
//! neuralut pipeline <config> [--seed N] [--epochs N] [--out DIR] [--rtl]
//! neuralut convert  <config> --params FILE --out FILE
//! neuralut synth    <config> --net FILE
//! neuralut simulate <config> --net FILE
//! neuralut rtl      <config> --net FILE --out DIR
//! neuralut serve    <config> --net FILE [--rate R] [--requests N]
//! neuralut serve    --listen HOST:PORT --models-dir DIR [--max-connections N]
//! neuralut report   --net FILE [--format table|json] [--out FILE]
//! neuralut stats    <config> --net FILE [--requests N] [--format prom|json|both]
//! ```
//!
//! (Hand-rolled argument parsing: clap is not vendored in this offline
//! image, and the surface is small.)

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use neuralut::coordinator::pipeline::{self, PipelineOpts};
use neuralut::coordinator::trainer::{TrainOpts, Trainer};
use neuralut::data::{Dataset, Workload};
use neuralut::fabric::{FabricOptions, Model};
use neuralut::luts::{convert, LutNetwork};
use neuralut::manifest::Manifest;
use neuralut::nn::params::ParamStore;
use neuralut::runtime::Runtime;
use neuralut::server::ServerConfig;
use neuralut::synth::synthesize;
use neuralut::util::stats;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parsed `--key value` / `--flag` options after the positional args.
struct Opts(HashMap<String, String>);

impl Opts {
    fn parse(args: &[String]) -> Result<(Vec<String>, Opts)> {
        let mut pos = Vec::new();
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let takes_value = !matches!(key, "rtl" | "quiet" | "full");
                if takes_value {
                    let v = args
                        .get(i + 1)
                        .with_context(|| format!("--{key} needs a value"))?;
                    map.insert(key.to_string(), v.clone());
                    i += 2;
                } else {
                    map.insert(key.to_string(), "true".into());
                    i += 1;
                }
            } else {
                pos.push(a.clone());
                i += 1;
            }
        }
        Ok((pos, Opts(map)))
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.0.get(key).map(|s| s.as_str())
    }

    fn usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{key}")))
            .transpose()
    }

    fn f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{key}")))
            .transpose()
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    /// Fabric options for inference commands: config file (if any), then
    /// env (`NEURALUT_ENGINE`, `NEURALUT_WORKERS`, `NEURALUT_OPT_LEVEL`,
    /// `NEURALUT_FABRIC_CACHE`), then the CLI flags — one resolution
    /// path, CLI winning.
    fn fabric(&self, file_cfg: Option<&ServerConfig>) -> Result<FabricOptions> {
        let mut fo = FabricOptions::from_env_and_config(file_cfg)?;
        if let Some(engine) = self.get("engine") {
            fo = fo.backend(engine);
        }
        if let Some(level) = self.get("opt-level") {
            fo = fo.opt_level(level.parse().context("--opt-level")?);
        }
        if let Some(path) = self.get("fabric-cache") {
            fo = fo.fabric_cache(PathBuf::from(path));
        }
        if let Some(dir) = self.get("aot-cache-dir") {
            fo = fo.aot_cache_dir(PathBuf::from(dir));
        }
        if let Some(w) = self.usize("workers")? {
            fo = fo.workers(w);
        }
        if let Some(d) = self.usize("queue-depth")? {
            fo = fo.queue_depth(d);
        }
        if let Some(mb) = self.usize("max-batch")? {
            fo = fo.max_batch(mb);
        }
        if let Some(us) = self.usize("batch-window")? {
            fo = fo.batch_window(std::time::Duration::from_micros(us as u64));
        }
        if let Some(ms) = self.usize("request-timeout")? {
            fo = fo.request_timeout(std::time::Duration::from_millis(ms as u64));
        }
        Ok(fo)
    }
}

fn load_bundle(name: &str) -> Result<(Manifest, Dataset)> {
    let dir = neuralut::artifacts_dir().join(name);
    let m = Manifest::load(&dir)?;
    let ds = Dataset::load_named(&m.dataset)?;
    Ok((m, ds))
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let (pos, opts) = Opts::parse(&args[1..])?;

    match cmd.as_str() {
        "list" => cmd_list(),
        "info" => cmd_info(&pos),
        "train" | "pipeline" => cmd_pipeline(cmd == "train", &pos, &opts),
        "convert" => cmd_convert(&pos, &opts),
        "synth" => cmd_synth(&pos, &opts),
        "simulate" => cmd_simulate(&pos, &opts),
        "rtl" => cmd_rtl(&pos, &opts),
        "vcd" => cmd_vcd(&pos, &opts),
        "serve" => cmd_serve(&pos, &opts),
        "report" => cmd_report(&opts),
        "stats" => cmd_stats(&pos, &opts),
        "suite" => cmd_suite(&pos),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `neuralut help`)"),
    }
}

fn print_usage() {
    println!(
        "neuralut — NeuraLUT (FPL 2024) codesign toolflow\n\n\
         commands:\n  \
         list                                   list artifact bundles\n  \
         info <config>                          bundle summary\n  \
         train <config> [--seed N] [--epochs N] [--out DIR]\n  \
         pipeline <config> [--seed N] [--epochs N] [--out DIR] [--rtl]\n  \
         convert <config> --params F --out F    trained params -> L-LUTs\n  \
         synth <config> --net F                 synthesis cost report\n  \
         simulate <config> --net F [--engine BACKEND] [--opt-level O0|O1|O2]\n  \
         \x20     [--fabric-cache FILE.nfab] [--aot-cache-dir DIR]\n  \
         rtl <config> --net F --out DIR         emit Verilog bundle\n  \
         vcd <config> --net F --out FILE        dump pipeline waveform (GTKWave)\n  \
         serve <config> --net F [--rate R] [--requests N] [--batch-window US]\n  \
         \x20     [--workers N] [--queue-depth N] [--engine BACKEND]\n  \
         \x20     [--opt-level O0|O1|O2] [--fabric-cache FILE.nfab]\n  \
         \x20     [--server-config FILE.toml] [--request-timeout MS]\n  \
         \x20     [--aot-cache-dir DIR]\n  \
         serve --listen HOST:PORT --models-dir DIR    network front door:\n  \
         \x20     [--max-connections N] [--serve-for SECS]  binary wire protocol\n  \
         \x20     [--server-config FILE.toml] [...]         + HTTP on one port,\n  \
         \x20     hot-swaps models when .nlut files in DIR change\n  \
         report --net F [--engine BACKEND] [--opt-level O0|O1|O2]\n  \
         \x20     [--format table|json] [--out FILE]   compile telemetry\n  \
         stats <config> --net F [--requests N] [--rate R]\n  \
         \x20     [--format prom|json|both]            serve + full telemetry dump\n  \
         suite <file.toml>                      run a batch of pipelines\n\n\
         BACKEND is a registered backend name ({}); NEURALUT_ENGINE /\n\
         NEURALUT_WORKERS / NEURALUT_OPT_LEVEL / NEURALUT_FABRIC_CACHE /\n\
         NEURALUT_REQUEST_TIMEOUT_MS / NEURALUT_LISTEN_ADDR /\n\
         NEURALUT_MAX_CONNECTIONS / NEURALUT_MODELS_DIR / NEURALUT_AOT set\n\
         ambient defaults the flags override.\n\
         --opt-level picks the netlist optimization pipeline (O1 default);\n\
         --fabric-cache compiles once into a .nfab artifact that later runs\n\
         and other processes reload; --aot-cache-dir holds the aot backends'\n\
         compiled .so objects (NEURALUT_AOT=off disables native codegen);\n\
         --request-timeout sheds requests whose\n\
         deadline passes before a worker reaches them.",
        neuralut::fabric::BackendRegistry::global().names().join(" | ")
    );
}

fn cmd_list() -> Result<()> {
    let root = neuralut::artifacts_dir();
    let mut found = 0;
    if root.exists() {
        let mut names: Vec<_> = std::fs::read_dir(&root)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("manifest.json").exists())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        for name in names {
            let m = Manifest::load(&root.join(&name))?;
            println!(
                "{:<24} mode={:<10} dataset={:<9} circuit={:?} beta={} F={} (L={},N={},S={})",
                m.name, m.mode, m.dataset, m.layers, m.beta, m.fan_in,
                m.sub_depth, m.sub_width, m.sub_skip
            );
            found += 1;
        }
    }
    if found == 0 {
        println!("no artifact bundles found under {} — run `make artifacts`",
                 root.display());
    }
    Ok(())
}

fn cmd_info(pos: &[String]) -> Result<()> {
    let name = pos.first().context("usage: info <config>")?;
    let (m, ds) = load_bundle(name)?;
    println!("bundle      : {}", m.name);
    println!("mode        : {}", m.mode);
    println!("dataset     : {} ({} train / {} test, {} feats, {} classes)",
             m.dataset, ds.n_train(), ds.n_test(), ds.n_feat, ds.n_class);
    println!("circuit     : {:?} (fan-in {}, beta {})", m.layers, m.fan_in, m.beta);
    println!("sub-network : L={} N={} S={} (mode-dependent)", m.sub_depth,
             m.sub_width, m.sub_skip);
    println!("parameters  : {} tensors, {} scalars", m.params.len(), m.total_params());
    println!("recipe      : {} epochs, batch {}, lr {:.1e}..{:.1e}, wd {:.1e}",
             m.epochs, m.batch, m.lr_min, m.lr_max, m.weight_decay);
    Ok(())
}

fn cmd_pipeline(train_only: bool, pos: &[String], opts: &Opts) -> Result<()> {
    let name = pos.first().context("usage: pipeline <config>")?;
    let (m, ds) = load_bundle(name)?;
    let rt = Runtime::cpu()?;
    let seed = opts.usize("seed")?.unwrap_or(0) as u64;
    let popts = PipelineOpts {
        train: TrainOpts {
            epochs: opts.usize("epochs")?,
            max_train: opts.usize("max-train")?,
            max_test: opts.usize("max-test")?,
            quiet: opts.flag("quiet"),
            eval_every: opts.usize("eval-every")?.unwrap_or(1),
        },
        verify_samples: opts.usize("verify")?.or(Some(2048)),
        out_dir: opts.get("out").map(PathBuf::from),
        emit_rtl: opts.flag("rtl"),
    };
    if train_only {
        let trainer = Trainer::new(&rt, &m, &ds)?;
        let r = trainer.run(seed, &popts.train)?;
        println!("final test accuracy: {:.4} ({} steps)", r.test_acc, r.steps);
        if let Some(dir) = &popts.out_dir {
            std::fs::create_dir_all(dir)?;
            r.params.save(&dir.join("params.nprm"))?;
            println!("params saved to {}", dir.join("params.nprm").display());
        }
        return Ok(());
    }
    let r = pipeline::run(&rt, &m, &ds, seed, &popts)?;
    pipeline::verify_consistent(&r, 0.05)?;
    println!("\n== pipeline result: {} (seed {seed}) ==", m.name);
    println!("accuracy    : fabric {:.4} (authoritative) | float monitor {:.4} ({} verified, {} boundary flips)",
             r.sim_acc, r.model_acc, r.n_verified, r.mismatches);
    println!("L-LUTs      : {} ({} layers)", r.net.num_luts(), r.net.layers.len());
    println!("P-LUTs      : {}   FF: {}", r.synth.luts, r.synth.ffs);
    println!("Fmax        : {:.0} MHz (depth {})", r.synth.fmax_mhz, r.synth.max_depth);
    println!("latency     : {:.1} ns ({} cycles)", r.synth.latency_ns, r.synth.latency_cycles);
    println!("area×delay  : {:.3e} LUT·ns", r.synth.area_delay);
    Ok(())
}

fn cmd_convert(pos: &[String], opts: &Opts) -> Result<()> {
    let name = pos.first().context("usage: convert <config> --params F --out F")?;
    let (m, _ds) = load_bundle(name)?;
    let rt = Runtime::cpu()?;
    let params_path = PathBuf::from(opts.get("params").context("--params required")?);
    let out = PathBuf::from(opts.get("out").context("--out required")?);
    let params = ParamStore::load(&params_path, &m)?;
    let net = convert::convert(&rt, &m, &params)?;
    net.save(&out)?;
    println!("converted {} L-LUTs -> {}", net.num_luts(), out.display());
    Ok(())
}

fn cmd_synth(pos: &[String], opts: &Opts) -> Result<()> {
    let name = pos.first().context("usage: synth <config> --net F")?;
    let (_m, _ds) = load_bundle(name)?;
    let net = LutNetwork::load(&PathBuf::from(opts.get("net").context("--net required")?))?;
    let r = synthesize(&net);
    println!("network {}: {} L-LUTs", r.name, net.num_luts());
    println!("{:<8} {:>8} {:>6} {:>10} {:>6}", "layer", "P-LUTs", "depth", "BDD nodes", "FF");
    for (i, l) in r.per_layer.iter().enumerate() {
        println!("{:<8} {:>8} {:>6} {:>10} {:>6}", i, l.luts, l.depth, l.bdd_nodes, l.ffs);
    }
    println!("total: {} LUT, {} FF, Fmax {:.0} MHz, latency {:.1} ns, ADP {:.3e}",
             r.luts, r.ffs, r.fmax_mhz, r.latency_ns, r.area_delay);
    Ok(())
}

fn cmd_simulate(pos: &[String], opts: &Opts) -> Result<()> {
    let name = pos.first().context("usage: simulate <config> --net F")?;
    let (_m, ds) = load_bundle(name)?;
    let model = Model::load(&PathBuf::from(opts.get("net").context("--net required")?))?;
    let t0 = std::time::Instant::now();
    let fabric = model.compile(&opts.fabric(None)?)?;
    let compile_s = t0.elapsed().as_secs_f64();
    let session = fabric.session();
    let t0 = std::time::Instant::now();
    let acc = session.accuracy(&ds.test_x, &ds.test_y)?;
    let dt = t0.elapsed().as_secs_f64();
    let ops = fabric
        .num_word_ops()
        .map(|n| format!(", {n} word ops"))
        .unwrap_or_default();
    println!("fabric accuracy: {:.4} on {} samples ({:.0} samples/s, latency {} cycles, \
              {} engine at {}{}, compile {:.3}s)",
             acc, ds.n_test(), ds.n_test() as f64 / dt, session.latency_cycles(),
             session.backend_name(), fabric.opt_level(), ops, compile_s);
    Ok(())
}

fn cmd_rtl(pos: &[String], opts: &Opts) -> Result<()> {
    let name = pos.first().context("usage: rtl <config> --net F --out DIR")?;
    let (_m, ds) = load_bundle(name)?;
    let net = LutNetwork::load(&PathBuf::from(opts.get("net").context("--net required")?))?;
    let out = PathBuf::from(opts.get("out").context("--out required")?);
    neuralut::rtl::write_rtl_bundle(&net, &out, &ds.test_x, 64)?;
    println!("RTL bundle written to {}", out.display());
    Ok(())
}

fn cmd_vcd(pos: &[String], opts: &Opts) -> Result<()> {
    let name = pos.first().context("usage: vcd <config> --net F --out FILE")?;
    let (_m, ds) = load_bundle(name)?;
    let net = LutNetwork::load(&PathBuf::from(opts.get("net").context("--net required")?))?;
    let out = PathBuf::from(opts.get("out").context("--out required")?);
    let n = opts.usize("samples")?.unwrap_or(32);
    neuralut::netlist::vcd::write_vcd(&net, &ds.test_x, n, &out)?;
    println!("waveform with {n} samples written to {}", out.display());
    Ok(())
}

fn cmd_suite(pos: &[String]) -> Result<()> {
    use neuralut::config::Suite;
    use neuralut::coordinator::experiments::{run_config, save_results};
    let path = PathBuf::from(pos.first().context("usage: suite <file.toml>")?);
    let suite = Suite::load(&path)?;
    let rt = Runtime::cpu()?;
    println!("suite '{}': {} runs x up to {} seeds", suite.name,
             suite.runs.len(), suite.seeds);
    let mut rows = Vec::new();
    for run in &suite.runs {
        let seeds = run.seeds.unwrap_or(suite.seeds);
        for seed in 0..seeds as u64 {
            let s = run_config(&rt, &run.config, seed, run.epochs)?;
            println!("{:<22} seed {seed}: fabric {:.4} ADP {:.3e}",
                     run.config, s.fabric_acc, s.area_delay);
            rows.push(s);
        }
    }
    let out = save_results(&suite.name, &rows)?;
    println!("suite results written to {}", out.display());
    Ok(())
}

fn cmd_serve(pos: &[String], opts: &Opts) -> Result<()> {
    if opts.get("listen").is_some() || opts.get("models-dir").is_some() {
        return cmd_serve_net(opts);
    }
    let name = pos.first().context("usage: serve <config> --net F")?;
    let (_m, ds) = load_bundle(name)?;
    let model = Model::load(&PathBuf::from(opts.get("net").context("--net required")?))?;
    let n_req = opts.usize("requests")?.unwrap_or(10_000);
    let rate = opts.f64("rate")?.unwrap_or(50_000.0);
    // One resolution path: defaults < config file < env < CLI flags.
    let file_cfg = opts
        .get("server-config")
        .map(|path| ServerConfig::load(&PathBuf::from(path)))
        .transpose()?;
    let fabric = model.compile(&opts.fabric(file_cfg.as_ref())?)?;
    if let Some(from) = &fabric.report().degraded_from {
        eprintln!("warning: serving DEGRADED — '{from}' failed to compile, using scalar");
    }
    let tuning = fabric.tuning();
    println!("serving {} at {:.0} req/s for {} requests \
              (window {} us, {} engine at {}, {} workers, queue depth {})...",
             model.name(), rate, n_req, tuning.batch_window.as_micros(),
             fabric.backend_name(), fabric.opt_level(), tuning.workers,
             tuning.queue_depth);
    let server = fabric.serve();
    let client = server.client();
    let workload = Workload::poisson(&ds, 99, n_req, rate);

    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for (t_arrival, feats) in workload.requests {
        let now = t0.elapsed().as_secs_f64();
        if t_arrival > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(t_arrival - now));
        }
        pending.push(client.infer_async(feats)?);
    }
    let mut lat_us = Vec::with_capacity(pending.len());
    let mut batch_sizes = Vec::with_capacity(pending.len());
    for rx in pending {
        let r = rx.recv()?;
        lat_us.push(r.latency.as_secs_f64() * 1e6);
        batch_sizes.push(r.batch_size as f64);
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = stats::summarize(&lat_us);
    let bs = stats::summarize(&batch_sizes);
    println!("throughput : {:.0} req/s (wall {:.2}s)", n_req as f64 / wall, wall);
    println!("latency us : p50 {:.0}  p95 {:.0}  p99 {:.0}  max {:.0}",
             s.p50, s.p95, s.p99, s.max);
    println!("batch size : mean {:.1}  p95 {:.0}", bs.mean, bs.p95);
    let st = server.stats();
    println!("server     : {} served, {} rejected, {} batches (mean {:.1})",
             st.served, st.rejected, st.batches, st.mean_batch);
    println!("stages us  : queue-wait p50 {:.0} p99 {:.0} | batch-form p50 {:.0} \
              p99 {:.0} | execute p50 {:.0} p99 {:.0}",
             st.queue_wait_p50_us, st.queue_wait_p99_us,
             st.batch_form_p50_us, st.batch_form_p99_us,
             st.execute_p50_us, st.execute_p99_us);
    println!("per worker : served {:?}, throughput [{}] req/s",
             st.per_worker_served,
             st.per_worker_rps
                 .iter()
                 .map(|r| format!("{r:.0}"))
                 .collect::<Vec<_>>()
                 .join(", "));
    Ok(())
}

/// `serve --listen HOST:PORT --models-dir DIR`: the network front door.
/// Serves every `.nlut` in DIR by file stem over one TCP port speaking
/// both the binary wire protocol and HTTP/1.1 (`POST /v1/infer`,
/// `GET /metrics`, `GET /healthz`), and hot-swaps a model when its file
/// changes on disk — zero downtime, in-flight requests drain on the old
/// generation. `--serve-for SECS` bounds the run (CI); otherwise it
/// serves until killed.
fn cmd_serve_net(opts: &Opts) -> Result<()> {
    use neuralut::net::{ModelManager, NetServer};
    let file_cfg = opts
        .get("server-config")
        .map(|path| ServerConfig::load(&PathBuf::from(path)))
        .transpose()?;
    let mut fo = opts.fabric(file_cfg.as_ref())?;
    if let Some(addr) = opts.get("listen") {
        fo = fo.listen_addr(addr);
    }
    if let Some(n) = opts.usize("max-connections")? {
        fo = fo.max_connections(n);
    }
    if let Some(dir) = opts.get("models-dir") {
        fo = fo.models_dir(PathBuf::from(dir));
    }
    // Network serving wants the compiled (and `.nfab`-persistable)
    // backend unless one was picked explicitly.
    if fo.get_backend().is_none() {
        fo = fo.backend("bitsliced");
    }
    let dir = fo
        .get_models_dir()
        .context(
            "network serving needs a models directory: --models-dir DIR, \
             `models_dir` in --server-config, or NEURALUT_MODELS_DIR",
        )?
        .to_path_buf();
    let net_cfg = fo.resolve_net()?;
    let manager = ModelManager::open(&dir, &fo)?;
    manager.start_watcher(std::time::Duration::from_millis(200));
    let server = NetServer::start(manager.clone(), &net_cfg)?;
    println!(
        "serving {} model(s) from {} on {} (cap {} connections)",
        manager.len(),
        dir.display(),
        server.local_addr(),
        net_cfg.max_connections
    );
    for m in manager.snapshot() {
        println!(
            "  {:<20} digest {:016x}  {} feats -> {} classes",
            m.name(),
            m.digest(),
            m.info().input_size,
            m.info().n_class
        );
    }
    println!("endpoints: binary (NLW1 framing) | POST /v1/infer | GET /metrics | GET /healthz");
    match opts.f64("serve-for")? {
        Some(secs) => std::thread::sleep(std::time::Duration::from_secs_f64(secs)),
        None => loop {
            // Serve until the process is killed; the watcher keeps
            // hot-swapping in the background.
            std::thread::park();
        },
    }
    drop(server);
    Ok(())
}

/// `report --net F`: compile (or reload the `.nfab` cache) and print the
/// [`CompileReport`](neuralut::obs::CompileReport) — per-pass wall time,
/// op deltas and the final netlist shape.
fn cmd_report(opts: &Opts) -> Result<()> {
    let model = Model::load(&PathBuf::from(opts.get("net").context("--net required")?))?;
    let mut fo = opts.fabric(None)?;
    // The scalar default has no compile pipeline to report on; default to
    // the compiled backend unless one was picked explicitly.
    if fo.get_backend().is_none() {
        fo = fo.backend("bitsliced");
    }
    let fabric = model.compile(&fo)?;
    let report = fabric.report();
    match opts.get("format").unwrap_or("table") {
        "table" => println!("{report}"),
        "json" => println!("{}", report.to_json().to_string()),
        other => bail!("unknown --format '{other}' (table | json)"),
    }
    if let Some(out) = opts.get("out") {
        std::fs::write(out, report.to_json().to_string())
            .with_context(|| format!("writing {out}"))?;
        eprintln!("report written to {out}");
    }
    Ok(())
}

/// `stats <config> --net F`: serve a short workload, then dump the whole
/// telemetry story — compile report exported as `neuralut_compile_*`
/// series merged with the `neuralut_server_*` request-path registry — as
/// Prometheus text and/or a JSON snapshot.
fn cmd_stats(pos: &[String], opts: &Opts) -> Result<()> {
    use neuralut::obs::{expo, MetricsRegistry};
    let name = pos.first().context("usage: stats <config> --net F")?;
    let (_m, ds) = load_bundle(name)?;
    let model = Model::load(&PathBuf::from(opts.get("net").context("--net required")?))?;
    let n_req = opts.usize("requests")?.unwrap_or(2_000);
    let rate = opts.f64("rate")?.unwrap_or(50_000.0);
    let mut fo = opts.fabric(None)?;
    if fo.get_backend().is_none() {
        fo = fo.backend("bitsliced");
    }
    let fabric = model.compile(&fo)?;
    let server = fabric.serve();
    let client = server.client();
    let workload = Workload::poisson(&ds, 99, n_req, rate);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n_req);
    for (t_arrival, feats) in workload.requests {
        let now = t0.elapsed().as_secs_f64();
        if t_arrival > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(t_arrival - now));
        }
        pending.push(client.infer_async(feats)?);
    }
    for rx in pending {
        rx.recv()?;
    }
    let reg = MetricsRegistry::new();
    fabric.report().export(&reg);
    let mut snap = reg.snapshot();
    snap.merge(server.metrics());
    let format = opts.get("format").unwrap_or("both");
    if !matches!(format, "prom" | "json" | "both") {
        bail!("unknown --format '{format}' (prom | json | both)");
    }
    if matches!(format, "prom" | "both") {
        print!("{}", expo::to_prometheus(&snap));
    }
    if matches!(format, "json" | "both") {
        println!("{}", expo::to_json(&snap).to_string());
    }
    Ok(())
}
