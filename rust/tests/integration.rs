//! Integration tests over the real AOT artifact bundles: the full codesign
//! loop (train → convert → simulate → synth → RTL) on the smallest config,
//! plus cross-component invariants. Requires `make artifacts` (tests skip
//! with a message when the bundle is missing, so `cargo test` stays usable
//! on a fresh checkout).

use std::sync::Arc;

use neuralut::coordinator::pipeline::{self, PipelineOpts};
use neuralut::coordinator::trainer::{TrainOpts, Trainer};
use neuralut::data::Dataset;
use neuralut::fabric::{FabricOptions, Model};
use neuralut::luts::{convert, LutNetwork};
use neuralut::manifest::Manifest;
use neuralut::netlist::Simulator;
use neuralut::nn::formulas;
use neuralut::runtime::Runtime;
use neuralut::synth::synthesize;

fn bundle(name: &str) -> Option<(Manifest, Dataset)> {
    let dir = neuralut::artifacts_dir().join(name);
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/{name} missing (run `make artifacts`)");
        return None;
    }
    let m = Manifest::load(&dir).unwrap();
    let ds = Dataset::load_named(&m.dataset).unwrap();
    Some((m, ds))
}

#[test]
fn full_pipeline_on_moons_is_consistent_and_learns() {
    let Some((m, ds)) = bundle("moons-neuralut") else { return };
    let rt = Runtime::cpu().unwrap();
    let opts = PipelineOpts {
        train: TrainOpts { epochs: Some(12), quiet: true, ..Default::default() },
        verify_samples: Some(512),
        out_dir: None,
        emit_rtl: false,
    };
    let r = pipeline::run(&rt, &m, &ds, 0, &opts).unwrap();
    pipeline::verify_consistent(&r, 0.05).unwrap();
    assert!(r.sim_acc > 0.85, "fabric accuracy too low: {}", r.sim_acc);
    // Bit-exactness: the float monitor and the fabric should agree on
    // (nearly) every prediction — with the current toolchain it is exact.
    assert!(
        r.mismatches * 100 <= r.n_verified,
        "boundary flips exceed 1%: {}/{}",
        r.mismatches,
        r.n_verified
    );
    // Synth report sanity.
    assert_eq!(r.synth.latency_cycles, m.layers.len());
    assert!(r.synth.luts > 0 && r.synth.fmax_mhz > 0.0);
}

#[test]
fn conversion_is_deterministic() {
    let Some((m, ds)) = bundle("moons-neuralut") else { return };
    let rt = Runtime::cpu().unwrap();
    let trainer = Trainer::new(&rt, &m, &ds).unwrap();
    let r = trainer
        .run(7, &TrainOpts { epochs: Some(1), quiet: true, ..Default::default() })
        .unwrap();
    let a = convert::convert(&rt, &m, &r.params).unwrap();
    let b = convert::convert(&rt, &m, &r.params).unwrap();
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        assert_eq!(la.tables, lb.tables);
    }
}

#[test]
fn same_seed_reproduces_same_training() {
    let Some((m, ds)) = bundle("moons-logicnets") else { return };
    let rt = Runtime::cpu().unwrap();
    let trainer = Trainer::new(&rt, &m, &ds).unwrap();
    let opts = TrainOpts { epochs: Some(2), quiet: true, ..Default::default() };
    let a = trainer.run(3, &opts).unwrap();
    let b = trainer.run(3, &opts).unwrap();
    assert_eq!(a.test_acc, b.test_acc);
    for (x, y) in a.params.tensors.iter().zip(&b.params.tensors) {
        assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
    }
}

#[test]
fn manifest_param_counts_match_table1_formulas() {
    let Some((m, _)) = bundle("moons-neuralut") else { return };
    // Per-layer neuron parameters (excluding BN + scale tail) must equal
    // M * T_N from the paper's closed forms.
    for (l, &(lo, hi)) in m.layer_param_slices.iter().enumerate() {
        let neuron_elems: usize = m.params[lo..hi - 5]
            .iter()
            .map(|p| p.elem_count())
            .sum();
        let f = m.layer_fan_in[l];
        let t = formulas::t_neuralut(f, m.sub_depth, m.sub_width, m.sub_skip);
        assert_eq!(neuron_elems, m.layers[l] * t, "layer {l}");
    }
}

#[test]
fn netlist_sim_matches_saved_network_after_roundtrip() {
    let Some((m, ds)) = bundle("moons-neuralut") else { return };
    let rt = Runtime::cpu().unwrap();
    let trainer = Trainer::new(&rt, &m, &ds).unwrap();
    let r = trainer
        .run(1, &TrainOpts { epochs: Some(1), quiet: true, ..Default::default() })
        .unwrap();
    let net = convert::convert(&rt, &m, &r.params).unwrap();
    let path = std::env::temp_dir().join("neuralut_it_net.nlut");
    net.save(&path).unwrap();
    let net2 = LutNetwork::load(&path).unwrap();
    let sim1 = Simulator::new(&net);
    let sim2 = Simulator::new(&net2);
    let x = &ds.test_x[..64 * ds.n_feat];
    assert_eq!(
        sim1.simulate_batch(x).logit_codes,
        sim2.simulate_batch(x).logit_codes
    );
}

#[test]
fn bitsliced_engine_matches_scalar_on_real_converted_model() {
    // The compiled fabric engine must be bit-exact on a *trained*
    // network, not just on random tables — trained tables carry the
    // structure (small support, shared sub-functions) the lowering pass
    // exploits, so this exercises the literal/constant/sharing paths.
    let Some((m, ds)) = bundle("moons-neuralut") else { return };
    let rt = Runtime::cpu().unwrap();
    let trainer = Trainer::new(&rt, &m, &ds).unwrap();
    let r = trainer
        .run(7, &TrainOpts { epochs: Some(2), quiet: true, ..Default::default() })
        .unwrap();
    let model = Model::from_network(convert::convert(&rt, &m, &r.params).unwrap());
    let sim = Simulator::new(model.network());
    let session = model
        .compile(&FabricOptions::new().backend("bitsliced"))
        .unwrap()
        .session();
    let a = sim.simulate_batch(&ds.test_x);
    let b = session.infer_batch(&ds.test_x).unwrap();
    assert_eq!(a.logit_codes, b.logit_codes);
    assert_eq!(a.predictions, b.predictions);
}

#[test]
fn server_agrees_with_direct_simulation_on_real_model() {
    let Some((m, ds)) = bundle("moons-logicnets") else { return };
    let rt = Runtime::cpu().unwrap();
    let trainer = Trainer::new(&rt, &m, &ds).unwrap();
    let r = trainer
        .run(2, &TrainOpts { epochs: Some(1), quiet: true, ..Default::default() })
        .unwrap();
    let net = Arc::new(convert::convert(&rt, &m, &r.params).unwrap());
    let sim = Simulator::new(&net);
    let server = Model::from_arc(net.clone())
        .compile(&FabricOptions::new())
        .unwrap()
        .serve();
    let client = server.client();
    for i in 0..32 {
        let row = ds.test_row(i).to_vec();
        let want = sim.simulate_batch(&row).predictions[0];
        assert_eq!(client.infer(row).unwrap().prediction, want);
    }
}

#[test]
fn rtl_bundle_expected_vectors_match_simulator() {
    let Some((m, ds)) = bundle("moons-neuralut") else { return };
    let rt = Runtime::cpu().unwrap();
    let trainer = Trainer::new(&rt, &m, &ds).unwrap();
    let r = trainer
        .run(4, &TrainOpts { epochs: Some(1), quiet: true, ..Default::default() })
        .unwrap();
    let net = convert::convert(&rt, &m, &r.params).unwrap();
    let dir = std::env::temp_dir().join("neuralut_it_rtl");
    neuralut::rtl::write_rtl_bundle(&net, &dir, &ds.test_x, 16).unwrap();
    let expected = std::fs::read_to_string(dir.join("expected.hex")).unwrap();
    let sim = Simulator::new(&net);
    for (i, line) in expected.lines().enumerate() {
        let row = ds.test_row(i);
        let res = sim.simulate_batch(row);
        let packed = neuralut::rtl::pack_output_hex(&net, &res.logit_codes);
        assert_eq!(line, packed, "vector {i}");
    }
}

#[test]
fn synth_cost_orders_modes_correctly() {
    // NeuraLUT tables (dense sub-network functions) must synthesize to at
    // least as many P-LUTs per L-LUT as LogicNets (linear) tables at the
    // same circuit geometry — the paper's §IV-A2 observation.
    let Some((m_n, ds)) = bundle("moons-neuralut") else { return };
    let Some((m_l, _)) = bundle("moons-logicnets") else { return };
    let rt = Runtime::cpu().unwrap();
    let mut per_lut = Vec::new();
    for m in [&m_n, &m_l] {
        let trainer = Trainer::new(&rt, m, &ds).unwrap();
        let r = trainer
            .run(0, &TrainOpts { epochs: Some(8), quiet: true, ..Default::default() })
            .unwrap();
        let net = convert::convert(&rt, m, &r.params).unwrap();
        let s = synthesize(&net);
        per_lut.push(s.luts as f64 / net.num_luts() as f64);
    }
    assert!(
        per_lut[0] >= per_lut[1] * 0.8,
        "neuralut {per_lut:?} should not be dramatically cheaper per L-LUT"
    );
}
