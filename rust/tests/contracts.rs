//! Contract tests across module boundaries that don't need AOT artifacts:
//! pipeline consistency checks, experiment summaries, schedule/ABI
//! contracts, server behaviour under load shapes.

use std::sync::Arc;
use std::time::Duration;

use neuralut::coordinator::experiments::{mean_std, RunSummary};
use neuralut::coordinator::schedule::sgdr_lr;
use neuralut::data::{Dataset, Workload};
use neuralut::fabric::{FabricOptions, Model};
use neuralut::luts::random_network;
use neuralut::netlist::vcd;
use neuralut::netlist::Simulator;
use neuralut::server::ServerConfig;
use neuralut::synth::synthesize;
use neuralut::util::json::Json;

fn summary(acc: f64) -> RunSummary {
    RunSummary {
        config: "c".into(),
        mode: "neuralut".into(),
        seed: 0,
        fabric_acc: acc,
        model_acc: acc,
        luts: 10,
        ffs: 5,
        fmax_mhz: 100.0,
        latency_ns: 10.0,
        latency_cycles: 2,
        area_delay: 100.0,
        l_luts: 4,
        bdd_nodes: 7,
        train_seconds: 0.1,
    }
}

#[test]
fn run_summary_serializes_to_valid_json() {
    let j = summary(0.9).to_json().to_string();
    let back = Json::parse(&j).unwrap();
    assert!((back.get("fabric_acc").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-12);
    assert_eq!(back.get("config").unwrap().as_str().unwrap(), "c");
}

#[test]
fn mean_std_across_seeds() {
    let rows = vec![summary(0.8), summary(0.9), summary(1.0)];
    let (m, s) = mean_std(&rows, |r| r.fabric_acc);
    assert!((m - 0.9).abs() < 1e-12);
    assert!((s - 0.1).abs() < 1e-9);
}

#[test]
fn sgdr_total_budget_spans_periods() {
    // With t0=5, mult=2 and 100 steps/epoch: restarts at 500, 1500, 3500.
    for (step, expect_max) in [(500, true), (1500, true), (3500, true),
                               (499, false), (1499, false)] {
        let lr = sgdr_lr(1e-4, 1e-2, 5, 2, 100, step);
        assert_eq!((lr - 1e-2).abs() < 1e-12, expect_max, "step {step}");
    }
}

#[test]
fn synth_report_scales_with_circuit_size() {
    let small = random_network(1, 16, 2, &[8, 4], 3, 2, 4);
    let large = random_network(1, 16, 2, &[64, 32, 4], 3, 2, 4);
    let rs = synthesize(&small);
    let rl = synthesize(&large);
    assert!(rl.luts > rs.luts);
    assert!(rl.ffs > rs.ffs);
    // Same depth class -> latency dominated by layer count + congestion.
    assert!(rl.latency_ns >= rs.latency_ns);
}

#[test]
fn vcd_pipeline_throughput_is_one_sample_per_cycle() {
    let net = random_network(9, 8, 2, &[6, 3], 2, 2, 4);
    let samples: Vec<Vec<f32>> = (0..10)
        .map(|i| (0..8).map(|j| ((i + j) % 5) as f32 / 5.0).collect())
        .collect();
    let trace = vcd::trace_pipeline(&net, &samples);
    // Every cycle after fill produces a distinct sample's result: compare
    // consecutive output-stage snapshots against the batch simulator.
    let sim = Simulator::new(&net);
    let mut flat = Vec::new();
    for s in &samples {
        flat.extend_from_slice(s);
    }
    let batch = sim.simulate_batch(&flat);
    let n_layers = net.layers.len();
    for i in 0..samples.len() {
        let got: Vec<i16> = trace.stages[i + n_layers].last().unwrap()
            .iter().map(|&v| v as i16).collect();
        assert_eq!(got, batch.logit_codes[i * 3..(i + 1) * 3].to_vec());
    }
}

#[test]
fn server_under_burst_load_preserves_fifo_correctness() {
    let net = Arc::new(random_network(10, 6, 2, &[4, 3], 2, 2, 4));
    let ds = Dataset::synthetic(3, 10, 64, 6, 3);
    let sim = Simulator::new(&net);
    let server = Model::from_arc(net.clone())
        .compile(
            &FabricOptions::new()
                .max_batch(8)
                .batch_window(Duration::from_micros(50)),
        )
        .unwrap()
        .serve();
    let client = server.client();
    // burst: submit 200 async then collect
    let w = Workload::poisson(&ds, 4, 200, 1e9); // effectively instant
    let mut pending = Vec::new();
    let mut want = Vec::new();
    for (_, feats) in w.requests {
        want.push(sim.simulate_batch(&feats).predictions[0]);
        pending.push(client.infer_async(feats).unwrap());
    }
    for (rx, want) in pending.into_iter().zip(want) {
        assert_eq!(rx.recv().unwrap().prediction, want);
    }
}

#[test]
fn server_config_file_selects_the_bitsliced_backend_end_to_end() {
    // Config file (TOML subset) -> ServerConfig -> FabricOptions -> the
    // fabric compiles the engine -> replies must match the scalar fabric
    // bit-exactly. (Env injected as empty so the test is deterministic
    // under a stray NEURALUT_ENGINE.)
    let cfg = ServerConfig::parse_toml(
        "max_batch = 16\nbatch_window_us = 50\nbackend = \"bitsliced\"",
    )
    .unwrap();
    assert_eq!(cfg.backend, "bitsliced");
    let opts = FabricOptions::with_env(&|_| None, Some(&cfg)).unwrap();
    let net = Arc::new(random_network(30, 6, 2, &[5, 3], 2, 2, 4));
    let ds = Dataset::synthetic(8, 11, 64, 6, 3);
    let sim = Simulator::new(&net);
    let fabric = Model::from_arc(net.clone()).compile(&opts).unwrap();
    assert_eq!(fabric.backend_name(), "bitsliced");
    assert_eq!(fabric.tuning().max_batch, 16);
    let server = fabric.serve();
    let client = server.client();
    let w = Workload::poisson(&ds, 9, 100, 1e9);
    let mut pending = Vec::new();
    let mut want = Vec::new();
    for (_, feats) in w.requests {
        want.push(sim.simulate_batch(&feats).predictions[0]);
        pending.push(client.infer_async(feats).unwrap());
    }
    for (rx, want) in pending.into_iter().zip(want) {
        assert_eq!(rx.recv().unwrap().prediction, want);
    }
}

#[test]
fn dataset_rows_roundtrip_via_workload_jitter_bounds() {
    let ds = Dataset::synthetic(5, 16, 32, 8, 4);
    let w = Workload::poisson(&ds, 6, 100, 1000.0);
    for (_, feats) in &w.requests {
        assert_eq!(feats.len(), 8);
        assert!(feats.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}

#[test]
fn cli_binary_basic_commands_work() {
    let bin = env!("CARGO_BIN_EXE_neuralut");
    let out = std::process::Command::new(bin).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("codesign toolflow"));
    let out = std::process::Command::new(bin).arg("list").output().unwrap();
    assert!(out.status.success());
    let out = std::process::Command::new(bin).arg("bogus").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn cli_info_reads_bundle_when_present() {
    let dir = neuralut::artifacts_dir().join("moons-neuralut");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let bin = env!("CARGO_BIN_EXE_neuralut");
    let out = std::process::Command::new(bin)
        .args(["info", "moons-neuralut"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("circuit"));
    assert!(text.contains("moons"));
}
